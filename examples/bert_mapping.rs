//! Mapping one BERT encoder layer onto crossbar tiles (paper Fig. 10 right).
//!
//! Run: `cargo run --release --example bert_mapping`
//!
//! Compares optimized pipeline packing against 1:1 mapping across square
//! tile sizes, with and without the "maximum parallelism" replication
//! (every FC weight matrix cloned once per token, N_rapa = S).

use xbarmap::area::AreaModel;
use xbarmap::frag;
use xbarmap::geom::Tile;
use xbarmap::nets::zoo;
use xbarmap::pack::{self, Discipline};
use xbarmap::perf::{self, rapa, Execution, TimingModel};
use xbarmap::util::table::{sig3, Table};

fn main() {
    let seq = 64;
    let net = zoo::bert_layer(seq);
    println!(
        "{} — {} weight matrices, {:.1}M weights, reuse {} per layer\n",
        net.name,
        net.n_layers(),
        net.total_weights() as f64 / 1e6,
        seq
    );

    let area = AreaModel::paper_default();
    let plans: [(&str, Vec<usize>); 2] = [
        ("plain", vec![1; net.n_layers()]),
        ("max-parallel xS", rapa::plan_uniform(&net, seq)),
    ];

    for (name, plan) in &plans {
        println!("== {name}");
        let mut t = Table::new(&["tile", "blocks (=1:1 tiles)", "tiles opt", "area opt mm2", "area 1:1 mm2"]);
        for k in 6..=13u32 {
            let tile = Tile::new(1 << k, 1 << k);
            let blocks = frag::fragment_network_replicated(&net, tile, plan);
            let packing = pack::simple::pack(&blocks, tile, Discipline::Pipeline);
            t.row(&[
                tile.to_string(),
                blocks.len().to_string(),
                packing.n_bins.to_string(),
                sig3(area.total_area_mm2(packing.n_bins, tile)),
                sig3(area.total_area_mm2(blocks.len(), tile)),
            ]);
        }
        println!("{}", t.render());
    }

    // throughput effect of the replication (Eq. 4)
    let timing = TimingModel::default();
    let t_plain = perf::latency(&net, &plans[0].1, &timing, Execution::Pipelined);
    let t_par = perf::latency(&net, &plans[1].1, &timing, Execution::Pipelined);
    println!(
        "pipeline beat: plain {:.1} ns vs max-parallel {:.1} ns ({}x faster at {}x the weights)",
        t_plain * 1e9,
        t_par * 1e9,
        sig3(t_plain / t_par),
        sig3(rapa::weight_inflation(&net, &plans[1].1)),
    );
}
