//! Mapping one BERT encoder layer onto crossbar tiles (paper Fig. 10 right)
//! through the `plan` front door.
//!
//! Run: `cargo run --release --example bert_mapping`
//!
//! Compares optimized pipeline packing against 1:1 mapping across square
//! tile sizes, with and without the "maximum parallelism" replication
//! (every FC weight matrix cloned once per token, N_rapa = S) — one
//! fixed-tile [`MapRequest`] per row.

use xbarmap::area::AreaModel;
use xbarmap::nets::zoo;
use xbarmap::pack::Discipline;
use xbarmap::perf::{self, rapa, Execution, TimingModel};
use xbarmap::plan::{MapRequest, Replication};
use xbarmap::util::table::{sig3, Table};

fn main() {
    let seq = 64;
    let net = zoo::bert_layer(seq);
    println!(
        "{} — {} weight matrices, {:.1}M weights, reuse {} per layer\n",
        net.name,
        net.n_layers(),
        net.total_weights() as f64 / 1e6,
        seq
    );

    let area = AreaModel::paper_default();
    let plans: [(&str, Replication); 2] = [
        ("plain", Replication::None),
        ("max-parallel xS", Replication::Uniform(seq)),
    ];

    for (name, replication) in &plans {
        println!("== {name}");
        let mut t = Table::new(&["tile", "blocks (=1:1 tiles)", "tiles opt", "area opt mm2", "area 1:1 mm2"]);
        for k in 6..=13u32 {
            let tile = 1usize << k;
            let best = MapRequest::zoo("bert")
                .tile(tile, tile)
                .discipline(Discipline::Pipeline)
                .replication(replication.clone())
                .build()
                .and_then(|p| p.plan())
                .expect("bert plan")
                .best;
            t.row(&[
                best.tile.to_string(),
                best.n_blocks.to_string(),
                best.n_tiles.to_string(),
                sig3(best.total_area_mm2),
                sig3(area.total_area_mm2(best.n_tiles_one_to_one, best.tile)),
            ]);
        }
        println!("{}", t.render());
    }

    // throughput effect of the replication (Eq. 4)
    let timing = TimingModel::default();
    let plain = vec![1; net.n_layers()];
    let par = rapa::plan_uniform(&net, seq);
    let t_plain = perf::latency(&net, &plain, &timing, Execution::Pipelined);
    let t_par = perf::latency(&net, &par, &timing, Execution::Pipelined);
    println!(
        "pipeline beat: plain {:.1} ns vs max-parallel {:.1} ns ({}x faster at {}x the weights)",
        t_plain * 1e9,
        t_par * 1e9,
        sig3(t_plain / t_par),
        sig3(rapa::weight_inflation(&net, &par)),
    );
}
