//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! Run: `make artifacts && cargo run --release --example lenet_e2e`
//!
//! Build time (Python, once): a 784-256-128-10 digits classifier is trained
//! in fp32 and its *crossbar* inference path — every matmul executed as
//! DAC -> NVM-tile analog MAC -> ADC on a 256x256 tile grid via the Pallas
//! kernel — is AOT-lowered to HLO text.
//!
//! Request time (this binary, Rust only):
//! 1. the coordinator maps the classifier onto physical tiles with the
//!    paper's packing machinery and prices the deployment (tiles, mm²,
//!    modeled latency);
//! 2. verifies the runtime against the golden test vector produced at
//!    build time (PJRT round-trip fidelity);
//! 3. serves a stream of synthetic digit requests through the quantized
//!    crossbar executable with dynamic batching, reporting throughput,
//!    batch latency percentiles and classification accuracy.

use anyhow::{anyhow, Result};
use xbarmap::coordinator::{digits, Coordinator, CoordinatorConfig};
use xbarmap::plan::MapRequest;
use xbarmap::runtime::Tensor;
use xbarmap::util::json::{self, Json};
use xbarmap::util::prng::Rng;

fn read_testvec(dir: &std::path::Path) -> Result<(Vec<f32>, Vec<usize>, Vec<f32>)> {
    let tv = json::parse(&std::fs::read_to_string(dir.join("testvec.json"))?)
        .map_err(|e| anyhow!("parse testvec.json: {e}"))?;
    let arr = |k: &str| -> Result<Vec<f32>> {
        Ok(tv
            .get(k)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("testvec missing {k}"))?
            .iter()
            .filter_map(Json::as_f64)
            .map(|v| v as f32)
            .collect())
    };
    let labels: Vec<usize> = tv
        .get("labels")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("testvec missing labels"))?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    Ok((arr("input")?, labels, arr("logits_crossbar")?))
}

fn main() -> Result<()> {
    // ---- 1. deployment ----
    let coordinator = Coordinator::new(&CoordinatorConfig::default())?;
    println!("== deployment");
    println!("  tile array        : {}", coordinator.tile);
    println!("  physical tiles    : {}", coordinator.mapping.n_tiles());
    println!("  packing efficiency: {:.3}", coordinator.mapping.packing_efficiency());
    println!("  total tile area   : {:.2} mm²", coordinator.total_area_mm2);
    println!("  modeled latency   : {:.0} ns (Eq. 3)", coordinator.modeled_latency_s * 1e9);
    // the coordinator maps its deployment through the plan front door;
    // this is the equivalent v1 wire request (`xbarmap plan` input line)
    let deploy_req = MapRequest::zoo("digits-mlp")
        .tile(coordinator.tile.n_row, coordinator.tile.n_col)
        .id("lenet-e2e-deployment");
    println!("  plan wire request : {}", deploy_req.to_json().dumps());

    // ---- 2. golden-vector verification (build-time jax == request-time rust) ----
    let (input, labels, want_logits) = read_testvec(&coordinator.artifacts)?;
    let n = labels.len();
    let got = coordinator.infer(&input, n)?;
    let max_diff = got
        .data
        .iter()
        .zip(&want_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("\n== golden test vector ({n} samples)");
    println!("  max |rust - jax| logit diff: {max_diff:.2e}");
    if max_diff > 1e-3 {
        return Err(anyhow!("PJRT round trip diverged from build-time jax results"));
    }
    let golden = Tensor::new(vec![n, 10], want_logits)?;
    let acc_golden = golden
        .argmax_rows()
        .iter()
        .zip(&labels)
        .filter(|(p, l)| p == l)
        .count() as f64
        / n as f64;
    println!("  golden-batch accuracy: {acc_golden:.3}");

    // ---- 3. serve a synthetic request stream ----
    let n_requests = 4096;
    println!("\n== serving {n_requests} synthetic digit requests (crossbar model)");
    let (tx, rx) = std::sync::mpsc::channel();
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(2024);
        for s in digits::synth_digits(&mut rng, n_requests, 0.35) {
            if tx.send(s).is_err() {
                break;
            }
        }
    });
    let stats = coordinator.serve(rx)?;
    producer.join().map_err(|_| anyhow!("producer panicked"))?;

    println!("  requests   : {}", stats.requests);
    println!("  batches    : {}", stats.batches);
    println!("  wall time  : {:.3} s", stats.wall_s);
    println!("  throughput : {:.0} req/s", stats.throughput_per_s);
    println!("  batch p50  : {:.3} ms", stats.batch_p50_s * 1e3);
    println!("  batch p95  : {:.3} ms", stats.batch_p95_s * 1e3);
    println!("  accuracy   : {:.4}", stats.accuracy);
    if let Some(build_acc) = coordinator.build_time_accuracy() {
        println!("  build-time crossbar accuracy (meta.json): {build_acc:.4}");
        if (stats.accuracy - build_acc).abs() > 0.05 {
            return Err(anyhow!(
                "served accuracy {:.3} deviates from build-time accuracy {build_acc:.3}",
                stats.accuracy
            ));
        }
    }
    println!("\nE2E OK: jax/pallas-compiled crossbar model served from rust at full fidelity");
    Ok(())
}
