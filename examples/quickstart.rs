//! Quickstart: pack the paper's 13-item demo list into T(512,512) tiles
//! with all three engines and both disciplines, and price the results.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Reproduces the paper's §2.2 headline: binary linear optimization packs
//! the list into 2 tiles densely and 4 tiles pipeline-enabled (Tables 3/5,
//! Figs. 5/6), while the greedy engines land within a bin or two.

use xbarmap::area::AreaModel;
use xbarmap::ilp;
use xbarmap::pack::{self, placement, Discipline};
use xbarmap::report::paper_demo_items;
use xbarmap::util::table::{sig3, Table};

fn main() {
    let tile = xbarmap::geom::Tile::new(512, 512);
    let items = paper_demo_items();
    let area = AreaModel::paper_default();

    println!("demo list: {} blocks, {} weights total\n", items.len(), items
        .iter()
        .map(|b| b.weights())
        .sum::<usize>());

    let mut t = Table::new(&["discipline", "engine", "tiles", "packing eff", "total area mm2"]);
    for discipline in [Discipline::Dense, Discipline::Pipeline] {
        let engines: Vec<(&str, pack::Packing)> = vec![
            ("simple (next-fit)", pack::simple::pack(&items, tile, discipline)),
            ("ffd", pack::ffd::pack(&items, tile, discipline)),
            (
                "lps (branch&bound)",
                ilp::solve_packing(&items, tile, discipline, ilp::Budget::default()).packing,
            ),
        ];
        for (name, packing) in engines {
            placement::validate(&packing).expect("engine produced a valid packing");
            t.row(&[
                discipline.to_string(),
                name.into(),
                packing.n_bins.to_string(),
                sig3(packing.packing_efficiency()),
                sig3(area.total_area_mm2(packing.n_bins, tile)),
            ]);
        }
    }
    println!("{}", t.render());

    // Show the optimal pipeline placement as a staircase diagram.
    let r = ilp::solve_packing(&items, tile, Discipline::Pipeline, ilp::Budget::default());
    println!(
        "pipeline optimum ({} bins, optimal={}, {} search nodes):",
        r.packing.n_bins, r.optimal, r.nodes
    );
    for (bin, placements) in r.packing.bins().iter().enumerate() {
        let desc: Vec<String> = placements
            .iter()
            .map(|p| {
                let b = r.packing.blocks[p.block];
                format!("item{}({}x{})@({},{})", p.block + 1, b.rows, b.cols, p.x, p.y)
            })
            .collect();
        println!("  bin {}: {}", bin + 1, desc.join("  "));
    }
}
