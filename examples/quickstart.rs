//! Quickstart: the `plan` front door on the paper's 13-item demo list.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Builds a [`MapRequest`] — the crate's canonical entry point — for an
//! inline network whose weight matrices reproduce the §2.2 demo list
//! exactly, prices it on T(512,512) tiles with all three engines and both
//! disciplines, and prints one request's v1 JSON wire form (what
//! `xbarmap plan` consumes per line).
//!
//! Reproduces the paper's §2.2 headline: binary linear optimization packs
//! the list into 2 tiles densely and 4 tiles pipeline-enabled (Tables 3/5,
//! Figs. 5/6), while the greedy engines land within a bin or two.

use xbarmap::nets::{Layer, Network};
use xbarmap::opt::Engine;
use xbarmap::pack::Discipline;
use xbarmap::plan::MapRequest;
use xbarmap::util::table::{sig3, Table};

/// The §2.2 demo list as an inline network: item `(r, c)` is a
/// fully-connected layer `fc(r-1, c)` whose bias row makes the weight
/// matrix exactly `r x c`, so fragmentation onto T(512,512) yields the
/// paper's 13 blocks verbatim.
fn demo13() -> Network {
    let items: [(usize, usize); 13] = [
        (257, 256), (257, 256), (257, 256), (129, 256), (129, 128),
        (129, 128), (129, 128), (129, 128), (65, 128), (148, 64),
        (65, 64), (65, 64), (65, 64),
    ];
    let layers = items
        .iter()
        .enumerate()
        .map(|(i, &(r, c))| Layer::fc(&format!("item{}", i + 1), r - 1, c))
        .collect();
    Network::new("demo13", "paper §2.2 demo list", layers)
}

fn main() {
    let base = MapRequest::inline(demo13()).tile(512, 512).placements(true);

    // the v1 wire form of one request — `xbarmap plan` reads one of these
    // per line and streams back one plan per line
    println!("wire request: {}\n", base.clone().id("quickstart").to_json().dumps());

    let mut t = Table::new(&["discipline", "engine", "tiles", "packing eff", "total area mm2"]);
    for discipline in [Discipline::Dense, Discipline::Pipeline] {
        for (name, engine) in [
            ("simple (next-fit)", Engine::Simple),
            ("ffd", Engine::Ffd),
            ("lps (branch&bound)", Engine::Ilp { max_nodes: Engine::DEFAULT_ILP_NODES }),
        ] {
            let plan = base
                .clone()
                .discipline(discipline)
                .engine(engine)
                .build()
                .and_then(|p| p.plan())
                .expect("demo plan");
            t.row(&[
                discipline.to_string(),
                name.into(),
                plan.best.n_tiles.to_string(),
                sig3(plan.best.packing_eff),
                sig3(plan.best.total_area_mm2),
            ]);
        }
    }
    println!("{}", t.render());

    // Show the optimal pipeline placement as a staircase diagram.
    let planner = base
        .discipline(Discipline::Pipeline)
        .engine(Engine::Ilp { max_nodes: Engine::DEFAULT_ILP_NODES })
        .build()
        .expect("valid demo request");
    let plan = planner.plan().expect("demo plan");
    let packing = planner.pack(plan.best.tile).expect("demo pack").packing;
    println!(
        "pipeline optimum ({} bins, optimal={}, {} search nodes):",
        plan.best.n_tiles, plan.provenance.optimal, plan.provenance.nodes
    );
    for (bin, placements) in packing.bins().iter().enumerate() {
        let desc: Vec<String> = placements
            .iter()
            .map(|p| {
                let b = packing.blocks[p.block];
                format!("item{}({}x{})@({},{})", p.block + 1, b.rows, b.cols, p.x, p.y)
            })
            .collect();
        println!("  bin {}: {}", bin + 1, desc.join("  "));
    }
}
