//! RAPA throughput/area trade-off on ResNet18 (paper Fig. 9's performance
//! claim: ~100x throughput for ~5x area).
//!
//! Run: `cargo run --release --example rapa_throughput`
//!
//! Maps ResNet18 onto 512x512 tiles through the `plan` front door under
//! three execution regimes and runs the cycle-level simulator on each
//! planner-produced packing: dense sequential, plain pipeline, and
//! RAPA-replicated pipeline at several replication factors.

use xbarmap::pack::Discipline;
use xbarmap::perf::Execution;
use xbarmap::plan::{MapRequest, Replication};
use xbarmap::sim::{self, SimConfig};
use xbarmap::util::table::{sig3, Table};

fn main() {
    let n_inferences = 256;

    let mut t = Table::new(&[
        "regime", "tiles", "area mm2", "first latency", "throughput inf/s", "speedup", "util",
    ]);

    let mut regimes: Vec<(String, Discipline, Execution, Replication)> = vec![
        ("dense sequential".to_string(), Discipline::Dense, Execution::Sequential, Replication::None),
        ("pipeline".to_string(), Discipline::Pipeline, Execution::Pipelined, Replication::None),
    ];
    for n0 in [8, 32, 128] {
        regimes.push((
            format!("pipeline + RAPA {n0}"),
            Discipline::Pipeline,
            Execution::Pipelined,
            Replication::Balanced(n0),
        ));
    }

    let mut base_throughput = None;
    for (name, discipline, exec, replication) in regimes {
        let planner = MapRequest::zoo("resnet18")
            .tile(512, 512)
            .discipline(discipline)
            .replication(replication)
            .build()
            .expect("valid regime request");
        let plan = planner.plan().expect("regime plan");
        let packing = planner.pack(plan.best.tile).expect("regime pack").packing;
        let mut cfg = SimConfig::new(planner.network(), exec);
        cfg.replication = planner.replication().to_vec();
        let rep = sim::simulate(planner.network(), &packing, &cfg, n_inferences);
        let speedup = match base_throughput {
            None => {
                base_throughput = Some(rep.throughput_per_s);
                1.0
            }
            Some(b) => rep.throughput_per_s / b,
        };
        t.row(&[
            name,
            plan.best.n_tiles.to_string(),
            sig3(plan.best.total_area_mm2),
            format!("{:.2} µs", rep.first_latency_s * 1e6),
            sig3(rep.throughput_per_s),
            format!("{:.1}x", speedup),
            sig3(rep.utilization),
        ]);
    }
    println!("{}", t.render());
    println!("(paper Fig. 9: RAPA 128/4 gives ~100x throughput over the non-pipelined dense\n mapping at ~5x the area)");
}
