//! RAPA throughput/area trade-off on ResNet18 (paper Fig. 9's performance
//! claim: ~100x throughput for ~5x area).
//!
//! Run: `cargo run --release --example rapa_throughput`
//!
//! Maps ResNet18 onto 512x512 tiles under three execution regimes and runs
//! the cycle-level simulator: dense sequential, plain pipeline, and
//! RAPA-replicated pipeline at several replication factors.

use xbarmap::area::AreaModel;
use xbarmap::geom::Tile;
use xbarmap::nets::zoo;
use xbarmap::pack::Discipline;
use xbarmap::perf::{rapa, Execution};
use xbarmap::sim::{map_and_simulate, SimConfig};
use xbarmap::util::table::{sig3, Table};

fn main() {
    let net = zoo::resnet18();
    let tile = Tile::new(512, 512);
    let area = AreaModel::paper_default();
    let n_inferences = 256;

    let mut t = Table::new(&[
        "regime", "tiles", "area mm2", "first latency", "throughput inf/s", "speedup", "util",
    ]);

    let mut base_throughput = None;
    let regimes: Vec<(String, Discipline, Execution, Vec<usize>)> = {
        let mut v = vec![
            (
                "dense sequential".to_string(),
                Discipline::Dense,
                Execution::Sequential,
                vec![1; net.n_layers()],
            ),
            (
                "pipeline".to_string(),
                Discipline::Pipeline,
                Execution::Pipelined,
                vec![1; net.n_layers()],
            ),
        ];
        for n0 in [8, 32, 128] {
            v.push((
                format!("pipeline + RAPA {n0}"),
                Discipline::Pipeline,
                Execution::Pipelined,
                rapa::plan_balanced(&net, n0),
            ));
        }
        v
    };

    for (name, discipline, exec, replication) in regimes {
        let mut cfg = SimConfig::new(&net, exec);
        cfg.replication = replication;
        let (packing, rep) = map_and_simulate(&net, tile, discipline, &cfg, n_inferences);
        let speedup = match base_throughput {
            None => {
                base_throughput = Some(rep.throughput_per_s);
                1.0
            }
            Some(b) => rep.throughput_per_s / b,
        };
        t.row(&[
            name,
            packing.n_bins.to_string(),
            sig3(area.total_area_mm2(packing.n_bins, tile)),
            format!("{:.2} µs", rep.first_latency_s * 1e6),
            sig3(rep.throughput_per_s),
            format!("{:.1}x", speedup),
            sig3(rep.utilization),
        ]);
    }
    println!("{}", t.render());
    println!("(paper Fig. 9: RAPA 128/4 gives ~100x throughput over the non-pipelined dense\n mapping at ~5x the area)");
}
