//! ResNet18/ImageNet tile-dimension optimization (paper §3.1, Figs. 8/9)
//! through the `plan` front door.
//!
//! Run: `cargo run --release --example resnet18_sweep`
//!
//! Builds one [`MapRequest`] per study — square and rectangular tile
//! spaces, dense and pipeline packing — and reads everything off the
//! returned plans: the per-aspect optima and the headline observations:
//! * minimum tiles != minimum area,
//! * pipeline costs ~2x dense area,
//! * a tall rectangular array (the paper's 2560x512) slashes the pipeline
//!   tile count at similar area.

use xbarmap::pack::Discipline;
use xbarmap::plan::MapRequest;
use xbarmap::util::table::{sig3, Table};

fn main() {
    for discipline in [Discipline::Dense, Discipline::Pipeline] {
        println!("== {discipline} packing, square arrays (Fig. 8)");
        let plan = MapRequest::zoo("resnet18")
            .discipline(discipline)
            .grid((6, 13), vec![1])
            .build()
            .and_then(|p| p.plan())
            .expect("sweep plan");
        let mut t = Table::new(&["tile", "blocks", "tiles", "tile eff", "pack eff", "area mm2", ""]);
        for p in &plan.points {
            t.row(&[
                p.tile.to_string(),
                p.n_blocks.to_string(),
                p.n_tiles.to_string(),
                sig3(p.tile_eff),
                sig3(p.packing_eff),
                sig3(p.total_area_mm2),
                if p.tile == plan.best.tile { "<- optimum".into() } else { "".into() },
            ]);
        }
        println!("{}", t.render());
    }

    println!("== pipeline packing, rectangular arrays (aspect 1..8)");
    let plan = MapRequest::zoo("resnet18")
        .discipline(Discipline::Pipeline)
        .build()
        .and_then(|p| p.plan())
        .expect("sweep plan");
    println!(
        "{} — modeled pipeline latency {:.1} ns, {:.0} inf/s\n",
        plan.network,
        plan.latency_s * 1e9,
        plan.throughput_per_s
    );
    let mut t = Table::new(&["aspect", "best tile", "tiles", "area mm2"]);
    for p in &plan.best_per_aspect {
        t.row(&[
            p.aspect.to_string(),
            p.tile.to_string(),
            p.n_tiles.to_string(),
            sig3(p.total_area_mm2),
        ]);
    }
    println!("{}", t.render());
    println!(
        "global pipeline optimum: {} with {} tiles at {} mm2 (paper: ~17 tiles of 2560x512)",
        plan.best.tile,
        plan.best.n_tiles,
        sig3(plan.best.total_area_mm2)
    );
}
