//! ResNet18/ImageNet tile-dimension optimization (paper §3.1, Figs. 8/9).
//!
//! Run: `cargo run --release --example resnet18_sweep`
//!
//! Sweeps square and rectangular tile arrays for dense and pipeline
//! packing, prints the per-aspect optima and the headline observations:
//! * minimum tiles != minimum area,
//! * pipeline costs ~2x dense area,
//! * a tall rectangular array (the paper's 2560x512) slashes the pipeline
//!   tile count at similar area.

use xbarmap::nets::zoo;
use xbarmap::opt::{self, SweepConfig};
use xbarmap::pack::Discipline;
use xbarmap::util::table::{sig3, Table};

fn main() {
    let net = zoo::resnet18();
    println!(
        "{} — {} layers, {:.1}M weights\n",
        net.name,
        net.n_layers(),
        net.total_weights() as f64 / 1e6
    );

    for discipline in [Discipline::Dense, Discipline::Pipeline] {
        println!("== {discipline} packing, square arrays (Fig. 8)");
        let cfg = SweepConfig::square(discipline);
        let pts = opt::sweep(&net, &cfg);
        let best = opt::optimum(&pts).unwrap();
        let mut t = Table::new(&["tile", "blocks", "tiles", "tile eff", "pack eff", "area mm2", ""]);
        for p in &pts {
            t.row(&[
                p.tile.to_string(),
                p.n_blocks.to_string(),
                p.n_tiles.to_string(),
                sig3(p.tile_eff),
                sig3(p.packing_eff),
                sig3(p.total_area_mm2),
                if p.tile == best.tile { "<- optimum".into() } else { "".into() },
            ]);
        }
        println!("{}", t.render());
    }

    println!("== pipeline packing, rectangular arrays (aspect 1..8)");
    let cfg = SweepConfig::paper_default(Discipline::Pipeline);
    let pts = opt::sweep(&net, &cfg);
    let mut t = Table::new(&["aspect", "best tile", "tiles", "area mm2"]);
    for p in opt::best_per_aspect(&pts) {
        t.row(&[
            p.aspect.to_string(),
            p.tile.to_string(),
            p.n_tiles.to_string(),
            sig3(p.total_area_mm2),
        ]);
    }
    println!("{}", t.render());
    let best = opt::optimum(&pts).unwrap();
    println!(
        "global pipeline optimum: {} with {} tiles at {} mm2 (paper: ~17 tiles of 2560x512)",
        best.tile,
        best.n_tiles,
        sig3(best.total_area_mm2)
    );
}
