"""AOT exporter: train the model, lower crossbar inference to HLO text.

This is the only entry point that writes ``artifacts/``.  Python never runs
after this; the Rust coordinator loads the HLO text through the PJRT C API.

Interchange format is **HLO text** — NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts
---------
model.hlo.txt       crossbar-quantized MLP forward, weights baked in as
                    constants (the NVM array is the weight store),
                    signature f32[B,784] -> (f32[B,10],)
model_fp32.hlo.txt  ideal float forward, same signature (accuracy oracle)
tile_mvm.hlo.txt    one physical-tile quantized MVM with *parameter*
                    weights, f32[B,n_row], f32[n_row,n_col] -> (f32[B,n_col],)
                    — the per-tile op the L3 scheduler drives directly
meta.json           shapes, batch size, tile config, train/eval metrics
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import TileConfig, crossbar_matmul
from .kernels.crossbar import _tile_kernel

# 128 amortizes PJRT dispatch + quantizer overhead 2.2x better than 32
# (EXPERIMENTS.md §Perf #4) and fills the 128-lane MXU batch dimension.
BATCH = 128
SEED = 7
TRAIN_STEPS = 250
EVAL_N = 2048


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True).

    Printed with ``print_large_constants=True``: the default printer elides
    big literals as ``constant({...})``, which the downstream parser happily
    accepts as zeros — silently serving an untrained model. The weights ARE
    the artifact (the NVM array is the weight store), so they must survive
    the text round trip.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # xla_extension 0.5.1's parser predates source_end_line/column metadata
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower_model(params, cfg: M.ModelConfig, batch: int) -> str:
    """Crossbar forward with weights closed over (constants in HLO)."""

    def fwd(x):
        return (M.forward_crossbar(params, x, cfg),)

    spec = jax.ShapeDtypeStruct((batch, cfg.layer_sizes[0]), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_model_fp32(params, cfg: M.ModelConfig, batch: int) -> str:
    def fwd(x):
        return (M.forward_fp32(params, x),)

    spec = jax.ShapeDtypeStruct((batch, cfg.layer_sizes[0]), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_tile_mvm(tile: TileConfig, batch: int) -> str:
    """Single-tile quantized MVM with weights as a runtime parameter."""

    def tile_op(x, w):
        return (crossbar_matmul(x, w, tile),)

    xs = jax.ShapeDtypeStruct((batch, tile.n_row), jnp.float32)
    ws = jax.ShapeDtypeStruct((tile.n_row, tile.n_col), jnp.float32)
    return to_hlo_text(jax.jit(tile_op).lower(xs, ws))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--steps", type=int, default=TRAIN_STEPS)
    ap.add_argument("--tile-rows", type=int, default=256)
    ap.add_argument("--tile-cols", type=int, default=256)
    args = ap.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)

    tile = TileConfig(n_row=args.tile_rows, n_col=args.tile_cols)
    cfg = M.ModelConfig(tile=tile)

    print(f"[aot] training fp32 MLP {cfg.layer_sizes} for {args.steps} steps ...")
    params, losses = M.train(jax.random.PRNGKey(SEED), steps=args.steps, cfg=cfg)

    x_eval, y_eval = M.synth_digits(jax.random.PRNGKey(1234), EVAL_N)
    acc_fp32 = M.accuracy(M.forward_fp32(params, x_eval), y_eval)
    acc_xbar = M.accuracy(M.forward_crossbar(params, x_eval[:256], cfg), y_eval[:256])
    print(f"[aot] eval: fp32 acc={acc_fp32:.4f}  crossbar acc={acc_xbar:.4f}")

    artifacts = {
        "model.hlo.txt": lower_model(params, cfg, args.batch),
        "model_fp32.hlo.txt": lower_model_fp32(params, cfg, args.batch),
        "tile_mvm.hlo.txt": lower_tile_mvm(tile, args.batch),
    }
    for name, text in artifacts.items():
        path = os.path.join(out, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] wrote {path} ({len(text)} chars)")

    # Golden test vector: the Rust runtime must reproduce these logits from
    # this input batch (integration_runtime.rs asserts allclose).
    x_vec, y_vec = M.synth_digits(jax.random.PRNGKey(4242), args.batch)
    logits_xbar = M.forward_crossbar(params, x_vec, cfg)
    logits_fp32 = M.forward_fp32(params, x_vec)
    testvec = {
        "input": [float(v) for v in x_vec.reshape(-1)],
        "labels": [int(v) for v in y_vec],
        "logits_crossbar": [float(v) for v in logits_xbar.reshape(-1)],
        "logits_fp32": [float(v) for v in logits_fp32.reshape(-1)],
        "shape_input": list(x_vec.shape),
        "shape_logits": list(logits_xbar.shape),
    }
    with open(os.path.join(out, "testvec.json"), "w") as f:
        json.dump(testvec, f)
    print(f"[aot] wrote {os.path.join(out, 'testvec.json')}")

    meta = {
        "batch": args.batch,
        "layer_sizes": list(cfg.layer_sizes),
        "layer_shapes_rows_cols": [list(s) for s in M.layer_shapes(cfg)],
        "tile": {
            "n_row": tile.n_row,
            "n_col": tile.n_col,
            "dac_bits": tile.dac_bits,
            "adc_bits": tile.adc_bits,
            "g_bits": tile.g_bits,
            "x_max": tile.x_max,
            "adc_alpha": tile.adc_alpha,
        },
        "train": {
            "steps": args.steps,
            "seed": SEED,
            "loss_first": losses[0],
            "loss_last": losses[-1],
            "acc_fp32": acc_fp32,
            "acc_crossbar": acc_xbar,
        },
        "artifacts": sorted(artifacts),
    }
    with open(os.path.join(out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] wrote {os.path.join(out, 'meta.json')}")


if __name__ == "__main__":
    main()
