"""Pallas crossbar-tile kernels (L1) and their pure-jnp oracles."""
from .crossbar import TileConfig, crossbar_matmul, quantize_uniform  # noqa: F401
from .ref import crossbar_matmul_ref  # noqa: F401
