"""L1 — Pallas kernel: analog NVM cross-bar tile matrix multiply.

The paper maps an ANN weight matrix onto a grid of fixed-capacity physical
cross-bar tiles T(n_row, n_col) (Haensch 2024, Fig. 1).  This kernel is the
numerical model of that hardware: the weight matrix W[K, N] is fragmented
onto a grid of ceil(K/n_row) x ceil(N/n_col) tiles — **the Pallas BlockSpec
grid is exactly the paper's fragmentation grid** — and each grid step
executes one tile's analog matrix-vector product:

  1. DAC:  the activation slice entering the tile's word lines is quantized
           to ``dac_bits`` uniform levels on a static range [-x_max, x_max];
  2. NVM:  the tile's weight block is quantized to ``g_bits`` conductance
           levels on the per-tile range [-max|w|, max|w|] (differential
           conductance-pair encoding);
  3. analog MAC along the tile's n_row word lines (Ohm + Kirchhoff);
  4. ADC:  the tile's bit-line partial sums are quantized to ``adc_bits``
           levels on the range +/- adc_alpha * x_max * w_max * n_row;
  5. digital accumulation of partial sums across the K-dimension tile row
     fragments (the inter-tile reduction the chip performs digitally).

Bits <= 0 disable the corresponding converter ("ideal" mode), in which case
the kernel computes a plain blocked matmul and must agree with jnp.matmul to
float tolerance.

``interpret=True`` always: the CPU PJRT client cannot execute Mosaic
custom-calls; correctness is established against ``ref.py`` and the AOT
artifact embeds the interpreted (plain-HLO) lowering.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclass(frozen=True)
class TileConfig:
    """Physical tile configuration for the crossbar kernel.

    Mirrors the paper's tile array T(n_row, n_col) plus converter precision.
    ``dac_bits``/``adc_bits``/``g_bits`` <= 0 mean ideal (no quantization).
    ``x_max`` is the static DAC full-scale (activation calibration range).
    ``adc_alpha`` scales the ADC full-scale relative to the worst-case
    analog column current x_max * w_max * n_row (1.0 = never clips).
    """

    n_row: int = 256
    n_col: int = 256
    dac_bits: int = 8
    adc_bits: int = 10
    g_bits: int = 8
    x_max: float = 4.0
    adc_alpha: float = 0.125

    def ideal(self) -> "TileConfig":
        """Same tile geometry with all converters disabled."""
        return replace(self, dac_bits=0, adc_bits=0, g_bits=0)

    def grid_for(self, k: int, n: int) -> tuple[int, int]:
        """Number of (row, col) tile fragments covering a K x N matrix."""
        return (pl.cdiv(k, self.n_row), pl.cdiv(n, self.n_col))


def quantize_uniform(v: jax.Array, bits: int, vmax: jax.Array) -> jax.Array:
    """Symmetric uniform quantizer with 2^(bits-1)-1 positive levels.

    Static ``bits`` (python int); dynamic range ``vmax`` (traced scalar).
    bits <= 0 passes through. A zero range maps everything to zero.
    """
    if bits <= 0:
        return v
    levels = float(2 ** (bits - 1) - 1)
    safe = jnp.where(vmax > 0.0, vmax, 1.0)
    step = safe / levels
    q = jnp.round(jnp.clip(v, -vmax, vmax) / step) * step
    return jnp.where(vmax > 0.0, q, jnp.zeros_like(v))


def _tile_kernel(x_ref, w_ref, o_ref, *, cfg: TileConfig, k_tiles: int):
    """One grid step == one physical tile's analog MVM (see module doc)."""
    kt = pl.program_id(1)  # K-fragment index (fastest-varying)

    x_blk = x_ref[...].astype(jnp.float32)
    w_blk = w_ref[...].astype(jnp.float32)

    # (2) conductance quantization on the per-tile range.
    w_max = jnp.max(jnp.abs(w_blk))
    w_q = quantize_uniform(w_blk, cfg.g_bits, w_max)

    # (1) DAC on the static activation range.
    x_q = quantize_uniform(x_blk, cfg.dac_bits, jnp.float32(cfg.x_max))

    # (3) analog MAC across the tile's word lines.
    acc = jnp.dot(x_q, w_q, preferred_element_type=jnp.float32)

    # (4) ADC on the bit lines: static full-scale per tile.
    adc_fs = jnp.float32(cfg.adc_alpha * cfg.x_max) * w_max * jnp.float32(cfg.n_row)
    acc = quantize_uniform(acc, cfg.adc_bits, adc_fs)

    # (5) digital accumulation across K-fragments.
    @pl.when(kt == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(kt > 0)
    def _accum():
        o_ref[...] = o_ref[...] + acc


def _pad_to(a: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = a.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("cfg",))
def crossbar_matmul(x: jax.Array, w: jax.Array, cfg: TileConfig = TileConfig()) -> jax.Array:
    """Analog-crossbar matrix product ``x @ w`` on a grid of physical tiles.

    x: [B, K] activations, w: [K, N] weights. Returns [B, N] float32.

    K and N are padded up to tile multiples before the pallas_call (zero
    weight rows/columns quantize to zero and contribute nothing); the
    result is sliced back to [B, N].
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes x={x.shape} w={w.shape}")
    b, k = x.shape
    n = w.shape[1]
    xp = _pad_to(x.astype(jnp.float32), 1, cfg.n_row)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, cfg.n_row), 1, cfg.n_col)
    k_tiles = xp.shape[1] // cfg.n_row
    n_tiles = wp.shape[1] // cfg.n_col

    out = pl.pallas_call(
        functools.partial(_tile_kernel, cfg=cfg, k_tiles=k_tiles),
        grid=(n_tiles, k_tiles),  # kt fastest => sequential digital reduce
        in_specs=[
            pl.BlockSpec((b, cfg.n_row), lambda nt, kt: (0, kt)),
            pl.BlockSpec((cfg.n_row, cfg.n_col), lambda nt, kt: (kt, nt)),
        ],
        out_specs=pl.BlockSpec((b, cfg.n_col), lambda nt, kt: (0, nt)),
        out_shape=jax.ShapeDtypeStruct((b, wp.shape[1]), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp)
    return out[:, :n]


def vmem_footprint_bytes(cfg: TileConfig, batch: int) -> int:
    """Estimated VMEM residency of one grid step (structure metric for
    EXPERIMENTS.md §Perf; interpret-mode wallclock is not a TPU proxy).

    x block + w block + out block, float32, double-buffered inputs.
    """
    f32 = 4
    x_blk = batch * cfg.n_row * f32
    w_blk = cfg.n_row * cfg.n_col * f32
    o_blk = batch * cfg.n_col * f32
    return 2 * (x_blk + w_blk) + o_blk


def mxu_utilization_estimate(cfg: TileConfig, batch: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes kept busy by one tile MVM (structure metric).

    A (batch x n_row) @ (n_row x n_col) block maps to ceil ratios of the
    mxu x mxu systolic array; utilization is the fill of the last partial
    tiles — 1.0 when batch, n_row, n_col are all multiples of ``mxu``.
    """
    def fill(d: int) -> float:
        import math

        return d / (math.ceil(d / mxu) * mxu)

    return fill(batch) * fill(cfg.n_row) * fill(cfg.n_col)
