"""Pure-jnp oracle for the crossbar kernel (no pallas).

Implements byte-for-byte the same math as ``crossbar.crossbar_matmul``:
pad to tile multiples, per-tile conductance quantization, static-range DAC,
per-tile ADC on the partial sums, digital accumulation across K fragments.
pytest asserts exact agreement (same ops, same order, same dtypes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .crossbar import TileConfig, quantize_uniform, _pad_to


def crossbar_matmul_ref(x: jax.Array, w: jax.Array, cfg: TileConfig = TileConfig()) -> jax.Array:
    """Reference analog-crossbar matmul: x[B,K] @ w[K,N] -> [B,N] f32."""
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes x={x.shape} w={w.shape}")
    b, k = x.shape
    n = w.shape[1]
    xp = _pad_to(x.astype(jnp.float32), 1, cfg.n_row)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, cfg.n_row), 1, cfg.n_col)
    k_tiles = xp.shape[1] // cfg.n_row
    n_tiles = wp.shape[1] // cfg.n_col

    x_q = quantize_uniform(xp, cfg.dac_bits, jnp.float32(cfg.x_max))

    out = jnp.zeros((b, wp.shape[1]), jnp.float32)
    for kt in range(k_tiles):
        xs = x_q[:, kt * cfg.n_row : (kt + 1) * cfg.n_row]
        for nt in range(n_tiles):
            blk = wp[kt * cfg.n_row : (kt + 1) * cfg.n_row, nt * cfg.n_col : (nt + 1) * cfg.n_col]
            w_max = jnp.max(jnp.abs(blk))
            w_q = quantize_uniform(blk, cfg.g_bits, w_max)
            acc = jnp.dot(xs, w_q, preferred_element_type=jnp.float32)
            adc_fs = jnp.float32(cfg.adc_alpha * cfg.x_max) * w_max * jnp.float32(cfg.n_row)
            acc = quantize_uniform(acc, cfg.adc_bits, adc_fs)
            out = out.at[:, nt * cfg.n_col : (nt + 1) * cfg.n_col].add(acc)
    return out[:, :n]
