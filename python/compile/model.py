"""L2 — JAX model: a multi-layer perceptron served from NVM crossbar tiles.

The build-time half of the end-to-end driver.  A 784-256-128-10 MLP
("digits classifier") is trained in float32 on a procedural synthetic-digits
dataset, then its inference path is expressed with every matmul routed
through the L1 crossbar kernel (``kernels.crossbar``), exactly as the mapped
chip would execute it: weight-stationary tiles, DAC/ADC quantization, digital
inter-tile accumulation.  ``aot.py`` lowers the crossbar forward (weights
baked in as constants — the NVM array *is* the weight store) to HLO text for
the Rust coordinator.

Everything here is deterministic (fixed PRNG keys) and runs at build time
only.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import TileConfig, crossbar_matmul
from .kernels.ref import crossbar_matmul_ref

LAYER_SIZES = (784, 256, 128, 10)
N_CLASSES = 10
IMG = 28


@dataclass(frozen=True)
class ModelConfig:
    """Model + tile configuration for the crossbar MLP."""

    layer_sizes: tuple[int, ...] = LAYER_SIZES
    tile: TileConfig = TileConfig(n_row=256, n_col=256)

    @property
    def n_layers(self) -> int:
        return len(self.layer_sizes) - 1


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig = ModelConfig()) -> list[dict]:
    """He-initialised [ {w: [in,out], b: [out]} ] parameter stack."""
    params = []
    sizes = cfg.layer_sizes
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_in, fan_out)) * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w, "b": jnp.zeros((fan_out,))})
    return params


def layer_shapes(cfg: ModelConfig = ModelConfig()) -> list[tuple[int, int]]:
    """(rows=fan_in+1, cols=fan_out) logical weight-matrix shapes — the same
    shapes the Rust fragmentation engine maps onto tiles (bias row folded in,
    matching the paper's ``+1`` convention for activation bias)."""
    s = cfg.layer_sizes
    return [(i + 1, o) for i, o in zip(s[:-1], s[1:])]


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _forward(params: list[dict], x: jax.Array, matmul: Callable) -> jax.Array:
    h = x
    last = len(params) - 1
    for i, layer in enumerate(params):
        h = matmul(h, layer["w"]) + layer["b"]
        if i != last:
            h = jax.nn.relu(h)
    return h


def forward_fp32(params: list[dict], x: jax.Array) -> jax.Array:
    """Ideal digital float32 forward (training path / accuracy oracle)."""
    return _forward(params, x, jnp.matmul)


def forward_crossbar(params: list[dict], x: jax.Array, cfg: ModelConfig = ModelConfig()) -> jax.Array:
    """Inference as the mapped chip executes it: every matmul is the L1
    pallas crossbar kernel on the cfg.tile grid."""
    return _forward(params, x, lambda a, w: crossbar_matmul(a, w, cfg.tile))


def forward_crossbar_ref(params: list[dict], x: jax.Array, cfg: ModelConfig = ModelConfig()) -> jax.Array:
    """Same inference semantics through the pure-jnp oracle (pytest cross-check)."""
    return _forward(params, x, lambda a, w: crossbar_matmul_ref(a, w, cfg.tile))


# ---------------------------------------------------------------------------
# Synthetic digits (procedural stand-in for MNIST; the paper uses datasets
# only as *shape sources*, see DESIGN.md substitutions)
# ---------------------------------------------------------------------------

def _digit_stencils() -> jnp.ndarray:
    """10 crude 7x7 digit stencils, upsampled to 28x28."""
    rows = {
        0: ["#####", "#...#", "#...#", "#...#", "#...#", "#...#", "#####"],
        1: ["..#..", ".##..", "..#..", "..#..", "..#..", "..#..", "#####"],
        2: ["#####", "....#", "....#", "#####", "#....", "#....", "#####"],
        3: ["#####", "....#", "....#", "#####", "....#", "....#", "#####"],
        4: ["#...#", "#...#", "#...#", "#####", "....#", "....#", "....#"],
        5: ["#####", "#....", "#....", "#####", "....#", "....#", "#####"],
        6: ["#####", "#....", "#....", "#####", "#...#", "#...#", "#####"],
        7: ["#####", "....#", "...#.", "..#..", ".#...", ".#...", ".#..."],
        8: ["#####", "#...#", "#...#", "#####", "#...#", "#...#", "#####"],
        9: ["#####", "#...#", "#...#", "#####", "....#", "....#", "#####"],
    }
    grids = []
    for d in range(10):
        g = jnp.array([[1.0 if c == "#" else 0.0 for c in f"{r:<5}"[:5]] for r in rows[d]])
        g = jnp.pad(g, ((0, 0), (1, 1)))  # 7x7
        grids.append(g)
    base = jnp.stack(grids)  # [10, 7, 7]
    return jnp.repeat(jnp.repeat(base, 4, axis=1), 4, axis=2)  # [10, 28, 28]


def synth_digits(key: jax.Array, n: int, noise: float = 0.35) -> tuple[jax.Array, jax.Array]:
    """n procedural digit images: stencil + sub-pixel shift + gaussian noise.

    Returns (x[n, 784] float32 in [0,1]-ish, labels[n] int32).
    """
    stencils = _digit_stencils()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    labels = jax.random.randint(k1, (n,), 0, N_CLASSES)
    imgs = stencils[labels]  # [n, 28, 28]
    # random +/-2 px roll per image (shape-preserving augmentation)
    sx = jax.random.randint(k2, (n,), -2, 3)
    sy = jax.random.randint(k3, (n,), -2, 3)
    imgs = jax.vmap(lambda im, a, b: jnp.roll(im, (a, b), axis=(0, 1)))(imgs, sx, sy)
    imgs = imgs + noise * jax.random.normal(k4, imgs.shape)
    return imgs.reshape(n, IMG * IMG).astype(jnp.float32), labels.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Training (fp32; the chip is inference-only, like the paper's mapping study)
# ---------------------------------------------------------------------------

def loss_fn(params: list[dict], x: jax.Array, y: jax.Array) -> jax.Array:
    logits = forward_fp32(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@jax.jit
def _sgd_step(params, x, y, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new, loss


def train(
    key: jax.Array,
    steps: int = 300,
    batch: int = 128,
    lr: float = 0.2,
    cfg: ModelConfig = ModelConfig(),
) -> tuple[list[dict], list[float]]:
    """Train the fp32 MLP on synthetic digits; returns (params, loss curve)."""
    kp, kd = jax.random.split(key)
    params = init_params(kp, cfg)
    losses = []
    for step in range(steps):
        kd, kb = jax.random.split(kd)
        x, y = synth_digits(kb, batch)
        params, loss = _sgd_step(params, x, y, lr)
        losses.append(float(loss))
    return params, losses


def accuracy(logits: jax.Array, y: jax.Array) -> float:
    return float(jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32)))
