"""AOT lowering: HLO text artifacts are well-formed and loadable by XLA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels import TileConfig


@pytest.fixture(scope="module")
def small_setup():
    tile = TileConfig(n_row=128, n_col=128)
    cfg = M.ModelConfig(tile=tile)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg, tile


class TestLowering:
    def test_model_hlo_text_wellformed(self, small_setup):
        params, cfg, _ = small_setup
        text = aot.lower_model(params, cfg, batch=4)
        assert "HloModule" in text
        assert "ENTRY" in text
        # batched input parameter is present
        assert "f32[4,784]" in text

    def test_fp32_hlo_has_no_quantization(self, small_setup):
        params, cfg, _ = small_setup
        text = aot.lower_model_fp32(params, cfg, batch=4)
        assert "round-nearest-even" not in text

    def test_crossbar_hlo_has_quantization(self, small_setup):
        params, cfg, _ = small_setup
        text = aot.lower_model(params, cfg, batch=4)
        assert "round-nearest-even" in text  # DAC/ADC/G quantizers survive

    def test_tile_mvm_has_weight_parameter(self, small_setup):
        _, _, tile = small_setup
        text = aot.lower_tile_mvm(tile, batch=4)
        assert "f32[4,128]" in text
        assert "f32[128,128]" in text

    def test_hlo_text_reparses(self, small_setup):
        """HLO text -> parse round trip: the text we hand to the Rust
        runtime is grammatically valid HLO with the expected entry shape.

        (Numeric execution of the text through PJRT is covered by the Rust
        integration test `integration_runtime`, which exercises the actual
        consumer — xla_extension 0.5.1's parser — rather than jaxlib's.)
        """
        from jax._src.lib import xla_client as xc

        params, cfg, _ = small_setup
        text = aot.lower_model(params, cfg, batch=4)
        mod = xc._xla.hlo_module_from_text(text)
        # re-emitting the parsed module keeps the entry signature
        assert "f32[4,784]" in mod.to_string()
        assert "f32[4,10]" in mod.to_string()

    def test_model_output_tuple_of_logits(self, small_setup):
        """Lowered entry returns a 1-tuple of [B,10] logits (return_tuple
        convention expected by Rust's `to_tuple1`)."""
        params, cfg, _ = small_setup
        text = aot.lower_model(params, cfg, batch=4)
        assert "(f32[4,10]{1,0})" in text  # tuple-wrapped logits root


class TestArtifactRegressions:
    """Guards for the two silent-corruption modes found during bring-up."""

    def test_constants_not_elided(self, small_setup):
        """The default HLO printer elides big literals as `constant({...})`,
        which the Rust-side parser accepts as ZEROS — silently serving an
        untrained model. The weights are the artifact; they must be present.
        """
        params, cfg, _ = small_setup
        text = aot.lower_model(params, cfg, batch=4)
        assert "{...}" not in text
        # a real first-layer weight row must appear verbatim
        w0 = float(params[0]["w"][0, 0])
        assert f"{w0:.9g}"[:6] in text or f"{w0}"[:6] in text

    def test_no_metadata_attributes(self, small_setup):
        """xla_extension 0.5.1's parser rejects source_end_line metadata
        emitted by newer printers; metadata must be stripped."""
        params, cfg, _ = small_setup
        text = aot.lower_model(params, cfg, batch=4)
        assert "metadata={" not in text

    def test_fp32_and_crossbar_share_entry_signature(self, small_setup):
        params, cfg, _ = small_setup
        a = aot.lower_model(params, cfg, batch=4)
        b = aot.lower_model_fp32(params, cfg, batch=4)
        for text in (a, b):
            assert "f32[4,784]" in text and "f32[4,10]" in text
