"""L1 correctness: pallas crossbar kernel vs the pure-jnp oracle.

The CORE correctness signal of the compile path: hypothesis sweeps shapes,
tile geometries and converter precisions and asserts kernel == oracle, and
ideal-mode kernel == jnp.matmul.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import TileConfig, crossbar_matmul, quantize_uniform
from compile.kernels.crossbar import (
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import crossbar_matmul_ref

TOL = dict(rtol=1e-5, atol=1e-5)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape).astype(jnp.float32)


def assert_kernel_close(got, want, cfg, w):
    """Kernel vs oracle with a quantizer-tie allowance.

    Identical math can still land a value exactly on a quantizer decision
    boundary where a 1-ULP difference between the fused (pallas) and eager
    (oracle) pipelines flips a full quantization step.  The discrepancy is
    then bounded by one LSB of the coarsest converter involved; we allow
    exactly that bound (and require near-exactness when it cannot occur).
    """
    lsb = 0.0
    w_max = float(jnp.max(jnp.abs(w)))
    if cfg.adc_bits > 0:
        lsb += cfg.adc_alpha * cfg.x_max * w_max * cfg.n_row / (2 ** (cfg.adc_bits - 1) - 1)
    if cfg.dac_bits > 0:
        # one DAC tie flips one input element by one DAC step
        lsb += cfg.x_max / (2 ** (cfg.dac_bits - 1) - 1) * w_max
    if cfg.g_bits > 0:
        # one conductance tie flips one weight by one G step
        lsb += w_max / (2 ** (cfg.g_bits - 1) - 1) * cfg.x_max
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1.01 * lsb + 1e-5)


# ---------------------------------------------------------------------------
# quantizer unit tests
# ---------------------------------------------------------------------------

class TestQuantizer:
    def test_passthrough_when_bits_zero(self):
        v = rand(0, (8, 8))
        np.testing.assert_array_equal(quantize_uniform(v, 0, jnp.float32(1.0)), v)

    def test_zero_range_maps_to_zero(self):
        v = rand(1, (4, 4))
        np.testing.assert_array_equal(
            quantize_uniform(v, 8, jnp.float32(0.0)), jnp.zeros_like(v)
        )

    def test_clips_to_range(self):
        v = jnp.array([-10.0, 10.0])
        q = quantize_uniform(v, 4, jnp.float32(1.0))
        np.testing.assert_allclose(q, [-1.0, 1.0], **TOL)

    def test_level_count(self):
        # 3 bits -> levels in {-3..3}/3 * vmax -> 7 distinct values on a ramp
        v = jnp.linspace(-1, 1, 1001)
        q = quantize_uniform(v, 3, jnp.float32(1.0))
        assert len(np.unique(np.asarray(q))) == 7

    def test_idempotent(self):
        v = rand(2, (16,))
        q1 = quantize_uniform(v, 6, jnp.float32(2.0))
        q2 = quantize_uniform(q1, 6, jnp.float32(2.0))
        np.testing.assert_allclose(q1, q2, **TOL)

    def test_symmetric(self):
        v = rand(3, (32,))
        q_pos = quantize_uniform(v, 5, jnp.float32(1.5))
        q_neg = quantize_uniform(-v, 5, jnp.float32(1.5))
        np.testing.assert_allclose(q_pos, -q_neg, **TOL)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

class TestKernelVsOracle:
    @pytest.mark.parametrize(
        "b,k,n,tr,tc",
        [
            (1, 64, 64, 64, 64),      # exactly one tile
            (4, 128, 128, 64, 64),    # 2x2 grid
            (2, 100, 60, 64, 64),     # padding in both dims
            (3, 300, 130, 128, 64),   # rectangular tiles, ragged edges
            (8, 64, 256, 256, 256),   # matrix smaller than one tile row dim
            (2, 513, 257, 256, 256),  # one row/col over a tile boundary
        ],
    )
    def test_quantized_matches_ref(self, b, k, n, tr, tc):
        cfg = TileConfig(n_row=tr, n_col=tc)
        x = rand(b * 1000 + k, (b, k))
        w = rand(n, (k, n), scale=0.1)
        got = crossbar_matmul(x, w, cfg)
        want = crossbar_matmul_ref(x, w, cfg)
        assert_kernel_close(got, want, cfg, w)

    @pytest.mark.parametrize("bits", [(2, 4, 2), (4, 6, 4), (8, 10, 8), (0, 8, 8), (8, 0, 8), (8, 8, 0)])
    def test_bit_width_sweep(self, bits):
        dac, adc, g = bits
        cfg = TileConfig(n_row=64, n_col=64, dac_bits=dac, adc_bits=adc, g_bits=g)
        x = rand(11, (4, 150))
        w = rand(12, (150, 70), scale=0.2)
        assert_kernel_close(crossbar_matmul(x, w, cfg), crossbar_matmul_ref(x, w, cfg), cfg, w)

    def test_ideal_mode_matches_matmul(self):
        cfg = TileConfig(n_row=128, n_col=128).ideal()
        x = rand(20, (8, 300))
        w = rand(21, (300, 200))
        np.testing.assert_allclose(crossbar_matmul(x, w, cfg), x @ w, rtol=1e-4, atol=1e-4)

    def test_zero_weights_give_zero(self):
        cfg = TileConfig(n_row=64, n_col=64)
        x = rand(30, (4, 128))
        w = jnp.zeros((128, 64))
        np.testing.assert_array_equal(crossbar_matmul(x, w, cfg), jnp.zeros((4, 64)))

    def test_shape_validation(self):
        cfg = TileConfig()
        with pytest.raises(ValueError):
            crossbar_matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)), cfg)
        with pytest.raises(ValueError):
            crossbar_matmul(jnp.zeros((2,)), jnp.zeros((2, 2)), cfg)

    def test_fragment_grid_counts(self):
        cfg = TileConfig(n_row=256, n_col=256)
        assert cfg.grid_for(784, 256) == (4, 1)
        assert cfg.grid_for(256, 256) == (1, 1)
        assert cfg.grid_for(257, 257) == (2, 2)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 5),
        k=st.integers(1, 200),
        n=st.integers(1, 150),
        tr=st.sampled_from([32, 64, 128]),
        tc=st.sampled_from([32, 64, 96]),
        dac=st.sampled_from([0, 4, 8]),
        adc=st.sampled_from([0, 6, 10]),
        g=st.sampled_from([0, 4, 8]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_kernel_equals_oracle(self, b, k, n, tr, tc, dac, adc, g, seed):
        cfg = TileConfig(n_row=tr, n_col=tc, dac_bits=dac, adc_bits=adc, g_bits=g)
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (b, k), jnp.float32)
        w = 0.2 * jax.random.normal(kw, (k, n), jnp.float32)
        assert_kernel_close(crossbar_matmul(x, w, cfg), crossbar_matmul_ref(x, w, cfg), cfg, w)

    @settings(max_examples=10, deadline=None)
    @given(dt=st.sampled_from([jnp.float32, jnp.bfloat16, jnp.float16]), seed=st.integers(0, 99))
    def test_hypothesis_dtypes_accepted(self, dt, seed):
        """Inputs of any float dtype are computed in f32 (analog domain)."""
        cfg = TileConfig(n_row=32, n_col=32)
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (2, 40), jnp.float32).astype(dt)
        w = jax.random.normal(kw, (40, 30), jnp.float32).astype(dt) * 0.2
        got = crossbar_matmul(x, w, cfg)
        want = crossbar_matmul_ref(x, w, cfg)
        assert got.dtype == jnp.float32
        assert_kernel_close(got, want, cfg, w.astype(jnp.float32))


# ---------------------------------------------------------------------------
# quantization error behaviour (physics sanity, not exactness)
# ---------------------------------------------------------------------------

class TestQuantBehaviour:
    def _err(self, cfg):
        x = rand(40, (8, 256))
        w = rand(41, (256, 128), scale=0.1)
        exact = x @ w
        got = crossbar_matmul(x, w, cfg)
        return float(jnp.sqrt(jnp.mean((got - exact) ** 2)) / jnp.sqrt(jnp.mean(exact**2)))

    def test_error_decreases_with_more_bits(self):
        errs = [
            self._err(TileConfig(n_row=256, n_col=128, dac_bits=b, adc_bits=b + 2, g_bits=b))
            for b in (3, 5, 8)
        ]
        assert errs[0] > errs[1] > errs[2]

    def test_8bit_error_small(self):
        err = self._err(TileConfig(n_row=256, n_col=128))
        assert err < 0.05, f"8-bit relative error too high: {err}"


# ---------------------------------------------------------------------------
# structure metrics used by EXPERIMENTS.md §Perf
# ---------------------------------------------------------------------------

class TestStructureMetrics:
    def test_vmem_footprint_monotone_in_tile(self):
        small = vmem_footprint_bytes(TileConfig(n_row=128, n_col=128), batch=32)
        large = vmem_footprint_bytes(TileConfig(n_row=512, n_col=512), batch=32)
        assert small < large

    def test_vmem_footprint_value(self):
        # 2*(B*R + R*C)*4 + B*C*4
        cfg = TileConfig(n_row=256, n_col=256)
        assert vmem_footprint_bytes(cfg, 32) == 2 * (32 * 256 + 256 * 256) * 4 + 32 * 256 * 4

    def test_mxu_utilization_full_when_aligned(self):
        assert mxu_utilization_estimate(TileConfig(n_row=256, n_col=256), 128) == 1.0

    def test_mxu_utilization_partial(self):
        u = mxu_utilization_estimate(TileConfig(n_row=100, n_col=256), 128)
        assert 0 < u < 1
