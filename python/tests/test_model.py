"""L2 correctness: crossbar MLP model, synthetic data, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import TileConfig

CFG = M.ModelConfig(tile=TileConfig(n_row=256, n_col=256))


@pytest.fixture(scope="module")
def trained():
    params, losses = M.train(jax.random.PRNGKey(7), steps=160, cfg=CFG)
    return params, losses


class TestData:
    def test_synth_digits_shapes_and_labels(self):
        x, y = M.synth_digits(jax.random.PRNGKey(0), 64)
        assert x.shape == (64, 784) and y.shape == (64,)
        assert int(y.min()) >= 0 and int(y.max()) <= 9

    def test_synth_digits_deterministic(self):
        a = M.synth_digits(jax.random.PRNGKey(3), 16)
        b = M.synth_digits(jax.random.PRNGKey(3), 16)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_synth_digits_classes_separable(self):
        """Noise-free stencils of different classes differ."""
        x, y = M.synth_digits(jax.random.PRNGKey(5), 256, noise=0.0)
        xs = {int(lbl): x[i] for i, lbl in enumerate(y)}
        keys = sorted(xs)
        assert len(keys) == 10
        for a in keys:
            for b in keys:
                if a < b:
                    assert float(jnp.abs(xs[a] - xs[b]).sum()) > 1.0


class TestParams:
    def test_init_shapes(self):
        params = M.init_params(jax.random.PRNGKey(0), CFG)
        sizes = CFG.layer_sizes
        assert len(params) == CFG.n_layers
        for p, (i, o) in zip(params, zip(sizes[:-1], sizes[1:])):
            assert p["w"].shape == (i, o) and p["b"].shape == (o,)

    def test_layer_shapes_bias_row(self):
        shapes = M.layer_shapes(CFG)
        assert shapes == [(785, 256), (257, 128), (129, 10)]


class TestForward:
    def test_fp32_shape(self):
        params = M.init_params(jax.random.PRNGKey(1), CFG)
        x, _ = M.synth_digits(jax.random.PRNGKey(2), 8)
        assert M.forward_fp32(params, x).shape == (8, 10)

    def test_crossbar_matches_its_oracle(self, trained):
        params, _ = trained
        x, _ = M.synth_digits(jax.random.PRNGKey(11), 16)
        a = M.forward_crossbar(params, x, CFG)
        b = M.forward_crossbar_ref(params, x, CFG)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_crossbar_close_to_fp32_predictions(self, trained):
        """Quantized inference preserves argmax on most samples."""
        params, _ = trained
        x, _ = M.synth_digits(jax.random.PRNGKey(12), 128)
        fp = jnp.argmax(M.forward_fp32(params, x), axis=1)
        xb = jnp.argmax(M.forward_crossbar(params, x, CFG), axis=1)
        agreement = float(jnp.mean((fp == xb).astype(jnp.float32)))
        assert agreement >= 0.95, f"argmax agreement {agreement}"


class TestTraining:
    def test_loss_decreases(self, trained):
        _, losses = trained
        assert losses[-1] < 0.5 * losses[0]

    def test_accuracy_above_chance(self, trained):
        params, _ = trained
        x, y = M.synth_digits(jax.random.PRNGKey(13), 512)
        assert M.accuracy(M.forward_fp32(params, x), y) > 0.9

    def test_crossbar_accuracy_close_to_fp32(self, trained):
        params, _ = trained
        x, y = M.synth_digits(jax.random.PRNGKey(14), 256)
        acc_fp = M.accuracy(M.forward_fp32(params, x), y)
        acc_xb = M.accuracy(M.forward_crossbar(params, x, CFG), y)
        assert acc_xb >= acc_fp - 0.05
