//! Bench: binary linear optimization — demonstrates the paper's §2.2
//! observation that branch & bound "comes at exponentially increased
//! execution time for larger problems", and prices the demo instances.

use xbarmap::geom::{Block, BlockKind, Tile};
use xbarmap::ilp::{self, bnb::BnbConfig, model::DenseModel, Budget};
use xbarmap::pack::Discipline;
use xbarmap::util::benchkit::Bench;
use xbarmap::util::prng::Rng;

fn random_blocks(rng: &mut Rng, n: usize, tile: Tile) -> Vec<Block> {
    (0..n)
        .map(|i| Block {
            rows: rng.range(tile.n_row / 8, tile.n_row / 2),
            cols: rng.range(tile.n_col / 8, tile.n_col / 2),
            layer: i,
            replica: 0,
            grid: (0, 0),
            kind: BlockKind::Sparse,
        })
        .collect()
}

fn main() {
    let mut b = Bench::from_env();
    let tile = Tile::new(512, 512);
    let demo = xbarmap::report::paper_demo_items();

    // the paper's exact instances
    b.run("exact/demo13/dense (Table 3)", || {
        ilp::solve_packing(&demo, tile, Discipline::Dense, Budget::default()).packing.n_bins
    });
    b.run("exact/demo13/pipeline (Table 5)", || {
        ilp::solve_packing(&demo, tile, Discipline::Pipeline, Budget::default())
            .packing
            .n_bins
    });

    // faithful Eq. 6 BILP via LP-bounded branch&bound (small only)
    let small: Vec<Block> = demo.iter().take(6).cloned().collect();
    let model = DenseModel::build(&small, tile);
    b.run("bilp-eq6/6-items/dense", || {
        ilp::bnb::solve(&model.lp, &BnbConfig::default(), None).nodes
    });

    // blow-up curve: nodes explored vs instance size at fixed budget
    println!("\n# branch&bound node growth (pipeline, budget 500k nodes)");
    let mut rng = Rng::new(1234);
    for n in [8usize, 16, 24, 32, 48] {
        let blocks = random_blocks(&mut rng, n, tile);
        let t0 = std::time::Instant::now();
        let r = ilp::solve_packing(
            &blocks,
            tile,
            Discipline::Pipeline,
            Budget { max_nodes: 500_000, ..Default::default() },
        );
        println!(
            "items {n:>3}: nodes {:>8} optimal {:>5} bins {} lb {} ({:.1} ms)",
            r.nodes,
            r.optimal,
            r.packing.n_bins,
            r.lower_bound,
            t0.elapsed().as_secs_f64() * 1e3
        );
    }

    b.emit_jsonl();
}
