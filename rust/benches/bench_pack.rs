//! Bench: fragmentation + greedy packing hot paths (the inner loop of the
//! §3.1 sweep — Table 6 / Fig. 7 workloads).

use xbarmap::frag;
use xbarmap::geom::Tile;
use xbarmap::nets::zoo;
use xbarmap::pack::{self, Discipline};
use xbarmap::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();
    let net = zoo::resnet18();

    for k in [8u32, 10] {
        let tile = Tile::new(1 << k, 1 << k);
        b.run(&format!("fragment/resnet18/{}", tile), || {
            frag::fragment_network(&net, tile)
        });
        let blocks = frag::fragment_network(&net, tile);
        for d in [Discipline::Dense, Discipline::Pipeline] {
            b.run(&format!("simple/resnet18/{tile}/{d}"), || {
                pack::simple::pack(&blocks, tile, d).n_bins
            });
            b.run(&format!("ffd/resnet18/{tile}/{d}"), || {
                pack::ffd::pack(&blocks, tile, d).n_bins
            });
        }
    }

    // the paper's 13-item demo (Table 3/5 instance)
    let demo = xbarmap::report::paper_demo_items();
    let tile = Tile::new(512, 512);
    b.run("simple/demo13/dense", || pack::simple::pack(&demo, tile, Discipline::Dense).n_bins);
    b.run("ffd/demo13/pipeline", || pack::ffd::pack(&demo, tile, Discipline::Pipeline).n_bins);

    b.emit_jsonl();
}
