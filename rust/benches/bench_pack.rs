//! Bench: fragmentation + packing hot paths (the inner loop of the §3.1
//! sweep — Table 6 / Fig. 7 workloads).
//!
//! The `plan/...` rows measure the full fixed-tile front door (fragment +
//! pack + price through a [`xbarmap::plan::MapRequest`]); the `fragment/`
//! and demo-list rows pin the stage internals the planner composes.

use xbarmap::frag;
use xbarmap::geom::Tile;
use xbarmap::nets::zoo;
use xbarmap::pack::{self, Discipline};
use xbarmap::plan::MapRequest;
use xbarmap::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();
    let net = zoo::resnet18();

    for k in [8u32, 10] {
        let tile = Tile::new(1 << k, 1 << k);
        b.run(&format!("fragment/resnet18/{}", tile), || {
            frag::fragment_network(&net, tile)
        });
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let simple =
                MapRequest::zoo("resnet18").tile(tile.n_row, tile.n_col).discipline(d);
            let simple = simple.build().unwrap();
            b.run(&format!("plan/simple/resnet18/{tile}/{d}"), || {
                simple.plan().unwrap().best.n_tiles
            });
            let ffd = MapRequest::zoo("resnet18")
                .tile(tile.n_row, tile.n_col)
                .discipline(d)
                .engine(xbarmap::opt::Engine::Ffd)
                .build()
                .unwrap();
            b.run(&format!("plan/ffd/resnet18/{tile}/{d}"), || ffd.plan().unwrap().best.n_tiles);
        }
    }

    // the paper's 13-item demo (Table 3/5 instance) — raw engine internals
    let demo = xbarmap::report::paper_demo_items();
    let tile = Tile::new(512, 512);
    b.run("simple/demo13/dense", || pack::simple::pack(&demo, tile, Discipline::Dense).n_bins);
    b.run("ffd/demo13/pipeline", || pack::ffd::pack(&demo, tile, Discipline::Pipeline).n_bins);

    // counted kernel vs per-block count-only engine on a block-heavy
    // workload (BERT layer S=64 replicated x64 at 64x64 tiles: ~10^5
    // blocks, ~12 shape classes). Both rows count bins only — this is the
    // inner loop of one §3.1 sweep point.
    let bert = zoo::bert_layer(64);
    let reps = vec![64usize; bert.n_layers()];
    let small = Tile::new(64, 64);
    let classes = frag::shape_classes(&bert, small, &reps);
    let mut counted_scratch = pack::counted::CountedScratch::new();
    b.run("counted/bert-x64/T(64,64)/pipeline", || {
        pack::counted::simple_bins(
            &classes,
            small,
            Discipline::Pipeline,
            pack::SortOrder::RowsDesc,
            &mut counted_scratch,
        )
    });
    let blocks = frag::fragment_network_replicated(&bert, small, &reps);
    let mut pack_scratch = pack::PackScratch::new();
    b.run("per-block/bert-x64/T(64,64)/pipeline", || {
        pack::simple::pack_into(
            &blocks,
            small,
            Discipline::Pipeline,
            pack::SortOrder::RowsDesc,
            &mut pack_scratch,
        )
    });

    b.emit_jsonl();
    match b.write_json_report("pack") {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_pack.json not written: {e}"),
    }
}
