//! Bench: fragmentation + packing hot paths (the inner loop of the §3.1
//! sweep — Table 6 / Fig. 7 workloads).
//!
//! The `plan/...` rows measure the full fixed-tile front door (fragment +
//! pack + price through a [`xbarmap::plan::MapRequest`]); the `fragment/`
//! and demo-list rows pin the stage internals the planner composes.

use xbarmap::frag;
use xbarmap::geom::Tile;
use xbarmap::nets::zoo;
use xbarmap::pack::{self, Discipline};
use xbarmap::plan::MapRequest;
use xbarmap::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();
    let net = zoo::resnet18();

    for k in [8u32, 10] {
        let tile = Tile::new(1 << k, 1 << k);
        b.run(&format!("fragment/resnet18/{}", tile), || {
            frag::fragment_network(&net, tile)
        });
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let simple =
                MapRequest::zoo("resnet18").tile(tile.n_row, tile.n_col).discipline(d);
            let simple = simple.build().unwrap();
            b.run(&format!("plan/simple/resnet18/{tile}/{d}"), || {
                simple.plan().unwrap().best.n_tiles
            });
            let ffd = MapRequest::zoo("resnet18")
                .tile(tile.n_row, tile.n_col)
                .discipline(d)
                .engine(xbarmap::opt::Engine::Ffd)
                .build()
                .unwrap();
            b.run(&format!("plan/ffd/resnet18/{tile}/{d}"), || ffd.plan().unwrap().best.n_tiles);
        }
    }

    // the paper's 13-item demo (Table 3/5 instance) — raw engine internals
    let demo = xbarmap::report::paper_demo_items();
    let tile = Tile::new(512, 512);
    b.run("simple/demo13/dense", || pack::simple::pack(&demo, tile, Discipline::Dense).n_bins);
    b.run("ffd/demo13/pipeline", || pack::ffd::pack(&demo, tile, Discipline::Pipeline).n_bins);

    b.emit_jsonl();
}
