//! Bench: PJRT request path — batched inference through the AOT crossbar
//! artifact (the e2e serving hot path). Requires `make artifacts`.

use xbarmap::coordinator::{digits, Coordinator, CoordinatorConfig};
use xbarmap::runtime::artifacts_dir;
use xbarmap::util::benchkit::Bench;
use xbarmap::util::prng::Rng;

fn main() {
    if !artifacts_dir(None).join("meta.json").exists() {
        eprintln!("skipping bench_runtime: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let mut b = Bench::from_env();

    for crossbar in [true, false] {
        let c = Coordinator::new(&CoordinatorConfig { crossbar, ..Default::default() })
            .expect("coordinator");
        let mut rng = Rng::new(11);
        let samples = digits::synth_digits(&mut rng, c.batch, 0.35);
        let flat: Vec<f32> = samples.iter().flat_map(|s| s.pixels.iter().copied()).collect();
        let name = if crossbar { "crossbar" } else { "fp32" };
        let n = c.batch;
        b.run(&format!("pjrt/{name}/batch{n}"), || {
            c.infer(&flat, n).expect("infer").data[0]
        });
        // per-request price at full batch
        let stats = b.results.last().unwrap();
        println!(
            "  -> {:.2} µs/request at batch {n}",
            stats.p50_ns / 1e3 / n as f64
        );
    }

    // workload generation cost (must stay tiny vs inference)
    let mut rng = Rng::new(12);
    b.run("workload/synth_digits x32", || digits::synth_digits(&mut rng, 32, 0.35).len());

    b.emit_jsonl();
}
