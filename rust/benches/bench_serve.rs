//! Bench: planning-service round trips over a real loopback socket —
//! the latency a tenant of `xbarmap serve --plans` actually observes.
//!
//! Three rows join the bench trajectory (`BENCH_serve.json`, gated in CI
//! like the sweep/pack files):
//!
//! * `serve/roundtrip/lenet-fixed256/solve` — cache disabled, so every
//!   iteration pays request decode + a real fixed-tile solve + response
//!   serialization + two socket hops;
//! * `serve/roundtrip/lenet-fixed256/cache-hit` — cache enabled and
//!   warmed, so iterations measure the admission/queue/cache/re-stamp
//!   path the multi-tenant steady state lives on;
//! * `serve/roundtrip/cmd-stats` — the in-band stats command, the floor
//!   the wire + queue machinery sets under any response.
//!
//! One persistent connection per row: connection setup is not the thing
//! being measured, and a tenant fleet holds connections open.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use xbarmap::service::{Service, ServiceConfig, ServiceHandle};
use xbarmap::util::benchkit::Bench;
use xbarmap::plan::wire;

fn start(cache: usize) -> (ServiceHandle, SocketAddr, std::thread::JoinHandle<wire::StatsSnapshot>) {
    let svc = Service::bind(&ServiceConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 16,
        cache_capacity: cache,
        ..ServiceConfig::default()
    })
    .expect("bind ephemeral service");
    let addr = svc.local_addr().unwrap();
    let handle = svc.handle();
    let join = std::thread::spawn(move || svc.run().unwrap());
    (handle, addr, join)
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

/// One request line out, one response line back (length keeps the work
/// alive through black_box in the runner).
fn roundtrip(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str, line: &mut String) -> usize {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    line.clear();
    assert!(reader.read_line(line).unwrap() > 0, "service hung up mid-bench");
    line.len()
}

fn main() {
    let mut b = Bench::from_env();
    let plan_req = r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#;
    let stats_req = r#"{"v":1,"cmd":"stats"}"#;
    let mut line = String::new();

    // cache off: every round trip is a real solve
    {
        let (handle, addr, join) = start(0);
        let (mut stream, mut reader) = connect(addr);
        b.run("serve/roundtrip/lenet-fixed256/solve", || {
            roundtrip(&mut stream, &mut reader, plan_req, &mut line)
        });
        assert!(line.contains("\"best\""), "expected a plan, got: {line}");
        drop((stream, reader));
        handle.shutdown();
        let stats = join.join().unwrap();
        assert_eq!(stats.cache_hits, 0);
    }

    // cache on and warmed: the multi-tenant steady state
    {
        let (handle, addr, join) = start(256);
        let (mut stream, mut reader) = connect(addr);
        roundtrip(&mut stream, &mut reader, plan_req, &mut line); // warm the entry
        b.run("serve/roundtrip/lenet-fixed256/cache-hit", || {
            roundtrip(&mut stream, &mut reader, plan_req, &mut line)
        });
        b.run("serve/roundtrip/cmd-stats", || {
            roundtrip(&mut stream, &mut reader, stats_req, &mut line)
        });
        assert!(line.contains("\"stats\""), "expected a stats frame, got: {line}");
        drop((stream, reader));
        handle.shutdown();
        let stats = join.join().unwrap();
        assert!(stats.cache_hits > 0, "cache-hit row never hit the cache");
    }

    b.emit_jsonl();
    match b.write_json_report("serve") {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_serve.json not written: {e}"),
    }
}
