//! Bench: planning-service round trips over a real loopback socket —
//! the latency a tenant of `xbarmap serve --plans` actually observes.
//!
//! The rows joining the bench trajectory (`BENCH_serve.json`, gated in
//! CI like the sweep/pack files):
//!
//! * `serve/roundtrip/lenet-fixed256/solve` — cache disabled, so every
//!   iteration pays request decode + a real fixed-tile solve + response
//!   serialization + two socket hops;
//! * `serve/roundtrip/lenet-fixed256/cache-hit` — cache enabled and
//!   warmed, so iterations measure the admission/queue/cache/re-stamp
//!   path the multi-tenant steady state lives on (the request is
//!   non-canonical, so each trip still pays a full JSON parse);
//! * `serve/roundtrip/lenet-fixed256/scan-hit` — the same warmed hit
//!   for a **canonical** id-carrying request, which the byte scanner
//!   (`plan::wire::scan`) resolves to an LRU probe without building a
//!   JSON tree — the delta against `cache-hit` is the parse work the
//!   fast path saves;
//! * `serve/roundtrip/cmd-stats` — the in-band stats command, the floor
//!   the wire + queue machinery sets under any response;
//! * `serve/roundtrip/lenet-fixed256/warehouse-hit` — LRU off, plan
//!   persisted by a *previous* service lifetime: every iteration pays the
//!   warm-boot disk tier (index lookup + segment read + CRC re-verify);
//! * `serve/roundtrip/lenet-grid68/coalesced-herd` — four clients fire
//!   the same canonical request concurrently with caching off, so each
//!   iteration is one solve plus three single-flight coalesced copies;
//! * `serve/roundtrip/lenet-fixed256/cluster-hit` — the same warmed
//!   cache-hit round trip through a two-shard cluster router, so the
//!   delta against `cache-hit` prices the routing hop (ring lookup +
//!   forwarder lane + worker socket round trip + re-sequencing).
//!
//! Round trips go through the crate's retrying client
//! ([`xbarmap::plan::client`]) — the same transport a tenant fleet and
//! the CI smoke test use — holding one persistent connection per row:
//! connection setup is not the thing being measured.

use std::net::SocketAddr;
use std::path::PathBuf;
use xbarmap::cluster::{Cluster, ClusterConfig};
use xbarmap::plan::client::{Client, ClientConfig};
use xbarmap::plan::wire;
use xbarmap::service::{Service, ServiceConfig, ServiceHandle};
use xbarmap::util::benchkit::Bench;

fn start_with(
    cfg: ServiceConfig,
) -> (ServiceHandle, SocketAddr, std::thread::JoinHandle<wire::StatsSnapshot>) {
    let svc = Service::bind(&cfg).expect("bind ephemeral service");
    let addr = svc.local_addr().unwrap();
    let handle = svc.handle();
    let join = std::thread::spawn(move || svc.run().unwrap());
    (handle, addr, join)
}

fn start(cache: usize) -> (ServiceHandle, SocketAddr, std::thread::JoinHandle<wire::StatsSnapshot>) {
    start_with(ServiceConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 16,
        cache_capacity: cache,
        ..ServiceConfig::default()
    })
}

fn connect(addr: SocketAddr) -> Client {
    Client::with_config(addr, ClientConfig { retries: 2, ..ClientConfig::default() })
}

/// One request line out, one response line back (length keeps the work
/// alive through black_box in the runner).
fn roundtrip(client: &mut Client, req: &str, line: &mut String) -> usize {
    *line = client.roundtrip_line(req).expect("service round trip");
    line.len()
}

fn main() {
    let mut b = Bench::from_env();
    let plan_req = r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#;
    let stats_req = r#"{"v":1,"cmd":"stats"}"#;
    let mut line = String::new();

    // cache off: every round trip is a real solve
    {
        let (handle, addr, join) = start(0);
        let mut client = connect(addr);
        b.run("serve/roundtrip/lenet-fixed256/solve", || {
            roundtrip(&mut client, plan_req, &mut line)
        });
        assert!(line.contains("\"best\""), "expected a plan, got: {line}");
        drop(client);
        handle.shutdown();
        let stats = join.join().unwrap();
        assert_eq!(stats.cache_hits, 0);
    }

    // cache on and warmed: the multi-tenant steady state
    {
        let (handle, addr, join) = start(256);
        let mut client = connect(addr);
        roundtrip(&mut client, plan_req, &mut line); // warm the entry
        b.run("serve/roundtrip/lenet-fixed256/cache-hit", || {
            roundtrip(&mut client, plan_req, &mut line)
        });
        // same tile point, but canonical bytes + a correlation id: the
        // wire scanner's candidate key matches the LRU entry directly,
        // so iterations skip the JSON tree entirely
        let scan_req = r#"{"v":1,"id":"bench-tenant","net":{"zoo":"lenet"},"discipline":"dense","engine":"simple","tiles":{"fixed":[256,256]},"objective":"min-area"}"#;
        // the canonical key already holds plan_req's plan (ids are
        // cleared from cache keys), so this is fast-pathed from trip one
        roundtrip(&mut client, scan_req, &mut line);
        b.run("serve/roundtrip/lenet-fixed256/scan-hit", || {
            roundtrip(&mut client, scan_req, &mut line)
        });
        assert!(line.contains("\"id\":\"bench-tenant\""), "expected a re-stamped id: {line}");
        b.run("serve/roundtrip/cmd-stats", || {
            roundtrip(&mut client, stats_req, &mut line)
        });
        assert!(line.contains("\"stats\""), "expected a stats frame, got: {line}");
        drop(client);
        handle.shutdown();
        let stats = join.join().unwrap();
        assert!(stats.cache_hits > 1, "cache-hit/scan-hit rows never hit the cache");
    }

    // warm boot: a prior service lifetime solved and persisted the plan;
    // this lifetime has no LRU, so every round trip reads the warehouse
    // (index lookup + segment read + CRC re-verify + verbatim respond)
    {
        let dir = std::env::temp_dir().join(format!("xbarmap-bench-wh-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let warehoused = || ServiceConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            queue_capacity: 16,
            cache_capacity: 0,
            warehouse: Some(dir.clone()),
            ..ServiceConfig::default()
        };
        {
            let (handle, addr, join) = start_with(warehoused());
            let mut client = connect(addr);
            roundtrip(&mut client, plan_req, &mut line); // solve + persist
            drop(client);
            handle.shutdown();
            let stats = join.join().unwrap();
            assert_eq!(stats.warehouse_writes, 1, "the solve must persist before the reboot");
        }
        let (handle, addr, join) = start_with(warehoused());
        let mut client = connect(addr);
        b.run("serve/roundtrip/lenet-fixed256/warehouse-hit", || {
            roundtrip(&mut client, plan_req, &mut line)
        });
        assert!(line.contains("\"best\""), "expected a plan, got: {line}");
        drop(client);
        handle.shutdown();
        let stats = join.join().unwrap();
        assert!(stats.warehouse_hits > 0, "warehouse-hit row never read the store");
        assert_eq!(stats.warehouse_writes, 0, "warm boot must not re-solve");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // thundering herd: four clients fire the same canonical request at
    // once with no cache and no warehouse — one solve, three coalesced
    {
        let (handle, addr, join) = start(0);
        let mut clients: Vec<Client> = (0..4).map(|_| connect(addr)).collect();
        let herd_req =
            r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"grid":{"row_exp":[6,8],"aspects":[1,2]}}}"#;
        b.run("serve/roundtrip/lenet-grid68/coalesced-herd", || {
            std::thread::scope(|s| {
                let waves: Vec<_> = clients
                    .iter_mut()
                    .map(|c| s.spawn(move || c.roundtrip_line(herd_req).expect("herd trip").len()))
                    .collect();
                waves.into_iter().map(|w| w.join().expect("herd client")).sum::<usize>()
            })
        });
        drop(clients);
        handle.shutdown();
        let stats = join.join().unwrap();
        assert!(stats.coalesced > 0, "herd row never coalesced");
        assert_eq!(stats.cache_hits, 0);
    }

    // routed: the identical warmed cache hit, but through the cluster
    // router and a real worker process — the delta vs cache-hit is the
    // price of the routing hop
    {
        let cluster = Cluster::bind(ClusterConfig {
            addr: "127.0.0.1:0".into(),
            shards: 2,
            exe: Some(PathBuf::from(env!("CARGO_BIN_EXE_xbarmap"))),
            worker_args: vec!["--workers".into(), "2".into(), "--queue".into(), "16".into()],
            ..ClusterConfig::default()
        })
        .expect("bind ephemeral cluster");
        let addr = cluster.local_addr().unwrap();
        let handle = cluster.handle();
        let join = std::thread::spawn(move || cluster.run().unwrap());
        let mut client = connect(addr);
        roundtrip(&mut client, plan_req, &mut line); // warm the owner's cache
        b.run("serve/roundtrip/lenet-fixed256/cluster-hit", || {
            roundtrip(&mut client, plan_req, &mut line)
        });
        assert!(line.contains("\"best\""), "expected a plan, got: {line}");
        drop(client);
        handle.shutdown();
        let stats = join.join().unwrap();
        assert!(stats.cache_hits > 0, "cluster-hit row never hit the owner's cache");
        assert_eq!(stats.shard_respawns, 0, "a shard died during the bench");
        assert_eq!(stats.degraded, 0, "the router fell back to degraded mode");
    }

    b.emit_jsonl();
    match b.write_json_report("serve") {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_serve.json not written: {e}"),
    }
}
