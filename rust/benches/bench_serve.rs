//! Bench: planning-service round trips over a real loopback socket —
//! the latency a tenant of `xbarmap serve --plans` actually observes.
//!
//! Three rows join the bench trajectory (`BENCH_serve.json`, gated in CI
//! like the sweep/pack files):
//!
//! * `serve/roundtrip/lenet-fixed256/solve` — cache disabled, so every
//!   iteration pays request decode + a real fixed-tile solve + response
//!   serialization + two socket hops;
//! * `serve/roundtrip/lenet-fixed256/cache-hit` — cache enabled and
//!   warmed, so iterations measure the admission/queue/cache/re-stamp
//!   path the multi-tenant steady state lives on;
//! * `serve/roundtrip/cmd-stats` — the in-band stats command, the floor
//!   the wire + queue machinery sets under any response.
//!
//! Round trips go through the crate's retrying client
//! ([`xbarmap::plan::client`]) — the same transport a tenant fleet and
//! the CI smoke test use — holding one persistent connection per row:
//! connection setup is not the thing being measured.

use std::net::SocketAddr;
use xbarmap::plan::client::{Client, ClientConfig};
use xbarmap::plan::wire;
use xbarmap::service::{Service, ServiceConfig, ServiceHandle};
use xbarmap::util::benchkit::Bench;

fn start(cache: usize) -> (ServiceHandle, SocketAddr, std::thread::JoinHandle<wire::StatsSnapshot>) {
    let svc = Service::bind(&ServiceConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 16,
        cache_capacity: cache,
        ..ServiceConfig::default()
    })
    .expect("bind ephemeral service");
    let addr = svc.local_addr().unwrap();
    let handle = svc.handle();
    let join = std::thread::spawn(move || svc.run().unwrap());
    (handle, addr, join)
}

fn connect(addr: SocketAddr) -> Client {
    Client::with_config(addr, ClientConfig { retries: 2, ..ClientConfig::default() })
}

/// One request line out, one response line back (length keeps the work
/// alive through black_box in the runner).
fn roundtrip(client: &mut Client, req: &str, line: &mut String) -> usize {
    *line = client.roundtrip_line(req).expect("service round trip");
    line.len()
}

fn main() {
    let mut b = Bench::from_env();
    let plan_req = r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#;
    let stats_req = r#"{"v":1,"cmd":"stats"}"#;
    let mut line = String::new();

    // cache off: every round trip is a real solve
    {
        let (handle, addr, join) = start(0);
        let mut client = connect(addr);
        b.run("serve/roundtrip/lenet-fixed256/solve", || {
            roundtrip(&mut client, plan_req, &mut line)
        });
        assert!(line.contains("\"best\""), "expected a plan, got: {line}");
        drop(client);
        handle.shutdown();
        let stats = join.join().unwrap();
        assert_eq!(stats.cache_hits, 0);
    }

    // cache on and warmed: the multi-tenant steady state
    {
        let (handle, addr, join) = start(256);
        let mut client = connect(addr);
        roundtrip(&mut client, plan_req, &mut line); // warm the entry
        b.run("serve/roundtrip/lenet-fixed256/cache-hit", || {
            roundtrip(&mut client, plan_req, &mut line)
        });
        b.run("serve/roundtrip/cmd-stats", || {
            roundtrip(&mut client, stats_req, &mut line)
        });
        assert!(line.contains("\"stats\""), "expected a stats frame, got: {line}");
        drop(client);
        handle.shutdown();
        let stats = join.join().unwrap();
        assert!(stats.cache_hits > 0, "cache-hit row never hit the cache");
    }

    b.emit_jsonl();
    match b.write_json_report("serve") {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_serve.json not written: {e}"),
    }
}
