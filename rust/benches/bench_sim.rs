//! Bench: cycle-level simulator throughput (Fig. 9 performance rows).

use xbarmap::geom::Tile;
use xbarmap::nets::zoo;
use xbarmap::pack::Discipline;
use xbarmap::perf::{rapa, Execution};
use xbarmap::sim::{map_and_simulate, simulate, SimConfig};
use xbarmap::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();
    let net = zoo::resnet18();
    let tile = Tile::new(512, 512);

    b.run("sim/resnet18/map+simulate/seq x100", || {
        let cfg = SimConfig::new(&net, Execution::Sequential);
        map_and_simulate(&net, tile, Discipline::Dense, &cfg, 100).1.makespan_cycles
    });

    // pre-mapped simulate (the steady-state inner loop)
    let cfg = SimConfig::new(&net, Execution::Pipelined);
    let blocks = xbarmap::frag::fragment_network(&net, tile);
    let packing = xbarmap::pack::simple::pack(&blocks, tile, Discipline::Pipeline);
    b.run("sim/resnet18/pipelined x1000 (pre-mapped)", || {
        simulate(&net, &packing, &cfg, 1000).makespan_cycles
    });

    let mut rapa_cfg = SimConfig::new(&net, Execution::Pipelined);
    rapa_cfg.replication = rapa::plan_balanced(&net, 128);
    b.run("sim/resnet18/rapa128 map+simulate x100", || {
        map_and_simulate(&net, tile, Discipline::Pipeline, &rapa_cfg, 100).1.makespan_cycles
    });

    b.emit_jsonl();
}
