//! Bench: the full §3.1 optimization sweep (the repro harness hot path —
//! Figs. 8, 9, 10 each run one or more of these).

use xbarmap::nets::zoo;
use xbarmap::opt::{self, Engine, SweepConfig};
use xbarmap::pack::Discipline;
use xbarmap::perf::rapa;
use xbarmap::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();
    let net = zoo::resnet18();

    b.run("sweep/resnet18/dense/square(8 sizes)", || {
        opt::sweep(&net, &SweepConfig::square(Discipline::Dense)).len()
    });
    b.run("sweep/resnet18/pipeline/full(64 configs)", || {
        opt::sweep(&net, &SweepConfig::paper_default(Discipline::Pipeline)).len()
    });

    let rapa_cfg = SweepConfig {
        replication: Some(rapa::plan_balanced(&net, 128)),
        ..SweepConfig::paper_default(Discipline::Pipeline)
    };
    b.run("sweep/resnet18/rapa128/full(64 configs)", || {
        opt::sweep(&net, &rapa_cfg).len()
    });

    let lps_cfg = SweepConfig {
        engine: Engine::Ilp { max_nodes: 50_000 },
        ..SweepConfig::square(Discipline::Dense)
    };
    b.run("sweep/resnet18/dense/square/lps-50k", || {
        opt::sweep(&net, &lps_cfg).len()
    });

    let big = zoo::resnet50();
    b.run("sweep/resnet50/pipeline/square", || {
        opt::sweep(&big, &SweepConfig::square(Discipline::Pipeline)).len()
    });

    b.emit_jsonl();
}
