//! Bench: the full §3.1 optimization sweep (the repro harness hot path —
//! Figs. 8, 9, 10 each run one or more of these).
//!
//! The 64-config benches run twice — once through the serial reference loop
//! and once through the parallel allocation-lean engine — so the recorded
//! `BENCH_sweep.json` medians document the speedup this engine exists for.
//! Set `XBARMAP_SWEEP_THREADS` to pin the worker count and
//! `XBARMAP_BENCH_FAST=1` for a CI smoke run.

use xbarmap::nets::zoo;
use xbarmap::opt::{self, Engine, SweepConfig};
use xbarmap::pack::Discipline;
use xbarmap::perf::rapa;
use xbarmap::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();
    let net = zoo::resnet18();
    println!("sweep workers: {}", opt::sweep_threads());

    let full = SweepConfig::paper_default(Discipline::Pipeline);
    b.run("sweep/resnet18/pipeline/full(64 configs)/serial", || {
        opt::sweep_serial(&net, &full).len()
    });
    b.run("sweep/resnet18/pipeline/full(64 configs)/parallel", || {
        opt::sweep(&net, &full).len()
    });

    b.run("sweep/resnet18/dense/square(8 sizes)", || {
        opt::sweep(&net, &SweepConfig::square(Discipline::Dense)).len()
    });

    let rapa_cfg = SweepConfig {
        replication: Some(rapa::plan_balanced(&net, 128)),
        ..SweepConfig::paper_default(Discipline::Pipeline)
    };
    b.run("sweep/resnet18/rapa128/full(64 configs)/serial", || {
        opt::sweep_serial(&net, &rapa_cfg).len()
    });
    b.run("sweep/resnet18/rapa128/full(64 configs)/parallel", || {
        opt::sweep(&net, &rapa_cfg).len()
    });

    let lps_cfg = SweepConfig {
        engine: Engine::Ilp { max_nodes: 50_000 },
        ..SweepConfig::square(Discipline::Dense)
    };
    b.run("sweep/resnet18/dense/square/lps-50k", || {
        opt::sweep(&net, &lps_cfg).len()
    });

    let big = zoo::resnet50();
    b.run("sweep/resnet50/pipeline/square", || {
        opt::sweep(&big, &SweepConfig::square(Discipline::Pipeline)).len()
    });

    // headline: wall-clock speedup of the parallel engine on the 64-config
    // ResNet-18 sweep (acceptance target: >= 2x on a multi-core host)
    let p50 = |name: &str| {
        b.results
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.p50_ns)
            .unwrap_or(f64::NAN)
    };
    let speedup = p50("sweep/resnet18/pipeline/full(64 configs)/serial")
        / p50("sweep/resnet18/pipeline/full(64 configs)/parallel");
    println!("parallel speedup (64-config pipeline sweep): {speedup:.2}x");

    b.emit_jsonl();
    match b.write_json_report("sweep") {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_sweep.json not written: {e}"),
    }
}
