//! Bench: the full §3.1 optimization sweep (the repro harness hot path —
//! Figs. 8, 9, 10 each run one or more of these).
//!
//! The parallel rows drive the sweep through the [`xbarmap::plan`] front
//! door (a `MapRequest` planned end to end — what `xbarmap plan`/`sweep`
//! serve); the serial rows pin the hidden `opt::sweep_serial` reference
//! loop, so the recorded `BENCH_sweep.json` medians document the speedup
//! the parallel engine exists for. Set `XBARMAP_SWEEP_THREADS` to pin the
//! worker count and `XBARMAP_BENCH_FAST=1` for a CI smoke run; CI gates
//! these medians against the committed baseline via `xbarmap bench-gate`.

use xbarmap::nets::zoo;
use xbarmap::opt::{self, Engine, SweepConfig};
use xbarmap::pack::Discipline;
use xbarmap::perf::rapa;
use xbarmap::plan::{MapRequest, Replication};
use xbarmap::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();
    let net = zoo::resnet18();
    println!("sweep workers: {}", opt::sweep_threads());

    let full = SweepConfig::paper_default(Discipline::Pipeline);
    b.run("sweep/resnet18/pipeline/full(64 configs)/serial", || {
        opt::sweep_serial(&net, &full).len()
    });
    let full_plan =
        MapRequest::zoo("resnet18").discipline(Discipline::Pipeline).build().unwrap();
    b.run("sweep/resnet18/pipeline/full(64 configs)/parallel", || {
        full_plan.plan().unwrap().points.len()
    });

    let dense_sq = MapRequest::zoo("resnet18").grid((6, 13), vec![1]).build().unwrap();
    b.run("sweep/resnet18/dense/square(8 sizes)", || dense_sq.plan().unwrap().points.len());

    let rapa_cfg = SweepConfig {
        replication: Some(rapa::plan_balanced(&net, 128)),
        ..SweepConfig::paper_default(Discipline::Pipeline)
    };
    b.run("sweep/resnet18/rapa128/full(64 configs)/serial", || {
        opt::sweep_serial(&net, &rapa_cfg).len()
    });
    let rapa_plan = MapRequest::zoo("resnet18")
        .discipline(Discipline::Pipeline)
        .replication(Replication::Balanced(128))
        .build()
        .unwrap();
    b.run("sweep/resnet18/rapa128/full(64 configs)/parallel", || {
        rapa_plan.plan().unwrap().points.len()
    });

    let lps_plan = MapRequest::zoo("resnet18")
        .grid((6, 13), vec![1])
        .engine(Engine::Ilp { max_nodes: 50_000 })
        .build()
        .unwrap();
    b.run("sweep/resnet18/dense/square/lps-50k", || lps_plan.plan().unwrap().points.len());

    let big_plan = MapRequest::zoo("resnet50")
        .discipline(Discipline::Pipeline)
        .grid((6, 13), vec![1])
        .build()
        .unwrap();
    b.run("sweep/resnet50/pipeline/square", || big_plan.plan().unwrap().points.len());

    // counted-kernel headline: a block-heavy config — one BERT layer
    // (S=64) replicated x64 fragments into ~10^5 blocks at 64x64 tiles,
    // but only ~2 shape classes per layer. The materialized row is the
    // per-block reference loop; the counted row is the same sweep through
    // the plan front door (shape-class census + closed-form runs), pinned
    // to ONE worker so the ratio isolates the kernel, not thread
    // parallelism (both rows single-threaded).
    let bert = zoo::bert_layer(64);
    let bert_cfg = SweepConfig {
        replication: Some(rapa::plan_uniform(&bert, 64)),
        ..SweepConfig::square(Discipline::Pipeline)
    };
    b.run("sweep/bert-x64/pipeline/square(8 sizes)/materialized", || {
        opt::sweep_serial(&bert, &bert_cfg).len()
    });
    let bert_plan = MapRequest::zoo("bert")
        .grid((6, 13), vec![1])
        .discipline(Discipline::Pipeline)
        .replication(Replication::Uniform(64))
        .threads(1)
        .build()
        .unwrap();
    b.run("sweep/bert-x64/pipeline/square(8 sizes)/counted", || {
        bert_plan.plan().unwrap().points.len()
    });

    // headline: wall-clock speedup of the parallel engine on the 64-config
    // ResNet-18 sweep (acceptance target: >= 2x on a multi-core host)
    let p50 = |name: &str| {
        b.results
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.p50_ns)
            .unwrap_or(f64::NAN)
    };
    let speedup = p50("sweep/resnet18/pipeline/full(64 configs)/serial")
        / p50("sweep/resnet18/pipeline/full(64 configs)/parallel");
    println!("parallel speedup (64-config pipeline sweep): {speedup:.2}x");
    // counted-path headline — both rows single-threaded, so this is the
    // kernel's own win (acceptance target: >= 3x median on the block-heavy
    // config; in practice orders of magnitude)
    let counted_speedup = p50("sweep/bert-x64/pipeline/square(8 sizes)/materialized")
        / p50("sweep/bert-x64/pipeline/square(8 sizes)/counted");
    println!("counted speedup (BERT x64 square sweep): {counted_speedup:.2}x");

    b.emit_jsonl();
    match b.write_json_report("sweep") {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_sweep.json not written: {e}"),
    }
}
