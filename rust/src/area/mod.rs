//! Tile area and efficiency model (paper Eq. 1–2).
//!
//! A tile consists of the cross-bar array (`n_row x n_col` unit cells of
//! `D_unit_in x D_unit_out` µm), peripheral strips along both array edges of
//! width `D_cnt` (DACs on word lines, ADCs + arithmetic on bit lines), and a
//! `D_cnt²` control corner (routing tables, synchronization):
//!
//! ```text
//! T_tile(n,m) = Din·Dout·n·m + (Din·n + Dout·m)·D_cnt + D_cnt²
//!             = (Din·n + D_cnt) · (Dout·m + D_cnt)
//! T_eff = T_array / T_tile                                   (Eq. 1, 2)
//! ```
//!
//! `D_cnt` is **calibrated** from a published design point: the paper uses
//! a tile efficiency of 20 % at 256x256 (Le Gallo et al., ref [26]), from
//! which Table 6's 239 mm² for 208 tiles gives a 1.87 µm unit cell.
//! An optional ADC-sharing exponent lets the peripheral strip grow
//! sublinearly with the array edge (paper §3.1's "design choices could
//! include the increase of shared columns per ADC").

pub mod yield_model;

use crate::geom::Tile;

/// Area model parameters (lengths in µm).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaModel {
    /// unit-cell pitch along word lines (input direction), µm
    pub d_unit_in: f64,
    /// unit-cell pitch along bit lines (output direction), µm
    pub d_unit_out: f64,
    /// peripheral/control strip width, µm
    pub d_cnt: f64,
    /// peripheral scaling exponent: strip contribution scales with
    /// (edge/ref_edge)^(gamma-1); 1.0 = paper's constant-width strips
    pub periph_gamma: f64,
    /// reference edge (cells) for the gamma scaling
    pub ref_edge: f64,
}

impl AreaModel {
    /// Calibrate `d_cnt` so that a square `cal_dim x cal_dim` tile has the
    /// given efficiency (default calibration: 20 % @ 256, ref [26]).
    pub fn calibrated(d_unit: f64, cal_dim: usize, cal_eff: f64) -> AreaModel {
        assert!(cal_eff > 0.0 && cal_eff < 1.0, "efficiency must be in (0,1)");
        let a = d_unit * d_unit * (cal_dim * cal_dim) as f64; // array area
        let p = 2.0 * d_unit * cal_dim as f64; // perimeter factor
        // A / (A + P·D + D²) = eff  =>  D² + P·D - A(1-eff)/eff = 0
        let rhs = a * (1.0 - cal_eff) / cal_eff;
        let d = (-p + (p * p + 4.0 * rhs).sqrt()) / 2.0;
        AreaModel {
            d_unit_in: d_unit,
            d_unit_out: d_unit,
            d_cnt: d,
            periph_gamma: 1.0,
            ref_edge: cal_dim as f64,
        }
    }

    /// The paper's default: 1.87 µm cell (Table 6 @256² back-calculation),
    /// 20 % efficiency at 256x256.
    pub fn paper_default() -> AreaModel {
        AreaModel::calibrated(1.87, 256, 0.20)
    }

    /// Effective peripheral width for an edge of `cells` unit cells.
    fn strip(&self, cells: usize) -> f64 {
        if self.periph_gamma == 1.0 {
            self.d_cnt
        } else {
            self.d_cnt * (cells as f64 / self.ref_edge).powf(self.periph_gamma - 1.0)
        }
    }

    /// Array (weight-storage) area, µm².
    pub fn array_area_um2(&self, t: Tile) -> f64 {
        self.d_unit_in * self.d_unit_out * (t.n_row * t.n_col) as f64
    }

    /// Full tile area, µm² (Eq. 2 denominator).
    pub fn tile_area_um2(&self, t: Tile) -> f64 {
        let a = self.array_area_um2(t);
        let strip_rows = self.strip(t.n_row); // DAC strip priced by rows
        let strip_cols = self.strip(t.n_col); // ADC strip priced by cols
        let p = self.d_unit_in * t.n_row as f64 * strip_cols
            + self.d_unit_out * t.n_col as f64 * strip_rows;
        let corner = strip_rows * strip_cols;
        a + p + corner
    }

    /// Tile efficiency T_eff (Eq. 1).
    pub fn efficiency(&self, t: Tile) -> f64 {
        self.array_area_um2(t) / self.tile_area_um2(t)
    }

    /// Total area for `n_tiles` tiles, mm².
    pub fn total_area_mm2(&self, n_tiles: usize, t: Tile) -> f64 {
        n_tiles as f64 * self.tile_area_um2(t) * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T256: Tile = Tile::new(256, 256);

    #[test]
    fn calibration_hits_target_efficiency() {
        let m = AreaModel::paper_default();
        let eff = m.efficiency(T256);
        assert!((eff - 0.20).abs() < 1e-9, "eff {eff}");
    }

    #[test]
    fn efficiency_scales_with_capacity() {
        // paper: "array efficiency will scale with the array tile capacity"
        let m = AreaModel::paper_default();
        let effs: Vec<f64> = (6..=13)
            .map(|k| m.efficiency(Tile::new(1 << k, 1 << k)))
            .collect();
        for w in effs.windows(2) {
            assert!(w[0] < w[1], "efficiency not increasing: {effs:?}");
        }
        assert!(effs[0] < 0.1 && *effs.last().unwrap() > 0.85);
    }

    #[test]
    fn factored_form_matches_expanded() {
        // with gamma = 1, area == (Din·n + D)(Dout·m + D)
        let m = AreaModel::paper_default();
        for t in [Tile::new(64, 64), Tile::new(512, 128), Tile::new(8192, 1024)] {
            let expanded = m.tile_area_um2(t);
            let factored = (m.d_unit_in * t.n_row as f64 + m.d_cnt)
                * (m.d_unit_out * t.n_col as f64 + m.d_cnt);
            assert!(
                (expanded - factored).abs() / factored < 1e-12,
                "{t}: {expanded} vs {factored}"
            );
        }
    }

    #[test]
    fn table6_absolute_area_ballpark() {
        // Table 6: 208 tiles @256² ≈ 239 mm² (the calibration source).
        let m = AreaModel::paper_default();
        let total = m.total_area_mm2(208, T256);
        assert!((200.0..280.0).contains(&total), "total {total} mm²");
    }

    #[test]
    fn rectangular_tiles_priced_consistently() {
        let m = AreaModel::paper_default();
        // same capacity, different aspect: rectangular pays more perimeter
        // on the long edge but the model must stay positive and finite
        let sq = m.tile_area_um2(Tile::new(512, 512));
        let rect = m.tile_area_um2(Tile::new(2048, 128));
        assert!(sq > 0.0 && rect > 0.0);
        // perimeter of 2048+128 > 512+512, so rect tile area is larger
        assert!(rect > sq);
    }

    #[test]
    fn adc_sharing_reduces_large_tile_cost() {
        let mut m = AreaModel::paper_default();
        let base = m.tile_area_um2(Tile::new(4096, 4096));
        m.periph_gamma = 0.5; // strips grow ~sqrt(edge)
        let shared = m.tile_area_um2(Tile::new(4096, 4096));
        assert!(shared < base);
        // at the reference edge the two models agree
        let at_ref = m.tile_area_um2(T256);
        m.periph_gamma = 1.0;
        assert!((at_ref - m.tile_area_um2(T256)).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "efficiency must be in (0,1)")]
    fn bad_calibration_rejected() {
        AreaModel::calibrated(1.0, 256, 1.5);
    }
}
