//! Manufacturing-yield extension (paper §5 future work: "explore the
//! impact of manufacturing yield on the optimization process, which would
//! impose additional constraints on the optimal tile array capacity").
//!
//! Poisson defect model: a tile of area `A` mm² yields with probability
//! `exp(-D0 * A)` for defect density `D0` (defects/mm²). Dead tiles must
//! be provisioned over, so the *effective* area of an `n`-tile mapping is
//! `n * A / yield(A)` — a convex penalty that grows with tile capacity and
//! pushes the optimum toward smaller arrays, exactly the constraint the
//! paper anticipates.

use super::AreaModel;
use crate::geom::Tile;
use crate::opt::SweepPoint;

/// Poisson yield model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct YieldModel {
    /// killer-defect density, defects per mm²
    pub defect_density: f64,
}

impl YieldModel {
    /// A model with `defect_density` killer defects per mm² (0 = perfect
    /// yield; panics on negative densities).
    pub fn new(defect_density: f64) -> YieldModel {
        assert!(defect_density >= 0.0, "defect density must be non-negative");
        YieldModel { defect_density }
    }

    /// Probability that one tile is functional.
    pub fn tile_yield(&self, area: &AreaModel, t: Tile) -> f64 {
        (-self.defect_density * area.tile_area_um2(t) * 1e-6).exp()
    }

    /// Expected tiles to provision for `n` good tiles.
    pub fn provisioned_tiles(&self, area: &AreaModel, t: Tile, n: usize) -> f64 {
        n as f64 / self.tile_yield(area, t)
    }

    /// Yield-adjusted total area, mm².
    pub fn effective_area_mm2(&self, area: &AreaModel, t: Tile, n: usize) -> f64 {
        self.provisioned_tiles(area, t, n) * area.tile_area_um2(t) * 1e-6
    }
}

/// Re-rank sweep points under a yield model; returns (point, effective
/// area) sorted ascending by effective area.
pub fn yield_ranked<'a>(
    points: &'a [SweepPoint],
    area: &AreaModel,
    ym: &YieldModel,
) -> Vec<(&'a SweepPoint, f64)> {
    let mut v: Vec<(&SweepPoint, f64)> = points
        .iter()
        .map(|p| (p, ym.effective_area_mm2(area, p.tile, p.n_tiles)))
        .collect();
    v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::zoo;
    use crate::opt::{self, SweepConfig};
    use crate::pack::Discipline;

    #[test]
    fn perfect_yield_at_zero_defects() {
        let area = AreaModel::paper_default();
        let ym = YieldModel::new(0.0);
        let t = Tile::new(1024, 1024);
        assert_eq!(ym.tile_yield(&area, t), 1.0);
        assert_eq!(ym.provisioned_tiles(&area, t, 10), 10.0);
    }

    #[test]
    fn yield_decreases_with_tile_area() {
        let area = AreaModel::paper_default();
        let ym = YieldModel::new(0.05);
        let y_small = ym.tile_yield(&area, Tile::new(256, 256));
        let y_large = ym.tile_yield(&area, Tile::new(4096, 4096));
        assert!(y_small > y_large);
        assert!(y_small > 0.9, "small tiles nearly always yield: {y_small}");
        assert!(y_large < 0.2, "huge tiles rarely yield at D0=0.05: {y_large}");
    }

    #[test]
    fn defects_shift_optimum_to_smaller_tiles() {
        // the §5 prediction, measured: with rising defect density the
        // yield-adjusted optimum moves to smaller arrays than the
        // defect-free optimum
        let net = zoo::resnet18();
        let area = AreaModel::paper_default();
        let pts = opt::sweep(&net, &SweepConfig::square(Discipline::Dense));
        let free = opt::optimum(&pts).unwrap();
        let harsh = YieldModel::new(0.2);
        let (best, _) = yield_ranked(&pts, &area, &harsh)[0];
        assert!(
            best.tile.capacity() < free.tile.capacity(),
            "yield-aware optimum {} should be smaller than defect-free {}",
            best.tile,
            free.tile
        );
    }

    #[test]
    fn ranking_is_ascending() {
        let net = zoo::lenet();
        let area = AreaModel::paper_default();
        let pts = opt::sweep(&net, &SweepConfig::square(Discipline::Dense));
        let ranked = yield_ranked(&pts, &area, &YieldModel::new(0.05));
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn negative_density_rejected() {
        YieldModel::new(-1.0);
    }
}
