//! `xbarlint` — repo-native static analysis for the service's
//! correctness invariants.
//!
//! Runs every rule in [`xbarmap::lint`] over the source tree and exits
//! non-zero on any finding, so CI can gate on it:
//!
//! ```text
//! cargo run --release --bin xbarlint -- --json ../BENCH_lint.json \
//!     --baseline ../BENCH_lint.json
//! ```
//!
//! Exit codes: `0` clean, `1` findings (or an allowlist that grew past
//! the `--baseline` counts), `2` usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;
use xbarmap::lint;
use xbarmap::util::cli::{usage, Args, OptSpec};
use xbarmap::util::json;

const ABOUT: &str = "static analysis for the xbarmap serving invariants (docs/STATIC_ANALYSIS.md)";

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "root",
            help: "repo root holding rust/ and docs/ (default: this checkout)",
            value: Some("DIR"),
            default: None,
        },
        OptSpec {
            name: "json",
            help: "write the BENCH-schema count report to this file",
            value: Some("FILE"),
            default: None,
        },
        OptSpec {
            name: "baseline",
            help: "fail if any lint/allow_* count exceeds this report's",
            value: Some("FILE"),
            default: None,
        },
        OptSpec { name: "quiet", help: "suppress the summary line", value: None, default: None },
    ]
}

fn main() -> ExitCode {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", usage("xbarlint", ABOUT, &[], &specs()));
        return ExitCode::SUCCESS;
    }
    match run(&raw) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("xbarlint: {e}");
            ExitCode::from(2)
        }
    }
}

fn run(raw: &[String]) -> Result<ExitCode, String> {
    let args = Args::parse(raw, &specs())?;
    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        // CARGO_MANIFEST_DIR is rust/; the repo root is one up
        None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(".."),
    };
    let report =
        lint::run(&root).map_err(|e| format!("scanning {}: {e}", root.display()))?;

    for finding in &report.findings {
        println!("{finding}");
    }

    let mut allow_regressions = 0usize;
    if let Some(path) = args.get("baseline") {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading baseline {path}: {e}"))?;
        let base = json::parse(&text).map_err(|e| format!("parsing baseline {path}: {e}"))?;
        for rule in lint::RULES {
            let now = report.allowed.get(rule).copied().unwrap_or(0);
            let was = base
                .get(&format!("lint/allow_{rule}"))
                .and_then(json::Json::as_f64)
                .unwrap_or(0.0) as u64;
            if now > was {
                allow_regressions += 1;
                println!(
                    "{rule:8} (allowlist)  lint: allow({rule}) sites grew {was} -> {now}; \
                     fix the new site or lower the baseline deliberately"
                );
            }
        }
    }

    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().pretty() + "\n")
            .map_err(|e| format!("writing {path}: {e}"))?;
    }

    if !args.flag("quiet") {
        let allows: u64 = report.allowed.values().sum();
        println!(
            "xbarlint: {} finding(s), {} allowlisted site(s), {} rule(s)",
            report.findings.len(),
            allows,
            lint::RULES.len()
        );
    }
    if report.findings.is_empty() && allow_regressions == 0 {
        Ok(ExitCode::SUCCESS)
    } else {
        Ok(ExitCode::from(1))
    }
}
