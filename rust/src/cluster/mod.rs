//! Self-healing sharded planning cluster: one front **router** process
//! that consistent-hashes requests across N supervised `serve --plans`
//! **worker** processes (`xbarmap serve --plans --cluster N`).
//!
//! The single-process service ([`crate::service`]) already contains a
//! panicking solve, but a worker that segfaults, leaks until the OOM
//! killer arrives, or wedges in a runaway allocation takes the whole
//! process with it. The cluster puts that blast radius behind a process
//! boundary: each shard is a child process on a loopback port negotiated
//! at spawn (the worker binds `:0` and announces the port on stdout), a
//! per-shard supervisor ([`supervisor`]) respawns the dead, and the
//! router replays the requests a dead shard still owed.
//!
//! **The contract is byte-identity.** For every client connection the
//! routed response stream is byte-for-byte what a single-process
//! [`crate::plan::serve_jsonl`] would have produced, faults included:
//!
//! * framing is shared code — the router reads lines through the same
//!   [`crate::service::LineReader`] the service uses, applies the same
//!   per-connection quota and in-flight admission (same frames, same
//!   wording), and delivers responses through the same re-sequencing
//!   [`Conn`] so out-of-order shard completions merge back into request
//!   order;
//! * plan frames returned by a shard are forwarded **verbatim** — never
//!   re-serialized, so float formatting cannot drift;
//! * shard error/reject frames are rebuilt with the client's own line
//!   number through the same [`wire`] constructors the service uses (a
//!   forwarder connection has its own line numbering; the client must
//!   see its own);
//! * replay is safe because planning is pure: a request re-sent to a
//!   fresh incarnation (counted in `replayed`) or solved by the router's
//!   embedded planner (degraded mode, counted in `degraded`) produces
//!   the same bytes the dead shard would have sent.
//!
//! Failover is replay-first, degrade-second: a forwarder that loses its
//! shard waits for the supervisor's respawn (bounded by
//! [`ClusterConfig::route_wait`]) and re-sends, up to
//! [`ClusterConfig::replay_budget`] attempts; past the budget — or
//! immediately while the shard's circuit breaker is open — the router
//! answers from its own in-process planner. Degraded answers skip the
//! dead shard's cache and warehouse, so they may be slower; they are
//! never different.
//!
//! Observability: in-band `stats`/`metrics` commands are answered by the
//! router with a **cluster snapshot** — live-probed per-shard counters,
//! the history of dead incarnations (so counters stay monotone across
//! respawns), and the router's own `shard_respawns` / `replayed` /
//! `degraded` counters (WIRE.md §6 defines the merge rules).

mod ring;
pub(crate) mod supervisor;

pub use ring::HashRing;

use crate::plan::{self, PlanError};
use crate::plan::client::{Client, ClientConfig};
use crate::plan::wire;
use crate::service::{self, conn::Conn, PlanCache, TenantLedger};
use crate::util::json::{self, Json};
use crate::util::mpmc::Queue;
use supervisor::Shard;

use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Capacity of one connection's per-shard forwarding lane. Small on
/// purpose: a full lane blocks the connection's reader, which is the
/// same TCP-window backpressure the single service applies via its
/// bounded queue.
const FORWARD_QUEUE: usize = 64;

/// Everything a router needs to run one cluster. Construct with
/// [`ClusterConfig::default`] and override; the supervision knobs exist
/// mostly so the chaos suites can compress minutes of failure handling
/// into milliseconds.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// router bind address, e.g. `127.0.0.1:0`
    pub addr: String,
    /// worker process count (clamped to ≥ 1)
    pub shards: usize,
    /// worker binary; `None` spawns [`std::env::current_exe`] — the
    /// router and its workers are the same binary in different roles
    pub exe: Option<PathBuf>,
    /// extra CLI flags passed through to every worker's `serve --plans`
    /// (workers/queue/cache sizing, deadline). Admission flags stay at
    /// the router: a worker quota would throttle the long-lived
    /// forwarder connections, not clients.
    pub worker_args: Vec<String>,
    /// warehouse **root**: shard `i` opens `root/shard-NN` (its own
    /// single-writer lock). Pre-shard with `warehouse precompute
    /// --cluster N`, which partitions by the same [`HashRing`].
    pub warehouse: Option<PathBuf>,
    /// per-connection request quota, enforced at the router (0 = off)
    pub per_conn_quota: usize,
    /// per-tenant request budget, enforced **only at the router** — the
    /// one place that sees every connection of a tenant. Workers never
    /// get a ledger of their own (forwarded requests would be double
    /// metered), matching how the other admission flags stay router-side
    pub tenant_quota: u64,
    /// shared secret for the `recalibrate` admin verb. The router
    /// authenticates the command, then fans the client's verbatim line
    /// out to every live shard — so the same token is also handed to the
    /// workers via `--admin-token` at spawn
    pub admin_token: Option<String>,
    /// cluster-wide in-flight admission cap at the router (0 = off)
    pub max_inflight: usize,
    /// solve budget for **degraded** local solves; forwarded requests
    /// use the deadline the workers were configured with
    pub deadline: Option<Duration>,
    /// overwrite this file with the aggregated metrics snapshot
    pub metrics_out: Option<PathBuf>,
    /// how often to overwrite `metrics_out`
    pub metrics_interval: Duration,
    /// how long a spawned worker gets to announce its port
    pub spawn_timeout: Duration,
    /// gap between liveness probes of each worker
    pub probe_interval: Duration,
    /// per-probe connect/read budget
    pub probe_timeout: Duration,
    /// consecutive missed probes before a worker is declared hung and
    /// killed — generous by default, because probes share the worker's
    /// request queue and a long legitimate solve answers late
    pub probe_misses: u32,
    /// base of the capped exponential respawn backoff
    pub respawn_backoff_base: Duration,
    /// backoff ceiling
    pub respawn_backoff_cap: Duration,
    /// consecutive stillborn incarnations (died before a healthy probe)
    /// that open the shard's circuit breaker
    pub breaker_threshold: u32,
    /// how long an open breaker parks before a half-open spawn attempt
    pub breaker_cooldown: Duration,
    /// failed forward attempts per request before degrading to the
    /// router's embedded planner
    pub replay_budget: u32,
    /// per-attempt wait for the owning shard to come (back) up
    pub route_wait: Duration,
    /// forwarder read budget per roundtrip — effectively the longest
    /// solve the cluster tolerates before treating the shard as lost
    pub forward_read_timeout: Duration,
    /// polite-exit budget per worker at shutdown before SIGKILL
    pub drain_timeout: Duration,
    /// trip shutdown on SIGINT/SIGTERM (the CLI sets this; tests don't)
    pub watch_sigint: bool,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            addr: "127.0.0.1:7878".into(),
            shards: 2,
            exe: None,
            worker_args: Vec::new(),
            warehouse: None,
            per_conn_quota: 0,
            tenant_quota: 0,
            admin_token: None,
            max_inflight: 0,
            deadline: None,
            metrics_out: None,
            metrics_interval: Duration::from_secs(10),
            spawn_timeout: Duration::from_secs(10),
            probe_interval: Duration::from_secs(1),
            probe_timeout: Duration::from_secs(3),
            probe_misses: 4,
            respawn_backoff_base: Duration::from_millis(50),
            respawn_backoff_cap: Duration::from_secs(5),
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_secs(10),
            replay_budget: 3,
            route_wait: Duration::from_secs(5),
            forward_read_timeout: Duration::from_secs(600),
            drain_timeout: Duration::from_secs(10),
            watch_sigint: false,
        }
    }
}

/// The shard subdirectory a cluster of any size agrees on: shard `i` of
/// warehouse root `root` lives at `root/shard-NN`. Shared with
/// `warehouse precompute --cluster` so pre-sharded stores land where the
/// workers will look.
pub fn shard_warehouse_dir(root: &Path, shard: usize) -> PathBuf {
    root.join(format!("shard-{shard:02}"))
}

/// The router's own counters — everything not observable from a shard.
#[derive(Default)]
pub(crate) struct RouterStats {
    connections: u64,
    /// plan responses produced by the embedded planner (degraded mode)
    local_served: u64,
    /// error frames the router emitted itself (parse errors, command
    /// errors, rejects, degraded failures)
    local_errors: u64,
    /// degraded solves that hit the local deadline
    local_timeouts: u64,
    /// degraded solves that panicked (contained, like a worker's)
    local_panics: u64,
    rejected_internal: u64,
    rejected_over_quota: u64,
    rejected_over_inflight: u64,
    shard_respawns: u64,
    replayed: u64,
    degraded: u64,
    /// tenant-budget refusals plus unauthorized `recalibrate` attempts,
    /// both policy refusals the router issues itself
    tenant_rejects: u64,
}

/// State shared by the accept loop, connection readers, forwarders,
/// supervisors and the metrics writer.
pub(crate) struct ClusterShared {
    pub(crate) cfg: ClusterConfig,
    ring: HashRing,
    pub(crate) shards: Vec<Shard>,
    shutdown: AtomicBool,
    /// set only after every owed response has gone out: supervisors keep
    /// workers alive through the drain because replay needs them
    stop_workers: AtomicBool,
    sigint: Option<&'static AtomicBool>,
    stats: Mutex<RouterStats>,
    /// requests admitted by the router and not yet answered
    inflight: AtomicUsize,
    /// per-tenant budgets, metered once at the router (workers get none)
    tenants: TenantLedger,
    started: Instant,
}

impl ClusterShared {
    fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
            || self.sigint.map(|f| f.load(Ordering::SeqCst)).unwrap_or(false)
    }

    pub(crate) fn workers_stopped(&self) -> bool {
        self.stop_workers.load(Ordering::SeqCst)
    }

    pub(crate) fn lock_stats(&self) -> MutexGuard<'_, RouterStats> {
        self.stats.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn note_reject(&self, kind: wire::RejectKind) {
        let mut r = self.lock_stats();
        r.local_errors += 1;
        match kind {
            wire::RejectKind::OverQuota => r.rejected_over_quota += 1,
            wire::RejectKind::OverInflight => r.rejected_over_inflight += 1,
            wire::RejectKind::Internal => r.rejected_internal += 1,
            wire::RejectKind::Deadline => r.local_timeouts += 1,
            wire::RejectKind::Unauthorized => r.tenant_rejects += 1,
        }
    }

    /// Count one tenant-budget refusal — same split as the service:
    /// `over-quota` on the wire, `tenant_rejects` in the counters, so
    /// re-dialing tenants and chatty sockets stay distinguishable.
    fn note_tenant_reject(&self) {
        let mut r = self.lock_stats();
        r.local_errors += 1;
        r.tenant_rejects += 1;
    }

    /// The cluster-wide snapshot: every shard live-probed (falling back
    /// to its folded history when dead), summed per WIRE.md §6's merge
    /// rules, plus the router's own counters.
    fn aggregate_metrics(&self) -> wire::MetricsSnapshot {
        let mut agg = wire::MetricsSnapshot::default();
        for shard in &self.shards {
            let m = shard.fresh(self.cfg.probe_timeout);
            supervisor::fold_counters(&mut agg, &m);
            supervisor::fold_gauges(&mut agg, &m);
        }
        let r = self.lock_stats();
        let s = &mut agg.stats;
        // client-facing connections only: the folded shard figure counts
        // forwarder and probe sockets, which are plumbing, so it is
        // replaced rather than added to
        s.connections = r.connections;
        s.served += r.local_served;
        s.errors += r.local_errors;
        s.timeouts += r.local_timeouts;
        s.panics += r.local_panics;
        s.rejected_internal += r.rejected_internal;
        s.shard_respawns = r.shard_respawns;
        s.replayed = r.replayed;
        s.degraded = r.degraded;
        // metering is router-only, but the shards' (normally zero)
        // counters still fold in so the merge rule has no special case
        s.tenant_rejects += r.tenant_rejects;
        agg.rejected_over_quota += r.rejected_over_quota;
        agg.rejected_over_inflight += r.rejected_over_inflight;
        drop(r);
        // a forwarded request is in flight at the router *and* inside its
        // shard; report the router's view (admitted, unanswered) instead
        // of double counting
        agg.inflight = self.inflight.load(Ordering::SeqCst) as u64;
        agg.uptime_s = self.started.elapsed().as_secs_f64();
        agg
    }

    fn aggregate_stats(&self) -> wire::StatsSnapshot {
        self.aggregate_metrics().stats
    }
}

/// One admitted, decoded request travelling a connection's per-shard
/// forwarding lane.
struct FwdJob {
    /// response slot in the connection's ordering
    seq: usize,
    /// the client's physical line number, restamped onto error frames
    line_no: usize,
    /// the raw request line, forwarded verbatim
    text: String,
    /// the decoded request when routing needed the JSON tree (the byte
    /// scanner fell back); None when the scanner routed the line, in
    /// which case the degraded local solve — the only consumer — parses
    /// `text` on demand. The happy path never builds a tree either way.
    req: Option<plan::MapRequest>,
}

/// A sharded planning router. Lifecycle mirrors [`crate::service::Service`]:
/// [`Cluster::bind`], then [`Cluster::run`] on a thread of its own, with a
/// [`ClusterHandle`] for control.
pub struct Cluster {
    listener: TcpListener,
    shared: Arc<ClusterShared>,
}

/// Remote control for a running [`Cluster`]: trip shutdown, read the
/// aggregated snapshots, inject faults.
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<ClusterShared>,
}

impl ClusterHandle {
    /// Begin graceful shutdown: stop accepting, drain every owed
    /// response (replaying or degrading as needed), then terminate the
    /// workers.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// The aggregated cluster counters (same numbers as in-band `stats`).
    pub fn stats(&self) -> wire::StatsSnapshot {
        self.shared.aggregate_stats()
    }

    /// The aggregated observability snapshot (same as in-band `metrics`).
    pub fn metrics(&self) -> wire::MetricsSnapshot {
        self.shared.aggregate_metrics()
    }

    /// SIGKILL shard `shard`'s current worker — the chaos suites' fault
    /// injector, exercising exactly the crash path production takes. A
    /// no-op between incarnations.
    pub fn kill_shard(&self, shard: usize) {
        let pid = self.shared.shards[shard].pid();
        if pid != 0 {
            crate::util::proc::force_kill(pid);
        }
    }
}

impl Cluster {
    /// Bind the router's listener. Workers are spawned by [`Cluster::run`].
    pub fn bind(cfg: ClusterConfig) -> std::io::Result<Cluster> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let shards = cfg.shards.max(1);
        Ok(Cluster {
            listener,
            shared: Arc::new(ClusterShared {
                ring: HashRing::for_cluster(shards),
                shards: (0..shards).map(|_| Shard::new()).collect(),
                shutdown: AtomicBool::new(false),
                stop_workers: AtomicBool::new(false),
                sigint: if cfg.watch_sigint { Some(service::sigint_flag()) } else { None },
                stats: Mutex::new(RouterStats::default()),
                inflight: AtomicUsize::new(0),
                tenants: TenantLedger::new(cfg.tenant_quota),
                started: Instant::now(),
                cfg,
            }),
        })
    }

    /// The bound address — read this after binding to `:0`.
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A [`ClusterHandle`] for control while [`Cluster::run`] blocks.
    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { shared: Arc::clone(&self.shared) }
    }

    /// Serve until shutdown, then drain and return the final aggregated
    /// stats. Blocks the calling thread; supervisors, connection readers
    /// and forwarders run on their own threads.
    pub fn run(self) -> std::io::Result<wire::StatsSnapshot> {
        let shared = self.shared;
        let mut sups = Vec::with_capacity(shared.shards.len());
        for i in 0..shared.shards.len() {
            let sh = Arc::clone(&shared);
            sups.push(std::thread::spawn(move || supervisor::run(&sh, i)));
        }
        let metrics_writer = shared.cfg.metrics_out.clone().map(|path| {
            let sh = Arc::clone(&shared);
            std::thread::spawn(move || {
                let mut last = Instant::now();
                while !sh.is_shutdown() {
                    std::thread::sleep(service::POLL);
                    if last.elapsed() >= sh.cfg.metrics_interval {
                        let _ = service::write_metrics_file(&path, &sh.aggregate_metrics());
                        last = Instant::now();
                    }
                }
            })
        });
        let fatal = |shared: &Arc<ClusterShared>, sups: Vec<std::thread::JoinHandle<()>>| {
            // same discipline as the service's fatal accept arm: never
            // leave supervisors (and their children) running behind a
            // router that stopped serving
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.stop_workers.store(true, Ordering::SeqCst);
            for s in sups {
                let _ = s.join();
            }
        };
        if let Err(e) = self.listener.set_nonblocking(true) {
            fatal(&shared, sups);
            return Err(e);
        }
        let mut readers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !shared.is_shutdown() {
            // reap finished readers each iteration (same rationale as the
            // service: the busy path never reaches an idle branch)
            let mut i = 0;
            while i < readers.len() {
                if readers[i].is_finished() {
                    let _ = readers.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    shared.lock_stats().connections += 1;
                    let _ = stream.set_nodelay(true);
                    let Ok(writer) = stream.try_clone() else { continue };
                    let _ = writer.set_write_timeout(Some(service::WRITE_TIMEOUT));
                    let sh = Arc::clone(&shared);
                    readers.push(std::thread::spawn(move || {
                        read_client(&sh, stream, Arc::new(Conn::new(writer)));
                    }));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(service::POLL);
                }
                Err(e) => {
                    for r in readers {
                        let _ = r.join();
                    }
                    fatal(&shared, sups);
                    return Err(e);
                }
            }
        }
        // Drain. Readers stop feeding within one poll and join their
        // forwarders, which finish every owed response — replaying onto
        // respawned shards or degrading locally, so termination is
        // bounded. Workers are stopped only after in-flight hits zero:
        // stopping them earlier would turn replays into degrades.
        for r in readers {
            let _ = r.join();
        }
        while shared.inflight.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(10));
        }
        shared.stop_workers.store(true, Ordering::SeqCst);
        for s in sups {
            let _ = s.join();
        }
        if let Some(w) = metrics_writer {
            let _ = w.join();
        }
        if let Some(path) = &shared.cfg.metrics_out {
            // final snapshot after the drain — the supervisors took a
            // last probe of each worker before terminating it, so this
            // reflects every response the cluster ever wrote
            let _ = service::write_metrics_file(path, &shared.aggregate_metrics());
        }
        Ok(shared.aggregate_stats())
    }
}

/// Read one client connection, mirroring the service's reader line for
/// line: same [`service::LineReader`] framing, same quota/admission
/// frames and wording, same sequencing through [`Conn`]. Commands and
/// undecodable lines are answered by the router itself; decodable plan
/// requests travel to their owning shard over a lazily created
/// per-(connection, shard) forwarding lane, whose dedicated forwarder
/// preserves that shard's FIFO order while [`Conn`] restores the global
/// request order across shards.
fn read_client(shared: &Arc<ClusterShared>, stream: TcpStream, conn: Arc<Conn>) {
    let mut lines = service::LineReader::new(stream);
    let mut seq = 0usize;
    let mut line_no = 0usize;
    let mut lanes: Vec<Option<Arc<Queue<FwdJob>>>> = (0..shared.shards.len()).map(|_| None).collect();
    let mut forwarders: Vec<std::thread::JoinHandle<()>> = Vec::new();
    // answered with a terminal frame: drain the client's backlog so the
    // kernel doesn't reset the socket under the owed responses
    let mut terminal = false;
    loop {
        let text = match lines.next(|| shared.is_shutdown()) {
            service::NextLine::End | service::NextLine::Abort => break,
            service::NextLine::Oversized => {
                line_no += 1;
                shared.lock_stats().local_errors += 1;
                let e = PlanError(format!(
                    "request line exceeds {} bytes",
                    service::MAX_LINE_BYTES
                ));
                conn.deliver(seq, wire::error_frame(line_no, &e).dumps());
                seq += 1;
                terminal = true;
                break;
            }
            service::NextLine::Line(text) => text,
        };
        line_no += 1;
        if text.is_empty() {
            continue;
        }
        if shared.cfg.per_conn_quota > 0 && seq >= shared.cfg.per_conn_quota {
            shared.note_reject(wire::RejectKind::OverQuota);
            let e = PlanError(format!(
                "connection exceeded its {}-request quota",
                shared.cfg.per_conn_quota
            ));
            conn.deliver(seq, wire::reject_frame(line_no, wire::RejectKind::OverQuota, &e).dumps());
            seq += 1;
            terminal = true;
            break;
        }
        // same admission rules — and command exemption — as the service,
        // decided by the same byte scanner with the same sniff fallback
        let scanned = wire::scan::scan(&text);
        let looks_like_cmd = match &scanned {
            wire::scan::Scan::Command => true,
            wire::scan::Scan::Request(_) => false,
            wire::scan::Scan::Fallback => {
                text.contains("\"cmd\"") && !text.contains("\"net\"")
            }
        };
        let admitted = shared.inflight.fetch_add(1, Ordering::SeqCst);
        if shared.cfg.max_inflight > 0 && admitted >= shared.cfg.max_inflight && !looks_like_cmd {
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            shared.note_reject(wire::RejectKind::OverInflight);
            let e = PlanError(format!(
                "service at its {}-request in-flight cap, retry later",
                shared.cfg.max_inflight
            ));
            conn.deliver(
                seq,
                wire::reject_frame(line_no, wire::RejectKind::OverInflight, &e).dumps(),
            );
            seq += 1;
            continue;
        }
        // The router answers commands, malformed lines, and policy
        // refusals itself — a shard's opinion would add nothing, and
        // commands must aggregate the whole cluster anyway; only plan
        // requests travel. A scanned request routes by the scanner's
        // candidate key without building a JSON tree: for a canonical
        // line it equals the canonical key, and a non-canonical line
        // merely lands on a different shard — a cache-locality cost,
        // never a correctness one, since every shard plans every request
        // identically. Tenant metering happens here for both shapes,
        // once, at the only tier that sees all of a tenant's connections.
        let mut forward: Option<(usize, Option<plan::MapRequest>)> = None;
        let local = match scanned {
            wire::scan::Scan::Request(s) => {
                if !shared.tenants.try_charge(&s.id) {
                    Some(tenant_reject(shared, line_no, &s.id))
                } else {
                    forward = Some((shared.ring.owner(&s.key), None));
                    None
                }
            }
            _ => match json::parse(&text) {
                // same message plan::parse_request_line produces, so
                // error frames stay byte-identical to serve_jsonl's
                Err(e) => {
                    Some(error_local(shared, line_no, &PlanError(format!("parse request: {e}"))))
                }
                Ok(j) => {
                    if j.get("cmd").is_some() && j.get("net").is_none() {
                        Some(respond_cmd(shared, &j, &text, line_no))
                    } else {
                        match plan::MapRequest::from_json(&j) {
                            Err(e) => Some(error_local(shared, line_no, &e)),
                            Ok(req) if !shared.tenants.try_charge(&req.id) => {
                                Some(tenant_reject(shared, line_no, &req.id))
                            }
                            Ok(req) => {
                                forward =
                                    Some((shared.ring.owner(&PlanCache::key(&req)), Some(req)));
                                None
                            }
                        }
                    }
                }
            },
        };
        if let Some((owner, req)) = forward {
            let lane = lanes[owner].get_or_insert_with(|| {
                let q = Arc::new(Queue::bounded(FORWARD_QUEUE));
                let (sh, lane, cn) = (Arc::clone(shared), Arc::clone(&q), Arc::clone(&conn));
                forwarders.push(std::thread::spawn(move || {
                    run_forwarder(&sh, owner, &lane, &cn);
                }));
                q
            });
            // blocks while the lane is full — this is the backpressure
            // path, same as the service's bounded queue
            if lane.push(FwdJob { seq, line_no, text, req }).is_err() {
                // lane closed: cannot happen while the reader holds it
                // open, but mirror the service's give-back discipline
                shared.inflight.fetch_sub(1, Ordering::SeqCst);
                break;
            }
        } else if let Some(response) = local {
            conn.deliver(seq, response);
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
        }
        seq += 1;
    }
    conn.finish_input(seq);
    for lane in lanes.iter().flatten() {
        lane.close();
    }
    for f in forwarders {
        let _ = f.join();
    }
    if terminal {
        service::drain_discard(&|| shared.is_shutdown(), lines.reader_mut());
    }
}

/// Count and build a router-emitted error frame (the cluster counterpart
/// of the service's `error_response`).
fn error_local(shared: &ClusterShared, line_no: usize, e: &PlanError) -> String {
    shared.lock_stats().local_errors += 1;
    wire::error_frame(line_no, e).dumps()
}

/// Count and build a tenant-budget refusal — identical wording to the
/// service's, because a client must not be able to tell which tier
/// refused it.
fn tenant_reject(shared: &ClusterShared, line_no: usize, id: &str) -> String {
    shared.note_tenant_reject();
    let e = PlanError(format!(
        "tenant '{id}' exceeded its {}-request quota",
        shared.tenants.quota()
    ));
    wire::reject_frame(line_no, wire::RejectKind::OverQuota, &e).dumps()
}

/// Answer an in-band command with the **cluster** snapshot — same
/// version rule, command set, and error wording as the service's
/// `respond_cmd`, different numbers behind them. `text` is the client's
/// verbatim line, which `recalibrate` fans out to the shards unmodified
/// so the workers authenticate the same token the router did.
fn respond_cmd(shared: &ClusterShared, j: &Json, text: &str, line_no: usize) -> String {
    let o = match j.as_obj() {
        Some(o) => o,
        None => {
            return error_local(shared, line_no, &PlanError("command must be a JSON object".into()))
        }
    };
    if let Err(e) = wire::check_version(o, "command") {
        return error_local(shared, line_no, &e);
    }
    match o.get("cmd").and_then(Json::as_str) {
        Some("stats") => wire::stats_frame(&shared.aggregate_stats()).dumps(),
        Some("metrics") => wire::metrics_frame(&shared.aggregate_metrics()).dumps(),
        Some("recalibrate") => {
            let authorized = match &shared.cfg.admin_token {
                Some(t) => o.get("token").and_then(Json::as_str) == Some(t.as_str()),
                None => false,
            };
            if !authorized {
                shared.note_reject(wire::RejectKind::Unauthorized);
                let e = PlanError("recalibrate requires a valid admin token".into());
                return wire::reject_frame(line_no, wire::RejectKind::Unauthorized, &e).dumps();
            }
            recalibrate_cluster(shared, text).dumps()
        }
        other => error_local(
            shared,
            line_no,
            &PlanError(format!(
                "unknown command '{}' (try \"stats\", \"metrics\" or \"recalibrate\")",
                other.unwrap_or("?")
            )),
        ),
    }
}

/// Fan an authenticated `recalibrate` out to every live shard — the
/// client's line verbatim, so each worker re-authenticates the same
/// shared secret it was spawned with — and aggregate the acks: the
/// reported `cache_entries` is the sum of what every reachable shard
/// flushed. A dead or unresponsive shard is skipped; its LRU dies with
/// its process anyway, so there is nothing stale left to flush there.
fn recalibrate_cluster(shared: &ClusterShared, text: &str) -> Json {
    let mut flushed = 0u64;
    for (i, shard) in shared.shards.iter().enumerate() {
        let Some((addr, _epoch)) = shard.route(0, shared.cfg.probe_timeout) else {
            continue;
        };
        let mut client = Client::with_config(
            addr,
            ClientConfig {
                connect_timeout: shared.cfg.probe_timeout,
                read_timeout: shared.cfg.probe_timeout,
                retries: 1,
                backoff_base: Duration::from_millis(5),
                backoff_cap: Duration::from_millis(100),
                seed: 0xca_11b ^ i as u64,
            },
        );
        let Ok(ack) = client.roundtrip_line(text) else { continue };
        flushed += json::parse(&ack)
            .ok()
            .and_then(|a| {
                a.get("recalibrated")
                    .and_then(|r| r.get("cache_entries"))
                    .and_then(Json::as_f64)
            })
            .unwrap_or(0.0) as u64;
    }
    wire::recalibrate_frame(flushed)
}

/// Drain one connection's lane to one shard, delivering each response
/// into the connection's sequence slot.
fn run_forwarder(shared: &Arc<ClusterShared>, owner: usize, lane: &Queue<FwdJob>, conn: &Conn) {
    // the persistent shard connection, pinned to the incarnation (epoch)
    // it was dialed against so a respawn forces a fresh dial
    let mut slot: Option<(u64, Client)> = None;
    while let Some(job) = lane.pop() {
        let seq = job.seq;
        let response = forward_one(shared, owner, &mut slot, &job);
        conn.deliver(seq, response);
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Produce one job's response: forward to the owning shard, replaying
/// onto fresh incarnations after a death, degrading to the embedded
/// planner past the budget or while the breaker is open.
fn forward_one(
    shared: &ClusterShared,
    owner: usize,
    slot: &mut Option<(u64, Client)>,
    job: &FwdJob,
) -> String {
    let shard = &shared.shards[owner];
    let mut failures = 0u32;
    // a failed attempt pins the epoch it failed against, so the next
    // attempt waits for a *newer* incarnation instead of hammering the
    // same dead socket until the budget burns out
    let mut min_epoch = 0u64;
    while failures < shared.cfg.replay_budget {
        let Some((addr, epoch)) = shard.route(min_epoch, shared.cfg.route_wait) else {
            break; // breaker open, stopping, or nothing came up in time
        };
        if slot.as_ref().map(|(e, _)| *e) != Some(epoch) {
            *slot = Some((epoch, forwarder_client(&shared.cfg, addr, owner)));
        }
        let Some((_, client)) = slot.as_mut() else {
            // defensive: the slot was populated above — treat a miss as
            // one failed attempt against this epoch rather than panicking
            failures += 1;
            min_epoch = epoch + 1;
            continue;
        };
        match client.roundtrip_line(&job.text) {
            Ok(response) => {
                if failures > 0 {
                    // the incarnation that owed this response died; a
                    // fresh one has now answered it
                    shared.lock_stats().replayed += 1;
                }
                return restamp(&response, job.line_no);
            }
            Err(_) => {
                *slot = None;
                failures += 1;
                min_epoch = epoch + 1;
            }
        }
    }
    shared.lock_stats().degraded += 1;
    solve_degraded(shared, job)
}

/// The forwarder's client to one shard incarnation. One internal retry
/// absorbs transient dial blips against a live shard; real failover
/// (fresh incarnations, degradation) belongs to [`forward_one`]'s loop.
/// The read budget is long on purpose: a slow solve is not a dead shard,
/// and hang detection is the supervisor's job — its kill resets the TCP
/// connection, which wakes this client with an error.
fn forwarder_client(cfg: &ClusterConfig, addr: SocketAddr, owner: usize) -> Client {
    Client::with_config(
        addr,
        ClientConfig {
            connect_timeout: Duration::from_secs(2),
            read_timeout: cfg.forward_read_timeout,
            retries: 1,
            backoff_base: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(100),
            seed: 0xf0_5eed ^ owner as u64,
        },
    )
}

/// Map a shard's response to what the client must see. Plan frames pass
/// **verbatim** — re-serializing risks float round-trip drift, and byte
/// identity is the contract. Error and reject frames are rebuilt through
/// the same [`wire`] constructors the service uses, carrying the
/// client's own physical line number instead of the forwarder
/// connection's.
fn restamp(response: &str, line_no: usize) -> String {
    let Ok(j) = json::parse(response) else {
        return response.to_string();
    };
    let Some(msg) = j.get("error").and_then(Json::as_str) else {
        return response.to_string();
    };
    let e = PlanError(msg.to_string());
    match j.get("reject").and_then(Json::as_str) {
        None => wire::error_frame(line_no, &e).dumps(),
        Some(token) => match reject_kind(token) {
            Some(kind) => wire::reject_frame(line_no, kind, &e).dumps(),
            // a token this build doesn't know: forward untouched rather
            // than guess (wrong line number beats a dropped reject type)
            None => response.to_string(),
        },
    }
}

/// The inverse of [`wire::RejectKind::token`].
fn reject_kind(token: &str) -> Option<wire::RejectKind> {
    Some(match token {
        "over-quota" => wire::RejectKind::OverQuota,
        "over-inflight" => wire::RejectKind::OverInflight,
        "internal" => wire::RejectKind::Internal,
        "deadline" => wire::RejectKind::Deadline,
        "unauthorized" => wire::RejectKind::Unauthorized,
        _ => return None,
    })
}

/// Answer a request from the router's own embedded planner — the
/// degraded path. Byte-identical to a shard's answer because planning is
/// a pure function of the canonical request; slower, because the dead
/// shard's cache and warehouse don't participate. Mirrors the worker's
/// solve exactly: same deadline arming, same panic probe, same panic
/// containment and frame wording.
fn solve_degraded(shared: &ClusterShared, job: &FwdJob) -> String {
    use crate::util::deadline::Deadline;
    let budget = shared.cfg.deadline;
    // a scanned job carries no tree — decode on demand, producing the
    // same error frame (and error count) a shard's full parse would have
    let req = match &job.req {
        Some(req) => req.clone(),
        None => match plan::parse_request_line(&job.text) {
            Ok(req) => req,
            Err(e) => return error_local(shared, job.line_no, &e),
        },
    };
    let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        if req.id == service::PANIC_PROBE_ID {
            // the worker-side live-fire hook, mirrored so degraded mode
            // answers it with the same typed internal reject
            // lint: allow(panic) deliberate live-fire probe; contained by
            // the catch_unwind wrapping this closure
            panic!("panic probe: request id {}", service::PANIC_PROBE_ID);
        }
        let deadline = match budget {
            Some(budget) => Deadline::after(budget),
            None => Deadline::NONE,
        };
        req.build().and_then(|p| p.plan_with_deadline(deadline))
    }));
    match solved {
        Ok(Ok(plan)) => {
            shared.lock_stats().local_served += 1;
            plan.to_json().dumps()
        }
        Ok(Err(e)) if e.is_deadline() => {
            shared.note_reject(wire::RejectKind::Deadline);
            wire::reject_frame(job.line_no, wire::RejectKind::Deadline, &e).dumps()
        }
        Ok(Err(e)) => error_local(shared, job.line_no, &e),
        Err(payload) => {
            shared.lock_stats().local_panics += 1;
            shared.note_reject(wire::RejectKind::Internal);
            let e = PlanError(format!(
                "planner panicked: {}",
                service::panic_message(payload.as_ref())
            ));
            wire::reject_frame(job.line_no, wire::RejectKind::Internal, &e).dumps()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn restamp_leaves_plan_frames_untouched() {
        let plan = r#"{"v":1,"id":"x","bins":[{"rows":64,"cols":64}],"weird":1.000000000000001}"#;
        assert_eq!(restamp(plan, 42), plan);
    }

    #[test]
    fn restamp_rewrites_the_line_number_of_error_frames() {
        let shard_frame = wire::error_frame(1, &PlanError("parse request: boom".into())).dumps();
        let restamped = restamp(&shard_frame, 7);
        let expect = wire::error_frame(7, &PlanError("parse request: boom".into())).dumps();
        assert_eq!(restamped, expect);
    }

    #[test]
    fn restamp_preserves_typed_reject_tokens() {
        for kind in [
            wire::RejectKind::OverQuota,
            wire::RejectKind::OverInflight,
            wire::RejectKind::Internal,
            wire::RejectKind::Deadline,
            wire::RejectKind::Unauthorized,
        ] {
            let shard_frame = wire::reject_frame(3, kind, &PlanError("why".into())).dumps();
            let expect = wire::reject_frame(9, kind, &PlanError("why".into())).dumps();
            assert_eq!(restamp(&shard_frame, 9), expect, "token {:?}", kind.token());
        }
    }

    #[test]
    fn shard_warehouse_dirs_are_stable_and_distinct() {
        let root = Path::new("/tmp/wh");
        assert_eq!(shard_warehouse_dir(root, 0), Path::new("/tmp/wh/shard-00"));
        assert_eq!(shard_warehouse_dir(root, 7), Path::new("/tmp/wh/shard-07"));
        assert_eq!(shard_warehouse_dir(root, 12), Path::new("/tmp/wh/shard-12"));
    }
}
