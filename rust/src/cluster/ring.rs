//! Consistent-hash ring over shard indices.
//!
//! The router keys every decodable request by its canonical form
//! ([`crate::service::PlanCache::key`]) and must send identical requests
//! to the same shard: that is what keeps each shard's plan cache and
//! warehouse tier effective, and what keeps single-flight coalescing
//! intact — a herd of identical requests lands on one shard and collapses
//! to one solve there. A plain `hash % N` would satisfy that, but
//! re-sharding (N → N+1) would move nearly every key to a new owner and
//! cold-start every warehouse at once. The classic fix is a ring of
//! virtual nodes: each shard owns [`VNODES`] points on a 64-bit circle
//! and a key belongs to the first point clockwise from its hash, so
//! growing the cluster moves only about 1/(N+1) of the keyspace and the
//! rest of the warm warehouses stay warm.
//!
//! The ring layout is a *wire-adjacent* contract: `xbarmap warehouse
//! precompute --cluster N` pre-shards a warehouse directory with the same
//! ring a router later routes with, so the hash must be stable across
//! builds and platforms — hence hand-rolled FNV-1a rather than
//! [`std::collections::hash_map::DefaultHasher`], whose output is
//! deliberately unstable.

/// Virtual nodes per shard: enough that each shard's keyspace share
/// concentrates near 1/N (with 64 points per shard the max/min owner
/// imbalance stays modest) while the whole ring remains a few KB and a
/// lookup one binary search.
const VNODES: usize = 64;

/// FNV-1a, 64-bit: tiny, allocation-free, and stable everywhere.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// A consistent-hash ring mapping canonical request keys to shard
/// indices `0..shards`.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(point, shard)` sorted by point; [`HashRing::owner`] binary-
    /// searches it and wraps at the top of the circle
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// A ring over `shards` shard indices with `vnodes` points each.
    /// Exposed for tests that study balance at other densities; cluster
    /// components use [`HashRing::for_cluster`] so they agree on one
    /// layout.
    pub fn new(shards: usize, vnodes: usize) -> HashRing {
        let shards = shards.max(1);
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(shards * vnodes);
        for s in 0..shards {
            for v in 0..vnodes {
                points.push((fnv1a(format!("shard-{s}-vnode-{v}").as_bytes()), s));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    /// The one ring layout every cluster component agrees on for a given
    /// shard count — the router and `warehouse precompute --cluster` must
    /// both construct their ring here or pre-sharded stores would land on
    /// the wrong workers.
    pub fn for_cluster(shards: usize) -> HashRing {
        HashRing::new(shards, VNODES)
    }

    /// The shard that owns `key`: the first ring point at or clockwise
    /// past the key's hash, wrapping at the top of the circle.
    pub fn owner(&self, key: &str) -> usize {
        let h = fnv1a(key.as_bytes());
        let i = self.points.partition_point(|&(p, _)| p < h);
        self.points[i % self.points.len()].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("canonical-request-key-{i}")).collect()
    }

    #[test]
    fn every_shard_owns_a_reasonable_share() {
        let ring = HashRing::for_cluster(4);
        let mut counts = [0usize; 4];
        for k in keys(4000) {
            counts[ring.owner(&k)] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            // perfect balance is 1000; vnode placement is hash-random, so
            // accept a wide band — the failure mode this guards against
            // is a shard owning (almost) nothing or (almost) everything
            assert!(
                (400..=1800).contains(&c),
                "shard {s} owns {c} of 4000 keys — ring badly imbalanced"
            );
        }
    }

    #[test]
    fn growing_the_ring_moves_only_a_minority_of_keys() {
        let before = HashRing::for_cluster(3);
        let after = HashRing::for_cluster(4);
        let ks = keys(4000);
        let moved = ks.iter().filter(|k| before.owner(k) != after.owner(k)).count();
        // consistent hashing's whole point: ~1/4 of keys move to the new
        // shard, the rest keep their owner (mod-N would move ~3/4)
        assert!(
            moved < 2000,
            "{moved} of 4000 keys changed owner going 3 → 4 shards"
        );
        assert!(moved > 0, "a new shard must take over some keys");
    }

    #[test]
    fn ownership_is_deterministic_and_in_range() {
        let a = HashRing::for_cluster(5);
        let b = HashRing::for_cluster(5);
        for k in keys(500) {
            let owner = a.owner(&k);
            assert_eq!(owner, b.owner(&k), "two rings over 5 shards must agree");
            assert!(owner < 5);
        }
    }

    #[test]
    fn a_single_shard_ring_owns_everything() {
        let ring = HashRing::for_cluster(1);
        for k in keys(64) {
            assert_eq!(ring.owner(&k), 0);
        }
    }
}
