//! Per-shard worker supervision: spawn, probe, respawn, circuit-break.
//!
//! Each shard gets one supervisor thread that owns its worker process for
//! the shard's whole life. The supervisor spawns `serve --plans
//! --announce` via [`proc::spawn_announced`] (the worker binds `:0` and
//! announces the port it got), publishes the address to the routing state
//! the forwarders wait on, then watches two signals:
//!
//! * **exit** — [`std::process::Child::try_wait`] polled every
//!   [`MONITOR_POLL`]: a crashed worker is detected within ~10 ms, which
//!   bounds how long replayed requests wait for a fresh incarnation;
//! * **liveness** — a periodic in-band `metrics` command. A worker that
//!   still holds its pid but stops answering for
//!   [`super::ClusterConfig::probe_misses`] consecutive probes is treated
//!   exactly like a crash: killed, reaped, respawned. The threshold is
//!   deliberately generous because probes share the worker's request
//!   queue — a worker deep in one long legitimate solve answers late,
//!   and late must not read as dead.
//!
//! The probe doubles as the metrics feed: every successful probe caches
//! the worker's [`wire::MetricsSnapshot`], and when an incarnation dies
//! its last snapshot is folded into a per-shard *retired* accumulator so
//! the cluster-wide counters stay monotone across respawns (a fresh
//! worker restarts its counters at zero; the history lives here).
//!
//! Respawns back off exponentially ([`respawn_backoff`]) while the shard
//! keeps dying before its first healthy probe, and after
//! [`super::ClusterConfig::breaker_threshold`] consecutive stillborn
//! incarnations the shard's circuit breaker opens: routing reports the
//! shard down without waiting, the router answers its keys from the
//! embedded planner (degraded mode), and the supervisor retries one
//! spawn per [`super::ClusterConfig::breaker_cooldown`] (half-open) until
//! one survives.

use super::{ClusterConfig, ClusterShared};
use crate::plan::client::{Client, ClientConfig};
use crate::plan::wire;
use crate::util::proc;
use std::net::SocketAddr;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// How often the monitor loop re-checks child exit and the stop flag.
const MONITOR_POLL: Duration = Duration::from_millis(10);

/// Where a shard's traffic should go right now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Status {
    /// no live incarnation, but a spawn is pending — worth waiting for
    Starting,
    /// a live incarnation listens here
    Up(SocketAddr),
    /// breaker open or cluster stopping: don't wait, degrade now
    Broken,
}

struct RouteState {
    status: Status,
    /// bumped every time a fresh incarnation comes up; forwarders that
    /// failed against epoch E wait for an epoch past E instead of
    /// hammering the same dead socket
    epoch: u64,
}

/// The folded metrics history of one shard.
#[derive(Default)]
struct Acc {
    /// monotone counters of every finished incarnation, folded together
    /// (gauges stay zero here — a dead worker has no queue depth)
    retired: wire::MetricsSnapshot,
    /// the most recent probe snapshot of the current incarnation
    last: Option<wire::MetricsSnapshot>,
}

/// One shard's routing state, metrics history, and kill handle — shared
/// between its supervisor thread, the forwarders, and aggregation.
pub(crate) struct Shard {
    state: Mutex<RouteState>,
    wake: Condvar,
    acc: Mutex<Acc>,
    /// pid of the current incarnation (0 between incarnations); exists
    /// for [`super::ClusterHandle::kill_shard`], the chaos fault injector
    pid: AtomicU32,
}

impl Shard {
    pub fn new() -> Shard {
        Shard {
            state: Mutex::new(RouteState { status: Status::Starting, epoch: 0 }),
            wake: Condvar::new(),
            acc: Mutex::new(Acc::default()),
            pid: AtomicU32::new(0),
        }
    }

    /// Wait up to `wait` for an incarnation with epoch ≥ `min_epoch` and
    /// return its address and epoch. `None` means degrade now: the
    /// breaker is open, the cluster is stopping, or no fresh incarnation
    /// appeared within the wait.
    pub fn route(&self, min_epoch: u64, wait: Duration) -> Option<(SocketAddr, u64)> {
        let deadline = Instant::now() + wait;
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            match s.status {
                Status::Up(addr) if s.epoch >= min_epoch => return Some((addr, s.epoch)),
                Status::Broken => return None,
                _ => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .wake
                .wait_timeout(s, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            s = guard;
        }
    }

    /// The current incarnation's pid, 0 between incarnations.
    pub fn pid(&self) -> u32 {
        self.pid.load(Ordering::SeqCst)
    }

    /// History + cached live counters (see [`fold_counters`]); gauges
    /// come from the live snapshot alone — a dead shard reports zeros.
    pub fn current(&self) -> wire::MetricsSnapshot {
        let acc = self.acc.lock().unwrap_or_else(|p| p.into_inner());
        let mut m = acc.retired;
        if let Some(live) = &acc.last {
            fold_counters(&mut m, live);
            fold_gauges(&mut m, live);
        }
        m
    }

    /// Like [`Shard::current`], but probe the live incarnation first so
    /// an in-band `stats`/`metrics` command reports up-to-the-request
    /// numbers rather than the last periodic probe's.
    pub fn fresh(&self, timeout: Duration) -> wire::MetricsSnapshot {
        let addr = {
            let s = self.state.lock().unwrap_or_else(|p| p.into_inner());
            match s.status {
                Status::Up(addr) => Some(addr),
                _ => None,
            }
        };
        if let Some(addr) = addr {
            if let Ok(m) = probe(addr, timeout) {
                self.acc.lock().unwrap_or_else(|p| p.into_inner()).last = Some(m);
            }
        }
        self.current()
    }

    fn set_status(&self, status: Status) {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.status = status;
        self.wake.notify_all();
    }

    fn set_up(&self, addr: SocketAddr) {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.epoch += 1;
        s.status = Status::Up(addr);
        self.wake.notify_all();
    }

    fn retire(&self) {
        let mut acc = self.acc.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(last) = acc.last.take() {
            fold_counters(&mut acc.retired, &last);
        }
    }
}

/// Fold the monotone counters (and latency-percentile maxima) of `from`
/// into `into`, leaving gauges untouched. Used both to retire a dead
/// incarnation into its shard's history and to sum shards into the
/// cluster snapshot. Percentiles take the elementwise max — there is no
/// way to merge two nearest-rank percentiles exactly without the raw
/// windows, and "the slowest shard's view" is the honest conservative
/// summary (documented in WIRE.md §6).
pub(crate) fn fold_counters(into: &mut wire::MetricsSnapshot, from: &wire::MetricsSnapshot) {
    let (s, t) = (&mut into.stats, &from.stats);
    s.served += t.served;
    s.errors += t.errors;
    s.cache_hits += t.cache_hits;
    s.connections += t.connections;
    s.panics += t.panics;
    s.timeouts += t.timeouts;
    s.rejected_internal += t.rejected_internal;
    s.warehouse_hits += t.warehouse_hits;
    s.warehouse_writes += t.warehouse_writes;
    s.coalesced += t.coalesced;
    s.shard_respawns += t.shard_respawns;
    s.replayed += t.replayed;
    s.degraded += t.degraded;
    s.tenant_rejects += t.tenant_rejects;
    s.plan_p50_s = s.plan_p50_s.max(t.plan_p50_s);
    s.plan_p95_s = s.plan_p95_s.max(t.plan_p95_s);
    into.rejected_over_quota += from.rejected_over_quota;
    into.rejected_over_inflight += from.rejected_over_inflight;
    into.cache_expired += from.cache_expired;
}

/// Fold the point-in-time gauges of `from` into `into` (sums; uptime
/// takes the max). Split from [`fold_counters`] because retiring a dead
/// incarnation must keep its counters and drop its gauges.
pub(crate) fn fold_gauges(into: &mut wire::MetricsSnapshot, from: &wire::MetricsSnapshot) {
    into.inflight += from.inflight;
    into.queue_depth += from.queue_depth;
    into.cache_entries += from.cache_entries;
    into.cache_bytes += from.cache_bytes;
    into.warehouse_bytes += from.warehouse_bytes;
    into.uptime_s = into.uptime_s.max(from.uptime_s);
}

/// One in-band `metrics` roundtrip against a worker — the liveness probe
/// and the metrics feed in a single request.
fn probe(addr: SocketAddr, timeout: Duration) -> Result<wire::MetricsSnapshot, crate::plan::PlanError> {
    let mut c = Client::with_config(
        addr,
        ClientConfig {
            connect_timeout: timeout,
            read_timeout: timeout,
            retries: 0,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(1),
            seed: 0x5b0b,
        },
    );
    let j = c.command("metrics")?;
    wire::metrics_from_json(&j)
}

/// The respawn delay after `strikes` consecutive incarnations died (or
/// failed to spawn) before their first healthy probe: capped exponential
/// backoff, zero after a death that followed a healthy period — a
/// one-off crash should restore capacity as fast as the spawn itself.
fn respawn_backoff(cfg: &ClusterConfig, strikes: u32) -> Duration {
    if strikes == 0 {
        return Duration::ZERO;
    }
    let factor = 1u32 << (strikes - 1).min(10);
    cfg.respawn_backoff_base.saturating_mul(factor).min(cfg.respawn_backoff_cap)
}

/// Sleep up to `total`, polling the stop flag; true means stop observed.
fn stopped_within(shared: &ClusterShared, total: Duration) -> bool {
    let deadline = Instant::now() + total;
    loop {
        if shared.workers_stopped() {
            return true;
        }
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return false;
        }
        std::thread::sleep(MONITOR_POLL.min(left));
    }
}

/// Spawn shard `index`'s worker: the same binary, `serve --plans` on an
/// ephemeral port, `--announce` so the port comes back on stdout, plus
/// the caller's pass-through worker flags and the shard's own warehouse
/// subdirectory (each shard must hold its own single-writer lock).
fn spawn_worker(shared: &ClusterShared, index: usize) -> std::io::Result<(Child, SocketAddr)> {
    let cfg = &shared.cfg;
    let exe = match &cfg.exe {
        Some(path) => path.clone(),
        None => std::env::current_exe()?,
    };
    let mut cmd = Command::new(exe);
    cmd.args(["serve", "--plans", "--addr", "127.0.0.1:0", "--announce", "--no-sigint"]);
    cmd.args(&cfg.worker_args);
    if let Some(root) = &cfg.warehouse {
        cmd.arg("--warehouse");
        cmd.arg(super::shard_warehouse_dir(root, index));
    }
    cmd.stdin(Stdio::null());
    let (mut child, announced) = proc::spawn_announced(cmd, "announce", cfg.spawn_timeout)?;
    match announced.parse::<SocketAddr>() {
        Ok(addr) => Ok((child, addr)),
        Err(_) => {
            let _ = child.kill();
            let _ = child.wait();
            Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("shard {index} announced an unparsable address {announced:?}"),
            ))
        }
    }
}

/// Supervise shard `index` until [`ClusterShared::workers_stopped`]:
/// spawn → publish → monitor → retire → (backoff/breaker) → respawn.
pub(crate) fn run(shared: &ClusterShared, index: usize) {
    let shard = &shared.shards[index];
    // consecutive incarnations that died before a healthy probe
    let mut strikes: u32 = 0;
    let mut first = true;
    while !shared.workers_stopped() {
        if !first && stopped_within(shared, respawn_backoff(&shared.cfg, strikes)) {
            break;
        }
        if strikes >= shared.cfg.breaker_threshold {
            // breaker open: stop hammering respawn; forwarders degrade
            // without waiting until the cooldown elapses, then one
            // half-open spawn attempt below probes whether the fault
            // (missing binary, bad flag, poisoned warehouse) cleared
            shard.set_status(Status::Broken);
            if stopped_within(shared, shared.cfg.breaker_cooldown) {
                break;
            }
        }
        shard.set_status(Status::Starting);
        let (mut child, addr) = match spawn_worker(shared, index) {
            Ok(pair) => pair,
            Err(_) => {
                strikes = strikes.saturating_add(1);
                first = false;
                continue;
            }
        };
        shard.pid.store(child.id(), Ordering::SeqCst);
        if !first {
            // counted per successful takeover, not per attempt: the wire
            // counter answers "how many times did a worker have to be
            // replaced", not "how hard was it"
            shared.lock_stats().shard_respawns += 1;
        }
        first = false;
        shard.set_up(addr);
        let mut last_probe = Instant::now();
        let mut missed = 0u32;
        let died = loop {
            if shared.workers_stopped() {
                break false;
            }
            match child.try_wait() {
                Ok(None) => {}
                Ok(Some(_)) | Err(_) => break true,
            }
            if last_probe.elapsed() >= shared.cfg.probe_interval {
                last_probe = Instant::now();
                match probe(addr, shared.cfg.probe_timeout) {
                    Ok(m) => {
                        missed = 0;
                        strikes = 0; // proven healthy: backoff resets
                        shard.acc.lock().unwrap_or_else(|p| p.into_inner()).last = Some(m);
                    }
                    Err(_) => {
                        missed += 1;
                        if missed >= shared.cfg.probe_misses {
                            // unresponsive far past its budget: a hang is
                            // handled exactly like a crash
                            let _ = child.kill();
                            let _ = child.wait();
                            break true;
                        }
                    }
                }
            }
            std::thread::sleep(MONITOR_POLL);
        };
        shard.pid.store(0, Ordering::SeqCst);
        if died {
            let _ = child.wait(); // reap (idempotent if already reaped)
            shard.set_status(Status::Starting);
            shard.retire();
            strikes = strikes.saturating_add(1);
            continue;
        }
        // cluster shutdown: the router set the stop flag only after every
        // owed response went out, so the worker just needs a polite exit.
        // One last probe first — counters accrued since the previous
        // periodic probe would otherwise vanish from the final snapshot.
        shard.set_status(Status::Broken);
        if let Ok(m) = probe(addr, shared.cfg.probe_timeout) {
            shard.acc.lock().unwrap_or_else(|p| p.into_inner()).last = Some(m);
        }
        proc::terminate(&mut child);
        if proc::wait_timeout(&mut child, shared.cfg.drain_timeout)
            .ok()
            .flatten()
            .is_none()
        {
            let _ = child.kill();
            let _ = child.wait();
        }
        shard.retire();
        return;
    }
    shard.set_status(Status::Broken);
}
