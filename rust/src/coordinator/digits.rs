//! Procedural synthetic-digits workload generator (Rust twin of
//! `python/compile/model.py::synth_digits`): 10 crude 7x7 stencils
//! upsampled to 28x28, randomly shifted by up to ±2 px and perturbed with
//! gaussian noise. Distributionally identical to the build-time training
//! set, so served accuracy matches the metrics recorded in meta.json.

use crate::util::prng::Rng;

pub const IMG: usize = 28;
pub const N_PIXELS: usize = IMG * IMG;
pub const N_CLASSES: usize = 10;

const ROWS: [[&str; 7]; 10] = [
    ["#####", "#...#", "#...#", "#...#", "#...#", "#...#", "#####"],
    ["..#..", ".##..", "..#..", "..#..", "..#..", "..#..", "#####"],
    ["#####", "....#", "....#", "#####", "#....", "#....", "#####"],
    ["#####", "....#", "....#", "#####", "....#", "....#", "#####"],
    ["#...#", "#...#", "#...#", "#####", "....#", "....#", "....#"],
    ["#####", "#....", "#....", "#####", "....#", "....#", "#####"],
    ["#####", "#....", "#....", "#####", "#...#", "#...#", "#####"],
    ["#####", "....#", "...#.", "..#..", ".#...", ".#...", ".#..."],
    ["#####", "#...#", "#...#", "#####", "#...#", "#...#", "#####"],
    ["#####", "#...#", "#...#", "#####", "....#", "....#", "#####"],
];

/// 28x28 stencil for one digit class (4x upsampled 7x7 with 1-col pad).
pub fn stencil(digit: usize) -> [f32; N_PIXELS] {
    assert!(digit < N_CLASSES);
    let mut small = [[0f32; 7]; 7];
    for (r, row) in ROWS[digit].iter().enumerate() {
        for (c, ch) in row.chars().enumerate() {
            // python pads the 5-wide glyph with one empty column each side
            small[r][c + 1] = if ch == '#' { 1.0 } else { 0.0 };
        }
    }
    let mut out = [0f32; N_PIXELS];
    for r in 0..IMG {
        for c in 0..IMG {
            out[r * IMG + c] = small[r / 4][c / 4];
        }
    }
    out
}

/// One generated sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub pixels: Vec<f32>,
    pub label: usize,
}

/// Generate `n` samples with the given noise level.
pub fn synth_digits(rng: &mut Rng, n: usize, noise: f32) -> Vec<Sample> {
    (0..n)
        .map(|_| {
            let label = rng.range(0, N_CLASSES - 1);
            let base = stencil(label);
            // integer roll by (-2..=2) in each axis, like jnp.roll; source
            // indices are precomputed per axis so the hot loop is a gather
            // plus noise (EXPERIMENTS.md §Perf #3)
            let sy = rng.range(0, 4) as isize - 2;
            let sx = rng.range(0, 4) as isize - 2;
            let mut col_src = [0usize; IMG];
            let mut row_src = [0usize; IMG];
            for i in 0..IMG {
                row_src[i] = (i as isize - sy).rem_euclid(IMG as isize) as usize;
                col_src[i] = (i as isize - sx).rem_euclid(IMG as isize) as usize;
            }
            let mut pixels = vec![0f32; N_PIXELS];
            for r in 0..IMG {
                let src_row = row_src[r] * IMG;
                for c in 0..IMG {
                    pixels[r * IMG + c] = base[src_row + col_src[c]] + noise * rng.normal() as f32;
                }
            }
            Sample { pixels, label }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stencils_distinct() {
        for a in 0..N_CLASSES {
            for b in (a + 1)..N_CLASSES {
                let (sa, sb) = (stencil(a), stencil(b));
                let diff: f32 = sa.iter().zip(&sb).map(|(x, y)| (x - y).abs()).sum();
                assert!(diff > 4.0, "stencils {a} and {b} too similar ({diff})");
            }
        }
    }

    #[test]
    fn samples_shapes_and_labels() {
        let mut rng = Rng::new(1);
        let s = synth_digits(&mut rng, 32, 0.35);
        assert_eq!(s.len(), 32);
        assert!(s.iter().all(|x| x.pixels.len() == N_PIXELS && x.label < N_CLASSES));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synth_digits(&mut Rng::new(7), 8, 0.3);
        let b = synth_digits(&mut Rng::new(7), 8, 0.3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.pixels, y.pixels);
        }
    }

    #[test]
    fn noise_free_sample_is_rolled_stencil() {
        let mut rng = Rng::new(3);
        let s = &synth_digits(&mut rng, 1, 0.0)[0];
        let total: f32 = s.pixels.iter().sum();
        let expect: f32 = stencil(s.label).iter().sum();
        assert!((total - expect).abs() < 1e-5, "roll must conserve mass");
    }

    #[test]
    fn all_classes_appear() {
        let mut rng = Rng::new(11);
        let s = synth_digits(&mut rng, 500, 0.0);
        let mut seen = [false; N_CLASSES];
        for x in &s {
            seen[x.label] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
