//! L3 serving coordinator: the request-path driver of the mapped chip.
//!
//! On startup it reads `artifacts/meta.json`, compiles the AOT crossbar
//! model through PJRT, maps the served network onto physical tiles with the
//! paper's packing machinery (so every inference is accounted against a
//! concrete tile configuration: count, area, modeled latency), and then
//! serves batched inference requests. Python is never on this path.
//!
//! [`batched_sweep`] is the design-service side of the coordinator: many
//! (network, sweep-config) requests priced concurrently with
//! deterministic, request-ordered results. It is a compatibility shim over
//! [`crate::plan::serve_batch`] — new callers should build
//! [`crate::plan::MapRequest`]s and serve those directly.

pub mod digits;

use crate::geom::Tile;
use crate::nets::Network;
use crate::opt::{SweepConfig, SweepPoint};
use crate::pack::{Discipline, Packing};
use crate::plan::{self, MapRequest, NetworkSpec, Replication};
use crate::runtime::{artifacts_dir, LoadedModel, Runtime, Tensor};
use crate::util::json::{self, Json};
use crate::util::stats;
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc::Receiver;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts: Option<String>,
    /// serve through the quantized crossbar model (false = fp32 oracle)
    pub crossbar: bool,
    pub discipline: Discipline,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { artifacts: None, crossbar: true, discipline: Discipline::Dense }
    }
}

/// Static description of the deployment (mapping + models + metadata).
pub struct Coordinator {
    #[allow(dead_code)]
    runtime: Runtime,
    model: LoadedModel,
    pub meta: Json,
    /// batch size the artifact was lowered with
    pub batch: usize,
    pub tile: Tile,
    pub mapping: Packing,
    pub total_area_mm2: f64,
    pub modeled_latency_s: f64,
    pub artifacts: PathBuf,
}

/// Serving statistics over a run.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub wall_s: f64,
    pub throughput_per_s: f64,
    pub batch_p50_s: f64,
    pub batch_p95_s: f64,
    pub accuracy: f64,
}

impl Coordinator {
    /// Load artifacts and build the deployment.
    pub fn new(cfg: &CoordinatorConfig) -> Result<Coordinator> {
        let dir = artifacts_dir(cfg.artifacts.as_deref());
        let meta_path = dir.join("meta.json");
        let meta_text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {meta_path:?} — run `make artifacts` first"))?;
        let meta = json::parse(&meta_text).map_err(|e| anyhow!("parse meta.json: {e}"))?;

        let batch = meta
            .get("batch")
            .and_then(Json::as_usize)
            .context("meta.json missing batch")?;
        let tile = Tile::new(
            meta.get("tile.n_row").and_then(Json::as_usize).context("meta tile.n_row")?,
            meta.get("tile.n_col").and_then(Json::as_usize).context("meta tile.n_col")?,
        );

        let runtime = Runtime::cpu()?;
        let artifact = if cfg.crossbar { "model.hlo.txt" } else { "model_fp32.hlo.txt" };
        let model = runtime.load_hlo_text(&dir.join(artifact))?;

        // map the served network onto the physical tile configuration
        // through the planning front door — one solve produces both the
        // adopted mapping and its pricing, so the two can never diverge
        // (the old plan()-then-pack() pair fragmented and packed twice)
        let planner = MapRequest::zoo("digits-mlp")
            .tile(tile.n_row, tile.n_col)
            .discipline(cfg.discipline)
            .build()
            .map_err(|e| anyhow!("deployment plan: {e}"))?;
        let (deployment, mapping) =
            planner.plan_deployment().map_err(|e| anyhow!("deployment plan: {e}"))?;
        let total_area_mm2 = deployment.best.total_area_mm2;
        let modeled_latency_s = deployment.latency_s;

        Ok(Coordinator {
            runtime,
            model,
            meta,
            batch,
            tile,
            mapping,
            total_area_mm2,
            modeled_latency_s,
            artifacts: dir,
        })
    }

    /// Run one padded batch through the PJRT executable.
    /// `x` is row-major [n, 784] with n <= batch; returns [n, 10] logits.
    pub fn infer(&self, x: &[f32], n: usize) -> Result<Tensor> {
        if n == 0 || n > self.batch {
            return Err(anyhow!("batch size {n} not in 1..={}", self.batch));
        }
        let width = digits::N_PIXELS;
        if x.len() != n * width {
            return Err(anyhow!("expected {} pixels, got {}", n * width, x.len()));
        }
        let mut padded = vec![0f32; self.batch * width];
        padded[..x.len()].copy_from_slice(x);
        let input = Tensor::new(vec![self.batch, width], padded)?;
        let out = self.model.run(&[input])?;
        // slice the real rows back out
        let classes = *out.shape.last().unwrap();
        Tensor::new(vec![n, classes], out.data[..n * classes].to_vec())
    }

    /// Classify a slice of samples (convenience over [`Self::infer`]).
    pub fn classify(&self, samples: &[digits::Sample]) -> Result<Vec<usize>> {
        let mut out = Vec::with_capacity(samples.len());
        for chunk in samples.chunks(self.batch) {
            let flat: Vec<f32> = chunk.iter().flat_map(|s| s.pixels.iter().copied()).collect();
            let logits = self.infer(&flat, chunk.len())?;
            out.extend(logits.argmax_rows());
        }
        Ok(out)
    }

    /// Serve a request stream with dynamic batching: drain up to `batch`
    /// queued requests per execution. The producer side runs on its own
    /// thread(s) feeding the channel; this loop owns the PJRT executable.
    pub fn serve(&self, rx: Receiver<digits::Sample>) -> Result<ServeStats> {
        let mut pending: Vec<digits::Sample> = Vec::with_capacity(self.batch);
        let mut batch_times: Vec<f64> = Vec::new();
        let mut correct = 0usize;
        let mut total = 0usize;
        let start = Instant::now();

        let flush = |pending: &mut Vec<digits::Sample>,
                         batch_times: &mut Vec<f64>,
                         correct: &mut usize,
                         total: &mut usize|
         -> Result<()> {
            if pending.is_empty() {
                return Ok(());
            }
            let t0 = Instant::now();
            let preds = self.classify(pending)?;
            batch_times.push(t0.elapsed().as_secs_f64());
            for (p, s) in preds.iter().zip(pending.iter()) {
                *correct += (*p == s.label) as usize;
            }
            *total += pending.len();
            pending.clear();
            Ok(())
        };

        // Greedy batching: take what is immediately available, execute,
        // then block for the next request.
        loop {
            match rx.try_recv() {
                Ok(s) => {
                    pending.push(s);
                    if pending.len() == self.batch {
                        flush(&mut pending, &mut batch_times, &mut correct, &mut total)?;
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    flush(&mut pending, &mut batch_times, &mut correct, &mut total)?;
                    match rx.recv() {
                        Ok(s) => pending.push(s),
                        Err(_) => break,
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
            }
        }
        flush(&mut pending, &mut batch_times, &mut correct, &mut total)?;

        let wall = start.elapsed().as_secs_f64();
        // total_cmp (NaN can't panic the sort) + the shared nearest-rank
        // percentile — the same definition the planning service's stats
        // frame reports, and exact at small batch counts where the old
        // `.round()` picker chose the wrong rank
        let mut sorted = batch_times.clone();
        stats::sort_samples(&mut sorted);
        Ok(ServeStats {
            requests: total,
            batches: batch_times.len(),
            wall_s: wall,
            throughput_per_s: total as f64 / wall.max(1e-12),
            batch_p50_s: stats::percentile_nearest_rank(&sorted, 0.50),
            batch_p95_s: stats::percentile_nearest_rank(&sorted, 0.95),
            accuracy: if total == 0 { 0.0 } else { correct as f64 / total as f64 },
        })
    }

    /// Accuracy recorded at build time by aot.py for the crossbar model.
    pub fn build_time_accuracy(&self) -> Option<f64> {
        self.meta.get("train.acc_crossbar").and_then(Json::as_f64)
    }
}

/// One batched-sweep work item: a named network plus the sweep
/// configuration to price it under.
#[derive(Debug, Clone)]
pub struct SweepRequest {
    pub name: String,
    pub net: Network,
    pub cfg: SweepConfig,
}

/// Result of one [`SweepRequest`].
#[derive(Debug, Clone)]
pub struct SweepResponse {
    pub name: String,
    pub points: Vec<SweepPoint>,
    pub best: Option<SweepPoint>,
}

/// Evaluate many networks' §3.1 sweeps concurrently (the coordinator's
/// batched-sweep entry point). Compatibility shim: each [`SweepRequest`]
/// is translated into a [`MapRequest`] and served through
/// [`plan::serve_batch`], so responses come back in request order with
/// values identical to a serial run.
#[doc(hidden)]
pub fn batched_sweep(requests: &[SweepRequest]) -> Vec<SweepResponse> {
    batched_sweep_with_threads(requests, crate::opt::sweep_threads())
}

/// [`batched_sweep`] with an explicit worker count.
#[doc(hidden)]
pub fn batched_sweep_with_threads(
    requests: &[SweepRequest],
    threads: usize,
) -> Vec<SweepResponse> {
    let map_requests: Vec<MapRequest> = requests.iter().map(to_map_request).collect();
    plan::serve_batch_with_threads(&map_requests, threads)
        .into_iter()
        .zip(requests)
        .map(|(r, req)| match r {
            Ok(p) => {
                SweepResponse { name: p.id.clone(), best: Some(p.best.clone()), points: p.points }
            }
            // legacy contract: a request the planner rejects (e.g. an
            // empty grid, which the old loop swept into zero points)
            // degrades to an empty response instead of failing the batch
            Err(_) => SweepResponse { name: req.name.clone(), points: Vec::new(), best: None },
        })
        .collect()
}

/// Translate a legacy [`SweepRequest`] into the typed front-door request
/// it always was: inline network, §3.1 grid, min-area objective.
fn to_map_request(r: &SweepRequest) -> MapRequest {
    let mut req = MapRequest::with_network(NetworkSpec::Inline(r.net.clone()))
        .id(&r.name)
        .grid(r.cfg.row_exp, r.cfg.aspects.clone())
        .engine(r.cfg.engine)
        .discipline(r.cfg.discipline)
        .sort(r.cfg.sort)
        .area(r.cfg.area);
    if let Some(plan) = &r.cfg.replication {
        req = req.replication(Replication::Explicit(plan.clone()));
    }
    req
}

#[cfg(test)]
mod tests {
    // Coordinator construction needs artifacts + a PJRT client; those paths
    // are covered by rust/tests/integration_runtime.rs. Pure helpers are
    // tested here.
    use super::*;
    use crate::nets::zoo;
    use crate::opt;

    #[test]
    fn config_defaults() {
        let c = CoordinatorConfig::default();
        assert!(c.crossbar);
        assert_eq!(c.discipline, Discipline::Dense);
        assert!(c.artifacts.is_none());
    }

    #[test]
    fn batched_sweep_matches_direct_and_preserves_order() {
        let requests = vec![
            SweepRequest {
                name: "lenet/dense".into(),
                net: zoo::lenet(),
                cfg: SweepConfig::square(Discipline::Dense),
            },
            SweepRequest {
                name: "lenet/pipeline".into(),
                net: zoo::lenet(),
                cfg: SweepConfig::square(Discipline::Pipeline),
            },
            SweepRequest {
                name: "resnet9/dense".into(),
                net: zoo::resnet9(),
                cfg: SweepConfig::square(Discipline::Dense),
            },
        ];
        let batched = batched_sweep_with_threads(&requests, 3);
        assert_eq!(batched.len(), requests.len());
        for (resp, req) in batched.iter().zip(&requests) {
            assert_eq!(resp.name, req.name);
            let direct = opt::sweep_serial(&req.net, &req.cfg);
            assert_eq!(resp.points.len(), direct.len());
            for (a, b) in resp.points.iter().zip(&direct) {
                assert_eq!((a.tile, a.n_tiles), (b.tile, b.n_tiles));
                assert_eq!(a.total_area_mm2.to_bits(), b.total_area_mm2.to_bits());
            }
            assert!(resp.best.is_some());
        }
    }

    #[test]
    fn batched_sweep_empty_and_single() {
        assert!(batched_sweep_with_threads(&[], 4).is_empty());
        let reqs = vec![SweepRequest {
            name: "solo".into(),
            net: zoo::lenet(),
            cfg: SweepConfig::square(Discipline::Dense),
        }];
        let out = batched_sweep_with_threads(&reqs, 16);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].points.len(), 8);
    }
}
