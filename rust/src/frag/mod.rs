//! Fragmentation of network layers onto a physical tile grid (§2.1, Eq. 5).
//!
//! A layer weight matrix `L(m_inp, m_out)` larger than the tile array
//! `T(n_row, n_col)` is cut along both axes into a grid of
//! `ceil(m_inp/n_row) x ceil(m_out/n_col)` blocks; block `(i, j)` has
//! `rows = min(n_row, m_inp − i·n_row)` and `cols = min(n_col, m_out − j·n_col)`.
//! Each block is classified into one of the four §2.1 kinds (Fig. 4).

use crate::geom::{Block, BlockKind, Tile};
use crate::nets::Network;

/// Census of block kinds produced by a fragmentation (paper Fig. 4 series).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Census {
    /// all blocks produced by the fragmentation
    pub total: usize,
    /// blocks filling the tile on both axes
    pub full: usize,
    /// blocks filling the tile's rows but not its columns
    pub row_full: usize,
    /// blocks filling the tile's columns but not its rows
    pub col_full: usize,
    /// blocks filling neither axis
    pub sparse: usize,
}

impl Census {
    /// Count each block kind across `blocks`.
    pub fn of(blocks: &[Block]) -> Census {
        let mut c = Census { total: blocks.len(), ..Census::default() };
        for b in blocks {
            match b.kind {
                BlockKind::Full => c.full += 1,
                BlockKind::RowFull => c.row_full += 1,
                BlockKind::ColFull => c.col_full += 1,
                BlockKind::Sparse => c.sparse += 1,
            }
        }
        c
    }
}

/// Classify a block's dimensions against the tile that produced it.
pub fn classify(rows: usize, cols: usize, tile: Tile) -> BlockKind {
    match (rows == tile.n_row, cols == tile.n_col) {
        (true, true) => BlockKind::Full,
        (true, false) => BlockKind::RowFull,
        (false, true) => BlockKind::ColFull,
        (false, false) => BlockKind::Sparse,
    }
}

/// One shape class of a fragmentation: `count` blocks of identical
/// `rows x cols` dimensions from one layer (all RAPA replicas merged),
/// with provenance back into the layer's fragmentation grid.
///
/// Eq. 5 cuts a layer into a `gr x gc` grid whose blocks take at most
/// **four** distinct shapes (the §2.1 kinds of Fig. 4): the full interior,
/// a right-edge column of row-full blocks, a bottom-edge row of col-full
/// blocks, and one sparse corner. [`shape_classes_into`] emits exactly
/// those classes — at most `4 x n_layers` of them, computed in closed form
/// from the layer shapes without materializing a single [`Block`] — and the
/// counted packing kernels ([`crate::pack::counted`]) price a tile
/// configuration from them alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeClass {
    /// word lines per block, 1..=n_row
    pub rows: usize,
    /// bit lines per block, 1..=n_col
    pub cols: usize,
    /// §2.1 kind relative to the fragmenting tile (unique per layer class)
    pub kind: BlockKind,
    /// total blocks in this class: grid span x `replicas`
    pub count: usize,
    /// index of the source network layer
    pub layer: usize,
    /// layer replicas (RAPA) merged into `count`
    pub replicas: usize,
    /// half-open range of fragmentation-grid row indices covered
    pub grid_rows: (usize, usize),
    /// half-open range of fragmentation-grid column indices covered
    pub grid_cols: (usize, usize),
}

impl ShapeClass {
    /// Blocks of this class per replica (its grid-range area).
    pub fn per_replica(&self) -> usize {
        (self.grid_rows.1 - self.grid_rows.0) * (self.grid_cols.1 - self.grid_cols.0)
    }

    /// Weights stored across all blocks of the class.
    pub fn weights(&self) -> usize {
        self.count * self.rows * self.cols
    }
}

/// Shape-class census of a replicated network fragmentation — the counted
/// equivalent of [`fragment_network_replicated_into`], O(layers) instead of
/// O(blocks). Classes come out grouped by layer in layer order (at most
/// four per layer), `out` is cleared first (capacity retained).
pub fn shape_classes_into(
    net: &Network,
    tile: Tile,
    replication: &[usize],
    out: &mut Vec<ShapeClass>,
) {
    assert_eq!(replication.len(), net.n_layers(), "replication arity");
    out.clear();
    for (li, layer) in net.layers.iter().enumerate() {
        let (m_inp, m_out) = layer.matrix_shape();
        assert!(m_inp > 0 && m_out > 0, "empty matrix {m_inp}x{m_out}");
        let replicas = replication[li].max(1);
        let gr = m_inp.div_ceil(tile.n_row);
        let gc = m_out.div_ceil(tile.n_col);
        let rem_r = m_inp % tile.n_row; // 0 = rows divide exactly
        let rem_c = m_out % tile.n_col;
        let fr = if rem_r == 0 { gr } else { gr - 1 }; // full-height grid rows
        let fc = if rem_c == 0 { gc } else { gc - 1 }; // full-width grid cols
        let mut push = |rows: usize, cols: usize, grid_rows: (usize, usize), grid_cols: (usize, usize)| {
            out.push(ShapeClass {
                rows,
                cols,
                kind: classify(rows, cols, tile),
                count: (grid_rows.1 - grid_rows.0) * (grid_cols.1 - grid_cols.0) * replicas,
                layer: li,
                replicas,
                grid_rows,
                grid_cols,
            });
        };
        if fr > 0 && fc > 0 {
            push(tile.n_row, tile.n_col, (0, fr), (0, fc));
        }
        if fr > 0 && rem_c > 0 {
            push(tile.n_row, rem_c, (0, fr), (fc, gc));
        }
        if rem_r > 0 && fc > 0 {
            push(rem_r, tile.n_col, (fr, gr), (0, fc));
        }
        if rem_r > 0 && rem_c > 0 {
            push(rem_r, rem_c, (fr, gr), (fc, gc));
        }
    }
}

/// Owned-allocation convenience form of [`shape_classes_into`].
pub fn shape_classes(net: &Network, tile: Tile, replication: &[usize]) -> Vec<ShapeClass> {
    let mut out = Vec::new();
    shape_classes_into(net, tile, replication, &mut out);
    out
}

/// Total blocks across a class list (== the materialized block count).
pub fn total_class_blocks(classes: &[ShapeClass]) -> usize {
    classes.iter().map(|c| c.count).sum()
}

/// Total weights across a class list (== [`total_block_weights`] of the
/// materialized blocks — the same integer, so efficiencies derived from it
/// are bit-identical).
pub fn total_class_weights(classes: &[ShapeClass]) -> usize {
    classes.iter().map(ShapeClass::weights).sum()
}

impl Census {
    /// [`Census::of`] computed from a shape-class census instead of a block
    /// list — identical counts, no blocks materialized.
    pub fn of_classes(classes: &[ShapeClass]) -> Census {
        let mut c = Census::default();
        for s in classes {
            c.total += s.count;
            match s.kind {
                BlockKind::Full => c.full += s.count,
                BlockKind::RowFull => c.row_full += s.count,
                BlockKind::ColFull => c.col_full += s.count,
                BlockKind::Sparse => c.sparse += s.count,
            }
        }
        c
    }
}

/// Fragment a single logical matrix `(m_inp, m_out)` for layer `layer`,
/// replica `replica`, onto tiles of dimension `tile`.
pub fn fragment_matrix(
    m_inp: usize,
    m_out: usize,
    tile: Tile,
    layer: usize,
    replica: usize,
) -> Vec<Block> {
    let mut out = Vec::new();
    fragment_matrix_into(m_inp, m_out, tile, layer, replica, &mut out);
    out
}

/// [`fragment_matrix`] appending into a caller-provided buffer — the
/// allocation-lean form the sweep's per-worker scratch arena uses so block
/// vectors are reused across grid points instead of reallocated.
pub fn fragment_matrix_into(
    m_inp: usize,
    m_out: usize,
    tile: Tile,
    layer: usize,
    replica: usize,
    out: &mut Vec<Block>,
) {
    assert!(m_inp > 0 && m_out > 0, "empty matrix {m_inp}x{m_out}");
    let gr = m_inp.div_ceil(tile.n_row);
    let gc = m_out.div_ceil(tile.n_col);
    out.reserve(gr * gc);
    for i in 0..gr {
        let rows = (m_inp - i * tile.n_row).min(tile.n_row);
        for j in 0..gc {
            let cols = (m_out - j * tile.n_col).min(tile.n_col);
            out.push(Block {
                rows,
                cols,
                layer,
                replica,
                grid: (i, j),
                kind: classify(rows, cols, tile),
            });
        }
    }
}

/// Fragment every layer of a network onto `tile` (replica 0 only).
///
/// Stage internal of the [`crate::plan`] front door — build a
/// [`crate::plan::MapRequest`] instead of wiring fragmentation and packing
/// by hand.
#[doc(hidden)]
pub fn fragment_network(net: &Network, tile: Tile) -> Vec<Block> {
    fragment_network_replicated(net, tile, &vec![1; net.n_layers()])
}

/// Fragment with a per-layer replication factor (RAPA, Fig. 3): layer `i`
/// contributes `replication[i]` identical copies of its fragment set,
/// tagged with distinct replica indices.
#[doc(hidden)]
pub fn fragment_network_replicated(
    net: &Network,
    tile: Tile,
    replication: &[usize],
) -> Vec<Block> {
    let mut out = Vec::new();
    fragment_network_replicated_into(net, tile, replication, &mut out);
    out
}

/// [`fragment_network_replicated`] into a caller-provided buffer (cleared
/// first, capacity retained across calls).
pub fn fragment_network_replicated_into(
    net: &Network,
    tile: Tile,
    replication: &[usize],
    out: &mut Vec<Block>,
) {
    assert_eq!(replication.len(), net.n_layers(), "replication arity");
    out.clear();
    for (li, layer) in net.layers.iter().enumerate() {
        let (m_inp, m_out) = layer.matrix_shape();
        for rep in 0..replication[li].max(1) {
            fragment_matrix_into(m_inp, m_out, tile, li, rep, out);
        }
    }
}

/// Total weights across blocks — must equal the replicated network total
/// (conservation invariant used by property tests).
pub fn total_block_weights(blocks: &[Block]) -> usize {
    blocks.iter().map(Block::weights).sum()
}

/// Sort order used by the simple packing algorithm (§3): descending row
/// dimension, then descending column dimension, then stable provenance.
pub fn sort_for_packing(blocks: &mut [Block]) {
    blocks.sort_by(|a, b| {
        b.rows
            .cmp(&a.rows)
            .then(b.cols.cmp(&a.cols))
            .then(a.layer.cmp(&b.layer))
            .then(a.replica.cmp(&b.replica))
            .then(a.grid.cmp(&b.grid))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::zoo;

    const T: Tile = Tile::new(256, 256);

    #[test]
    fn exact_fit_single_full_block() {
        let b = fragment_matrix(256, 256, T, 0, 0);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].kind, BlockKind::Full);
        assert_eq!((b[0].rows, b[0].cols), (256, 256));
    }

    #[test]
    fn small_matrix_single_sparse_block() {
        let b = fragment_matrix(100, 50, T, 3, 1);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].kind, BlockKind::Sparse);
        assert_eq!(b[0].layer, 3);
        assert_eq!(b[0].replica, 1);
    }

    #[test]
    fn one_over_boundary_produces_grid() {
        let b = fragment_matrix(257, 257, T, 0, 0);
        assert_eq!(b.len(), 4);
        let kinds: Vec<BlockKind> = b.iter().map(|x| x.kind).collect();
        assert_eq!(
            kinds,
            vec![BlockKind::Full, BlockKind::RowFull, BlockKind::ColFull, BlockKind::Sparse]
        );
        assert_eq!((b[3].rows, b[3].cols), (1, 1));
        assert_eq!(b[3].grid, (1, 1));
    }

    #[test]
    fn weights_conserved() {
        for (mi, mo) in [(785, 256), (1000, 1000), (1, 1), (256, 512), (2049, 1000)] {
            let blocks = fragment_matrix(mi, mo, T, 0, 0);
            assert_eq!(total_block_weights(&blocks), mi * mo, "{mi}x{mo}");
        }
    }

    #[test]
    fn network_fragmentation_conserves_weights() {
        let net = zoo::resnet18();
        let blocks = fragment_network(&net, T);
        assert_eq!(total_block_weights(&blocks), net.total_weights());
    }

    #[test]
    fn replication_multiplies_blocks_and_weights() {
        let net = zoo::lenet();
        let reps = vec![4, 2, 1, 1, 1];
        let blocks = fragment_network_replicated(&net, T, &reps);
        let single = fragment_network(&net, T);
        let expected: usize = net
            .layers
            .iter()
            .zip(&reps)
            .map(|(l, r)| l.weights() * r)
            .sum();
        assert_eq!(total_block_weights(&blocks), expected);
        assert!(blocks.len() > single.len());
        // replica tags distinct per layer copy
        assert!(blocks.iter().any(|b| b.layer == 0 && b.replica == 3));
    }

    #[test]
    fn census_counts() {
        let blocks = fragment_matrix(512, 300, T, 0, 0);
        // grid 2x2: (256,256)F (256,44)RF (256,256)F (256,44)RF
        let c = Census::of(&blocks);
        assert_eq!(c.total, 4);
        assert_eq!(c.full, 2);
        assert_eq!(c.row_full, 2);
        assert_eq!(c.col_full + c.sparse, 0);
    }

    #[test]
    fn census_fig4_trend_larger_tiles_fewer_blocks() {
        let net = zoo::resnet18();
        let counts: Vec<usize> = (6..=13)
            .map(|k| fragment_network(&net, Tile::new(1 << k, 1 << k)).len())
            .collect();
        for w in counts.windows(2) {
            assert!(w[0] >= w[1], "block count not monotone: {counts:?}");
        }
        // at huge arrays every layer is a single sparse block
        assert_eq!(*counts.last().unwrap(), net.n_layers());
    }

    #[test]
    fn sort_for_packing_descending_rows() {
        let mut blocks = fragment_network(&zoo::alexnet(), T);
        sort_for_packing(&mut blocks);
        for w in blocks.windows(2) {
            assert!(
                w[0].rows > w[1].rows
                    || (w[0].rows == w[1].rows && w[0].cols >= w[1].cols)
                    || (w[0].rows == w[1].rows && w[0].cols == w[1].cols),
                "not sorted: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn rectangular_tile_classification() {
        let t = Tile::new(512, 64);
        let b = fragment_matrix(512, 32, t, 0, 0);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].kind, BlockKind::RowFull);
        let b = fragment_matrix(100, 64, t, 0, 0);
        assert_eq!(b[0].kind, BlockKind::ColFull);
    }

    #[test]
    #[should_panic(expected = "empty matrix")]
    fn zero_dim_rejected() {
        fragment_matrix(0, 5, T, 0, 0);
    }

    /// Reference census computed the slow way: materialize and bucket.
    fn classes_via_blocks(net: &crate::nets::Network, tile: Tile, reps: &[usize]) -> Census {
        Census::of(&fragment_network_replicated(net, tile, reps))
    }

    #[test]
    fn shape_classes_match_materialized_census_across_zoo() {
        for net in [zoo::lenet(), zoo::alexnet(), zoo::resnet18(), zoo::bert_layer(64)] {
            let ones = vec![1usize; net.n_layers()];
            for tile in [Tile::new(64, 64), Tile::new(256, 256), Tile::new(2048, 512)] {
                let classes = shape_classes(&net, tile, &ones);
                assert!(classes.len() <= 4 * net.n_layers(), "{}: {} classes", net.name, classes.len());
                assert_eq!(
                    Census::of_classes(&classes),
                    classes_via_blocks(&net, tile, &ones),
                    "{} {tile}",
                    net.name
                );
                let blocks = fragment_network(&net, tile);
                assert_eq!(total_class_blocks(&classes), blocks.len());
                assert_eq!(total_class_weights(&classes), total_block_weights(&blocks));
            }
        }
    }

    #[test]
    fn shape_classes_respect_replication() {
        let net = zoo::lenet();
        let reps = vec![4, 2, 1, 3, 1];
        let tile = Tile::new(256, 256);
        let classes = shape_classes(&net, tile, &reps);
        assert_eq!(Census::of_classes(&classes), classes_via_blocks(&net, tile, &reps));
        assert_eq!(
            total_class_weights(&classes),
            total_block_weights(&fragment_network_replicated(&net, tile, &reps))
        );
        // replicas multiply counts, and per-replica spans stay grid-exact
        for c in &classes {
            assert_eq!(c.count, c.per_replica() * c.replicas);
            assert_eq!(c.replicas, reps[c.layer].max(1));
        }
    }

    #[test]
    fn shape_class_kinds_are_unique_per_layer() {
        // at most one class of each §2.1 kind per layer — the as-given
        // run reconstruction in pack::counted relies on this
        let net = zoo::resnet18();
        let ones = vec![1usize; net.n_layers()];
        for tile in [Tile::new(64, 64), Tile::new(512, 512), Tile::new(8192, 8192)] {
            let classes = shape_classes(&net, tile, &ones);
            for li in 0..net.n_layers() {
                let kinds: Vec<BlockKind> =
                    classes.iter().filter(|c| c.layer == li).map(|c| c.kind).collect();
                let mut dedup = kinds.clone();
                dedup.dedup();
                assert_eq!(kinds.len(), dedup.len(), "layer {li} at {tile}: {kinds:?}");
            }
        }
    }

    #[test]
    fn shape_classes_exact_fit_is_one_full_class() {
        let net = crate::nets::Network::new(
            "exact",
            "test",
            vec![{
                let mut l = crate::nets::Layer::fc("fc", 256, 256);
                l.bias = false; // 256x256 exactly
                l
            }],
        );
        let classes = shape_classes(&net, T, &[1]);
        assert_eq!(classes.len(), 1);
        assert_eq!(classes[0].kind, BlockKind::Full);
        assert_eq!(classes[0].count, 1);
        assert_eq!(classes[0].grid_rows, (0, 1));
    }
}
