//! Fragmentation of network layers onto a physical tile grid (§2.1, Eq. 5).
//!
//! A layer weight matrix `L(m_inp, m_out)` larger than the tile array
//! `T(n_row, n_col)` is cut along both axes into a grid of
//! `ceil(m_inp/n_row) x ceil(m_out/n_col)` blocks; block `(i, j)` has
//! `rows = min(n_row, m_inp − i·n_row)` and `cols = min(n_col, m_out − j·n_col)`.
//! Each block is classified into one of the four §2.1 kinds (Fig. 4).

use crate::geom::{Block, BlockKind, Tile};
use crate::nets::Network;

/// Census of block kinds produced by a fragmentation (paper Fig. 4 series).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Census {
    pub total: usize,
    pub full: usize,
    pub row_full: usize,
    pub col_full: usize,
    pub sparse: usize,
}

impl Census {
    pub fn of(blocks: &[Block]) -> Census {
        let mut c = Census { total: blocks.len(), ..Census::default() };
        for b in blocks {
            match b.kind {
                BlockKind::Full => c.full += 1,
                BlockKind::RowFull => c.row_full += 1,
                BlockKind::ColFull => c.col_full += 1,
                BlockKind::Sparse => c.sparse += 1,
            }
        }
        c
    }
}

/// Classify a block's dimensions against the tile that produced it.
pub fn classify(rows: usize, cols: usize, tile: Tile) -> BlockKind {
    match (rows == tile.n_row, cols == tile.n_col) {
        (true, true) => BlockKind::Full,
        (true, false) => BlockKind::RowFull,
        (false, true) => BlockKind::ColFull,
        (false, false) => BlockKind::Sparse,
    }
}

/// Fragment a single logical matrix `(m_inp, m_out)` for layer `layer`,
/// replica `replica`, onto tiles of dimension `tile`.
pub fn fragment_matrix(
    m_inp: usize,
    m_out: usize,
    tile: Tile,
    layer: usize,
    replica: usize,
) -> Vec<Block> {
    let mut out = Vec::new();
    fragment_matrix_into(m_inp, m_out, tile, layer, replica, &mut out);
    out
}

/// [`fragment_matrix`] appending into a caller-provided buffer — the
/// allocation-lean form the sweep's per-worker scratch arena uses so block
/// vectors are reused across grid points instead of reallocated.
pub fn fragment_matrix_into(
    m_inp: usize,
    m_out: usize,
    tile: Tile,
    layer: usize,
    replica: usize,
    out: &mut Vec<Block>,
) {
    assert!(m_inp > 0 && m_out > 0, "empty matrix {m_inp}x{m_out}");
    let gr = m_inp.div_ceil(tile.n_row);
    let gc = m_out.div_ceil(tile.n_col);
    out.reserve(gr * gc);
    for i in 0..gr {
        let rows = (m_inp - i * tile.n_row).min(tile.n_row);
        for j in 0..gc {
            let cols = (m_out - j * tile.n_col).min(tile.n_col);
            out.push(Block {
                rows,
                cols,
                layer,
                replica,
                grid: (i, j),
                kind: classify(rows, cols, tile),
            });
        }
    }
}

/// Fragment every layer of a network onto `tile` (replica 0 only).
///
/// Stage internal of the [`crate::plan`] front door — build a
/// [`crate::plan::MapRequest`] instead of wiring fragmentation and packing
/// by hand.
#[doc(hidden)]
pub fn fragment_network(net: &Network, tile: Tile) -> Vec<Block> {
    fragment_network_replicated(net, tile, &vec![1; net.n_layers()])
}

/// Fragment with a per-layer replication factor (RAPA, Fig. 3): layer `i`
/// contributes `replication[i]` identical copies of its fragment set,
/// tagged with distinct replica indices.
#[doc(hidden)]
pub fn fragment_network_replicated(
    net: &Network,
    tile: Tile,
    replication: &[usize],
) -> Vec<Block> {
    let mut out = Vec::new();
    fragment_network_replicated_into(net, tile, replication, &mut out);
    out
}

/// [`fragment_network_replicated`] into a caller-provided buffer (cleared
/// first, capacity retained across calls).
pub fn fragment_network_replicated_into(
    net: &Network,
    tile: Tile,
    replication: &[usize],
    out: &mut Vec<Block>,
) {
    assert_eq!(replication.len(), net.n_layers(), "replication arity");
    out.clear();
    for (li, layer) in net.layers.iter().enumerate() {
        let (m_inp, m_out) = layer.matrix_shape();
        for rep in 0..replication[li].max(1) {
            fragment_matrix_into(m_inp, m_out, tile, li, rep, out);
        }
    }
}

/// Total weights across blocks — must equal the replicated network total
/// (conservation invariant used by property tests).
pub fn total_block_weights(blocks: &[Block]) -> usize {
    blocks.iter().map(Block::weights).sum()
}

/// Sort order used by the simple packing algorithm (§3): descending row
/// dimension, then descending column dimension, then stable provenance.
pub fn sort_for_packing(blocks: &mut [Block]) {
    blocks.sort_by(|a, b| {
        b.rows
            .cmp(&a.rows)
            .then(b.cols.cmp(&a.cols))
            .then(a.layer.cmp(&b.layer))
            .then(a.replica.cmp(&b.replica))
            .then(a.grid.cmp(&b.grid))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::zoo;

    const T: Tile = Tile::new(256, 256);

    #[test]
    fn exact_fit_single_full_block() {
        let b = fragment_matrix(256, 256, T, 0, 0);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].kind, BlockKind::Full);
        assert_eq!((b[0].rows, b[0].cols), (256, 256));
    }

    #[test]
    fn small_matrix_single_sparse_block() {
        let b = fragment_matrix(100, 50, T, 3, 1);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].kind, BlockKind::Sparse);
        assert_eq!(b[0].layer, 3);
        assert_eq!(b[0].replica, 1);
    }

    #[test]
    fn one_over_boundary_produces_grid() {
        let b = fragment_matrix(257, 257, T, 0, 0);
        assert_eq!(b.len(), 4);
        let kinds: Vec<BlockKind> = b.iter().map(|x| x.kind).collect();
        assert_eq!(
            kinds,
            vec![BlockKind::Full, BlockKind::RowFull, BlockKind::ColFull, BlockKind::Sparse]
        );
        assert_eq!((b[3].rows, b[3].cols), (1, 1));
        assert_eq!(b[3].grid, (1, 1));
    }

    #[test]
    fn weights_conserved() {
        for (mi, mo) in [(785, 256), (1000, 1000), (1, 1), (256, 512), (2049, 1000)] {
            let blocks = fragment_matrix(mi, mo, T, 0, 0);
            assert_eq!(total_block_weights(&blocks), mi * mo, "{mi}x{mo}");
        }
    }

    #[test]
    fn network_fragmentation_conserves_weights() {
        let net = zoo::resnet18();
        let blocks = fragment_network(&net, T);
        assert_eq!(total_block_weights(&blocks), net.total_weights());
    }

    #[test]
    fn replication_multiplies_blocks_and_weights() {
        let net = zoo::lenet();
        let reps = vec![4, 2, 1, 1, 1];
        let blocks = fragment_network_replicated(&net, T, &reps);
        let single = fragment_network(&net, T);
        let expected: usize = net
            .layers
            .iter()
            .zip(&reps)
            .map(|(l, r)| l.weights() * r)
            .sum();
        assert_eq!(total_block_weights(&blocks), expected);
        assert!(blocks.len() > single.len());
        // replica tags distinct per layer copy
        assert!(blocks.iter().any(|b| b.layer == 0 && b.replica == 3));
    }

    #[test]
    fn census_counts() {
        let blocks = fragment_matrix(512, 300, T, 0, 0);
        // grid 2x2: (256,256)F (256,44)RF (256,256)F (256,44)RF
        let c = Census::of(&blocks);
        assert_eq!(c.total, 4);
        assert_eq!(c.full, 2);
        assert_eq!(c.row_full, 2);
        assert_eq!(c.col_full + c.sparse, 0);
    }

    #[test]
    fn census_fig4_trend_larger_tiles_fewer_blocks() {
        let net = zoo::resnet18();
        let counts: Vec<usize> = (6..=13)
            .map(|k| fragment_network(&net, Tile::new(1 << k, 1 << k)).len())
            .collect();
        for w in counts.windows(2) {
            assert!(w[0] >= w[1], "block count not monotone: {counts:?}");
        }
        // at huge arrays every layer is a single sparse block
        assert_eq!(*counts.last().unwrap(), net.n_layers());
    }

    #[test]
    fn sort_for_packing_descending_rows() {
        let mut blocks = fragment_network(&zoo::alexnet(), T);
        sort_for_packing(&mut blocks);
        for w in blocks.windows(2) {
            assert!(
                w[0].rows > w[1].rows
                    || (w[0].rows == w[1].rows && w[0].cols >= w[1].cols)
                    || (w[0].rows == w[1].rows && w[0].cols == w[1].cols),
                "not sorted: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn rectangular_tile_classification() {
        let t = Tile::new(512, 64);
        let b = fragment_matrix(512, 32, t, 0, 0);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].kind, BlockKind::RowFull);
        let b = fragment_matrix(100, 64, t, 0, 0);
        assert_eq!(b[0].kind, BlockKind::ColFull);
    }

    #[test]
    #[should_panic(expected = "empty matrix")]
    fn zero_dim_rejected() {
        fragment_matrix(0, 5, T, 0, 0);
    }
}
