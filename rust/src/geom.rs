//! Core geometric types shared across the mapping pipeline.
//!
//! Orientation convention (paper Fig. 1/2): the **row** dimension is the
//! input (word-line) direction — a weight matrix occupies `rows = fan_in`
//! word lines — and the **column** dimension is the output (bit-line)
//! direction — `cols = fan_out` bit lines.  A physical tile array
//! `Tile(n_row, n_col)` hosts blocks whose `rows <= n_row && cols <= n_col`.

use std::fmt;

/// Physical tile array dimensions T(n_row, n_col).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tile {
    /// word lines (input / vertical extent of a block)
    pub n_row: usize,
    /// bit lines (output / lateral extent of a block)
    pub n_col: usize,
}

impl Tile {
    /// A tile with `n_row` word lines and `n_col` bit lines.
    pub const fn new(n_row: usize, n_col: usize) -> Self {
        Tile { n_row, n_col }
    }

    /// Array capacity in cross-points (weights it can store).
    pub fn capacity(&self) -> usize {
        self.n_row * self.n_col
    }

    /// Aspect ratio n_row / n_col as used in the §3.1 sweep.
    pub fn aspect(&self) -> f64 {
        self.n_row as f64 / self.n_col as f64
    }

    /// The §3.1 integer aspect factor, exactly: `Some(n_row / n_col)` when
    /// the rows are an integer multiple of the columns, `None` otherwise
    /// (wide or non-integer-aspect tiles never alias into a grid bucket).
    pub fn exact_aspect(&self) -> Option<usize> {
        if self.n_col > 0 && self.n_row % self.n_col == 0 {
            Some(self.n_row / self.n_col)
        } else {
            None
        }
    }

    /// Whether the tile is square (aspect factor 1, the sweep's anchor
    /// column).
    pub fn is_square(&self) -> bool {
        self.n_row == self.n_col
    }

    /// Whether a `rows x cols` block fits this tile in both dimensions.
    pub fn fits(&self, rows: usize, cols: usize) -> bool {
        rows <= self.n_row && cols <= self.n_col
    }
}

impl fmt::Display for Tile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "T({},{})", self.n_row, self.n_col)
    }
}

/// The four fragment kinds of §2.1 (relative to the tile that produced them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BlockKind {
    /// i) p_in == n_row and p_out == n_col — fills a tile exactly.
    Full,
    /// ii) p_in == n_row, p_out < n_col — row (input) dimension full.
    RowFull,
    /// iii) p_in < n_row, p_out == n_col — column (output) dimension full.
    ColFull,
    /// iv) both dimensions partial — packable with other layers' blocks.
    Sparse,
}

/// A fragmented logical block: part of one network layer destined for a
/// single physical tile. Provenance fields drive pipeline conflict rules
/// (blocks of different layers must not share word/bit lines, Fig. 2)
/// and the execution simulator's dataflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Block {
    /// word lines occupied (input rows), 1..=n_row
    pub rows: usize,
    /// bit lines occupied (output cols), 1..=n_col
    pub cols: usize,
    /// index of the source network layer
    pub layer: usize,
    /// RAPA replica index (0 for the primary copy)
    pub replica: usize,
    /// position of this fragment in the layer's fragmentation grid
    pub grid: (usize, usize),
    /// which of the four §2.1 fragment kinds this block is
    pub kind: BlockKind,
}

impl Block {
    /// Weights stored in this block.
    pub fn weights(&self) -> usize {
        self.rows * self.cols
    }
}

/// Placement of one block inside one bin (tile), lower-left corner at
/// word line `y`, bit line `x` (paper Fig. 5/6 layout coordinates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// index of the placed block in the packing's block list
    pub block: usize,
    /// index of the bin (physical tile) hosting the block
    pub bin: usize,
    /// bit-line (column) offset
    pub x: usize,
    /// word-line (row) offset
    pub y: usize,
}

/// Axis-aligned interval arithmetic used by the placement validator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// inclusive lower bound
    pub lo: usize,
    /// exclusive upper bound
    pub hi: usize,
}

impl Span {
    /// The half-open interval `[lo, lo + len)`.
    pub fn new(lo: usize, len: usize) -> Self {
        Span { lo, hi: lo + len }
    }

    /// Whether two half-open intervals share at least one point.
    pub fn overlaps(&self, other: &Span) -> bool {
        self.lo < other.hi && other.lo < self.hi
    }

    /// Number of points covered.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// Whether the interval covers nothing.
    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_basics() {
        let t = Tile::new(512, 256);
        assert_eq!(t.capacity(), 131072);
        assert_eq!(t.aspect(), 2.0);
        assert!(!t.is_square());
        assert!(t.fits(512, 256));
        assert!(!t.fits(513, 1));
        assert!(!t.fits(1, 257));
        assert_eq!(t.to_string(), "T(512,256)");
    }

    #[test]
    fn exact_aspect_is_rounding_free() {
        assert_eq!(Tile::new(512, 512).exact_aspect(), Some(1));
        assert_eq!(Tile::new(2560, 512).exact_aspect(), Some(5));
        assert_eq!(Tile::new(96, 64).exact_aspect(), None); // 1.5, not 1
        assert_eq!(Tile::new(64, 96).exact_aspect(), None); // wide tile
        assert_eq!(Tile::new(64, 0).exact_aspect(), None);
    }

    #[test]
    fn block_weights() {
        let b = Block { rows: 3, cols: 4, layer: 0, replica: 0, grid: (0, 0), kind: BlockKind::Sparse };
        assert_eq!(b.weights(), 12);
    }

    #[test]
    fn span_overlap() {
        let a = Span::new(0, 10);
        assert!(a.overlaps(&Span::new(9, 1)));
        assert!(!a.overlaps(&Span::new(10, 5)));
        assert!(Span::new(5, 10).overlaps(&Span::new(0, 6)));
        assert!(!Span::new(5, 1).overlaps(&Span::new(6, 1)));
        assert_eq!(Span::new(2, 3).len(), 3);
        assert!(Span::new(4, 0).is_empty());
    }
}
