//! Generic 0/1 branch & bound over a BILP, bounded by LP relaxations
//! (the "binary variables ... branch-and-bound algorithm" of §2.2).
//!
//! Best-first search on the LP bound; branching on the most fractional
//! variable; node and time budgets (the paper notes the algorithm
//! "increases in complexity with problem size ... at exponentially
//! increased execution time" — budgets make that observable rather than
//! fatal, and the solver then reports its best incumbent and bound).

use super::simplex::{self, Cmp, Constraint, Lp, LpResult};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

/// Search budget.
#[derive(Debug, Clone, Copy)]
pub struct BnbConfig {
    pub max_nodes: u64,
    pub time_limit: Duration,
    /// treat objectives as integral (bin counts): prune with ceil(bound)
    pub integral_objective: bool,
}

impl Default for BnbConfig {
    fn default() -> Self {
        BnbConfig {
            max_nodes: 200_000,
            time_limit: Duration::from_secs(30),
            integral_objective: true,
        }
    }
}

/// Outcome of a branch & bound run.
#[derive(Debug, Clone)]
pub struct BnbResult {
    /// best incumbent: (objective, 0/1 assignment)
    pub best: Option<(f64, Vec<u8>)>,
    /// global lower bound proven so far
    pub lower_bound: f64,
    pub nodes: u64,
    /// true when optimality was proven within budget
    pub proven: bool,
}

#[derive(Debug)]
struct Node {
    bound: f64,
    fixes: Vec<(usize, u8)>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // max-heap: invert for best-first (lowest bound first)
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

/// Solve `min c·x, x ∈ {0,1}^n` under `lp`'s constraints.
/// `incumbent` (objective, assignment) warm-starts pruning.
pub fn solve(lp: &Lp, cfg: &BnbConfig, incumbent: Option<(f64, Vec<u8>)>) -> BnbResult {
    let start = Instant::now();
    let mut best = incumbent;
    let mut nodes = 0u64;
    let mut heap = BinaryHeap::new();

    let root_bound = match lp_with_fixes(lp, &[]) {
        LpResult::Optimal { objective, x } => {
            if let Some(assign) = integral(&x) {
                return BnbResult {
                    best: Some((objective, assign)),
                    lower_bound: objective,
                    nodes: 1,
                    proven: true,
                };
            }
            objective
        }
        LpResult::Infeasible => {
            return BnbResult { best, lower_bound: f64::INFINITY, nodes: 1, proven: true }
        }
        _ => f64::NEG_INFINITY,
    };
    heap.push(Node { bound: root_bound, fixes: vec![] });

    let mut exhausted = false;
    while let Some(node) = heap.pop() {
        if nodes >= cfg.max_nodes || start.elapsed() > cfg.time_limit {
            // push back so the bound report stays correct
            heap.push(node);
            exhausted = true;
            break;
        }
        nodes += 1;
        if prune(node.bound, &best, cfg) {
            continue;
        }
        // Re-solve (bound may be stale relative to a new incumbent, and we
        // need the fractional solution to pick the branching variable).
        let (objective, x) = match lp_with_fixes(lp, &node.fixes) {
            LpResult::Optimal { objective, x } => (objective, x),
            _ => continue,
        };
        if prune(objective, &best, cfg) {
            continue;
        }
        if let Some(assign) = integral(&x) {
            if best.as_ref().map_or(true, |(obj, _)| objective < obj - 1e-9) {
                best = Some((objective, assign));
            }
            continue;
        }
        // branch on most fractional variable
        let branch_var = x
            .iter()
            .enumerate()
            .filter(|(i, _)| !node.fixes.iter().any(|(v, _)| v == i))
            .min_by(|(_, a), (_, b)| {
                let fa = (**a - 0.5).abs();
                let fb = (**b - 0.5).abs();
                fa.partial_cmp(&fb).unwrap_or(Ordering::Equal)
            })
            .map(|(i, _)| i)
            .expect("fractional solution with no free variable");
        for val in [1u8, 0u8] {
            let mut fixes = node.fixes.clone();
            fixes.push((branch_var, val));
            match lp_with_fixes(lp, &fixes) {
                LpResult::Optimal { objective, x } => {
                    if prune(objective, &best, cfg) {
                        continue;
                    }
                    if let Some(assign) = integral(&x) {
                        if best.as_ref().map_or(true, |(obj, _)| objective < obj - 1e-9) {
                            best = Some((objective, assign));
                        }
                    } else {
                        heap.push(Node { bound: objective, fixes });
                    }
                }
                _ => {}
            }
        }
    }

    let frontier_bound = heap.peek().map(|n| n.bound).unwrap_or(f64::INFINITY);
    let lower_bound = match &best {
        Some((obj, _)) if !exhausted => *obj,
        Some((obj, _)) => frontier_bound.min(*obj),
        None => frontier_bound,
    };
    let proven = !exhausted;
    BnbResult { best, lower_bound, nodes, proven }
}

fn prune(bound: f64, best: &Option<(f64, Vec<u8>)>, cfg: &BnbConfig) -> bool {
    match best {
        None => false,
        Some((obj, _)) => {
            let effective = if cfg.integral_objective { (bound - 1e-6).ceil() } else { bound };
            effective >= obj - 1e-9
        }
    }
}

fn integral(x: &[f64]) -> Option<Vec<u8>> {
    let mut out = Vec::with_capacity(x.len());
    for &v in x {
        if v < 1e-6 {
            out.push(0);
        } else if (v - 1.0).abs() < 1e-6 {
            out.push(1);
        } else {
            return None;
        }
    }
    Some(out)
}

/// Build the LP with variables fixed by appending equality rows.
fn lp_with_fixes(lp: &Lp, fixes: &[(usize, u8)]) -> LpResult {
    if fixes.is_empty() {
        return simplex::solve(lp);
    }
    let mut lp2 = lp.clone();
    for &(v, val) in fixes {
        lp2.constraints.push(Constraint {
            terms: vec![(v, 1.0)],
            cmp: Cmp::Eq,
            rhs: val as f64,
        });
    }
    simplex::solve(&lp2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::simplex::{Cmp, Constraint, Lp};

    /// knapsack-as-covering: min Σ x_i s.t. Σ w_i x_i >= W.
    fn covering(weights: &[f64], demand: f64) -> Lp {
        let n = weights.len();
        let mut cons = vec![Constraint {
            terms: weights.iter().enumerate().map(|(i, &w)| (i, w)).collect(),
            cmp: Cmp::Ge,
            rhs: demand,
        }];
        for v in 0..n {
            cons.push(Constraint { terms: vec![(v, 1.0)], cmp: Cmp::Le, rhs: 1.0 });
        }
        Lp { n_vars: n, objective: vec![1.0; n], constraints: cons }
    }

    #[test]
    fn covering_exact() {
        // need >= 10 from {6, 5, 4, 3}: best is two items (6+4 or 6+5)
        let lp = covering(&[6.0, 5.0, 4.0, 3.0], 10.0);
        let r = solve(&lp, &BnbConfig::default(), None);
        let (obj, x) = r.best.unwrap();
        assert_eq!(obj.round() as usize, 2);
        assert!(r.proven);
        let picked: f64 = x
            .iter()
            .zip([6.0, 5.0, 4.0, 3.0])
            .map(|(&b, w)| b as f64 * w)
            .sum();
        assert!(picked >= 10.0);
    }

    #[test]
    fn infeasible_bilp() {
        // Σ x_i >= 5 with only 2 unit items
        let lp = covering(&[1.0, 1.0], 5.0);
        let r = solve(&lp, &BnbConfig::default(), None);
        assert!(r.best.is_none());
        assert!(r.proven);
    }

    #[test]
    fn incumbent_is_respected() {
        let lp = covering(&[6.0, 5.0, 4.0, 3.0], 10.0);
        // seed with the all-ones solution (objective 4)
        let seed = Some((4.0, vec![1, 1, 1, 1]));
        let r = solve(&lp, &BnbConfig::default(), seed);
        assert_eq!(r.best.unwrap().0.round() as usize, 2);
    }

    #[test]
    fn budget_exhaustion_reports_bound() {
        let weights: Vec<f64> = (0..14).map(|i| 3.0 + (i % 5) as f64).collect();
        let lp = covering(&weights, 30.0);
        let cfg = BnbConfig { max_nodes: 2, ..Default::default() };
        let r = solve(&lp, &cfg, Some((14.0, vec![1; 14])));
        // with 2 nodes it cannot prove optimality but keeps the incumbent
        assert!(r.best.is_some());
        assert!(r.lower_bound <= 14.0);
    }

    #[test]
    fn integral_detection() {
        assert_eq!(integral(&[0.0, 1.0, 0.0]), Some(vec![0, 1, 0]));
        assert_eq!(integral(&[0.5]), None);
        assert_eq!(integral(&[1.0 - 1e-9]), Some(vec![1]));
    }
}
