//! Specialized branch & bound for the two packing problems.
//!
//! The generic BILP route (model.rs + bnb.rs) is faithful to the paper but,
//! exactly as the paper observes, blows up beyond a few dozen items. This
//! module searches the *combinatorial* space directly — items assigned in
//! sorted order to open bins/shelves with symmetry breaking and capacity
//! bounds — which proves optimality on demo-scale instances in micro-
//! seconds and, under a node budget, improves the greedy incumbent on
//! network-scale instances (reporting the residual gap like an LPS run
//! that hit its iteration limit).
//!
//! The search works over an index permutation into the borrowed block
//! slice (no block-vector clones), and [`solve_with_hint`] /
//! [`solve_bins`] accept an *upper-bound hint* from a neighbouring sweep
//! configuration so grid points warm-start instead of solving cold
//! (EXPERIMENTS.md §Perf #3).

use crate::frag::{self, ShapeClass};
use crate::geom::{Block, Placement, Tile};
use crate::pack::{counted, ffd, simple, Discipline, PackScratch, Packing, SortOrder};
use crate::util::deadline::Deadline;

/// How many node expansions the search runs between wall-clock deadline
/// reads. The stride amortizes the `Instant::now()` call (one clock read
/// per ~thousand nodes) and — because the check never touches the node
/// counter — keeps node accounting bit-identical whether or not a
/// deadline is set (`solve_bins_census_matches_per_block_solver` pins the
/// equality).
const DEADLINE_STRIDE: u64 = 1024;

/// Node budget for the exact search.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    /// node-expansion budget: the search stops (keeping the incumbent,
    /// reporting `optimal: false`) once this many nodes were expanded
    pub max_nodes: u64,
    /// instances with more blocks than this skip the tree search and keep
    /// the greedy incumbent (the paper's "not always feasible to obtain a
    /// solution" regime for branch & bound at scale)
    pub max_items: usize,
    /// wall-clock counterpart of `max_nodes`: checked cooperatively every
    /// [`DEADLINE_STRIDE`] nodes, and on expiry the search bails exactly
    /// like node exhaustion (incumbent kept, not proven). Unset
    /// ([`Deadline::NONE`], the default) costs nothing — the node
    /// accounting is bit-identical with and without it
    pub deadline: Deadline,
}

impl Default for Budget {
    fn default() -> Self {
        Budget { max_nodes: 2_000_000, max_items: 400, deadline: Deadline::NONE }
    }
}

/// Result of an exact / budgeted solve.
#[derive(Debug, Clone)]
pub struct ExactResult {
    pub packing: Packing,
    /// proven lower bound on the bin count
    pub lower_bound: usize,
    /// true when `packing.n_bins == lower_bound` or the search space was
    /// exhausted within budget
    pub optimal: bool,
    pub nodes: u64,
}

/// Count-only result for the sweep hot path: same solver, no [`Packing`]
/// materialized (the sweep prices configurations by bin count alone).
#[derive(Debug, Clone, Copy)]
pub struct BinsResult {
    pub n_bins: usize,
    pub lower_bound: usize,
    pub optimal: bool,
    pub nodes: u64,
}

/// Combinatorial lower bounds on the number of bins.
pub fn lower_bound(blocks: &[Block], tile: Tile, discipline: Discipline) -> usize {
    if blocks.is_empty() {
        return 0;
    }
    let area: usize = blocks.iter().map(Block::weights).sum();
    let lb_area = area.div_ceil(tile.capacity());
    match discipline {
        Discipline::Dense => lb_area.max(1),
        Discipline::Pipeline => {
            let rows: usize = blocks.iter().map(|b| b.rows).sum();
            let cols: usize = blocks.iter().map(|b| b.cols).sum();
            lb_area
                .max(rows.div_ceil(tile.n_row))
                .max(cols.div_ceil(tile.n_col))
                .max(1)
        }
    }
}

/// [`lower_bound`] computed from a shape-class census — the same integer
/// (the bounds are sums over blocks, and the census carries exact counts),
/// in O(classes) with no blocks materialized.
pub fn lower_bound_classes(classes: &[ShapeClass], tile: Tile, discipline: Discipline) -> usize {
    if frag::total_class_blocks(classes) == 0 {
        return 0;
    }
    let area: usize = classes.iter().map(ShapeClass::weights).sum();
    let lb_area = area.div_ceil(tile.capacity());
    match discipline {
        Discipline::Dense => lb_area.max(1),
        Discipline::Pipeline => {
            let rows: usize = classes.iter().map(|c| c.count * c.rows).sum();
            let cols: usize = classes.iter().map(|c| c.count * c.cols).sum();
            lb_area
                .max(rows.div_ceil(tile.n_row))
                .max(cols.div_ceil(tile.n_col))
                .max(1)
        }
    }
}

/// Solve to optimality or budget exhaustion, warm-started with the better
/// of the simple (next-fit) and FFD packings.
pub fn solve(blocks: &[Block], tile: Tile, discipline: Discipline, budget: Budget) -> ExactResult {
    solve_with_hint(blocks, tile, discipline, budget, None)
}

/// Like [`solve`], with an optional upper-bound hint from a neighbouring
/// sweep configuration. The search first explores only assignments with at
/// most `hint` bins (tighter pruning than the greedy incumbent's bound);
/// if it *proves* that space empty it iteratively relaxes toward the plain
/// incumbent bound with the remaining node budget, so a misleading hint
/// can cost budget but never correctness. Bin counts returned are always
/// those of an actual packing for *this* tile.
pub fn solve_with_hint(
    blocks: &[Block],
    tile: Tile,
    discipline: Discipline,
    budget: Budget,
    hint: Option<usize>,
) -> ExactResult {
    let lb = lower_bound(blocks, tile, discipline);
    let nf = simple::pack(blocks, tile, discipline);
    let ff = ffd::pack(blocks, tile, discipline);
    let incumbent = if ff.n_bins <= nf.n_bins { ff } else { nf };
    if incumbent.n_bins <= lb {
        return ExactResult { packing: incumbent, lower_bound: lb, optimal: true, nodes: 0 };
    }
    if blocks.len() > budget.max_items {
        return ExactResult { packing: incumbent, lower_bound: lb, optimal: false, nodes: 0 };
    }
    match discipline {
        Discipline::Pipeline => {
            let s = pipeline_search(blocks, tile, budget, incumbent.n_bins, lb, hint, 0);
            let (packing, optimal) = match s.assign {
                Some(a) => {
                    let p = decode_pipeline(blocks, &s.order, tile, &a);
                    let opt = s.proven || p.n_bins == lb;
                    (p, opt)
                }
                None => (incumbent, s.proven),
            };
            ExactResult { packing, lower_bound: lb, optimal, nodes: s.nodes }
        }
        Discipline::Dense => {
            let s = dense_search(blocks, tile, budget, incumbent.n_bins, lb, hint, 0);
            let (packing, optimal) = match s.assign {
                Some(a) => {
                    let p = decode_dense(blocks, &s.order, tile, &a);
                    let opt = s.proven || p.n_bins == lb;
                    (p, opt)
                }
                None => (incumbent, s.proven),
            };
            ExactResult { packing, lower_bound: lb, optimal, nodes: s.nodes }
        }
    }
}

/// Count-only variant of [`solve_with_hint`] for the sweep hot path: greedy
/// incumbents run through the caller's [`PackScratch`] (no block-vector
/// clones, no `Packing`), and only the bin count of the best assignment is
/// returned. Values agree with [`solve_with_hint`] for identical inputs.
/// `scratch.placements` is cleared before returning — the reported count
/// need not come from the engine that ran through the scratch last.
pub fn solve_bins(
    blocks: &[Block],
    tile: Tile,
    discipline: Discipline,
    budget: Budget,
    hint: Option<usize>,
    scratch: &mut PackScratch,
) -> BinsResult {
    let lb = lower_bound(blocks, tile, discipline);
    if blocks.is_empty() {
        return BinsResult { n_bins: 0, lower_bound: 0, optimal: true, nodes: 0 };
    }
    let nf = simple::pack_into(blocks, tile, discipline, SortOrder::RowsDesc, scratch);
    let ff = ffd::pack_into(blocks, tile, discipline, scratch);
    let incumbent = ff.min(nf);
    // count-only API: the scratch holds FFD's placements at this point,
    // which need not correspond to the returned bin count (it may come
    // from the simple engine or the search below) — never hand them back
    scratch.placements.clear();
    if incumbent <= lb {
        return BinsResult { n_bins: incumbent, lower_bound: lb, optimal: true, nodes: 0 };
    }
    if blocks.len() > budget.max_items {
        return BinsResult { n_bins: incumbent, lower_bound: lb, optimal: false, nodes: 0 };
    }
    let s = search_bins(blocks, tile, discipline, budget, incumbent, lb, hint, 0);
    if s.found {
        BinsResult { n_bins: s.bins, lower_bound: lb, optimal: s.proven || s.bins == lb, nodes: s.nodes }
    } else {
        BinsResult { n_bins: incumbent, lower_bound: lb, optimal: s.proven, nodes: s.nodes }
    }
}

/// Count-only solve straight from a shape-class census — the fully counted
/// ILP path the sweep uses. The greedy incumbents and the lower bound are
/// computed from the classes alone (O(classes), see
/// [`crate::pack::counted`]); blocks are materialized via the `materialize`
/// callback **only** when an actual tree search is warranted (incumbent
/// above the bound and the instance within `budget.max_items`).
///
/// Counted preprocessing before the search:
/// * **Full blocks are pinned one-per-tile** — a block filling the tile in
///   both dimensions shares it with nothing, so the search runs over the
///   remaining blocks only, against `pinned` saturated (inert) bins. The
///   per-block reference descends its Full items as a branchless chain of
///   one node each; that node charge is replayed here per deepening pass,
///   so node budgets (and therefore results) stay **bit-identical** to
///   [`solve_bins`] on the materialized set.
/// * identical-block symmetry breaking inside the search itself (shared
///   with the per-block path — see `pipe_dfs`/`dense_dfs`).
///
/// `blocks` is a caller scratch buffer; on return it holds the non-Full
/// remainder of the materialized set (or is untouched when no search ran).
#[allow(clippy::too_many_arguments)]
pub fn solve_bins_census(
    classes: &[ShapeClass],
    tile: Tile,
    discipline: Discipline,
    budget: Budget,
    hint: Option<usize>,
    blocks: &mut Vec<Block>,
    materialize: impl FnOnce(&mut Vec<Block>),
    counted_scratch: &mut counted::CountedScratch,
) -> BinsResult {
    let total = frag::total_class_blocks(classes);
    if total == 0 {
        return BinsResult { n_bins: 0, lower_bound: 0, optimal: true, nodes: 0 };
    }
    let lb = lower_bound_classes(classes, tile, discipline);
    let nf = counted::simple_bins(classes, tile, discipline, SortOrder::RowsDesc, counted_scratch);
    let ff = counted::ffd_bins(classes, tile, discipline, counted_scratch);
    let incumbent = ff.min(nf);
    if incumbent <= lb {
        return BinsResult { n_bins: incumbent, lower_bound: lb, optimal: true, nodes: 0 };
    }
    if total > budget.max_items {
        return BinsResult { n_bins: incumbent, lower_bound: lb, optimal: false, nodes: 0 };
    }
    let pinned: usize = classes
        .iter()
        .filter(|c| c.rows == tile.n_row && c.cols == tile.n_col)
        .map(|c| c.count)
        .sum();
    materialize(blocks);
    debug_assert_eq!(blocks.len(), total, "materialize() must produce the censused blocks");
    blocks.retain(|b| !(b.rows == tile.n_row && b.cols == tile.n_col));
    debug_assert_eq!(blocks.len(), total - pinned);
    let s = search_bins(blocks, tile, discipline, budget, incumbent, lb, hint, pinned);
    if s.found {
        BinsResult { n_bins: s.bins, lower_bound: lb, optimal: s.proven || s.bins == lb, nodes: s.nodes }
    } else {
        BinsResult { n_bins: incumbent, lower_bound: lb, optimal: s.proven, nodes: s.nodes }
    }
}

struct SearchSummary {
    found: bool,
    bins: usize,
    nodes: u64,
    proven: bool,
}

/// Dispatch to the discipline's branch & bound, count-only form. `pinned`
/// Full blocks are represented as saturated bins the search never touches
/// (pass 0 when `blocks` is the complete set).
#[allow(clippy::too_many_arguments)]
fn search_bins(
    blocks: &[Block],
    tile: Tile,
    discipline: Discipline,
    budget: Budget,
    incumbent: usize,
    lb: usize,
    hint: Option<usize>,
    pinned: usize,
) -> SearchSummary {
    match discipline {
        Discipline::Pipeline => {
            let s = pipeline_search(blocks, tile, budget, incumbent, lb, hint, pinned);
            SearchSummary { found: s.assign.is_some(), bins: s.bins, nodes: s.nodes, proven: s.proven }
        }
        Discipline::Dense => {
            let s = dense_search(blocks, tile, budget, incumbent, lb, hint, pinned);
            SearchSummary { found: s.assign.is_some(), bins: s.bins, nodes: s.nodes, proven: s.proven }
        }
    }
}

// ---------------------------------------------------------------------------
// Pipeline: two-constraint vector packing
// ---------------------------------------------------------------------------

struct PipeSearch {
    /// item position -> original block index (sorted placement order)
    order: Vec<u32>,
    /// winning assignment (item position -> bin), if one beat the bound
    assign: Option<Vec<usize>>,
    /// bins of `assign` when present, else the final search bound
    bins: usize,
    nodes: u64,
    /// every assignment better than the returned solution (or, with no
    /// solution, better than the plain incumbent bound) was ruled out
    proven: bool,
}

struct PipeCtx<'a> {
    blocks: &'a [Block],
    order: &'a [u32], // item position -> original index, sorted desc
    tile: Tile,
    budget: u64,
    /// wall-clock budget, read every [`DEADLINE_STRIDE`] nodes; expiry
    /// sets `exhausted` exactly like running out of nodes
    deadline: Deadline,
    nodes: u64,
    best_bins: usize,
    best_assign: Option<Vec<usize>>, // item -> bin
    lb: usize,
    // suffix sums over the sorted order, for bounds
    suffix_rows: Vec<usize>,
    suffix_cols: Vec<usize>,
    exhausted: bool,
    /// saturated bins pinned outside the search (one per excluded Full
    /// block); they hold no slack and fit nothing, so only the bin-count
    /// offset remains
    pinned: usize,
}

impl PipeCtx<'_> {
    #[inline]
    fn item(&self, i: usize) -> Block {
        self.blocks[self.order[i] as usize]
    }

    fn n_items(&self) -> usize {
        self.order.len()
    }
}

fn pipeline_search(
    blocks: &[Block],
    tile: Tile,
    budget: Budget,
    incumbent_bins: usize,
    lb: usize,
    hint: Option<usize>,
    pinned: usize,
) -> PipeSearch {
    let mut order: Vec<u32> = (0..blocks.len() as u32).collect();
    order.sort_by(|&ia, &ib| {
        let (a, b) = (&blocks[ia as usize], &blocks[ib as usize]);
        (b.rows + b.cols)
            .cmp(&(a.rows + a.cols))
            .then(b.rows.cmp(&a.rows))
            .then(ia.cmp(&ib))
    });
    let n = order.len();
    let mut suffix_rows = vec![0usize; n + 1];
    let mut suffix_cols = vec![0usize; n + 1];
    for i in (0..n).rev() {
        let b = &blocks[order[i] as usize];
        suffix_rows[i] = suffix_rows[i + 1] + b.rows;
        suffix_cols[i] = suffix_cols[i + 1] + b.cols;
    }

    let mut ctx = PipeCtx {
        blocks,
        order: &order,
        tile,
        budget: budget.max_nodes,
        deadline: budget.deadline,
        nodes: 0,
        best_bins: incumbent_bins,
        best_assign: None,
        lb,
        suffix_rows,
        suffix_cols,
        exhausted: false,
        pinned,
    };
    let mut bins_rows: Vec<usize> = Vec::new();
    let mut bins_cols: Vec<usize> = Vec::new();
    let mut assign = vec![usize::MAX; n];

    // Iterative deepening on the bin bound, starting from the neighbour's
    // hint: each pass explores only assignments with fewer bins than
    // `target`. Without a hint this is a single pass at the incumbent bound
    // (the classic cold solve, node for node); with a hint the first pass
    // is much narrower and usually terminal. A pass that proves its space
    // empty raises the target, so a misleading hint can never degrade the
    // result below the cold solve's.
    //
    // The first pass runs at `hint + 1`, not `hint`: a neighbour's achieved
    // count is expected to be *matched*, not beaten, and the DFS shrinks
    // its own bound as it finds better solutions anyway — so the common
    // plateau case (optimum == hint) resolves in one pass instead of
    // proving `< hint` empty twice. `lb + 1` floor: a pass at
    // `target <= lb` is empty by construction.
    let mut target = incumbent_bins
        .min(hint.map_or(usize::MAX, |h| h.saturating_add(1)))
        .max(lb + 1);
    loop {
        ctx.best_bins = target;
        ctx.exhausted = false;
        bins_rows.clear();
        bins_cols.clear();
        assign.fill(usize::MAX);
        // replay the branchless descent through the pinned Full blocks (one
        // node each, every pass) so budgets behave exactly as if they were
        // search items — lb >= pinned guarantees the per-block search never
        // prunes inside that chain
        for _ in 0..ctx.pinned {
            if ctx.nodes >= ctx.budget {
                ctx.exhausted = true;
                break;
            }
            ctx.nodes += 1;
        }
        // one deadline read per deepening pass (passes are few) so an
        // already-expired budget never starts a descent
        if !ctx.exhausted && ctx.deadline.is_set() && ctx.deadline.expired() {
            ctx.exhausted = true;
        }
        if !ctx.exhausted {
            pipe_dfs(&mut ctx, 0, &mut bins_rows, &mut bins_cols, &mut assign);
        }
        if ctx.best_assign.is_some() || ctx.exhausted || target >= incumbent_bins {
            break;
        }
        target += 1;
    }

    // destructure first so ctx's borrow of `order` ends before the move
    let PipeCtx { best_assign, best_bins, nodes, exhausted, .. } = ctx;
    PipeSearch { assign: best_assign, bins: best_bins, nodes, proven: !exhausted, order }
}

fn pipe_dfs(
    ctx: &mut PipeCtx,
    i: usize,
    bins_rows: &mut Vec<usize>,
    bins_cols: &mut Vec<usize>,
    assign: &mut Vec<usize>,
) {
    if ctx.nodes >= ctx.budget {
        ctx.exhausted = true;
        return;
    }
    ctx.nodes += 1;
    // amortized wall-clock check: never touches the node counter, so node
    // accounting is bit-identical whether or not a deadline is set
    if ctx.deadline.is_set() && ctx.nodes % DEADLINE_STRIDE == 0 && ctx.deadline.expired() {
        ctx.exhausted = true;
        return;
    }
    let used = ctx.pinned + bins_rows.len();
    if i == ctx.n_items() {
        if used < ctx.best_bins {
            ctx.best_bins = used;
            ctx.best_assign = Some(assign.clone());
        }
        return;
    }
    if used >= ctx.best_bins {
        return;
    }
    // bound: remaining demand minus slack in open bins (pinned bins are
    // saturated — zero slack by construction)
    let slack_rows: usize = bins_rows.iter().map(|&r| ctx.tile.n_row - r).sum();
    let slack_cols: usize = bins_cols.iter().map(|&c| ctx.tile.n_col - c).sum();
    let need_rows = ctx.suffix_rows[i].saturating_sub(slack_rows);
    let need_cols = ctx.suffix_cols[i].saturating_sub(slack_cols);
    let extra = need_rows
        .div_ceil(ctx.tile.n_row)
        .max(need_cols.div_ceil(ctx.tile.n_col));
    if used + extra >= ctx.best_bins {
        return;
    }

    let it = ctx.item(i);
    // identical-block symmetry breaking: a block identical to its
    // predecessor in the sorted order never goes in an earlier bin — any
    // solution permutes (swap the two interchangeable blocks) into this
    // canonical form, so the restriction is loss-free
    let min_bin = if i > 0 {
        let prev = ctx.item(i - 1);
        if prev.rows == it.rows && prev.cols == it.cols { assign[i - 1] } else { 0 }
    } else {
        0
    };
    // try open bins, skipping bins with identical residual capacity
    let mut tried: Vec<(usize, usize)> = Vec::new();
    for b in min_bin..bins_rows.len() {
        let key = (bins_rows[b], bins_cols[b]);
        if tried.contains(&key) {
            continue;
        }
        if bins_rows[b] + it.rows <= ctx.tile.n_row && bins_cols[b] + it.cols <= ctx.tile.n_col {
            tried.push(key);
            bins_rows[b] += it.rows;
            bins_cols[b] += it.cols;
            assign[i] = b;
            pipe_dfs(ctx, i + 1, bins_rows, bins_cols, assign);
            assign[i] = usize::MAX;
            bins_rows[b] -= it.rows;
            bins_cols[b] -= it.cols;
            if ctx.exhausted || ctx.best_bins == ctx.lb {
                return;
            }
        }
    }
    // open a new bin (symmetry: the new bin is always the next index)
    if used + 1 <= ctx.best_bins - 1 {
        assign[i] = bins_rows.len();
        bins_rows.push(it.rows);
        bins_cols.push(it.cols);
        pipe_dfs(ctx, i + 1, bins_rows, bins_cols, assign);
        assign[i] = usize::MAX;
        bins_rows.pop();
        bins_cols.pop();
    }
}

fn decode_pipeline(blocks: &[Block], order: &[u32], tile: Tile, assign: &[usize]) -> Packing {
    let n_bins = assign.iter().copied().max().map_or(0, |m| m + 1);
    let mut rows_used = vec![0usize; n_bins];
    let mut cols_used = vec![0usize; n_bins];
    let mut placements = Vec::with_capacity(assign.len());
    for (i, &b) in assign.iter().enumerate() {
        let oi = order[i] as usize;
        let blk = blocks[oi];
        placements.push(Placement { block: oi, bin: b, x: cols_used[b], y: rows_used[b] });
        rows_used[b] += blk.rows;
        cols_used[b] += blk.cols;
    }
    Packing {
        tile,
        discipline: Discipline::Pipeline,
        blocks: blocks.to_vec(),
        placements,
        n_bins,
    }
}

// ---------------------------------------------------------------------------
// Dense: two-level shelf packing
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq, Eq)]
struct Shelf {
    width: usize,
    fill: usize,
    x: usize,
}

#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct DBin {
    col_used: usize,
    shelves: Vec<Shelf>,
}

struct DenseSearch {
    order: Vec<u32>,
    assign: Option<Vec<(usize, usize)>>, // item -> (bin, shelf)
    bins: usize,
    nodes: u64,
    proven: bool,
}

struct DenseCtx<'a> {
    blocks: &'a [Block],
    order: &'a [u32], // item position -> original index, sorted desc by cols then rows
    tile: Tile,
    budget: u64,
    /// wall-clock budget, read every [`DEADLINE_STRIDE`] nodes (see
    /// [`PipeCtx::deadline`])
    deadline: Deadline,
    nodes: u64,
    best_bins: usize,
    best_assign: Option<Vec<(usize, usize)>>,
    lb: usize,
    suffix_area: Vec<usize>,
    exhausted: bool,
    /// saturated bins pinned outside the search (see [`PipeCtx::pinned`])
    pinned: usize,
}

impl DenseCtx<'_> {
    #[inline]
    fn item(&self, i: usize) -> Block {
        self.blocks[self.order[i] as usize]
    }

    fn n_items(&self) -> usize {
        self.order.len()
    }
}

fn dense_search(
    blocks: &[Block],
    tile: Tile,
    budget: Budget,
    incumbent_bins: usize,
    lb: usize,
    hint: Option<usize>,
    pinned: usize,
) -> DenseSearch {
    let mut order: Vec<u32> = (0..blocks.len() as u32).collect();
    order.sort_by(|&ia, &ib| {
        let (a, b) = (&blocks[ia as usize], &blocks[ib as usize]);
        b.cols
            .cmp(&a.cols)
            .then(b.rows.cmp(&a.rows))
            .then(ia.cmp(&ib))
    });
    let n = order.len();
    let mut suffix_area = vec![0usize; n + 1];
    for i in (0..n).rev() {
        suffix_area[i] = suffix_area[i + 1] + blocks[order[i] as usize].weights();
    }

    let mut ctx = DenseCtx {
        blocks,
        order: &order,
        tile,
        budget: budget.max_nodes,
        deadline: budget.deadline,
        nodes: 0,
        best_bins: incumbent_bins,
        best_assign: None,
        lb,
        suffix_area,
        exhausted: false,
        pinned,
    };
    let mut bins: Vec<DBin> = Vec::new();
    let mut assign = vec![(usize::MAX, usize::MAX); n];

    // Iterative deepening from the hinted bound (see pipeline_search).
    let mut target = incumbent_bins
        .min(hint.map_or(usize::MAX, |h| h.saturating_add(1)))
        .max(lb + 1);
    loop {
        ctx.best_bins = target;
        ctx.exhausted = false;
        bins.clear();
        assign.fill((usize::MAX, usize::MAX));
        // replay the pinned Full blocks' branchless node charge (see
        // pipeline_search)
        for _ in 0..ctx.pinned {
            if ctx.nodes >= ctx.budget {
                ctx.exhausted = true;
                break;
            }
            ctx.nodes += 1;
        }
        // per-pass deadline read (see pipeline_search)
        if !ctx.exhausted && ctx.deadline.is_set() && ctx.deadline.expired() {
            ctx.exhausted = true;
        }
        if !ctx.exhausted {
            dense_dfs(&mut ctx, 0, &mut bins, &mut assign);
        }
        if ctx.best_assign.is_some() || ctx.exhausted || target >= incumbent_bins {
            break;
        }
        target += 1;
    }

    // destructure first so ctx's borrow of `order` ends before the move
    let DenseCtx { best_assign, best_bins, nodes, exhausted, .. } = ctx;
    DenseSearch { assign: best_assign, bins: best_bins, nodes, proven: !exhausted, order }
}

fn dense_dfs(
    ctx: &mut DenseCtx,
    i: usize,
    bins: &mut Vec<DBin>,
    assign: &mut Vec<(usize, usize)>,
) {
    if ctx.nodes >= ctx.budget {
        ctx.exhausted = true;
        return;
    }
    ctx.nodes += 1;
    // amortized wall-clock check (see pipe_dfs): node accounting is
    // untouched, so results are bit-identical when no deadline fires
    if ctx.deadline.is_set() && ctx.nodes % DEADLINE_STRIDE == 0 && ctx.deadline.expired() {
        ctx.exhausted = true;
        return;
    }
    let used = ctx.pinned + bins.len();
    if i == ctx.n_items() {
        if used < ctx.best_bins {
            ctx.best_bins = used;
            ctx.best_assign = Some(assign.clone());
        }
        return;
    }
    if used >= ctx.best_bins {
        return;
    }
    // area bound: free space in open bins (shelf leftovers + unopened
    // cols); pinned bins are packed solid and contribute none
    let free: usize = bins
        .iter()
        .map(|b| {
            let shelf_free: usize = b
                .shelves
                .iter()
                .map(|s| (ctx.tile.n_row - s.fill) * s.width)
                .sum();
            shelf_free + (ctx.tile.n_col - b.col_used) * ctx.tile.n_row
        })
        .sum();
    let need = ctx.suffix_area[i].saturating_sub(free);
    if used + need.div_ceil(ctx.tile.capacity()) >= ctx.best_bins {
        return;
    }

    let it = ctx.item(i);
    // identical-block symmetry breaking (see pipe_dfs): a block identical
    // to its predecessor never takes a lexicographically earlier
    // (bin, shelf) slot
    let (min_b, min_s) = if i > 0 {
        let prev = ctx.item(i - 1);
        if prev.rows == it.rows && prev.cols == it.cols { assign[i - 1] } else { (0, 0) }
    } else {
        (0, 0)
    };
    // 1) join an existing shelf (item cols <= shelf width by sort order)
    let mut tried_shelves: Vec<(usize, usize)> = Vec::new();
    for b in min_b..bins.len() {
        let s_lo = if b == min_b { min_s } else { 0 };
        for s in s_lo..bins[b].shelves.len() {
            let sh = &bins[b].shelves[s];
            let key = (sh.width, sh.fill);
            if sh.fill + it.rows > ctx.tile.n_row || it.cols > sh.width {
                continue;
            }
            if tried_shelves.contains(&key) {
                continue;
            }
            tried_shelves.push(key);
            bins[b].shelves[s].fill += it.rows;
            assign[i] = (b, s);
            dense_dfs(ctx, i + 1, bins, assign);
            assign[i] = (usize::MAX, usize::MAX);
            bins[b].shelves[s].fill -= it.rows;
            if ctx.exhausted || ctx.best_bins == ctx.lb {
                return;
            }
        }
    }
    // 2) open a new shelf in an existing bin (slot (b, shelves.len()) is
    //    always lexicographically >= the predecessor's for b >= min_b)
    let mut tried_bins: Vec<usize> = Vec::new();
    for b in min_b..bins.len() {
        let key = bins[b].col_used;
        if bins[b].col_used + it.cols > ctx.tile.n_col || tried_bins.contains(&key) {
            continue;
        }
        tried_bins.push(key);
        let x = bins[b].col_used;
        bins[b].shelves.push(Shelf { width: it.cols, fill: it.rows, x });
        bins[b].col_used += it.cols;
        assign[i] = (b, bins[b].shelves.len() - 1);
        dense_dfs(ctx, i + 1, bins, assign);
        assign[i] = (usize::MAX, usize::MAX);
        bins[b].col_used -= it.cols;
        bins[b].shelves.pop();
        if ctx.exhausted || ctx.best_bins == ctx.lb {
            return;
        }
    }
    // 3) open a new bin
    if used + 1 <= ctx.best_bins - 1 {
        assign[i] = (bins.len(), 0);
        bins.push(DBin {
            col_used: it.cols,
            shelves: vec![Shelf { width: it.cols, fill: it.rows, x: 0 }],
        });
        dense_dfs(ctx, i + 1, bins, assign);
        assign[i] = (usize::MAX, usize::MAX);
        bins.pop();
    }
}

fn decode_dense(
    blocks: &[Block],
    order: &[u32],
    tile: Tile,
    assign: &[(usize, usize)],
) -> Packing {
    let n_bins = assign.iter().map(|&(b, _)| b).max().map_or(0, |m| m + 1);
    // replay: shelf x offsets and fills in assignment order
    #[derive(Default, Clone)]
    struct RBin {
        col_used: usize,
        shelf_x: Vec<usize>,
        shelf_fill: Vec<usize>,
    }
    let mut rbins = vec![RBin::default(); n_bins];
    let mut placements = Vec::with_capacity(assign.len());
    for (i, &(b, s)) in assign.iter().enumerate() {
        let oi = order[i] as usize;
        let blk = blocks[oi];
        let rb = &mut rbins[b];
        if s == rb.shelf_x.len() {
            rb.shelf_x.push(rb.col_used);
            rb.shelf_fill.push(0);
            rb.col_used += blk.cols;
        }
        placements.push(Placement {
            block: oi,
            bin: b,
            x: rbins[b].shelf_x[s],
            y: rbins[b].shelf_fill[s],
        });
        rbins[b].shelf_fill[s] += blk.rows;
    }
    Packing { tile, discipline: Discipline::Dense, blocks: blocks.to_vec(), placements, n_bins }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::BlockKind;
    use crate::pack::placement::validate;

    fn blk(rows: usize, cols: usize, layer: usize) -> Block {
        Block { rows, cols, layer, replica: 0, grid: (0, 0), kind: BlockKind::Sparse }
    }

    fn paper_items() -> Vec<Block> {
        [
            (257, 256), (257, 256), (257, 256), (129, 256), (129, 128),
            (129, 128), (129, 128), (129, 128), (65, 128), (148, 64),
            (65, 64), (65, 64), (65, 64),
        ]
        .iter()
        .enumerate()
        .map(|(i, &(r, c))| blk(r, c, i))
        .collect()
    }

    #[test]
    fn dense_demo_optimum_two_bins() {
        // Paper Table 3 / Fig. 5 headline
        let r = solve(&paper_items(), Tile::new(512, 512), Discipline::Dense, Budget::default());
        validate(&r.packing).unwrap();
        assert_eq!(r.packing.n_bins, 2);
        assert!(r.optimal);
    }

    #[test]
    fn pipeline_demo_optimum_four_bins() {
        // Paper Table 5 / Fig. 6 headline
        let r =
            solve(&paper_items(), Tile::new(512, 512), Discipline::Pipeline, Budget::default());
        validate(&r.packing).unwrap();
        assert_eq!(r.packing.n_bins, 4);
        assert!(r.optimal);
        assert_eq!(r.lower_bound, 4); // ceil(1920/512) on columns
    }

    #[test]
    fn lower_bounds() {
        let t = Tile::new(512, 512);
        let items = paper_items();
        assert_eq!(lower_bound(&items, t, Discipline::Dense), 2); // area 326720
        assert_eq!(lower_bound(&items, t, Discipline::Pipeline), 4);
        assert_eq!(lower_bound(&[], t, Discipline::Dense), 0);
    }

    #[test]
    fn trivial_instances_fast_path() {
        let t = Tile::new(64, 64);
        let items = vec![blk(64, 64, 0), blk(64, 64, 1)];
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let r = solve(&items, t, d, Budget::default());
            assert_eq!(r.packing.n_bins, 2);
            assert!(r.optimal);
            assert_eq!(r.nodes, 0, "greedy already optimal, no search needed");
        }
    }

    #[test]
    fn budget_exhaustion_keeps_incumbent() {
        let items: Vec<Block> =
            (0..40).map(|i| blk(100 + (i * 37) % 150, 90 + (i * 53) % 160, i)).collect();
        let t = Tile::new(512, 512);
        let r = solve(&items, t, Discipline::Pipeline, Budget { max_nodes: 50, ..Default::default() });
        validate(&r.packing).unwrap();
        assert!(r.packing.n_bins >= r.lower_bound);
    }

    #[test]
    fn exact_never_worse_than_greedy() {
        use crate::frag::fragment_network;
        use crate::nets::zoo;
        let tile = Tile::new(512, 512);
        let blocks = fragment_network(&zoo::lenet(), tile);
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let greedy = crate::pack::ffd::pack(&blocks, tile, d);
            let r = solve(&blocks, tile, d, Budget { max_nodes: 100_000, ..Default::default() });
            validate(&r.packing).unwrap();
            assert!(r.packing.n_bins <= greedy.n_bins);
            assert!(r.packing.n_bins >= r.lower_bound);
        }
    }

    #[test]
    fn dense_decode_roundtrip_valid() {
        let items = paper_items();
        let r = solve(&items, Tile::new(512, 512), Discipline::Dense, Budget::default());
        // all 13 blocks present exactly once with original indices
        let mut seen: Vec<usize> = r.packing.placements.iter().map(|p| p.block).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..13).collect::<Vec<_>>());
    }

    #[test]
    fn solve_bins_matches_full_solve() {
        use crate::frag::fragment_network;
        use crate::nets::zoo;
        let mut scratch = PackScratch::default();
        for tile in [Tile::new(256, 256), Tile::new(512, 512)] {
            let blocks = fragment_network(&zoo::lenet(), tile);
            for d in [Discipline::Dense, Discipline::Pipeline] {
                for hint in [None, Some(1), Some(usize::MAX)] {
                    let budget = Budget { max_nodes: 50_000, ..Default::default() };
                    let full = solve_with_hint(&blocks, tile, d, budget, hint);
                    let bins = solve_bins(&blocks, tile, d, budget, hint, &mut scratch);
                    assert_eq!(bins.n_bins, full.packing.n_bins, "{tile} {d} {hint:?}");
                    assert_eq!(bins.lower_bound, full.lower_bound);
                    assert_eq!(bins.optimal, full.optimal);
                    assert_eq!(bins.nodes, full.nodes);
                }
            }
        }
    }

    #[test]
    fn misleading_hint_never_degrades_result() {
        // a hint below the true optimum forces the fallback phase; the
        // result must match the cold solve's bin count
        let items = paper_items();
        let t = Tile::new(512, 512);
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let cold = solve(&items, t, d, Budget::default());
            let warm = solve_with_hint(&items, t, d, Budget::default(), Some(1));
            validate(&warm.packing).unwrap();
            assert_eq!(warm.packing.n_bins, cold.packing.n_bins, "{d}");
            // a truthful hint (the cold optimum itself) must also agree
            let tight =
                solve_with_hint(&items, t, d, Budget::default(), Some(cold.packing.n_bins));
            assert_eq!(tight.packing.n_bins, cold.packing.n_bins, "{d} tight");
        }
    }

    #[test]
    fn solve_bins_census_matches_per_block_solver() {
        use crate::nets::zoo;
        use crate::nets::{Layer, Network};
        let mut pscratch = PackScratch::default();
        let mut cscratch = counted::CountedScratch::new();
        let mut buf = Vec::new();
        // lenet exercises the no-Full-blocks case; the inline net fragments
        // into five Full blocks at 128x128, so the pinned search path (and
        // its node-charge replay) is exercised under the tight budget too
        let nets = vec![
            (zoo::lenet(), vec![Tile::new(128, 128), Tile::new(256, 256), Tile::new(512, 512)]),
            (
                Network::new(
                    "full-heavy",
                    "test",
                    vec![Layer::fc("a", 300, 300), Layer::fc("b", 200, 150)],
                ),
                vec![Tile::new(128, 128)],
            ),
        ];
        for (net, tiles) in nets {
            let ones = vec![1usize; net.n_layers()];
            for tile in tiles {
                let classes = frag::shape_classes(&net, tile, &ones);
                let blocks = frag::fragment_network(&net, tile);
            for d in [Discipline::Dense, Discipline::Pipeline] {
                for hint in [None, Some(1), Some(usize::MAX)] {
                    // a tight budget exercises exhaustion parity: the pinned
                    // search must stop at the same point the per-block
                    // search (which descends its Full items) would
                    for max_nodes in [200u64, 50_000] {
                        let budget = Budget { max_nodes, ..Default::default() };
                        let per_block = solve_bins(&blocks, tile, d, budget, hint, &mut pscratch);
                        let census = solve_bins_census(
                            &classes,
                            tile,
                            d,
                            budget,
                            hint,
                            &mut buf,
                            |out| {
                                frag::fragment_network_replicated_into(&net, tile, &ones, out)
                            },
                            &mut cscratch,
                        );
                        let what = format!("{tile} {d} {hint:?} n{max_nodes}");
                        assert_eq!(census.n_bins, per_block.n_bins, "{what}: bins");
                        assert_eq!(census.lower_bound, per_block.lower_bound, "{what}: lb");
                        assert_eq!(census.optimal, per_block.optimal, "{what}: optimal");
                        assert_eq!(census.nodes, per_block.nodes, "{what}: nodes");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn symmetry_broken_search_still_proves_identical_block_optima() {
        // five identical 300x300 blocks in a 512x512 tile: one per bin in
        // both disciplines, strictly above the area bound, so the search
        // must run (greedy == 5 > lb) and prove 5 optimal
        let items: Vec<Block> = (0..5).map(|i| blk(300, 300, i)).collect();
        let t = Tile::new(512, 512);
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let r = solve(&items, t, d, Budget::default());
            validate(&r.packing).unwrap();
            assert_eq!(r.packing.n_bins, 5, "{d}");
            assert!(r.optimal, "{d}");
            assert!(r.nodes > 0, "{d}: search must actually run");
        }
    }

    #[test]
    fn lower_bound_classes_matches_block_lower_bound() {
        use crate::nets::zoo;
        for net in [zoo::lenet(), zoo::resnet18()] {
            let ones = vec![1usize; net.n_layers()];
            for tile in [Tile::new(64, 64), Tile::new(256, 256), Tile::new(4096, 512)] {
                let classes = frag::shape_classes(&net, tile, &ones);
                let blocks = frag::fragment_network(&net, tile);
                for d in [Discipline::Dense, Discipline::Pipeline] {
                    assert_eq!(
                        lower_bound_classes(&classes, tile, d),
                        lower_bound(&blocks, tile, d),
                        "{} {tile} {d}",
                        net.name
                    );
                }
            }
        }
        assert_eq!(lower_bound_classes(&[], Tile::new(64, 64), Discipline::Dense), 0);
    }

    #[test]
    fn hint_prunes_nodes_on_demo_instances() {
        // warm-starting with the known optimum should never need more nodes
        // than the cold search
        let items = paper_items();
        let t = Tile::new(512, 512);
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let cold = solve(&items, t, d, Budget::default());
            let warm = solve_with_hint(&items, t, d, Budget::default(), Some(cold.packing.n_bins));
            assert!(
                warm.nodes <= cold.nodes,
                "{d}: warm {} nodes > cold {}",
                warm.nodes,
                cold.nodes
            );
        }
    }
}
