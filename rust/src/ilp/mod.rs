//! Binary linear optimization for the packing problems (paper §2.2).
//!
//! Three layers:
//! * [`simplex`] — dense two-phase LP solver (substrate for lp_solve [36]);
//! * [`model`] + [`bnb`] — the *faithful* Eq. 6/Eq. 7 BILP formulations
//!   solved by LP-bounded branch & bound (demonstrates the paper's method
//!   and its blow-up on larger instances);
//! * [`exact`] — specialized combinatorial branch & bound over the same
//!   solution spaces, fast enough to prove the demo optima and to tighten
//!   greedy incumbents at network scale under a node budget.
//!
//! [`solve_packing`] is the orchestrating entry point used by the sweep
//! and the repro harness ("LPS" rows/curves).

pub mod bnb;
pub mod exact;
pub mod model;
pub mod simplex;

use crate::geom::{Block, Tile};
use crate::pack::{Discipline, PackScratch};

pub use exact::{lower_bound_classes, solve_bins_census, BinsResult, Budget, ExactResult};

/// Solve a packing instance exactly (or best-effort under budget),
/// warm-started by the greedy engines. This is the "LPS" column/curve
/// generator for Table 6 and Fig. 7.
///
/// Engine internal of the [`crate::plan`] front door — build a
/// [`crate::plan::MapRequest`] instead of calling the solver directly.
#[doc(hidden)]
pub fn solve_packing(
    blocks: &[Block],
    tile: Tile,
    discipline: Discipline,
    budget: Budget,
) -> ExactResult {
    exact::solve(blocks, tile, discipline, budget)
}

/// Count-only solve over a materialized block slice: no `Packing` built,
/// the greedy incumbents run through the caller's scratch arena, and an
/// optional upper-bound hint from a neighbouring configuration warm-starts
/// the branch & bound (see [`exact::solve_bins`]). The sweep itself goes
/// further and uses [`solve_bins_census`], which prices from the
/// shape-class census and only materializes blocks when the search runs.
pub fn solve_packing_bins(
    blocks: &[Block],
    tile: Tile,
    discipline: Discipline,
    budget: Budget,
    hint: Option<usize>,
    scratch: &mut PackScratch,
) -> BinsResult {
    exact::solve_bins(blocks, tile, discipline, budget, hint, scratch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::BlockKind;
    use crate::ilp::bnb::BnbConfig;
    use crate::ilp::model::{DenseModel, PipelineModel};
    use crate::pack::placement::validate;

    fn blk(rows: usize, cols: usize, layer: usize) -> Block {
        Block { rows, cols, layer, replica: 0, grid: (0, 0), kind: BlockKind::Sparse }
    }

    fn paper_items() -> Vec<Block> {
        [
            (257, 256), (257, 256), (257, 256), (129, 256), (129, 128),
            (129, 128), (129, 128), (129, 128), (65, 128), (148, 64),
            (65, 64), (65, 64), (65, 64),
        ]
        .iter()
        .enumerate()
        .map(|(i, &(r, c))| blk(r, c, i))
        .collect()
    }

    /// The headline BILP result, via the faithful Eq. 6 formulation:
    /// dense packing of the 13-item list into T(512,512) uses 2 bins.
    /// (Debug builds solve the LP relaxations ~20x slower, so they run the
    /// first 8 items — still cross-validated against the exact search.)
    #[test]
    fn eq6_bilp_dense_demo_two_bins() {
        let tile = Tile::new(512, 512);
        let blocks: Vec<Block> = if cfg!(debug_assertions) {
            paper_items().into_iter().take(8).collect()
        } else {
            paper_items()
        };
        let m = DenseModel::build(&blocks, tile);
        // the specialized search provides the expected optimum
        let seed = exact::solve(&blocks, tile, Discipline::Dense, Budget::default());
        assert!(seed.optimal);
        if !cfg!(debug_assertions) {
            assert_eq!(seed.packing.n_bins, 2, "paper Table 3 headline");
        }
        let r = bnb::solve(&m.lp, &BnbConfig::default(), None);
        let (obj, assign) = r.best.expect("no BILP solution found");
        assert_eq!(obj.round() as usize, seed.packing.n_bins, "Eq.6 optimum");
        let p = m.decode(&blocks, tile, &assign);
        validate(&p).unwrap();
        assert_eq!(p.n_bins, seed.packing.n_bins);
    }

    /// Eq. 7 formulation on a reduced instance (the full 13-item pipeline
    /// BILP needs thousands of LP-bounded nodes — the exact::solve path
    /// covers the full demo; bench_ilp measures the blow-up).
    #[test]
    fn eq7_bilp_small_pipeline() {
        let tile = Tile::new(512, 512);
        let blocks = vec![
            blk(257, 256, 0),
            blk(257, 256, 1),
            blk(129, 256, 2),
            blk(129, 128, 3),
            blk(65, 64, 4),
        ];
        let m = PipelineModel::build(&blocks, tile);
        let r = bnb::solve(&m.lp, &BnbConfig::default(), None);
        let (obj, assign) = r.best.expect("no BILP solution");
        let p = m.decode(&blocks, tile, &assign);
        validate(&p).unwrap();
        // rows: 257+257+129+129+65 = 837 -> >= 2 bins; cols 960 -> >= 2
        // and 2 bins are achievable: {item0,item2,item4},{item1,item3}
        assert_eq!(obj.round() as usize, 2);
        assert_eq!(p.n_bins, 2);
        assert!(r.proven);
    }

    #[test]
    fn solve_packing_matches_specialized() {
        let tile = Tile::new(512, 512);
        let blocks = paper_items();
        let d = solve_packing(&blocks, tile, Discipline::Dense, Budget::default());
        let p = solve_packing(&blocks, tile, Discipline::Pipeline, Budget::default());
        assert_eq!((d.packing.n_bins, p.packing.n_bins), (2, 4));
        assert!(d.optimal && p.optimal);
    }
}
