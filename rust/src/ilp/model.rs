//! BILP formulations of the packing problems (paper Eq. 6 and Eq. 7).
//!
//! These builders produce the *faithful* binary linear programs the paper
//! solves with lp_solve, over items sorted in the order the formulations
//! assume (non-increasing width so any later item fits a shelf initialized
//! by an earlier one).  Variable layout is recorded so solutions can be
//! decoded back into geometric [`Packing`]s.

use super::simplex::{Cmp, Constraint, Lp};
use crate::geom::{Block, Placement, Tile};
use crate::pack::{Discipline, Packing};

/// Dense (Eq. 6) model: shelf packing.
///
/// Variables (items pre-sorted by non-increasing cols, then rows):
/// * `y[j]`    — item j initializes a shelf;
/// * `q[i]`    — item i's shelf initializes a bin;
/// * `x[i][j]`, i<j — item j joins the shelf initialized by item i;
/// * `z[k][i]`, k<i — shelf i goes into the bin initialized by shelf k.
///
/// Objective: minimize Σ q (number of bins).
pub struct DenseModel {
    pub lp: Lp,
    pub order: Vec<usize>, // model item -> index into the original blocks
    n: usize,
}

/// Pipeline (Eq. 7) model: one staircase per bin.
///
/// Variables: `y[j]` bin j used; `x[i][j]`, j <= i — item i in bin j
/// (symmetry breaking: item i may only use the first i+1 bins).
pub struct PipelineModel {
    pub lp: Lp,
    pub order: Vec<usize>,
    n: usize,
}

fn sorted_order(blocks: &[Block]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..blocks.len()).collect();
    order.sort_by(|&a, &b| {
        blocks[b]
            .cols
            .cmp(&blocks[a].cols)
            .then(blocks[b].rows.cmp(&blocks[a].rows))
            .then(a.cmp(&b))
    });
    order
}

impl DenseModel {
    /// Index helpers over the packed variable vector.
    fn y(&self, j: usize) -> usize {
        j
    }
    fn q(&self, i: usize) -> usize {
        self.n + i
    }
    /// x[i][j] for i<j, row-major upper triangle.
    fn x(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < j);
        2 * self.n + tri_index(self.n, i, j)
    }
    fn z(&self, k: usize, i: usize) -> usize {
        debug_assert!(k < i);
        2 * self.n + self.n * (self.n - 1) / 2 + tri_index(self.n, k, i)
    }

    pub fn n_vars(&self) -> usize {
        2 * self.n + self.n * (self.n - 1)
    }

    pub fn build(blocks: &[Block], tile: Tile) -> DenseModel {
        let order = sorted_order(blocks);
        let n = order.len();
        let mut m = DenseModel { lp: Lp::default(), order, n };
        let nv = m.n_vars();
        let rows = |i: usize| blocks[m.order[i]].rows as f64;
        let cols = |i: usize| blocks[m.order[i]].cols as f64;
        let t1 = tile.n_row as f64;
        let t2 = tile.n_col as f64;

        let mut obj = vec![0.0; nv];
        for i in 0..n {
            obj[m.q(i)] = 1.0; // Eq. 6a
        }
        let mut cons: Vec<Constraint> = Vec::new();

        // Eq. 6b: every item joins exactly one shelf (its own or earlier).
        for j in 0..n {
            let mut terms: Vec<(usize, f64)> = (0..j).map(|i| (m.x(i, j), 1.0)).collect();
            terms.push((m.y(j), 1.0));
            cons.push(Constraint { terms, cmp: Cmp::Eq, rhs: 1.0 });
        }
        // Eq. 6c: shelf row capacity: Σ_j rows_j x[i][j] <= (T1 - rows_i) y[i].
        for i in 0..n {
            let mut terms: Vec<(usize, f64)> =
                (i + 1..n).map(|j| (m.x(i, j), rows(j))).collect();
            terms.push((m.y(i), -(t1 - rows(i))));
            cons.push(Constraint { terms, cmp: Cmp::Le, rhs: 0.0 });
        }
        // Eq. 6e: a shelf initializes a bin or joins an earlier shelf's bin.
        for i in 0..n {
            let mut terms: Vec<(usize, f64)> = (0..i).map(|k| (m.z(k, i), 1.0)).collect();
            terms.push((m.q(i), 1.0));
            terms.push((m.y(i), -1.0));
            cons.push(Constraint { terms, cmp: Cmp::Eq, rhs: 0.0 });
        }
        // Eq. 6d: bin column capacity: Σ_i cols_i z[k][i] <= (T2 - cols_k) q[k].
        for k in 0..n {
            let mut terms: Vec<(usize, f64)> =
                (k + 1..n).map(|i| (m.z(k, i), cols(i))).collect();
            terms.push((m.q(k), -(t2 - cols(k))));
            cons.push(Constraint { terms, cmp: Cmp::Le, rhs: 0.0 });
        }
        // binary upper bounds
        for v in 0..nv {
            cons.push(Constraint { terms: vec![(v, 1.0)], cmp: Cmp::Le, rhs: 1.0 });
        }
        m.lp = Lp { n_vars: nv, objective: obj, constraints: cons };
        m
    }

    /// Decode a 0/1 assignment into a geometric packing.
    pub fn decode(&self, blocks: &[Block], tile: Tile, assignment: &[u8]) -> Packing {
        let n = self.n;
        // shelf membership
        let mut shelf_of = vec![usize::MAX; n];
        for j in 0..n {
            if assignment[self.y(j)] == 1 {
                shelf_of[j] = j;
            } else {
                for i in 0..j {
                    if assignment[self.x(i, j)] == 1 {
                        shelf_of[j] = i;
                    }
                }
            }
        }
        // bin membership of shelves
        let mut bin_of_shelf = vec![usize::MAX; n];
        let mut bin_ids = Vec::new();
        for i in 0..n {
            if assignment[self.y(i)] == 0 {
                continue;
            }
            if assignment[self.q(i)] == 1 {
                bin_of_shelf[i] = bin_ids.len();
                bin_ids.push(i);
            }
        }
        for i in 0..n {
            if assignment[self.y(i)] == 1 && bin_of_shelf[i] == usize::MAX {
                for k in 0..i {
                    if assignment[self.z(k, i)] == 1 {
                        bin_of_shelf[i] = bin_of_shelf[k];
                    }
                }
            }
        }
        // geometric layout: shelves side by side (x), members stacked (y)
        let mut shelf_x = vec![0usize; n];
        let mut bin_col_used = vec![0usize; bin_ids.len()];
        for i in 0..n {
            if assignment[self.y(i)] == 1 {
                let b = bin_of_shelf[i];
                shelf_x[i] = bin_col_used[b];
                bin_col_used[b] += blocks[self.order[i]].cols;
            }
        }
        let mut shelf_fill = vec![0usize; n];
        let mut placements = Vec::with_capacity(n);
        for j in 0..n {
            let sh = shelf_of[j];
            let b = bin_of_shelf[sh];
            placements.push(Placement {
                block: self.order[j],
                bin: b,
                x: shelf_x[sh],
                y: shelf_fill[sh],
            });
            shelf_fill[sh] += blocks[self.order[j]].rows;
        }
        Packing {
            tile,
            discipline: Discipline::Dense,
            blocks: blocks.to_vec(),
            placements,
            n_bins: bin_ids.len(),
        }
    }
}

impl PipelineModel {
    fn y(&self, j: usize) -> usize {
        j
    }
    /// x[i][j] defined for j <= i (symmetry breaking).
    fn x(&self, i: usize, j: usize) -> usize {
        debug_assert!(j <= i);
        self.n + i * (i + 1) / 2 + j
    }

    pub fn n_vars(&self) -> usize {
        self.n + self.n * (self.n + 1) / 2
    }

    pub fn build(blocks: &[Block], tile: Tile) -> PipelineModel {
        let order = sorted_order(blocks);
        let n = order.len();
        let mut m = PipelineModel { lp: Lp::default(), order, n };
        let nv = m.n_vars();
        let rows = |i: usize| blocks[m.order[i]].rows as f64;
        let cols = |i: usize| blocks[m.order[i]].cols as f64;

        let mut obj = vec![0.0; nv];
        for j in 0..n {
            obj[m.y(j)] = 1.0; // Eq. 7a
        }
        let mut cons: Vec<Constraint> = Vec::new();
        // Eq. 7b (per item): Σ_j x[i][j] = 1
        for i in 0..n {
            let terms: Vec<(usize, f64)> = (0..=i).map(|j| (m.x(i, j), 1.0)).collect();
            cons.push(Constraint { terms, cmp: Cmp::Eq, rhs: 1.0 });
        }
        // Eq. 7c/7d: bin word-line and bit-line capacity.
        for j in 0..n {
            let mut rterms: Vec<(usize, f64)> =
                (j..n).map(|i| (m.x(i, j), rows(i))).collect();
            rterms.push((m.y(j), -(tile.n_row as f64)));
            cons.push(Constraint { terms: rterms, cmp: Cmp::Le, rhs: 0.0 });
            let mut cterms: Vec<(usize, f64)> =
                (j..n).map(|i| (m.x(i, j), cols(i))).collect();
            cterms.push((m.y(j), -(tile.n_col as f64)));
            cons.push(Constraint { terms: cterms, cmp: Cmp::Le, rhs: 0.0 });
        }
        // Eq. 7e is implied by the capacity rows when rows/cols > 0, but we
        // keep the explicit link for items that are degenerate in one dim.
        for i in 0..n {
            for j in 0..=i {
                cons.push(Constraint {
                    terms: vec![(m.x(i, j), 1.0), (m.y(j), -1.0)],
                    cmp: Cmp::Le,
                    rhs: 0.0,
                });
            }
        }
        // symmetry: bins open in order
        for j in 1..n {
            cons.push(Constraint {
                terms: vec![(m.y(j), 1.0), (m.y(j - 1), -1.0)],
                cmp: Cmp::Le,
                rhs: 0.0,
            });
        }
        for v in 0..nv {
            cons.push(Constraint { terms: vec![(v, 1.0)], cmp: Cmp::Le, rhs: 1.0 });
        }
        m.lp = Lp { n_vars: nv, objective: obj, constraints: cons };
        m
    }

    /// Decode a 0/1 assignment into a staircase packing.
    pub fn decode(&self, blocks: &[Block], tile: Tile, assignment: &[u8]) -> Packing {
        let n = self.n;
        let mut bin_of = vec![usize::MAX; n];
        for i in 0..n {
            for j in 0..=i {
                if assignment[self.x(i, j)] == 1 {
                    bin_of[i] = j;
                }
            }
        }
        let used: Vec<usize> = {
            let mut u: Vec<usize> = bin_of.clone();
            u.sort_unstable();
            u.dedup();
            u
        };
        let remap = |j: usize| used.iter().position(|&u| u == j).unwrap();
        let mut rows_used = vec![0usize; used.len()];
        let mut cols_used = vec![0usize; used.len()];
        let mut placements = Vec::with_capacity(n);
        for i in 0..n {
            let b = remap(bin_of[i]);
            placements.push(Placement {
                block: self.order[i],
                bin: b,
                x: cols_used[b],
                y: rows_used[b],
            });
            rows_used[b] += blocks[self.order[i]].rows;
            cols_used[b] += blocks[self.order[i]].cols;
        }
        Packing {
            tile,
            discipline: Discipline::Pipeline,
            blocks: blocks.to_vec(),
            placements,
            n_bins: used.len(),
        }
    }
}

/// Upper-triangle linear index for (i, j) with i < j over n items.
fn tri_index(n: usize, i: usize, j: usize) -> usize {
    debug_assert!(i < j && j < n);
    i * n - i * (i + 1) / 2 + (j - i - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::BlockKind;

    fn blk(rows: usize, cols: usize, layer: usize) -> Block {
        Block { rows, cols, layer, replica: 0, grid: (0, 0), kind: BlockKind::Sparse }
    }

    #[test]
    fn tri_index_is_bijection() {
        let n = 7;
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..n {
            for j in i + 1..n {
                assert!(seen.insert(tri_index(n, i, j)));
            }
        }
        assert_eq!(seen.len(), n * (n - 1) / 2);
        assert_eq!(*seen.iter().max().unwrap(), n * (n - 1) / 2 - 1);
    }

    #[test]
    fn dense_model_var_counts() {
        let blocks = vec![blk(2, 2, 0), blk(2, 2, 1), blk(2, 2, 2)];
        let m = DenseModel::build(&blocks, Tile::new(4, 4));
        assert_eq!(m.n_vars(), 2 * 3 + 3 * 2); // y,q + x,z triangles
        assert_eq!(m.lp.n_vars, m.n_vars());
        // 3 Eq6b + 3 Eq6c + 3 Eq6e + 3 Eq6d + bounds
        assert_eq!(m.lp.constraints.len(), 12 + m.n_vars());
    }

    #[test]
    fn pipeline_model_var_counts() {
        let blocks = vec![blk(2, 2, 0), blk(2, 2, 1), blk(2, 2, 2)];
        let m = PipelineModel::build(&blocks, Tile::new(4, 4));
        assert_eq!(m.n_vars(), 3 + 6);
        let n_link = 6; // x<=y pairs
        let n_sym = 2;
        assert_eq!(m.lp.constraints.len(), 3 + 6 + n_link + n_sym + m.n_vars());
    }

    #[test]
    fn order_sorted_by_cols_desc() {
        let blocks = vec![blk(1, 10, 0), blk(1, 30, 1), blk(1, 20, 2)];
        let m = DenseModel::build(&blocks, Tile::new(64, 64));
        assert_eq!(m.order, vec![1, 2, 0]);
    }

    #[test]
    fn dense_decode_single_shelf() {
        // two items stacked in one shelf in one bin
        let blocks = vec![blk(2, 4, 0), blk(2, 3, 1)];
        let m = DenseModel::build(&blocks, Tile::new(8, 8));
        let mut a = vec![0u8; m.n_vars()];
        a[m.y(0)] = 1;
        a[m.q(0)] = 1;
        a[m.x(0, 1)] = 1;
        let p = m.decode(&blocks, Tile::new(8, 8), &a);
        assert_eq!(p.n_bins, 1);
        crate::pack::placement::validate(&p).unwrap();
        // stacked along rows at the same x
        assert_eq!(p.placements[0].x, p.placements[1].x);
        assert_ne!(p.placements[0].y, p.placements[1].y);
    }

    #[test]
    fn pipeline_decode_staircase() {
        let blocks = vec![blk(2, 2, 0), blk(3, 3, 1)];
        let m = PipelineModel::build(&blocks, Tile::new(8, 8));
        let mut a = vec![0u8; m.n_vars()];
        a[m.y(0)] = 1;
        a[m.x(0, 0)] = 1;
        a[m.x(1, 0)] = 1;
        let p = m.decode(&blocks, Tile::new(8, 8), &a);
        assert_eq!(p.n_bins, 1);
        crate::pack::placement::validate(&p).unwrap();
    }
}
