//! Dense two-phase primal simplex for linear programs.
//!
//! Substrate for the binary linear optimization of §2.2 (the paper used
//! lp_solve [36]): solves `min c·x  s.t.  A x {<=,=,>=} b, x >= 0`.
//! Bland's anti-cycling rule, explicit artificial variables, dense tableau.
//! Problem sizes here are the LP relaxations of Eq. 6/Eq. 7 at demo scale
//! (hundreds of rows/columns), for which a dense tableau is the right tool.

/// Constraint comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Eq,
    Ge,
}

/// A sparse linear constraint `Σ coef_i · x_i  (cmp)  rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub terms: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// LP in natural form: minimize `objective · x` subject to `constraints`,
/// `x >= 0`.
#[derive(Debug, Clone, Default)]
pub struct Lp {
    pub n_vars: usize,
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

/// Simplex outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal { objective: f64, x: Vec<f64> },
    Infeasible,
    Unbounded,
    IterationLimit,
}

const EPS: f64 = 1e-9;

/// Solve the LP with two-phase dense simplex.
pub fn solve(lp: &Lp) -> LpResult {
    let m = lp.constraints.len();
    let n = lp.n_vars;
    assert_eq!(lp.objective.len(), n, "objective arity");

    // Normalize rows to b >= 0 and count slack/artificial columns.
    // Column layout: [x (n)] [slack/surplus (n_slack)] [artificial (n_art)]
    let mut n_slack = 0usize;
    let mut n_art = 0usize;
    struct RowPlan {
        flip: bool,
        slack: Option<(usize, f64)>, // (col offset within slack, sign)
        art: Option<usize>,          // col offset within artificials
    }
    let mut plans = Vec::with_capacity(m);
    for c in &lp.constraints {
        let flip = c.rhs < 0.0;
        let cmp = if flip {
            match c.cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            }
        } else {
            c.cmp
        };
        let (slack, art) = match cmp {
            Cmp::Le => {
                let s = Some((n_slack, 1.0));
                n_slack += 1;
                (s, None)
            }
            Cmp::Ge => {
                let s = Some((n_slack, -1.0));
                n_slack += 1;
                let a = Some(n_art);
                n_art += 1;
                (s, a)
            }
            Cmp::Eq => {
                let a = Some(n_art);
                n_art += 1;
                (None, a)
            }
        };
        plans.push(RowPlan { flip, slack, art });
    }

    let total = n + n_slack + n_art;
    // tableau: m rows x (total + 1) cols (last = rhs)
    let mut t = vec![vec![0.0f64; total + 1]; m];
    let mut basis = vec![usize::MAX; m];
    for (i, (c, plan)) in lp.constraints.iter().zip(&plans).enumerate() {
        let sign = if plan.flip { -1.0 } else { 1.0 };
        for &(j, v) in &c.terms {
            assert!(j < n, "constraint references var {j} >= n_vars {n}");
            t[i][j] += sign * v;
        }
        t[i][total] = sign * c.rhs;
        if let Some((off, s)) = plan.slack {
            t[i][n + off] = s;
            if s > 0.0 {
                basis[i] = n + off;
            }
        }
        if let Some(off) = plan.art {
            t[i][n + n_slack + off] = 1.0;
            basis[i] = n + n_slack + off;
        }
        debug_assert!(basis[i] != usize::MAX);
    }

    let max_iters = 50 * (m + total).max(100);

    // ---- Phase 1: minimize sum of artificials ----
    if n_art > 0 {
        // objective c[a_k] = 1 for artificials; express in terms of the
        // starting basis by subtracting each artificial-basic row, which
        // zeroes the basic artificial columns and accumulates -b in rhs.
        let mut cost = vec![0.0f64; total + 1];
        for k in 0..n_art {
            cost[n + n_slack + k] = 1.0;
        }
        for i in 0..m {
            if basis[i] >= n + n_slack {
                for j in 0..=total {
                    cost[j] -= t[i][j];
                }
            }
        }
        match pivot_loop(&mut t, &mut basis, &mut cost, total, max_iters) {
            PivotOutcome::Done => {}
            PivotOutcome::Unbounded => return LpResult::Infeasible, // phase-1 bounded by 0
            PivotOutcome::Limit => return LpResult::IterationLimit,
        }
        if -cost[total] > 1e-7 {
            return LpResult::Infeasible;
        }
        // Drive remaining artificials out of the basis where possible.
        for i in 0..m {
            if basis[i] >= n + n_slack {
                if let Some(j) = (0..n + n_slack).find(|&j| t[i][j].abs() > EPS) {
                    pivot(&mut t, &mut basis, i, j, total);
                } // else the row is redundant (all-zero): harmless
            }
        }
    }

    // ---- Phase 2: minimize the real objective ----
    let mut cost = vec![0.0f64; total + 1];
    for j in 0..n {
        cost[j] = lp.objective[j];
    }
    // express objective in terms of non-basic variables
    for i in 0..m {
        let bj = basis[i];
        if bj < total && cost[bj].abs() > EPS {
            let factor = cost[bj];
            for j in 0..=total {
                cost[j] -= factor * t[i][j];
            }
        }
    }
    // forbid artificials from re-entering
    let art_start = n + n_slack;

    let outcome = pivot_loop_restricted(&mut t, &mut basis, &mut cost, total, art_start, max_iters);
    match outcome {
        PivotOutcome::Unbounded => return LpResult::Unbounded,
        PivotOutcome::Limit => return LpResult::IterationLimit,
        PivotOutcome::Done => {}
    }

    let mut x = vec![0.0f64; n];
    for i in 0..m {
        if basis[i] < n {
            x[basis[i]] = t[i][total];
        }
    }
    let objective = lp.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
    LpResult::Optimal { objective, x }
}

enum PivotOutcome {
    Done,
    Unbounded,
    Limit,
}

fn pivot_loop(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    cost: &mut [f64],
    total: usize,
    max_iters: usize,
) -> PivotOutcome {
    pivot_loop_restricted(t, basis, cost, total, total, max_iters)
}

/// Simplex pivoting; columns >= `col_limit` are excluded from entering.
fn pivot_loop_restricted(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    cost: &mut [f64],
    total: usize,
    col_limit: usize,
    max_iters: usize,
) -> PivotOutcome {
    let m = t.len();
    for iter in 0..max_iters {
        // entering column: Dantzig rule, Bland fallback after stalling
        let bland = iter > max_iters / 2;
        let mut enter = usize::MAX;
        if bland {
            for j in 0..col_limit {
                if cost[j] < -EPS {
                    enter = j;
                    break;
                }
            }
        } else {
            let mut best = -EPS;
            for j in 0..col_limit {
                if cost[j] < best {
                    best = cost[j];
                    enter = j;
                }
            }
        }
        if enter == usize::MAX {
            return PivotOutcome::Done;
        }
        // leaving row: min ratio; Bland tie-break on basis index
        let mut leave = usize::MAX;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i][enter] > EPS {
                let ratio = t[i][total] / t[i][enter];
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave != usize::MAX
                        && basis[i] < basis[leave])
                {
                    best_ratio = ratio;
                    leave = i;
                }
            }
        }
        if leave == usize::MAX {
            return PivotOutcome::Unbounded;
        }
        pivot_with_cost(t, basis, cost, leave, enter, total);
    }
    PivotOutcome::Limit
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let piv = t[row][col];
    debug_assert!(piv.abs() > EPS);
    for j in 0..=total {
        t[row][j] /= piv;
    }
    for i in 0..t.len() {
        if i != row && t[i][col].abs() > EPS {
            let f = t[i][col];
            for j in 0..=total {
                t[i][j] -= f * t[row][j];
            }
        }
    }
    basis[row] = col;
}

fn pivot_with_cost(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    cost: &mut [f64],
    row: usize,
    col: usize,
    total: usize,
) {
    pivot(t, basis, row, col, total);
    if cost[col].abs() > EPS {
        let f = cost[col];
        for j in 0..=total {
            cost[j] -= f * t[row][j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lp(n: usize, obj: &[f64], cons: &[(&[(usize, f64)], Cmp, f64)]) -> Lp {
        Lp {
            n_vars: n,
            objective: obj.to_vec(),
            constraints: cons
                .iter()
                .map(|(t, c, r)| Constraint { terms: t.to_vec(), cmp: *c, rhs: *r })
                .collect(),
        }
    }

    fn assert_optimal(r: LpResult, want_obj: f64, want_x: Option<&[f64]>) {
        match r {
            LpResult::Optimal { objective, x } => {
                assert!((objective - want_obj).abs() < 1e-6, "obj {objective} want {want_obj}");
                if let Some(w) = want_x {
                    for (i, (a, b)) in x.iter().zip(w).enumerate() {
                        assert!((a - b).abs() < 1e-6, "x[{i}] {a} want {b}");
                    }
                }
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_max_as_min() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18  => opt 36 at (2,6)
        let r = solve(&lp(
            2,
            &[-3.0, -5.0],
            &[
                (&[(0, 1.0)], Cmp::Le, 4.0),
                (&[(1, 2.0)], Cmp::Le, 12.0),
                (&[(0, 3.0), (1, 2.0)], Cmp::Le, 18.0),
            ],
        ));
        assert_optimal(r, -36.0, Some(&[2.0, 6.0]));
    }

    #[test]
    fn equality_constraints() {
        // min x + y s.t. x + y = 5, x - y = 1 => (3,2), obj 5
        let r = solve(&lp(
            2,
            &[1.0, 1.0],
            &[
                (&[(0, 1.0), (1, 1.0)], Cmp::Eq, 5.0),
                (&[(0, 1.0), (1, -1.0)], Cmp::Eq, 1.0),
            ],
        ));
        assert_optimal(r, 5.0, Some(&[3.0, 2.0]));
    }

    #[test]
    fn ge_constraints() {
        // min 2x + 3y s.t. x + y >= 4, x >= 1 => (4,0) obj 8
        let r = solve(&lp(
            2,
            &[2.0, 3.0],
            &[
                (&[(0, 1.0), (1, 1.0)], Cmp::Ge, 4.0),
                (&[(0, 1.0)], Cmp::Ge, 1.0),
            ],
        ));
        assert_optimal(r, 8.0, Some(&[4.0, 0.0]));
    }

    #[test]
    fn infeasible_detected() {
        let r = solve(&lp(
            1,
            &[1.0],
            &[
                (&[(0, 1.0)], Cmp::Le, 1.0),
                (&[(0, 1.0)], Cmp::Ge, 2.0),
            ],
        ));
        assert_eq!(r, LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x s.t. x >= 0 (no upper bound)
        let r = solve(&lp(1, &[-1.0], &[(&[(0, 1.0)], Cmp::Ge, 0.0)]));
        assert_eq!(r, LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // min x s.t. -x <= -3  (i.e. x >= 3)
        let r = solve(&lp(1, &[1.0], &[(&[(0, -1.0)], Cmp::Le, -3.0)]));
        assert_optimal(r, 3.0, Some(&[3.0]));
    }

    #[test]
    fn degenerate_lp_terminates() {
        // several redundant constraints through the same vertex
        let r = solve(&lp(
            2,
            &[-1.0, -1.0],
            &[
                (&[(0, 1.0), (1, 1.0)], Cmp::Le, 2.0),
                (&[(0, 2.0), (1, 2.0)], Cmp::Le, 4.0),
                (&[(0, 1.0)], Cmp::Le, 2.0),
                (&[(1, 1.0)], Cmp::Le, 2.0),
            ],
        ));
        assert_optimal(r, -2.0, None);
    }

    #[test]
    fn bin_packing_lp_relaxation_fractional() {
        // 3 unit items, bins of capacity 2: LP uses 1.5 bins.
        // vars: y0..y2 bin-open, x[i][j] item i in bin j (9 vars, offset 3)
        let xv = |i: usize, j: usize| 3 + i * 3 + j;
        let mut cons: Vec<Constraint> = Vec::new();
        for i in 0..3 {
            cons.push(Constraint {
                terms: (0..3).map(|j| (xv(i, j), 1.0)).collect(),
                cmp: Cmp::Eq,
                rhs: 1.0,
            });
        }
        for j in 0..3 {
            let mut terms: Vec<(usize, f64)> = (0..3).map(|i| (xv(i, j), 1.0)).collect();
            terms.push((j, -2.0));
            cons.push(Constraint { terms, cmp: Cmp::Le, rhs: 0.0 });
        }
        for j in 0..3 {
            cons.push(Constraint { terms: vec![(j, 1.0)], cmp: Cmp::Le, rhs: 1.0 });
        }
        let mut obj = vec![0.0; 12];
        obj[0] = 1.0;
        obj[1] = 1.0;
        obj[2] = 1.0;
        let r = solve(&Lp { n_vars: 12, objective: obj, constraints: cons });
        match r {
            LpResult::Optimal { objective, .. } => {
                assert!((objective - 1.5).abs() < 1e-6, "obj {objective}")
            }
            other => panic!("{other:?}"),
        }
    }
}
