//! # xbarmap
//!
//! Reproduction of *"A Simple Packing Algorithm for Optimized Mapping of
//! Artificial Neural Networks onto Non-Volatile Memory Cross-Bar Arrays"*
//! (W. Haensch, 2024).
//!
//! The library maps the layers of an artificial neural network onto a set of
//! fixed-capacity physical cross-bar array tiles, treating the mapping as a
//! two-dimensional bin-packing problem, and searches over tile array
//! dimensions (capacity and aspect ratio) for the configuration that
//! minimises total tile area under a chosen design objective:
//!
//! * **dense packing** — maximum weight-storage density, shared input/output
//!   lines allowed (no pipelining),
//! * **pipeline packing** — non-overlapping input/output channels so that all
//!   network layers can operate simultaneously,
//! * **RAPA** — replicated arrays with permuted assignment for load-balanced
//!   pipelined CNN throughput.
//!
//! Three packing engines are provided: the paper's *simple packing
//! algorithm* ([`pack::simple`]), classical first-fit-decreasing baselines
//! ([`pack::ffd`]), and an exact branch-and-bound **binary linear
//! optimization** solver ([`ilp`]) implementing the paper's Eq. 6 (dense)
//! and Eq. 7 (pipeline) formulations (substituting the paper's lp_solve).
//!
//! The §3.1 tile-dimension search ([`opt::sweep`]) is a parallel,
//! allocation-lean evaluation engine: grid points fan out over scoped
//! worker threads with deterministic result ordering, each worker reuses a
//! scratch arena (fragmentation + packing buffers) across the grid points
//! it evaluates, and ILP points warm-start from neighbouring
//! configurations. [`coordinator::batched_sweep`] serves many networks'
//! sweeps concurrently; [`opt::sweep_serial`] is the reference loop the
//! determinism suite pins the engine against.
//!
//! The numerical hot path (analog tile matrix-vector product with DAC/ADC
//! quantisation) is an AOT-compiled JAX/Pallas kernel executed from Rust
//! through the PJRT C API ([`runtime`], behind the `pjrt` cargo feature);
//! Python never runs at request time.
pub mod geom;
pub mod nets;
pub mod frag;
pub mod pack;
pub mod ilp;
pub mod area;
pub mod perf;
pub mod opt;
pub mod sim;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod util;
