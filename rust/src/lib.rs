//! # xbarmap
//!
//! Reproduction of *"A Simple Packing Algorithm for Optimized Mapping of
//! Artificial Neural Networks onto Non-Volatile Memory Cross-Bar Arrays"*
//! (W. Haensch, 2024), grown into a servable mapping engine.
//!
//! The library maps the layers of an artificial neural network onto a set of
//! fixed-capacity physical cross-bar array tiles, treating the mapping as a
//! two-dimensional bin-packing problem, and searches over tile array
//! dimensions (capacity and aspect ratio) for the configuration that
//! optimises a chosen design objective.
//!
//! ## The front door: [`plan`]
//!
//! All of that is driven through one typed, serializable API — build a
//! [`plan::MapRequest`], validate it into a [`plan::Planner`], get a
//! [`plan::MapPlan`]:
//!
//! ```no_run
//! use xbarmap::plan::MapRequest;
//! use xbarmap::pack::Discipline;
//! use xbarmap::opt::Engine;
//!
//! // §3.1 sweep: every tile dimension 2^6..2^13 x aspects 1..8, priced
//! // with the paper's area model, optimum = minimum total tile area.
//! let plan = MapRequest::zoo("resnet18")
//!     .discipline(Discipline::Pipeline)
//!     .engine(Engine::Simple)
//!     .build()
//!     .unwrap()
//!     .plan()
//!     .unwrap();
//! println!("{} tiles of {} at {} mm2", plan.best.n_tiles, plan.best.tile,
//!          plan.best.total_area_mm2);
//!
//! // One fixed tile, with explicit per-tile placements:
//! let packed = MapRequest::zoo("lenet").tile(256, 256).placements(true)
//!     .build().unwrap().plan().unwrap();
//! assert!(packed.placements.is_some());
//! ```
//!
//! Requests select the network (zoo name or inline layer spec), the tile
//! space (fixed tile or §3.1 grid), the packing discipline and engine, the
//! design objective (min-area | min-tiles | max-throughput), RAPA
//! replication, the ILP node budget and the sweep worker count. Plans carry
//! every evaluated point, the per-aspect minima, the chosen optimum,
//! optional placements, Eq. 3/4 latency/throughput, and provenance (budget
//! spent, warm-start hits, proof status).
//!
//! Both sides have a versioned JSON wire format ([`plan::wire`], `"v":1`):
//! [`plan::serve_jsonl`] streams JSONL requests to JSONL plans (the
//! `xbarmap plan` subcommand), and [`plan::serve_batch`] prices many
//! decoded requests concurrently for multi-tenant serving.
//!
//! For an always-on deployment, [`service`] wires the same wire format
//! into a long-running TCP listener — `xbarmap serve --plans --addr
//! HOST:PORT` — with a bounded request queue feeding a shared worker pool
//! (fair interleaving across connections, backpressure instead of
//! unbounded buffering), a canonical-request LRU plan cache with an
//! optional TTL, per-connection request quotas and a service-wide
//! in-flight admission cap (typed `"reject"` frames on the same wire),
//! graceful SIGINT/SIGTERM shutdown that drains in-flight plans, in-band
//! `{"v":1,"cmd":"stats"}` / `{"v":1,"cmd":"metrics"}` requests reporting
//! counters and p50/p95 plan latency, and a periodic `--metrics-out`
//! gauge snapshot in the `BENCH_*.json` schema. The failure envelope is
//! typed too: a panicking solve is contained to its one request
//! (`"reject":"internal"`, worker survives), and `--deadline-ms` arms a
//! per-solve wall-clock [`util::deadline::Deadline`] threaded through the
//! sweep and kernel checkpoints (`"reject":"deadline"`). [`plan::client`]
//! is the matching retrying client. Behind the LRU, [`store`] adds a
//! persistent second cache tier — an append-only on-disk plan warehouse
//! (`--warehouse DIR`) with torn-tail-tolerant boot, offline precompute
//! (`xbarmap warehouse precompute`) and compaction — and concurrent
//! misses on one canonical key are single-flight coalesced so a
//! thundering herd costs one solve. Per connection, responses are
//! byte-identical to piping the same stream through
//! [`plan::serve_jsonl`]. For fault isolation beyond one process,
//! [`cluster`] shards the same wire across N supervised `serve --plans`
//! worker processes (`--cluster N`): consistent-hash routing on the
//! canonical request key, automatic respawn of crashed or hung workers,
//! replay of the responses a dead worker still owed, and a degraded mode
//! that answers from the router's embedded planner when a shard stays
//! down — all without breaking per-connection byte-identity. The wire
//! protocol is specified normatively in `docs/WIRE.md`;
//! `docs/ARCHITECTURE.md` maps the paper's equations to the modules
//! below.
//!
//! ## Under the hood
//!
//! * **Disciplines** (paper §2.2): *dense* shelf packing (maximum density,
//!   shared input/output lines) and *pipeline* staircase packing
//!   (non-overlapping channels so all layers operate simultaneously), plus
//!   RAPA replication for load-balanced pipelined CNN throughput.
//! * **Engines**: the paper's *simple packing algorithm* ([`pack::simple`]),
//!   first-fit-decreasing baselines ([`pack::ffd`]), and an exact
//!   branch-and-bound **binary linear optimization** solver ([`ilp`])
//!   implementing the paper's Eq. 6/Eq. 7 formulations.
//! * **Counted kernels** ([`frag::ShapeClass`] + [`pack::counted`]):
//!   Eq. 5 fragmentation produces at most four distinct block shapes per
//!   layer, so bin counts are computed in closed form over an O(layers)
//!   shape-class census instead of materializing and sorting O(blocks) —
//!   exactly equal (bit-identical efficiencies) to the per-block engines,
//!   and the default pricing path whenever placements aren't requested
//!   (`MapPlan::provenance.counted`).
//! * **Sweep** ([`opt`]): a parallel, counted §3.1 evaluation engine —
//!   every grid point is an independent task fanned over scoped workers
//!   with deterministic ordering, per-worker scratch arenas, and ILP
//!   warm-starts from counted simple-engine hints. The planner is its only
//!   intended caller; the stage functions stay available as
//!   `#[doc(hidden)]` internals.
//! * **Serving** ([`coordinator`]): batched inference through the
//!   AOT-compiled JAX/Pallas crossbar kernel via the PJRT C API
//!   ([`runtime`], behind the `pjrt` cargo feature) — Python never runs at
//!   request time — with the deployment mapped and priced by the planner.
// Public items must be documented. The serving surface (`plan`,
// `service`, `cluster`, `store`, `util`), the packing/optimization core
// (`pack`, `opt`, `lint`), the model zoo (`nets`, `frag`, `perf`,
// `report`) and the geometry/area substrate (`geom`, `area`) are fully
// audited; the modules below still carry per-module allows — remove
// one, fix what `cargo doc` flags (CI runs the doc build with warnings
// denied), repeat. `xbarlint`'s ledger-sync rule fails CI both on a new
// undocumented item in an audited module and on an allow that outlived
// its last undocumented item.
#![warn(missing_docs)]

pub mod geom;
pub mod lint;
pub mod nets;
pub mod frag;
pub mod pack;
#[allow(missing_docs)]
pub mod ilp;
pub mod area;
pub mod perf;
pub mod opt;
pub mod plan;
pub mod service;
pub mod cluster;
pub mod store;
#[allow(missing_docs)]
pub mod sim;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod coordinator;
pub mod report;
pub mod util;
