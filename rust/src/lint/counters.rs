//! Rule `counter`: every counter the wire serializes must actually be
//! incremented on a live path.
//!
//! [`wire_drift`](super::wire_drift) keeps the *name sets* of the
//! serializers, decoders and spec in lockstep, but a counter can pass
//! all four of those checks while being a zero forever: declared in
//! `StatsSnapshot`, serialized by `counters_to_obj`, documented in
//! `docs/WIRE.md` — and never bumped anywhere. That is exactly the
//! failure mode of wiring a new reject class (e.g. `tenant_rejects`)
//! through the frames but forgetting the `+= 1` in the service loop:
//! dashboards read a permanently flat line and nobody notices. This
//! rule extracts the serializer key set from `counters_to_obj` and
//! requires an identifier-boundary `key +=` increment in non-test code
//! somewhere under `service/` or `cluster/` for each key.
//!
//! Derived keys (`plan_p50_s`, `plan_p95_s` — percentiles folded from
//! latency samples at snapshot time, not monotonic counters) are
//! exempt via [`DERIVED`].

use super::scan::Source;
use super::wire_drift::{fn_body, set_arg_keys};
use super::{Finding, RULE_COUNTER};
use std::collections::BTreeSet;

/// Serializer keys that are derived measurements rather than monotonic
/// `+=` counters: the latency percentiles are computed from the sample
/// ring at snapshot time, so no increment site exists by design.
pub const DERIVED: &[&str] = &["plan_p50_s", "plan_p95_s"];

/// Check that every counter key serialized by `counters_to_obj` in
/// `wire_rs` (the text of `plan/wire.rs`) has at least one
/// identifier-boundary `key +=` increment in the non-test code of
/// `sources` — `(repo-relative path, text)` pairs drawn from
/// `rust/src/service/` and `rust/src/cluster/`.
pub fn check_texts(wire_rs: &str, sources: &[(String, String)]) -> Vec<Finding> {
    let wire = Source::parse(wire_rs);
    let mut keys = set_arg_keys(&fn_body(&wire, "counters_to_obj"));
    for derived in DERIVED {
        keys.remove(*derived);
    }

    let mut incremented: BTreeSet<String> = BTreeSet::new();
    for (_, text) in sources {
        let src = Source::parse(text);
        for ln in &src.lines {
            if ln.in_test {
                continue;
            }
            for key in &keys {
                if !incremented.contains(key.as_str()) && has_increment(&ln.code, key) {
                    incremented.insert(key.clone());
                }
            }
        }
    }

    keys.difference(&incremented)
        .map(|key| Finding {
            rule: RULE_COUNTER,
            path: "rust/src/plan/wire.rs".to_string(),
            line: 1,
            message: format!(
                "counter '{key}' is serialized by counters_to_obj but never incremented \
                 (`{key} +=`) on a non-test path under service/ or cluster/ — it will \
                 report zero forever"
            ),
        })
        .collect()
}

/// Whether `code` (string literals already blanked by the scanner)
/// contains `key +=` with an identifier boundary on the left of `key`,
/// so `served +=` matches `s.served += 1` but neither `observed +=`
/// nor `served_total +=` count for key `served`.
fn has_increment(code: &str, key: &str) -> bool {
    let mut pos = 0usize;
    while let Some(p) = code[pos..].find(key) {
        let at = pos + p;
        let boundary =
            code[..at].chars().next_back().map_or(true, |c| !c.is_alphanumeric() && c != '_');
        if boundary && code[at + key.len()..].trim_start().starts_with("+=") {
            return true;
        }
        pos = at + key.len();
    }
    false
}
