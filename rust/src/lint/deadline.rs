//! Rule `deadline`: cancellation coverage in the solver loops.
//!
//! The service's per-request deadline
//! ([`crate::util::deadline::Deadline`]) only works if every solver
//! hot loop polls it — a kernel that never checks `.expired()` is
//! unkillable, and one slow request then holds a worker past its
//! budget (the supervisor's only remedy is killing the whole shard).
//! Each file listed in [`SOLVER_FILES`] must mention `Deadline` and
//! contain at least one `.expired()` checkpoint in non-test code;
//! token scan, by design — reachability from the public entry points
//! is what the deadline integration tests pin, this rule just stops a
//! new kernel module from silently shipping without the check.

use super::scan::Source;
use super::{Finding, RULE_DEADLINE};

/// Solver-loop files that must poll the deadline (relative to
/// `rust/src`). A new solver family joins this list when it lands.
pub const SOLVER_FILES: &[&str] = &["opt/mod.rs", "pack/counted.rs", "ilp/exact.rs"];

/// Check one solver file's text; `label` names it in findings.
pub fn check_text(label: &str, text: &str) -> Vec<Finding> {
    let src = Source::parse(text);
    let blob: String = src
        .lines
        .iter()
        .filter(|ln| !ln.in_test)
        .map(|ln| ln.code.as_str())
        .collect::<Vec<_>>()
        .join("\n");
    let message = if !blob.contains("Deadline") {
        "solver module never mentions Deadline — kernels here cannot be cancelled"
    } else if !blob.contains(".expired()") {
        "solver module imports Deadline but has no .expired() checkpoint"
    } else {
        return Vec::new();
    };
    vec![Finding {
        rule: RULE_DEADLINE,
        path: label.to_string(),
        line: 1,
        message: message.to_string(),
    }]
}
