//! Rule `docs`: the `#[allow(missing_docs)]` ledger in `lib.rs` must
//! exactly match reality.
//!
//! `lib.rs` carries `#![warn(missing_docs)]` plus a shrinking list of
//! per-module allows. Two drift modes, both findings:
//!
//! * **regression** — an audited module (no allow) gains an
//!   undocumented pub item; CI's doc build catches this too, but only
//!   on a toolchain with rustdoc, and this rule names the item;
//! * **stale allow** — a listed module no longer has any undocumented
//!   pub item, so the allow hides future regressions for free. The
//!   rule forces the allow to be removed the moment the module is
//!   clean, which is what keeps the ledger a burn-down list instead
//!   of a fossil.
//!
//! The detector mirrors rustc's `missing_docs` reachability rules on
//! the subset of Rust this tree uses: fully-`pub` items only (not
//! `pub(crate)`), `pub use` exempt, trait impls exempt, `#[doc(hidden)]`
//! exempt, struct fields / enum variants / variant fields / trait items
//! included, and an out-of-line `pub mod x;` is documented by its
//! file's leading `//!` docs.

use super::scan::Source;

/// The `lib.rs` allow ledger: `(module, allowed)` in declaration order.
pub struct Ledger {
    /// one entry per `pub mod name;` in `lib.rs`
    pub modules: Vec<(String, bool)>,
}

/// Parse the `#[allow(missing_docs)]` / `pub mod name;` sequence out of
/// `lib.rs`.
pub fn parse_ledger(lib_rs: &str) -> Ledger {
    let src = Source::parse(lib_rs);
    let mut modules = Vec::new();
    let mut pending_allow = false;
    for ln in &src.lines {
        let flat: String = ln.code.chars().filter(|c| !c.is_whitespace()).collect();
        if flat.starts_with("#[allow(missing_docs)") {
            pending_allow = true;
            continue;
        }
        let stripped = ln.code.trim();
        if let Some(rest) = stripped.strip_prefix("pub mod ") {
            if let Some(name) = rest.strip_suffix(';') {
                modules.push((name.trim().to_string(), pending_allow));
                pending_allow = false;
                continue;
            }
        }
        if !stripped.is_empty() {
            pending_allow = false;
        }
    }
    Ledger { modules }
}

/// Undocumented fully-pub items in one file's text as
/// `(line, description)` pairs. `mod_has_docs` answers whether an
/// out-of-line `mod name;` declaration's target file opens with `//!`
/// docs (the caller resolves the filesystem; fixtures stub it).
pub fn undocumented(text: &str, mod_has_docs: &dyn Fn(&str) -> bool) -> Vec<(usize, String)> {
    let src = Source::parse(text);
    let type_vis = local_type_visibility(&src);

    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut scopes: Vec<(usize, Kind)> = Vec::new();
    let mut pending_doc = false;
    let mut pending_hidden = false;
    let mut pending_allow = false;
    let mut head: Option<Kind> = None;

    for (idx, ln) in src.lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        let stripped = ln.code.trim();
        let comment = ln.comment.trim();
        // `///` reaches the scanner as a comment starting with `/` on a
        // line whose code channel is empty
        if stripped.is_empty() && comment.starts_with('/') {
            pending_doc = true;
        }
        if stripped.starts_with("#[") {
            let flat: String = stripped.chars().filter(|c| !c.is_whitespace()).collect();
            if flat.starts_with("#[doc(hidden)") {
                pending_hidden = true;
            } else if flat.starts_with("#[doc") {
                pending_doc = true;
            }
            if flat.starts_with("#[allow(missing_docs)") {
                pending_allow = true;
            }
            // attributes keep the pending flags alive for the item below
        } else if !stripped.is_empty() {
            let inner = scopes.last().map(|s| s.1);
            let documented = pending_doc || pending_hidden || pending_allow;
            let exported = is_exported(&scopes);
            let suppressed =
                matches!(inner, Some(Kind::Fn) | Some(Kind::Block) | Some(Kind::Hidden));
            head = Some(Kind::Block);
            if !suppressed {
                if let Some((fully_pub, kw, name)) = item_head(stripped) {
                    let mut item_documented = documented;
                    if kw == "mod" && stripped.ends_with(';') && !item_documented {
                        item_documented = mod_has_docs(&name);
                    }
                    let exempt = kw == "use" || kw == "macro_rules";
                    if fully_pub && exported && !exempt && !item_documented {
                        out.push((idx + 1, format!("{kw} {name}")));
                    }
                    head = Some(match kw {
                        "mod" => {
                            if fully_pub {
                                Kind::ModPub
                            } else {
                                Kind::ModPriv
                            }
                        }
                        "struct" | "union" => {
                            if fully_pub && exported {
                                Kind::StructPub
                            } else {
                                Kind::StructPriv
                            }
                        }
                        "enum" => {
                            if fully_pub && exported {
                                Kind::EnumPub
                            } else {
                                Kind::EnumPriv
                            }
                        }
                        "trait" => {
                            if fully_pub && exported {
                                Kind::TraitPub
                            } else {
                                Kind::TraitPriv
                            }
                        }
                        "fn" => Kind::Fn,
                        _ => Kind::Block,
                    });
                    if pending_hidden {
                        head = Some(Kind::Hidden);
                    }
                } else if stripped == "impl"
                    || stripped.starts_with("impl ")
                    || stripped.starts_with("impl<")
                {
                    head = Some(impl_kind(stripped, &type_vis, exported));
                    if pending_hidden {
                        head = Some(Kind::Hidden);
                    }
                } else {
                    match inner {
                        Some(Kind::StructPub) => {
                            if let Some(field) = pub_field_name(stripped) {
                                if exported && !documented {
                                    out.push((idx + 1, format!("field {field}")));
                                }
                            }
                        }
                        Some(Kind::EnumPub) => {
                            if let Some(variant) = variant_name(stripped) {
                                if exported && !documented {
                                    out.push((idx + 1, format!("variant {variant}")));
                                }
                                if stripped.contains('{') {
                                    head = Some(Kind::Variant);
                                }
                            }
                        }
                        Some(Kind::Variant) => {
                            if let Some(field) = plain_field_name(stripped) {
                                if exported && !documented {
                                    out.push((idx + 1, format!("variant field {field}")));
                                }
                            }
                        }
                        Some(Kind::TraitPub) => {
                            if let Some(item) = trait_item_name(stripped) {
                                if exported && !documented {
                                    out.push((idx + 1, format!("trait item {item}")));
                                }
                                if stripped.starts_with("fn ") {
                                    head = Some(Kind::Fn);
                                }
                            }
                        }
                        _ => {}
                    }
                }
            }
            pending_doc = false;
            pending_hidden = false;
            pending_allow = false;
        }
        for c in ln.code.chars() {
            if c == '{' {
                depth += 1;
                scopes.push((depth, head.take().unwrap_or(Kind::Block)));
            } else if c == '}' {
                if scopes.last().map(|s| s.0) == Some(depth) {
                    scopes.pop();
                }
                depth = depth.saturating_sub(1);
            }
        }
    }
    out
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    ModPub,
    ModPriv,
    ImplPub,
    ImplPriv,
    TraitImpl,
    StructPub,
    StructPriv,
    EnumPub,
    EnumPriv,
    Variant,
    TraitPub,
    TraitPriv,
    Fn,
    Hidden,
    Block,
}

/// An item inside any of these scopes is unreachable from the crate's
/// public docs, so `missing_docs` would not fire on it.
fn is_exported(scopes: &[(usize, Kind)]) -> bool {
    !scopes.iter().any(|(_, k)| {
        matches!(
            k,
            Kind::ModPriv
                | Kind::Fn
                | Kind::Hidden
                | Kind::Block
                | Kind::ImplPriv
                | Kind::TraitImpl
                | Kind::StructPriv
                | Kind::EnumPriv
                | Kind::TraitPriv
        )
    })
}

/// Visibility of `struct`/`enum`/`union` types declared in this file,
/// so inherent-impl methods can be skipped when the type is private.
/// Types not in the map (cross-file impls) are assumed public.
fn local_type_visibility(src: &Source) -> std::collections::BTreeMap<String, bool> {
    let mut vis = std::collections::BTreeMap::new();
    for ln in &src.lines {
        let stripped = ln.code.trim();
        if let Some((fully_pub, kw, name)) = item_head(stripped) {
            if matches!(kw, "struct" | "enum" | "union") {
                vis.insert(name, fully_pub);
            }
        }
    }
    vis
}

/// Classify an `impl` line: trait impls are exempt from `missing_docs`;
/// inherent impls inherit the target type's visibility.
fn impl_kind(
    stripped: &str,
    type_vis: &std::collections::BTreeMap<String, bool>,
    exported: bool,
) -> Kind {
    let rest = &stripped["impl".len()..];
    // skip generics: `impl<T: Ord> Foo<T>` — find the matching `>`
    let rest = if let Some(r) = rest.strip_prefix('<') {
        let mut depth = 1usize;
        let mut cut = r.len();
        for (i, c) in r.char_indices() {
            match c {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth == 0 {
                        cut = i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        &r[cut..]
    } else {
        rest
    };
    if rest.contains(" for ") {
        return Kind::TraitImpl;
    }
    let tname: String =
        rest.trim_start().chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
    let type_pub = type_vis.get(&tname).copied().unwrap_or(true);
    if type_pub && exported {
        Kind::ImplPub
    } else {
        Kind::ImplPriv
    }
}

/// Parse an item head: optional visibility, modifiers, then an item
/// keyword and name. Returns `(fully_pub, keyword, name)`.
fn item_head(stripped: &str) -> Option<(bool, &'static str, String)> {
    const KEYWORDS: &[&str] =
        &["fn", "struct", "enum", "trait", "type", "const", "static", "mod", "union", "use"];
    let mut rest = stripped;
    let mut fully_pub = false;
    let first = word(rest);
    if first == "pub" {
        let after = &rest[3..];
        if let Some(r) = after.trim_start().strip_prefix('(') {
            // pub(crate) / pub(super) / pub(in …): not fully public
            let close = r.find(')')?;
            rest = r[close + 1..].trim_start();
        } else {
            fully_pub = true;
            rest = after.trim_start();
        }
    }
    loop {
        let w = word(rest);
        if w.is_empty() {
            return None;
        }
        if w == "macro_rules" && rest[w.len()..].starts_with('!') {
            let name = word(rest[w.len() + 1..].trim_start());
            return Some((fully_pub, "macro_rules", name.to_string()));
        }
        if KEYWORDS.contains(&w) {
            // `const fn`, `const unsafe fn`: const as modifier
            if w == "const" {
                let after = rest[w.len()..].trim_start();
                let next = word(after);
                if next == "fn" || next == "unsafe" || next == "extern" {
                    rest = after;
                    continue;
                }
            }
            let keyword = KEYWORDS.iter().copied().find(|k| *k == w)?;
            let name = word(rest[w.len()..].trim_start());
            return Some((fully_pub, keyword, name.to_string()));
        }
        match w {
            "default" | "async" | "unsafe" => rest = rest[w.len()..].trim_start(),
            "extern" => {
                // `extern "" fn` (the scanner emptied the ABI string)
                let after = rest[w.len()..].trim_start();
                rest = after.strip_prefix("\"\"").unwrap_or(after).trim_start();
            }
            _ => return None,
        }
    }
}

/// Leading identifier characters of `s`.
fn word(s: &str) -> &str {
    let end = s.find(|c: char| !c.is_alphanumeric() && c != '_').unwrap_or(s.len());
    &s[..end]
}

/// `pub name:` — a public struct field line.
fn pub_field_name(stripped: &str) -> Option<String> {
    let rest = stripped.strip_prefix("pub ")?;
    let name = word(rest.trim_start());
    if !name.is_empty() && rest.trim_start()[name.len()..].trim_start().starts_with(':') {
        Some(name.to_string())
    } else {
        None
    }
}

/// `Name`, `Name(…)`, `Name {` or `Name,` — an enum variant line.
fn variant_name(stripped: &str) -> Option<String> {
    let name = word(stripped);
    if name.is_empty() || !name.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
        return None;
    }
    let rest = stripped[name.len()..].trim_start();
    if rest.is_empty() || rest.starts_with('(') || rest.starts_with('{') || rest.starts_with(',') {
        Some(name.to_string())
    } else {
        None
    }
}

/// `name:` — a struct-variant field line (no `pub`; variant fields
/// share the variant's visibility).
fn plain_field_name(stripped: &str) -> Option<String> {
    let name = word(stripped);
    if !name.is_empty() && stripped[name.len()..].trim_start().starts_with(':') {
        Some(name.to_string())
    } else {
        None
    }
}

/// `fn`/`type`/`const` items inside a pub trait body.
fn trait_item_name(stripped: &str) -> Option<String> {
    for kw in ["fn ", "type ", "const "] {
        if let Some(rest) = stripped.strip_prefix(kw) {
            let name = word(rest.trim_start());
            if !name.is_empty() {
                return Some(name.to_string());
            }
        }
    }
    None
}
