// Seeded violations for the `counter` rule: `served` only bumps inside
// a #[cfg(test)] region, `errors` only as the suffix of a longer
// identifier, and `tenant_rejects` only inside a string literal —
// none of those are live increments, so all three must be flagged.

fn handle(s: &mut StatsSnapshot) {
    s.my_errors += 1;
    log("tenant_rejects += 1 happens elsewhere, honest");
}

#[cfg(test)]
mod tests {
    fn bump(s: &mut StatsSnapshot) {
        s.served += 1;
    }
}
