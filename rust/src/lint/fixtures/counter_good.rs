// Known-good service loop for the `counter` rule: every monotonic
// counter the fixture serializer emits has an identifier-boundary
// `+=` site in non-test code (an aggregation fold counts too).

fn note_served(s: &mut StatsSnapshot) {
    s.served += 1;
}

fn note_reject(s: &mut StatsSnapshot) {
    s.errors += 1;
    s.tenant_rejects += 1;
}

fn fold(total: &mut StatsSnapshot, shard: &StatsSnapshot) {
    total.served += shard.served;
}
