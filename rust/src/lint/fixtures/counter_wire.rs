// Miniature wire serializer for the `counter` rule: three monotonic
// counters that demand live `+=` sites, plus one derived percentile
// (`plan_p50_s`) that the rule must exempt via counters::DERIVED.

fn counters_to_obj(s: &StatsSnapshot) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("served", s.served as f64);
    o.set("errors", s.errors as f64);
    o.set("tenant_rejects", s.tenant_rejects as f64);
    o.set("plan_p50_s", s.plan_p50_s);
    o
}
