// Seeded violation for the `deadline` rule: a solver loop that never
// polls any deadline — this kernel cannot be cancelled.

pub fn solve(sizes: &[u64]) -> u64 {
    let mut best = u64::MAX;
    for window in 1..=sizes.len() {
        let cost: u64 = sizes.iter().take(window).sum();
        if cost < best {
            best = cost;
        }
    }
    best
}
