// Known-good corpus for the `deadline` rule: the solver loop polls a
// Deadline checkpoint, so the service's budget can cancel it.

use crate::util::deadline::Deadline;

pub fn solve(sizes: &[u64], deadline: Deadline) -> Option<u64> {
    let mut best = u64::MAX;
    for window in 1..=sizes.len() {
        if deadline.is_set() && deadline.expired() {
            return None;
        }
        let cost: u64 = sizes.iter().take(window).sum();
        if cost < best {
            best = cost;
        }
    }
    Some(best)
}
