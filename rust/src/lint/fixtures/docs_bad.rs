//! Fixture module with seeded missing-docs violations.

/// Documented struct with one undocumented field.
pub struct Mixed {
    /// documented field
    pub fine: u32,
    pub missing: u32,
}

pub fn undocumented_fn() -> u32 {
    0
}

/// Documented enum with an undocumented variant.
pub enum Partial {
    /// documented variant
    Fine,
    Missing,
}
