//! Fixture module with every exported item documented.

/// Fully documented struct.
pub struct Clean {
    /// documented field
    pub fine: u32,
    // private field needs no docs
    hidden: u32,
}

impl Clean {
    /// Documented constructor.
    pub fn new() -> Self {
        Self { fine: 0, hidden: 0 }
    }

    fn private_helper(&self) -> u32 {
        self.hidden
    }
}

/// Documented function.
pub fn documented_fn() -> u32 {
    0
}

pub(crate) fn crate_only_needs_no_docs() -> u32 {
    1
}
