// Seeded violations for the `lock` rule: raw unwrap on a Mutex guard,
// and no poison-recovering helper anywhere in the file.

use std::sync::Mutex;

pub struct Counter {
    inner: Mutex<u64>,
}

impl Counter {
    pub fn bump(&self) -> u64 {
        let mut v = self.inner.lock().unwrap();
        *v += 1;
        *v
    }

    pub fn read(&self) -> u64 {
        *self.inner.lock().expect("poisoned")
    }
}
