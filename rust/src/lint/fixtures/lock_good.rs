// Known-good corpus for the `lock` rule: the house poison-recovering
// helper pattern, with every call site funneling through it.

use std::sync::{Mutex, MutexGuard};

pub struct Counter {
    inner: Mutex<u64>,
}

impl Counter {
    fn lock(&self) -> MutexGuard<'_, u64> {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub fn bump(&self) -> u64 {
        let mut v = self.lock();
        *v += 1;
        *v
    }

    pub fn read(&self) -> u64 {
        *self.lock()
    }
}
