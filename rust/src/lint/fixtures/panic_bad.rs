// Seeded violations for the `panic` rule: every non-test site below
// must be reported (the annotated one is allowlisted, not a finding).

pub fn first(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn second(v: Option<u32>) -> u32 {
    v.expect("bad: panics on request path")
}

pub fn third() {
    panic!("boom");
}

pub fn fourth(d: &[u32]) -> u32 {
    d[0] + d[1]
}

pub fn allowed(d: &[u32]) -> u32 {
    // lint: allow(panic) length checked by the caller
    d[2]
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
