// Known-good corpus for the `panic` rule: typed errors, combinators,
// and panic tokens that only appear in comments, strings or test code.

/// "call .unwrap() here" — token inside a string literal, not code.
pub fn typed(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "empty .unwrap() story: panic!(no)".to_string())
}

// .expect( in a comment is not a finding, and neither is d[0] here.
pub fn combinators(v: Option<u32>) -> u32 {
    v.unwrap_or_default().max(v.unwrap_or(3))
}

pub fn non_literal_index(d: &[u32], i: usize) -> Option<u32> {
    d.get(i).copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_panic() {
        let d = [1u32, 2];
        assert_eq!(d[0], 1);
        Some(5u32).unwrap();
        if false {
            panic!("tests are out of scope");
        }
    }
}
