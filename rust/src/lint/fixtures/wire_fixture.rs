// Miniature wire module for the `wire` drift rule: two stats counters
// and one metrics gauge, serializers and decoders in lockstep.

fn counters_to_obj(s: &StatsSnapshot) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("served", s.served as f64);
    o.set("errors", s.errors as f64);
    o
}

fn counters_from_obj(s: &Json) -> StatsSnapshot {
    StatsSnapshot {
        served: get_u64(s, "served"),
        errors: get_u64(s, "errors"),
    }
}

pub fn metrics_frame(m: &MetricsSnapshot) -> Json {
    let mut inner = counters_to_obj(&m.stats);
    inner.set("inflight", m.inflight as f64);
    let mut o = JsonObj::new();
    o.set("v", 1.0).set("metrics", inner);
    Json::Obj(o)
}

pub fn metrics_from_json(m: &Json) -> MetricsSnapshot {
    MetricsSnapshot {
        stats: counters_from_obj(m),
        inflight: get_u64(m, "inflight"),
    }
}

pub fn metrics_medians(m: &MetricsSnapshot) -> Json {
    let mut o = JsonObj::new();
    o.set("_schema", "fixture");
    o.set("serve/served", m.stats.served as f64);
    o.set("serve/inflight", m.inflight as f64);
    Json::Obj(o)
}
