//! Rule `lock`: poison discipline in `service/` and `cluster/`.
//!
//! Every `.lock()` in non-test code must flow through a
//! poison-recovering helper — the house pattern
//! `lock().unwrap_or_else(PoisonError::into_inner)` wrapped in a
//! per-struct `fn lock(…)`/`fn lock_stats(…)` — never a raw
//! `.unwrap()`/`.expect(…)`. A panicking lock holder (contained by the
//! worker's `catch_unwind`) otherwise poisons the mutex and wedges
//! every later request on that path, turning one bad request into a
//! full outage.
//!
//! Two checks per file:
//! 1. any `.lock()` whose statement also unwraps/expects is a finding
//!    (annotatable with `// lint: allow(lock) reason`);
//! 2. a file that owns a `Mutex` and locks it must define the
//!    recovering helper somewhere (`unwrap_or_else` + `into_inner` in
//!    the same statement as a `.lock()`), so call sites have something
//!    to funnel through.

use super::scan::Source;
use super::{Finding, Report, RULE_LOCK};

/// Modules the rule walks (relative to `rust/src`).
pub const SCOPE: &[&str] = &["service", "cluster"];

/// Check one file's text; `label` names it in findings.
pub fn check_file(label: &str, text: &str, report: &mut Report) {
    let src = Source::parse(text);
    let has_mutex =
        src.lines.iter().any(|ln| !ln.in_test && ln.code.contains("Mutex"));
    let mut locks = false;
    let mut has_helper = false;
    for (idx, ln) in src.lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        let mut pos = 0usize;
        while let Some(p) = ln.code[pos..].find(".lock()") {
            let at = pos + p;
            locks = true;
            let window = statement_window(&src, idx, at);
            if window.contains(".unwrap()") || window.contains(".expect(") {
                if src.allowed(idx, RULE_LOCK) {
                    report.allow(RULE_LOCK, 1);
                } else {
                    report.findings.push(Finding {
                        rule: RULE_LOCK,
                        path: label.to_string(),
                        line: idx + 1,
                        message: ".lock() consumed by unwrap/expect — poison panics the holder"
                            .to_string(),
                    });
                }
            }
            if window.contains("unwrap_or_else") && window.contains("into_inner") {
                has_helper = true;
            }
            pos = at + ".lock()".len();
        }
    }
    if has_mutex && locks && !has_helper {
        report.findings.push(Finding {
            rule: RULE_LOCK,
            path: label.to_string(),
            line: 1,
            message: "file locks a Mutex but defines no poison-recovering helper \
                      (unwrap_or_else + into_inner)"
                .to_string(),
        });
    }
}

/// The statement around a `.lock()` occurrence: the rest of its line
/// plus up to two continuation lines or until a `;` — enough to see a
/// chained `.unwrap()`/`.unwrap_or_else(…)` that rustfmt wrapped.
fn statement_window(src: &Source, idx: usize, at: usize) -> String {
    let mut window = src.lines[idx].code[at..].to_string();
    let mut j = idx + 1;
    while !window.contains(';') && j < src.lines.len() && j <= idx + 2 {
        window.push_str(&src.lines[j].code);
        j += 1;
    }
    window
}
