//! `xbarlint`: repo-native static analysis for the service's
//! correctness invariants.
//!
//! Six rules, each a token-level scan over the source tree (no `syn`,
//! no dependencies — the same zero-dependency discipline as the rest
//! of the crate; see docs/STATIC_ANALYSIS.md for the rule catalog,
//! the allow-comment grammar and how to add a rule):
//!
//! * [`panics`] — panic-freedom on request paths (`service`,
//!   `cluster`, `store`, `plan`);
//! * [`locks`] — `.lock()` must flow through poison-recovering
//!   helpers in `service`/`cluster`;
//! * [`deadline`] — solver loop modules must poll
//!   [`crate::util::deadline::Deadline`];
//! * [`wire_drift`] — counter/gauge name sets in `plan/wire.rs` and
//!   `docs/WIRE.md` must match exactly;
//! * [`counters`] — every counter `plan/wire.rs` serializes must be
//!   incremented (`key +=`) on a non-test `service`/`cluster` path;
//! * [`docs_ledger`] — the `#[allow(missing_docs)]` list in `lib.rs`
//!   must equal the set of modules with undocumented pub items.
//!
//! Sites that are provably fine carry `// lint: allow(rule) reason`
//! annotations; everything else is a finding, and the `xbarlint`
//! binary exits non-zero on any finding. Allowlisted counts are
//! reported to `BENCH_lint.json` so their trajectory is gate-able
//! ("allows never increase") like a perf number.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub mod counters;
pub mod deadline;
pub mod docs_ledger;
pub mod locks;
pub mod panics;
pub mod scan;
pub mod wire_drift;

/// Rule id of [`panics`].
pub const RULE_PANIC: &str = "panic";
/// Rule id of [`locks`].
pub const RULE_LOCK: &str = "lock";
/// Rule id of [`deadline`].
pub const RULE_DEADLINE: &str = "deadline";
/// Rule id of [`wire_drift`].
pub const RULE_WIRE: &str = "wire";
/// Rule id of [`counters`].
pub const RULE_COUNTER: &str = "counter";
/// Rule id of [`docs_ledger`].
pub const RULE_DOCS: &str = "docs";

/// Every rule id, in report order.
pub const RULES: &[&str] =
    &[RULE_PANIC, RULE_LOCK, RULE_DEADLINE, RULE_WIRE, RULE_COUNTER, RULE_DOCS];

/// One non-allowlisted violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// rule id (one of [`RULES`])
    pub rule: &'static str,
    /// repo-relative path of the offending file
    pub path: String,
    /// 1-based line number (1 when the finding is file-scoped)
    pub line: usize,
    /// what drifted and why it matters
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:8} {}:{}  {}", self.rule, self.path, self.line, self.message)
    }
}

/// Aggregated lint outcome: findings (gate: must be empty) plus the
/// per-rule count of allowlisted sites (gate: must never grow).
#[derive(Debug, Default)]
pub struct Report {
    /// non-allowlisted violations across every rule
    pub findings: Vec<Finding>,
    /// rule id → `// lint: allow(rule)`-annotated site count
    pub allowed: BTreeMap<&'static str, u64>,
}

impl Report {
    /// Record `n` allowlisted sites for `rule`.
    pub fn allow(&mut self, rule: &'static str, n: u64) {
        *self.allowed.entry(rule).or_insert(0) += n;
    }

    /// Findings for one rule.
    pub fn findings_for(&self, rule: &str) -> usize {
        self.findings.iter().filter(|f| f.rule == rule).count()
    }

    /// The BENCH-schema report object: flat name → count, with a
    /// `_schema` marker. `lint/findings*` rows gate at zero (the binary
    /// exits non-zero on any finding anyway); `lint/allow_*` rows are
    /// the burn-down trajectory and must never increase.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{Json, JsonObj};
        let mut o = JsonObj::new();
        o.set(
            "_schema",
            "xbarlint counts: lint/findings_<rule> must stay 0; lint/allow_<rule> \
             is the annotated-allowlist burn-down and must never increase \
             (see docs/STATIC_ANALYSIS.md)",
        );
        o.set("lint/findings", self.findings.len() as f64);
        for rule in RULES {
            o.set(&format!("lint/findings_{rule}"), self.findings_for(rule) as f64);
        }
        for rule in RULES {
            o.set(
                &format!("lint/allow_{rule}"),
                self.allowed.get(rule).copied().unwrap_or(0) as f64,
            );
        }
        Json::Obj(o)
    }
}

/// Run every rule against the repo rooted at `root` (the directory
/// holding `rust/` and `docs/`).
pub fn run(root: &Path) -> std::io::Result<Report> {
    let src = root.join("rust").join("src");
    let mut report = Report::default();

    for module in panics::SCOPE {
        for path in walk_rs(&src.join(module))? {
            let text = std::fs::read_to_string(&path)?;
            panics::check_file(&rel(root, &path), &text, &mut report);
        }
    }
    for module in locks::SCOPE {
        for path in walk_rs(&src.join(module))? {
            let text = std::fs::read_to_string(&path)?;
            locks::check_file(&rel(root, &path), &text, &mut report);
        }
    }
    for file in deadline::SOLVER_FILES {
        let path = src.join(file);
        if !path.exists() {
            report.findings.push(Finding {
                rule: RULE_DEADLINE,
                path: format!("rust/src/{file}"),
                line: 1,
                message: "solver module listed in deadline::SOLVER_FILES is missing".to_string(),
            });
            continue;
        }
        let text = std::fs::read_to_string(&path)?;
        report.findings.extend(deadline::check_text(&rel(root, &path), &text));
    }
    let wire_rs = std::fs::read_to_string(src.join("plan").join("wire.rs"))?;
    let wire_md = std::fs::read_to_string(root.join("docs").join("WIRE.md"))?;
    report.findings.extend(wire_drift::check_texts(&wire_rs, &wire_md));

    let mut counter_sources: Vec<(String, String)> = Vec::new();
    for module in ["service", "cluster"] {
        for path in walk_rs(&src.join(module))? {
            let text = std::fs::read_to_string(&path)?;
            counter_sources.push((rel(root, &path), text));
        }
    }
    report.findings.extend(counters::check_texts(&wire_rs, &counter_sources));

    check_docs_ledger(root, &src, &mut report)?;
    Ok(report)
}

/// The docs-ledger rule over the real tree: parse `lib.rs`, scan every
/// module's files, and reconcile against the allow list.
fn check_docs_ledger(root: &Path, src: &Path, report: &mut Report) -> std::io::Result<()> {
    let lib_rs = std::fs::read_to_string(src.join("lib.rs"))?;
    let ledger = docs_ledger::parse_ledger(&lib_rs);
    for (module, allowed) in &ledger.modules {
        let mut items: Vec<(String, usize, String)> = Vec::new();
        for path in module_files(src, module)? {
            let text = std::fs::read_to_string(&path)?;
            let dir = path.parent().map(Path::to_path_buf).unwrap_or_default();
            let resolver = |name: &str| mod_file_has_inner_docs(&dir, name);
            for (line, desc) in docs_ledger::undocumented(&text, &resolver) {
                items.push((rel(root, &path), line, desc));
            }
        }
        if *allowed && items.is_empty() {
            report.findings.push(Finding {
                rule: RULE_DOCS,
                path: "rust/src/lib.rs".to_string(),
                line: 1,
                message: format!(
                    "stale #[allow(missing_docs)]: module '{module}' is fully documented"
                ),
            });
        }
        if !*allowed {
            for (path, line, desc) in items {
                report.findings.push(Finding {
                    rule: RULE_DOCS,
                    path,
                    line,
                    message: format!("undocumented pub item ({desc}) in audited module '{module}'"),
                });
            }
        }
    }
    Ok(())
}

/// Whether `dir/name.rs` or `dir/name/mod.rs` opens with `//!` inner
/// docs (which document the `pub mod name;` declaration itself).
fn mod_file_has_inner_docs(dir: &Path, name: &str) -> bool {
    for cand in [dir.join(format!("{name}.rs")), dir.join(name).join("mod.rs")] {
        let Ok(text) = std::fs::read_to_string(&cand) else {
            continue;
        };
        for line in text.lines() {
            let s = line.trim();
            if s.is_empty() {
                continue;
            }
            if s.starts_with("//!") {
                return true;
            }
            if s.starts_with("//") {
                continue;
            }
            return false;
        }
    }
    false
}

/// The file set of module `name`: `src/name.rs`, or every `.rs` file
/// under `src/name/` (fixture corpora excluded).
fn module_files(src: &Path, name: &str) -> std::io::Result<Vec<PathBuf>> {
    let single = src.join(format!("{name}.rs"));
    if single.exists() {
        return Ok(vec![single]);
    }
    walk_rs(&src.join(name))
}

/// Every `.rs` file under `dir`, recursively, sorted, skipping any
/// `fixtures` directory (fixture snippets contain seeded violations).
pub fn walk_rs(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        if d.file_name().is_some_and(|n| n == "fixtures") {
            continue;
        }
        let entries = match std::fs::read_dir(&d) {
            Ok(e) => e,
            Err(_) => continue, // module dir absent: nothing to walk
        };
        for entry in entries {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// `path` relative to `root`, with `/` separators.
fn rel(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests;
