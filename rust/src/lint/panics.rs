//! Rule `panic`: panic-freedom on request paths.
//!
//! Non-test code under `service/`, `cluster/`, `store/` and `plan/`
//! must not contain panic-capable tokens — `.unwrap()`, `.expect(`,
//! `panic!(`, `unreachable!(`, `unimplemented!(`, `todo!(` — or
//! numeric-literal indexing (`d[0]`-style slicing suspects). A request
//! that trips one of these takes down a worker (the service contains
//! the panic, but the counted panic is still an availability event);
//! the rule forces each site to either restructure into a typed error
//! or carry an explicit `// lint: allow(panic) reason` annotation.
//!
//! Deliberately *not* flagged: `assert!`/`debug_assert!` families
//! (invariant contracts, audited separately), non-literal indexing
//! (`xs[i]` — too common in kernels to annotate usefully), and
//! `.unwrap_or…` combinators (infallible by construction).

use super::scan::Source;
use super::{Finding, Report, RULE_PANIC};

const TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "unimplemented!(", "todo!("];

/// Modules whose request paths the rule walks (relative to `rust/src`).
pub const SCOPE: &[&str] = &["service", "cluster", "store", "plan"];

/// Check one file's text; `label` names it in findings.
pub fn check_file(label: &str, text: &str, report: &mut Report) {
    let src = Source::parse(text);
    for (idx, ln) in src.lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        let mut hits: Vec<&str> = TOKENS.iter().copied().filter(|t| ln.code.contains(t)).collect();
        if has_literal_index(&ln.code) {
            hits.push("literal-index");
        }
        if hits.is_empty() {
            continue;
        }
        if src.allowed(idx, RULE_PANIC) {
            report.allow(RULE_PANIC, hits.len() as u64);
            continue;
        }
        report.findings.push(Finding {
            rule: RULE_PANIC,
            path: label.to_string(),
            line: idx + 1,
            message: format!("panic-capable token(s) {} on a request path", hits.join(", ")),
        });
    }
}

/// `ident[<digit>` — indexing/slicing with a numeric literal, the
/// out-of-bounds suspect shape (`d[0]` after a length check is the
/// annotated idiom; `xs[i]` is out of scope).
fn has_literal_index(code: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    chars.windows(3).any(|w| {
        (w[0].is_alphanumeric() || w[0] == '_' || w[0] == ']')
            && w[1] == '['
            && w[2].is_ascii_digit()
    })
}
