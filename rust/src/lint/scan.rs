//! Line/token scanner behind every `xbarlint` rule.
//!
//! Not a parser: a character state machine that splits each source line
//! into **code** (with comments removed and every string/char literal
//! body replaced by an empty one, so token rules never match inside
//! text), the line's **comment** text (where `lint: allow(...)`
//! annotations live), and the ordered **string literals** the line
//! carried (the wire-drift rule reads counter names out of these). It
//! also brace-matches `#[cfg(test)]` regions so rules can skip test
//! code, which is allowed to `unwrap()` freely.
//!
//! Handled Rust surface: line comments, nested block comments, string
//! and byte-string literals with escapes, raw strings (`r"…"`,
//! `r#"…"#`, any hash depth), char literals vs. lifetimes. That is the
//! whole grammar a token scan needs; anything deeper (macros, type
//! syntax) deliberately stays out of scope — see docs/STATIC_ANALYSIS.md
//! for the design bet.

/// One scanned source line.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// code with comments stripped and literal bodies emptied (`""`)
    pub code: String,
    /// the line-comment text (text after `//`, including doc comments)
    pub comment: String,
    /// string-literal bodies on this line, in source order
    pub strings: Vec<String>,
    /// inside a `#[cfg(test)]` brace block
    pub in_test: bool,
}

/// A scanned source file.
#[derive(Debug, Clone, Default)]
pub struct Source {
    /// scanned lines, index 0 = line 1
    pub lines: Vec<Line>,
}

enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
}

impl Source {
    /// Scan `text` into per-line code/comment/string channels and mark
    /// `#[cfg(test)]` regions.
    pub fn parse(text: &str) -> Source {
        let chars: Vec<char> = text.chars().collect();
        let n = chars.len();
        let mut lines: Vec<Line> = Vec::new();
        let mut cur = Line::default();
        let mut cur_str = String::new();
        let mut state = State::Code;
        let mut i = 0usize;
        let at = |i: usize, pat: &str| -> bool {
            chars[i..].iter().take(pat.chars().count()).copied().eq(pat.chars())
        };
        while i < n {
            let c = chars[i];
            if c == '\n' {
                match state {
                    State::LineComment => state = State::Code,
                    State::Str => cur_str.push('\n'),
                    _ => {}
                }
                lines.push(std::mem::take(&mut cur));
                i += 1;
                continue;
            }
            match state {
                State::LineComment => {
                    cur.comment.push(c);
                    i += 1;
                }
                State::BlockComment(depth) => {
                    if at(i, "/*") {
                        state = State::BlockComment(depth + 1);
                        i += 2;
                    } else if at(i, "*/") {
                        state =
                            if depth == 1 { State::Code } else { State::BlockComment(depth - 1) };
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                State::Str => {
                    if c == '\\' && i + 1 < n {
                        cur_str.push(c);
                        cur_str.push(chars[i + 1]);
                        i += 2;
                    } else if c == '"' {
                        cur.strings.push(std::mem::take(&mut cur_str));
                        cur.code.push_str("\"\"");
                        state = State::Code;
                        i += 1;
                    } else {
                        cur_str.push(c);
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    let close = "\"".to_string() + &"#".repeat(hashes);
                    if at(i, &close) {
                        cur.strings.push(std::mem::take(&mut cur_str));
                        cur.code.push_str("\"\"");
                        state = State::Code;
                        i += 1 + hashes;
                    } else {
                        cur_str.push(c);
                        i += 1;
                    }
                }
                State::Code => {
                    if at(i, "//") {
                        state = State::LineComment;
                        i += 2;
                    } else if at(i, "/*") {
                        state = State::BlockComment(1);
                        i += 2;
                    } else if c == '"' {
                        state = State::Str;
                        i += 1;
                    } else if (c == 'r' || c == 'b')
                        && (i == 0 || !ident_char(chars[i - 1]))
                        && raw_str_open(&chars, i).is_some()
                    {
                        let (hashes, skip) = match raw_str_open(&chars, i) {
                            Some(v) => v,
                            None => (0, 1), // unreachable: guarded above
                        };
                        state = State::RawStr(hashes);
                        i += skip;
                    } else if c == '\'' {
                        // char literal vs lifetime
                        if i + 1 < n && chars[i + 1] == '\\' {
                            let mut j = i + 2;
                            while j < n && chars[j] != '\'' {
                                j += 1;
                            }
                            cur.code.push_str("' '");
                            i = j + 1;
                        } else if i + 2 < n && chars[i + 2] == '\'' {
                            cur.code.push_str("' '");
                            i += 3;
                        } else {
                            cur.code.push(c); // lifetime
                            i += 1;
                        }
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                }
            }
        }
        lines.push(cur);
        mark_test_regions(&mut lines);
        Source { lines }
    }

    /// Whether a `// lint: allow(rule) reason` annotation covers line
    /// `idx` — on the line itself or on a directly preceding block of
    /// comment-only lines. The reason is mandatory: a bare
    /// `lint: allow(panic)` does not count.
    pub fn allowed(&self, idx: usize, rule: &str) -> bool {
        if allow_matches(&self.lines[idx].comment, rule) {
            return true;
        }
        let mut j = idx;
        while j > 0 {
            j -= 1;
            let ln = &self.lines[j];
            if !ln.code.trim().is_empty() || ln.comment.is_empty() {
                return false;
            }
            if allow_matches(&ln.comment, rule) {
                return true;
            }
        }
        false
    }
}

/// `true` when `comment` carries `lint: allow(rule) <reason>` for this
/// rule, with a non-empty reason.
fn allow_matches(comment: &str, rule: &str) -> bool {
    let Some(p) = comment.find("lint: allow(") else {
        return false;
    };
    let rest = &comment[p + "lint: allow(".len()..];
    let Some(close) = rest.find(')') else {
        return false;
    };
    &rest[..close] == rule && !rest[close + 1..].trim().is_empty()
}

fn ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// At a `r`/`b` position, detect `r"`, `r#"`, `br"`, … Returns
/// `(hash_count, chars_to_skip_past_opening_quote)`.
fn raw_str_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if j >= chars.len() || chars[j] != 'r' {
            return None;
        }
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Brace-match every `#[cfg(test)]` attribute's following block and set
/// `in_test` on the lines inside it.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth = 0usize;
    let mut test_depths: Vec<usize> = Vec::new();
    let mut pending = false;
    for ln in lines.iter_mut() {
        let flat: String = ln.code.chars().filter(|c| !c.is_whitespace()).collect();
        if flat.contains("#[cfg(test)]") {
            pending = true;
        }
        for c in ln.code.chars() {
            if c == '{' {
                depth += 1;
                if pending {
                    test_depths.push(depth);
                    pending = false;
                }
            } else if c == '}' {
                if test_depths.last() == Some(&depth) {
                    test_depths.pop();
                }
                depth = depth.saturating_sub(1);
            }
        }
        if !test_depths.is_empty() {
            ln.in_test = true;
        }
    }
}
