//! Every rule against its seeded-violation and known-good fixture
//! corpus (`fixtures/`), plus scanner and allow-grammar edge cases.

use super::scan::Source;
use super::{counters, deadline, docs_ledger, locks, panics, wire_drift};
use super::{Report, RULE_LOCK, RULE_PANIC};

const PANIC_BAD: &str = include_str!("fixtures/panic_bad.rs");
const PANIC_GOOD: &str = include_str!("fixtures/panic_good.rs");
const LOCK_BAD: &str = include_str!("fixtures/lock_bad.rs");
const LOCK_GOOD: &str = include_str!("fixtures/lock_good.rs");
const DEADLINE_BAD: &str = include_str!("fixtures/deadline_bad.rs");
const DEADLINE_GOOD: &str = include_str!("fixtures/deadline_good.rs");
const WIRE_RS: &str = include_str!("fixtures/wire_fixture.rs");
const WIRE_GOOD_MD: &str = include_str!("fixtures/wire_good.md");
const WIRE_BAD_MD: &str = include_str!("fixtures/wire_bad.md");
const DOCS_BAD: &str = include_str!("fixtures/docs_bad.rs");
const DOCS_GOOD: &str = include_str!("fixtures/docs_good.rs");
const COUNTER_WIRE: &str = include_str!("fixtures/counter_wire.rs");
const COUNTER_GOOD: &str = include_str!("fixtures/counter_good.rs");
const COUNTER_BAD: &str = include_str!("fixtures/counter_bad.rs");

// ---- scanner ----

#[test]
fn scanner_empties_strings_and_strips_comments() {
    let src = Source::parse("let s = \"a.unwrap()b\"; // panic!(no)\n");
    let ln = &src.lines[0];
    assert_eq!(ln.code, "let s = \"\"; ");
    assert_eq!(ln.strings, vec!["a.unwrap()b".to_string()]);
    assert!(ln.comment.contains("panic!(no)"));
}

#[test]
fn scanner_handles_raw_strings_with_hashes() {
    let src = Source::parse("let s = r#\"x.unwrap() \"quoted\" end\"#;\n");
    let ln = &src.lines[0];
    assert!(!ln.code.contains(".unwrap()"), "token leaked out of raw string: {:?}", ln.code);
    assert_eq!(ln.strings, vec!["x.unwrap() \"quoted\" end".to_string()]);
}

#[test]
fn scanner_distinguishes_char_literals_from_lifetimes() {
    let src = Source::parse("fn f<'a>(x: &'a str) -> char { '\\n' }\n");
    let code = &src.lines[0].code;
    assert!(code.contains("<'a>"), "lifetime mangled: {code:?}");
    assert!(!code.contains("\\n"), "char literal body kept: {code:?}");
}

#[test]
fn scanner_marks_cfg_test_regions() {
    let text = "fn a() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn b() { y.unwrap(); }\n}\n";
    let src = Source::parse(text);
    assert!(!src.lines[0].in_test);
    assert!(src.lines[3].in_test);
}

#[test]
fn allow_requires_rule_match_and_reason() {
    let src = Source::parse("x.unwrap(); // lint: allow(panic)\n");
    assert!(!src.allowed(0, "panic"), "reason-less allow must not count");
    let src = Source::parse("x.unwrap(); // lint: allow(panic) caller checked\n");
    assert!(src.allowed(0, "panic"));
    assert!(!src.allowed(0, "lock"), "allow is per-rule");
}

#[test]
fn allow_covers_from_preceding_comment_block() {
    let text = "// lint: allow(panic) two-line\n// explanation\nx.unwrap();\n";
    let src = Source::parse(text);
    assert!(src.allowed(2, "panic"));
    let text = "// lint: allow(panic) stale\nlet y = 1;\nx.unwrap();\n";
    let src = Source::parse(text);
    assert!(!src.allowed(2, "panic"), "code between comment and site breaks coverage");
}

// ---- rule: panic ----

#[test]
fn panic_rule_flags_seeded_violations() {
    let mut report = Report::default();
    panics::check_file("fixtures/panic_bad.rs", PANIC_BAD, &mut report);
    assert_eq!(report.findings.len(), 4, "{:#?}", report.findings);
    let joined: String =
        report.findings.iter().map(|f| f.message.as_str()).collect::<Vec<_>>().join("; ");
    for token in [".unwrap()", ".expect(", "panic!(", "literal-index"] {
        assert!(joined.contains(token), "missing {token} in: {joined}");
    }
    assert_eq!(report.allowed.get(RULE_PANIC), Some(&1), "annotated d[2] site");
}

#[test]
fn panic_rule_passes_known_good_corpus() {
    let mut report = Report::default();
    panics::check_file("fixtures/panic_good.rs", PANIC_GOOD, &mut report);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    assert!(report.allowed.is_empty());
}

// ---- rule: lock ----

#[test]
fn lock_rule_flags_raw_unwrap_and_missing_helper() {
    let mut report = Report::default();
    locks::check_file("fixtures/lock_bad.rs", LOCK_BAD, &mut report);
    assert_eq!(report.findings.len(), 3, "{:#?}", report.findings);
    assert_eq!(report.findings.iter().filter(|f| f.message.contains("unwrap/expect")).count(), 2);
    assert!(report
        .findings
        .iter()
        .any(|f| f.line == 1 && f.message.contains("poison-recovering helper")));
}

#[test]
fn lock_rule_passes_helper_pattern() {
    let mut report = Report::default();
    locks::check_file("fixtures/lock_good.rs", LOCK_GOOD, &mut report);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    assert_eq!(report.allowed.get(RULE_LOCK), None);
}

// ---- rule: deadline ----

#[test]
fn deadline_rule_flags_unpollable_solver() {
    let findings = deadline::check_text("fixtures/deadline_bad.rs", DEADLINE_BAD);
    assert_eq!(findings.len(), 1, "{findings:#?}");
    assert!(findings[0].message.contains("never mentions Deadline"));
}

#[test]
fn deadline_rule_flags_import_without_checkpoint() {
    let text = "use crate::util::deadline::Deadline;\npub fn solve(d: Deadline) {}\n";
    let findings = deadline::check_text("inline", text);
    assert_eq!(findings.len(), 1, "{findings:#?}");
    assert!(findings[0].message.contains("no .expired() checkpoint"));
}

#[test]
fn deadline_rule_passes_polling_solver() {
    let findings = deadline::check_text("fixtures/deadline_good.rs", DEADLINE_GOOD);
    assert!(findings.is_empty(), "{findings:#?}");
}

// ---- rule: wire ----

#[test]
fn wire_rule_passes_lockstep_spec() {
    let findings = wire_drift::check_texts(WIRE_RS, WIRE_GOOD_MD);
    assert!(findings.is_empty(), "{findings:#?}");
}

#[test]
fn wire_rule_flags_seeded_drift_on_both_sides() {
    let findings = wire_drift::check_texts(WIRE_RS, WIRE_BAD_MD);
    assert_eq!(findings.len(), 2, "{findings:#?}");
    // spec documents a counter the code never emits…
    assert!(findings.iter().any(|f| f.message.contains("cache_hits")));
    // …and omits one it does
    assert!(findings.iter().any(|f| f.message.contains("errors")));
    // snapshot schema drifted independently
    assert!(findings.iter().any(|f| f.message.contains("serve/queue_depth")));
}

// ---- rule: counter ----

#[test]
fn counter_rule_passes_incremented_counters() {
    let sources = vec![("fixtures/counter_good.rs".to_string(), COUNTER_GOOD.to_string())];
    let findings = counters::check_texts(COUNTER_WIRE, &sources);
    assert!(findings.is_empty(), "{findings:#?}");
}

#[test]
fn counter_rule_flags_test_only_string_only_and_suffix_sites() {
    let sources = vec![("fixtures/counter_bad.rs".to_string(), COUNTER_BAD.to_string())];
    let findings = counters::check_texts(COUNTER_WIRE, &sources);
    assert_eq!(findings.len(), 3, "{findings:#?}");
    for key in ["'served'", "'errors'", "'tenant_rejects'"] {
        assert!(
            findings.iter().any(|f| f.message.contains(key)),
            "missing finding for {key}: {findings:#?}"
        );
    }
    // the derived percentile is exempt even with no increment anywhere
    assert!(!findings.iter().any(|f| f.message.contains("plan_p50_s")), "{findings:#?}");
}

#[test]
fn counter_rule_spots_increments_across_any_source_in_the_set() {
    let sources = vec![
        ("fixtures/counter_bad.rs".to_string(), COUNTER_BAD.to_string()),
        ("fixtures/counter_good.rs".to_string(), COUNTER_GOOD.to_string()),
    ];
    let findings = counters::check_texts(COUNTER_WIRE, &sources);
    assert!(findings.is_empty(), "a live site in any scanned file satisfies the rule");
}

// ---- rule: docs ----

#[test]
fn docs_rule_flags_undocumented_items() {
    let items = docs_ledger::undocumented(DOCS_BAD, &|_| false);
    let descs: Vec<&str> = items.iter().map(|(_, d)| d.as_str()).collect();
    assert_eq!(
        descs,
        vec!["field missing", "fn undocumented_fn", "variant Missing"],
        "{items:#?}"
    );
}

#[test]
fn docs_rule_passes_documented_module() {
    let items = docs_ledger::undocumented(DOCS_GOOD, &|_| false);
    assert!(items.is_empty(), "{items:#?}");
}

#[test]
fn docs_rule_accepts_mod_decl_documented_by_target_file() {
    let text = "pub mod child;\n";
    let flagged = docs_ledger::undocumented(text, &|_| false);
    assert_eq!(flagged.len(), 1, "{flagged:#?}");
    let resolved = docs_ledger::undocumented(text, &|name| name == "child");
    assert!(resolved.is_empty(), "{resolved:#?}");
}

#[test]
fn ledger_parses_allow_annotations_in_order() {
    let lib = "#![warn(missing_docs)]\n\npub mod a;\n#[allow(missing_docs)] // queued\n\
               pub mod b;\npub mod c;\n";
    let ledger = docs_ledger::parse_ledger(lib);
    assert_eq!(
        ledger.modules,
        vec![
            ("a".to_string(), false),
            ("b".to_string(), true),
            ("c".to_string(), false),
        ]
    );
}

// ---- report ----

#[test]
fn report_json_carries_schema_and_per_rule_counts() {
    let mut report = Report::default();
    report.allow(RULE_PANIC, 9);
    report.findings.push(super::Finding {
        rule: RULE_LOCK,
        path: "rust/src/service/x.rs".to_string(),
        line: 7,
        message: "demo".to_string(),
    });
    let json = report.to_json().dumps();
    for key in ["_schema", "lint/findings", "lint/findings_lock", "lint/allow_panic"] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
    assert_eq!(report.findings_for(RULE_LOCK), 1);
    assert_eq!(report.findings_for(RULE_PANIC), 0);
}
