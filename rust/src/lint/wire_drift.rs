//! Rule `wire`: counter/gauge name sets cannot drift.
//!
//! The stats/metrics wire surface has four coupled name sets: the
//! serializers in `plan/wire.rs` (`counters_to_obj`, `metrics_frame`,
//! `metrics_medians`), their decoders (`counters_from_obj`,
//! `metrics_from_json`), the normative example frames in
//! `docs/WIRE.md` §6, and the metrics-snapshot schema in §8. A counter
//! added to one and not the others silently ships a gauge nobody can
//! read (or documents one nobody emits) — today only the pinned
//! example frames in `tests/docs_wire.rs` catch a subset of that. This
//! rule extracts each set by token scan and fails on any asymmetric
//! difference, naming the keys on each side.

use super::scan::Source;
use super::{Finding, RULE_WIRE};
use std::collections::BTreeSet;

/// Compare the serializer/decoder/spec name sets extracted from
/// `plan/wire.rs` (`wire_rs`) and `docs/WIRE.md` (`wire_md`).
pub fn check_texts(wire_rs: &str, wire_md: &str) -> Vec<Finding> {
    let src = Source::parse(wire_rs);
    let stats_ser = set_arg_keys(&fn_body(&src, "counters_to_obj"));
    let stats_dec = get_arg_keys(&fn_body(&src, "counters_from_obj"));
    let mut metrics_ser = set_arg_keys(&fn_body(&src, "metrics_frame"));
    metrics_ser.remove("v");
    metrics_ser.remove("metrics");
    let metrics_dec: BTreeSet<String> = get_arg_keys(&fn_body(&src, "metrics_from_json"))
        .difference(&stats_ser)
        .cloned()
        .collect();
    let mut medians = set_arg_keys(&fn_body(&src, "metrics_medians"));
    medians.remove("_schema");

    let mut findings = Vec::new();
    let out = &mut findings;
    diff(out, "rust/src/plan/wire.rs", &stats_ser, &stats_dec, "stats-serializer", "stats-decoder");
    diff(
        out,
        "rust/src/plan/wire.rs",
        &metrics_ser,
        &metrics_dec,
        "metrics-serializer",
        "metrics-decoder",
    );

    match doc_frame_keys(wire_md, "## 6.", "jsonl", "\"stats\":{") {
        None => out.push(missing_doc("no stats example frame in WIRE.md section 6")),
        Some(doc) => diff(out, "docs/WIRE.md", &stats_ser, &doc, "code-stats", "spec-stats"),
    }
    let all_metrics: BTreeSet<String> = stats_ser.union(&metrics_ser).cloned().collect();
    match doc_frame_keys(wire_md, "## 6.", "jsonl", "\"metrics\":{") {
        None => out.push(missing_doc("no metrics example frame in WIRE.md section 6")),
        Some(doc) => diff(out, "docs/WIRE.md", &all_metrics, &doc, "code-metrics", "spec-metrics"),
    }
    match doc_medians_keys(wire_md) {
        None => out.push(missing_doc("no metrics-snapshot example in WIRE.md section 8")),
        Some(doc) => diff(out, "docs/WIRE.md", &medians, &doc, "code-snapshot", "spec-snapshot"),
    }
    findings
}

/// Push a drift finding when `a` and `b` differ, naming the keys only
/// on each side.
fn diff(
    findings: &mut Vec<Finding>,
    path: &str,
    a: &BTreeSet<String>,
    b: &BTreeSet<String>,
    la: &str,
    lb: &str,
) {
    if a != b {
        let only_a: Vec<&str> = a.difference(b).map(String::as_str).collect();
        let only_b: Vec<&str> = b.difference(a).map(String::as_str).collect();
        findings.push(Finding {
            rule: RULE_WIRE,
            path: path.to_string(),
            line: 1,
            message: format!(
                "counter drift: {la}-only [{}]; {lb}-only [{}]",
                only_a.join(", "),
                only_b.join(", ")
            ),
        });
    }
}

fn missing_doc(message: &str) -> Finding {
    Finding {
        rule: RULE_WIRE,
        path: "docs/WIRE.md".to_string(),
        line: 1,
        message: message.to_string(),
    }
}

/// The scanned lines of `fn name`'s brace-matched body.
pub(super) fn fn_body<'a>(src: &'a Source, name: &str) -> Vec<&'a super::scan::Line> {
    let needle = format!("fn {name}");
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut target: Option<usize> = None;
    let mut seen = false;
    for ln in &src.lines {
        if !seen && ln.code.contains(&needle) {
            seen = true;
        }
        for c in ln.code.chars() {
            if c == '{' {
                depth += 1;
                if seen && target.is_none() {
                    target = Some(depth);
                }
            } else if c == '}' {
                if target == Some(depth) {
                    return out;
                }
                depth = depth.saturating_sub(1);
            }
        }
        if seen && target.is_some() {
            out.push(ln);
        }
    }
    out
}

/// String literals passed as the first argument of `.set(` calls in
/// `body` — the serializer-side key set. Handles the key literal
/// landing on the line after a rustfmt-wrapped `.set(`.
pub(super) fn set_arg_keys(body: &[&super::scan::Line]) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let mut pending = false;
    for ln in body {
        let code = &ln.code;
        let mut si = 0usize;
        let mut pos = 0usize;
        while let Some(p) = code[pos..].find("\"\"") {
            let at = pos + p;
            let head = code[..at].trim_end();
            let is_key = head.ends_with(".set(") || (pending && head.is_empty());
            pending = false;
            if is_key {
                if let Some(s) = ln.strings.get(si) {
                    keys.insert(s.clone());
                }
            }
            si += 1;
            pos = at + 2;
        }
        if code.trim_end().ends_with(".set(") {
            pending = true;
        }
    }
    keys
}

/// String literals passed as the key argument of `get_u64(…, "…")` /
/// `get_f64(…, "…")` calls in `body` — the decoder-side key set.
fn get_arg_keys(body: &[&super::scan::Line]) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    for ln in body {
        let code = &ln.code;
        for getter in ["get_u64(", "get_f64("] {
            let mut pos = 0usize;
            while let Some(p) = code[pos..].find(getter) {
                let after = pos + p + getter.len();
                // expect `ident, ""` (any whitespace) before the literal
                if let Some(q) = code[after..].find("\"\"") {
                    let between = &code[after..after + q];
                    let arg_shape = |c: char| {
                        c.is_alphanumeric() || c == '_' || c == ',' || c.is_whitespace()
                    };
                    if between.chars().all(arg_shape) && between.contains(',') {
                        let idx = code[..after + q].matches("\"\"").count();
                        if let Some(s) = ln.strings.get(idx) {
                            keys.insert(s.clone());
                        }
                    }
                }
                pos = after;
            }
        }
    }
    keys
}

/// Keys of the flat JSON object following `anchor` inside the first
/// fenced `lang` block after the heading starting with `section` —
/// e.g. the `"stats":{…}` frame in WIRE.md §6.
fn doc_frame_keys(md: &str, section: &str, lang: &str, anchor: &str) -> Option<BTreeSet<String>> {
    for line in md_block(md, section, lang) {
        if let Some(p) = line.find(anchor) {
            let rest = &line[p + anchor.len()..];
            let body = match rest.find('}') {
                Some(end) => &rest[..end],
                None => rest,
            };
            return Some(quoted_keys(body));
        }
    }
    None
}

/// The `"serve/…"` keys of the §8 metrics-snapshot example block.
fn doc_medians_keys(md: &str) -> Option<BTreeSet<String>> {
    let block = md_block(md, "## 8.", "json");
    if block.is_empty() {
        return None;
    }
    let mut keys = BTreeSet::new();
    for line in block {
        for key in quoted_keys(line) {
            if key.starts_with("serve/") {
                keys.insert(key);
            }
        }
    }
    Some(keys)
}

/// Lines of the first ``` `lang` fence after the heading prefix.
fn md_block<'a>(md: &'a str, section: &str, lang: &str) -> Vec<&'a str> {
    let mut out = Vec::new();
    let mut seen = false;
    let mut active = false;
    let fence = format!("```{lang}");
    for line in md.lines() {
        if line.starts_with(section) {
            seen = true;
        } else if seen && !active && line.starts_with(&fence) {
            active = true;
        } else if active && line.starts_with("```") {
            return out;
        } else if active {
            out.push(line);
        } else if seen && line.starts_with("## ") {
            seen = false;
        }
    }
    out
}

/// `"key":` occurrences in a JSON fragment.
fn quoted_keys(fragment: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let bytes: Vec<char> = fragment.chars().collect();
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i] == '"' {
            let start = i + 1;
            let mut j = start;
            while j < bytes.len() && bytes[j] != '"' {
                j += 1;
            }
            if j < bytes.len() {
                let mut k = j + 1;
                while k < bytes.len() && bytes[k].is_whitespace() {
                    k += 1;
                }
                if bytes.get(k) == Some(&':') {
                    keys.insert(bytes[start..j].iter().collect());
                }
                i = j + 1;
                continue;
            }
        }
        i += 1;
    }
    keys
}
