//! xbarmap CLI — leader entrypoint.
//!
//! Subcommands:
//! * `repro`  — regenerate paper tables/figures into an output directory;
//! * `sweep`  — run the §3.1 optimization sweep for a zoo network;
//! * `pack`   — pack one network onto one tile dimension, print placement;
//! * `info`   — show a network's layers, WM shapes and reuse factors;
//! * `serve`  — end-to-end serving through the AOT crossbar artifact.

use anyhow::{anyhow, Result};
use std::path::Path;
use xbarmap::area::AreaModel;
use xbarmap::coordinator::{digits, Coordinator, CoordinatorConfig};
use xbarmap::frag;
use xbarmap::geom::Tile;
use xbarmap::ilp;
use xbarmap::nets::zoo;
use xbarmap::opt::{self, Engine, SweepConfig};
use xbarmap::pack::{self, Discipline};
use xbarmap::report;
use xbarmap::util::cli::{usage, Args, OptSpec};
use xbarmap::util::prng::Rng;
use xbarmap::util::table::{sig3, Table};

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("repro", "regenerate paper tables/figures (table1 table3 table5 fig4 fig7 fig8 fig9 table6 fig10 latency | all)"),
    ("sweep", "run the §3.1 tile-dimension optimization sweep"),
    ("pack", "pack a network onto one tile dimension"),
    ("info", "describe a zoo network"),
    ("serve", "serve synthetic digit requests through the AOT crossbar model"),
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{}", usage("xbarmap", "ANN-to-crossbar mapping optimizer", SUBCOMMANDS, &[]));
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "repro" => cmd_repro(rest),
        "sweep" => cmd_sweep(rest),
        "pack" => cmd_pack(rest),
        "info" => cmd_info(rest),
        "serve" => cmd_serve(rest),
        "--help" | "help" | "-h" => {
            print!("{}", usage("xbarmap", "ANN-to-crossbar mapping optimizer", SUBCOMMANDS, &[]));
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' — try `xbarmap help`")),
    }
}

fn parse_discipline(s: &str) -> Result<Discipline> {
    match s {
        "dense" => Ok(Discipline::Dense),
        "pipeline" => Ok(Discipline::Pipeline),
        _ => Err(anyhow!("--discipline must be dense|pipeline, got {s}")),
    }
}

fn parse_engine(s: &str, nodes: u64) -> Result<Engine> {
    match s {
        "simple" => Ok(Engine::Simple),
        "ffd" => Ok(Engine::Ffd),
        "lps" | "ilp" => Ok(Engine::Ilp { max_nodes: nodes }),
        _ => Err(anyhow!("--engine must be simple|ffd|lps, got {s}")),
    }
}

fn net_by_name(name: &str) -> Result<xbarmap::nets::Network> {
    zoo::by_name(name).ok_or_else(|| {
        anyhow!("unknown network '{name}' (try lenet|alexnet|resnet9|resnet18|resnet34|resnet50|bert|digits-mlp)")
    })
}

fn cmd_repro(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "out", help: "output directory", value: Some("DIR"), default: Some("results") },
        OptSpec { name: "fast", help: "smaller sweeps/budgets (CI)", value: None, default: None },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let out = a.get("out").unwrap().to_string();
    let written = report::run(&a.positional, Path::new(&out), a.flag("fast"))?;
    println!("\nwrote {} experiment(s) to {out}/", written.len());
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "net", help: "zoo network", value: Some("NAME"), default: Some("resnet18") },
        OptSpec { name: "discipline", help: "dense|pipeline", value: Some("D"), default: Some("dense") },
        OptSpec { name: "engine", help: "simple|ffd|lps", value: Some("E"), default: Some("simple") },
        OptSpec { name: "aspects", help: "max aspect ratio (1..=8)", value: Some("N"), default: Some("8") },
        OptSpec { name: "rapa", help: "balanced RAPA replication n0", value: Some("N"), default: None },
        OptSpec { name: "ilp-nodes", help: "branch&bound node budget", value: Some("N"), default: Some("2000000") },
        OptSpec { name: "threads", help: "sweep worker threads (0 = auto)", value: Some("N"), default: Some("0") },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let net = net_by_name(a.req("net").map_err(|e| anyhow!(e))?)?;
    let discipline = parse_discipline(a.req("discipline").map_err(|e| anyhow!(e))?)?;
    let nodes = a.req_usize("ilp-nodes").map_err(|e| anyhow!(e))? as u64;
    let engine = parse_engine(a.req("engine").map_err(|e| anyhow!(e))?, nodes)?;
    let max_aspect = a.req_usize("aspects").map_err(|e| anyhow!(e))?.clamp(1, 8);
    let threads = a.req_usize("threads").map_err(|e| anyhow!(e))?;
    let mut cfg = SweepConfig {
        discipline,
        engine,
        aspects: (1..=max_aspect).collect(),
        ..SweepConfig::paper_default(discipline)
    };
    if let Some(n0) = a.get_usize("rapa").map_err(|e| anyhow!(e))? {
        cfg.replication = Some(xbarmap::perf::rapa::plan_balanced(&net, n0));
    }
    let pts = if threads == 0 {
        opt::sweep(&net, &cfg)
    } else {
        opt::sweep_with_threads(&net, &cfg, threads)
    };
    let mut t = Table::new(&["tile", "aspect", "blocks", "tiles", "tile eff", "pack eff", "area mm2"]);
    for p in &pts {
        t.row(&[
            p.tile.to_string(),
            p.aspect.to_string(),
            p.n_blocks.to_string(),
            p.n_tiles.to_string(),
            sig3(p.tile_eff),
            sig3(p.packing_eff),
            sig3(p.total_area_mm2),
        ]);
    }
    println!("{}", t.render());
    for p in opt::best_per_aspect(&pts) {
        println!("best @aspect {}: {} tiles={} area={} mm2", p.aspect, p.tile, p.n_tiles, sig3(p.total_area_mm2));
    }
    let best = opt::optimum(&pts).unwrap();
    println!(
        "\nOPTIMUM {} ({}): {} tiles, {} mm2, tile_eff {}",
        best.tile,
        cfg.engine,
        best.n_tiles,
        sig3(best.total_area_mm2),
        sig3(best.tile_eff)
    );
    Ok(())
}

fn cmd_pack(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "net", help: "zoo network", value: Some("NAME"), default: Some("lenet") },
        OptSpec { name: "rows", help: "tile word lines", value: Some("N"), default: Some("256") },
        OptSpec { name: "cols", help: "tile bit lines", value: Some("N"), default: Some("256") },
        OptSpec { name: "discipline", help: "dense|pipeline", value: Some("D"), default: Some("dense") },
        OptSpec { name: "engine", help: "simple|ffd|lps", value: Some("E"), default: Some("simple") },
        OptSpec { name: "ilp-nodes", help: "branch&bound node budget", value: Some("N"), default: Some("2000000") },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let net = net_by_name(a.req("net").map_err(|e| anyhow!(e))?)?;
    let tile = Tile::new(
        a.req_usize("rows").map_err(|e| anyhow!(e))?,
        a.req_usize("cols").map_err(|e| anyhow!(e))?,
    );
    let discipline = parse_discipline(a.req("discipline").map_err(|e| anyhow!(e))?)?;
    let nodes = a.req_usize("ilp-nodes").map_err(|e| anyhow!(e))? as u64;
    let engine = parse_engine(a.req("engine").map_err(|e| anyhow!(e))?, nodes)?;
    let blocks = frag::fragment_network(&net, tile);
    let packing = match engine {
        Engine::Simple => pack::simple::pack(&blocks, tile, discipline),
        Engine::Ffd => pack::ffd::pack(&blocks, tile, discipline),
        Engine::Ilp { max_nodes } => {
            let r = ilp::solve_packing(
                &blocks,
                tile,
                discipline,
                ilp::Budget { max_nodes, ..Default::default() },
            );
            println!(
                "LPS: lower bound {} | optimal {} | nodes {}",
                r.lower_bound, r.optimal, r.nodes
            );
            r.packing
        }
    };
    pack::placement::validate(&packing).map_err(|e| anyhow!("invalid packing: {e}"))?;
    let area = AreaModel::paper_default();
    println!(
        "{} on {} [{discipline}/{engine}]: {} blocks -> {} tiles | packing eff {} | tile eff {} | total {} mm2",
        net.name,
        tile,
        blocks.len(),
        packing.n_bins,
        sig3(packing.packing_efficiency()),
        sig3(area.efficiency(tile)),
        sig3(area.total_area_mm2(packing.n_bins, tile)),
    );
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let specs = [OptSpec { name: "net", help: "zoo network", value: Some("NAME"), default: Some("resnet18") }];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let net = net_by_name(a.req("net").map_err(|e| anyhow!(e))?)?;
    println!("{} — {} ({} layers, {} weights)", net.name, net.input_desc, net.n_layers(), net.total_weights());
    let mut t = Table::new(&["layer", "WM rows", "WM cols", "weights", "N_reuse"]);
    for l in &net.layers {
        let (r, c) = l.matrix_shape();
        t.row(&[
            l.name.clone(),
            r.to_string(),
            c.to_string(),
            l.weights().to_string(),
            l.reuse().to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "requests", help: "number of synthetic requests", value: Some("N"), default: Some("2048") },
        OptSpec { name: "artifacts", help: "artifacts directory", value: Some("DIR"), default: None },
        OptSpec { name: "seed", help: "workload PRNG seed", value: Some("N"), default: Some("7") },
        OptSpec { name: "fp32", help: "serve the fp32 oracle instead of the crossbar model", value: None, default: None },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let n = a.req_usize("requests").map_err(|e| anyhow!(e))?;
    let seed = a.req_usize("seed").map_err(|e| anyhow!(e))? as u64;
    let cfg = CoordinatorConfig {
        artifacts: a.get("artifacts").map(|s| s.to_string()),
        crossbar: !a.flag("fp32"),
        ..Default::default()
    };
    let coordinator = Coordinator::new(&cfg)?;
    println!(
        "deployment: DigitsMLP on {} -> {} tiles, {} mm2, modeled latency {:.1} ns",
        coordinator.tile,
        coordinator.mapping.n_tiles(),
        sig3(coordinator.total_area_mm2),
        coordinator.modeled_latency_s * 1e9,
    );

    let (tx, rx) = std::sync::mpsc::channel();
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(seed);
        for s in digits::synth_digits(&mut rng, n, 0.35) {
            if tx.send(s).is_err() {
                break;
            }
        }
    });
    let stats = coordinator.serve(rx)?;
    producer.join().map_err(|_| anyhow!("producer thread panicked"))?;

    println!(
        "served {} requests in {} batches over {:.3}s -> {:.0} req/s | batch p50 {:.3} ms p95 {:.3} ms | accuracy {:.4}",
        stats.requests,
        stats.batches,
        stats.wall_s,
        stats.throughput_per_s,
        stats.batch_p50_s * 1e3,
        stats.batch_p95_s * 1e3,
        stats.accuracy,
    );
    if let Some(build_acc) = coordinator.build_time_accuracy() {
        println!("build-time crossbar accuracy (meta.json): {build_acc:.4}");
    }
    Ok(())
}
