//! xbarmap CLI — leader entrypoint.
//!
//! Subcommands:
//! * `repro`      — regenerate paper tables/figures into an output directory;
//! * `sweep`      — run the §3.1 optimization sweep for a zoo network;
//! * `pack`       — pack one network onto one tile dimension, print placement;
//! * `plan`       — serve JSONL MapRequests as JSONL MapPlans (file or
//!   stdin; `--connect` forwards them to a running planning service);
//! * `info`       — show a network's layers, WM shapes and reuse factors;
//! * `serve`      — end-to-end serving through the AOT crossbar artifact, or
//!   with `--plans` the long-running TCP/JSONL planning service;
//! * `warehouse`  — manage the persistent plan store: `precompute` prices
//!   the zoo × common-grid cross-product offline into a warehouse
//!   directory, `compact` rewrites live records into fresh segments,
//!   `stat` reports what a boot would load;
//! * `bench-gate` — compare BENCH_*.json medians against a baseline.
//!
//! `sweep` and `pack` are thin shims over the [`xbarmap::plan`] front door;
//! `plan` is its wire-format service endpoint.

use anyhow::{anyhow, Result};
use std::io::Write as _;
use std::path::Path;
use xbarmap::cluster;
use xbarmap::coordinator::{digits, Coordinator, CoordinatorConfig};
use xbarmap::nets::zoo;
use xbarmap::opt::Engine;
use xbarmap::pack::Discipline;
use xbarmap::plan::{self, MapRequest, Replication};
use xbarmap::report;
use xbarmap::service::{PlanCache, Service, ServiceConfig};
use xbarmap::store::{Warehouse, WarehouseConfig};
use xbarmap::util::benchkit;
use xbarmap::util::cli::{usage, Args, OptSpec};
use xbarmap::util::json;
use xbarmap::util::prng::Rng;
use xbarmap::util::table::{sig3, Table};

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("repro", "regenerate paper tables/figures (table1 table3 table5 fig4 fig7 fig8 fig9 table6 fig10 latency | all)"),
    ("sweep", "run the §3.1 tile-dimension optimization sweep"),
    ("pack", "pack a network onto one tile dimension"),
    ("plan", "stream JSONL mapping requests -> JSONL plans (v1 wire format)"),
    ("info", "describe a zoo network"),
    ("serve", "serve inference (--plans: long-running TCP/JSONL planning service)"),
    ("warehouse", "manage the persistent plan store (precompute | compact | stat)"),
    ("bench-gate", "fail when bench medians regress past a baseline"),
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{}", usage("xbarmap", "ANN-to-crossbar mapping optimizer", SUBCOMMANDS, &[]));
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "repro" => cmd_repro(rest),
        "sweep" => cmd_sweep(rest),
        "pack" => cmd_pack(rest),
        "plan" => cmd_plan(rest),
        "info" => cmd_info(rest),
        "serve" => cmd_serve(rest),
        "warehouse" => cmd_warehouse(rest),
        "bench-gate" => cmd_bench_gate(rest),
        "--help" | "help" | "-h" => {
            print!("{}", usage("xbarmap", "ANN-to-crossbar mapping optimizer", SUBCOMMANDS, &[]));
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' — try `xbarmap help`")),
    }
}

fn cmd_repro(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "out", help: "output directory", value: Some("DIR"), default: Some("results") },
        OptSpec { name: "fast", help: "smaller sweeps/budgets (CI)", value: None, default: None },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let out = a.get("out").unwrap().to_string();
    let written = report::run(&a.positional, Path::new(&out), a.flag("fast"))?;
    println!("\nwrote {} experiment(s) to {out}/", written.len());
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "net", help: "zoo network", value: Some("NAME"), default: Some("resnet18") },
        OptSpec { name: "discipline", help: "dense|pipeline", value: Some("D"), default: Some("dense") },
        OptSpec { name: "engine", help: "simple|ffd|lps", value: Some("E"), default: Some("simple") },
        OptSpec { name: "aspects", help: "max aspect ratio (1..=8)", value: Some("N"), default: Some("8") },
        OptSpec { name: "rapa", help: "balanced RAPA replication n0", value: Some("N"), default: None },
        OptSpec { name: "ilp-nodes", help: "branch&bound node budget", value: Some("N"), default: Some("2000000") },
        OptSpec { name: "threads", help: "sweep worker threads (0 = auto)", value: Some("N"), default: Some("0") },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let discipline: Discipline =
        a.req("discipline").map_err(|e| anyhow!(e))?.parse().map_err(|e: String| anyhow!(e))?;
    let nodes = a.req_usize("ilp-nodes").map_err(|e| anyhow!(e))? as u64;
    let engine = Engine::parse_with_budget(a.req("engine").map_err(|e| anyhow!(e))?, nodes)
        .map_err(|e| anyhow!(e))?;
    let max_aspect = a.req_usize("aspects").map_err(|e| anyhow!(e))?.clamp(1, 8);
    let threads = a.req_usize("threads").map_err(|e| anyhow!(e))?;

    let mut request = MapRequest::zoo(a.req("net").map_err(|e| anyhow!(e))?)
        .discipline(discipline)
        .engine(engine)
        .grid((6, 13), (1..=max_aspect).collect())
        .threads(threads);
    if let Some(n0) = a.get_usize("rapa").map_err(|e| anyhow!(e))? {
        request = request.replication(Replication::Balanced(n0));
    }
    let mapping = request.build()?.plan()?;

    let mut t = Table::new(&["tile", "aspect", "blocks", "tiles", "tile eff", "pack eff", "area mm2"]);
    for p in &mapping.points {
        t.row(&[
            p.tile.to_string(),
            p.aspect.to_string(),
            p.n_blocks.to_string(),
            p.n_tiles.to_string(),
            sig3(p.tile_eff),
            sig3(p.packing_eff),
            sig3(p.total_area_mm2),
        ]);
    }
    println!("{}", t.render());
    for p in &mapping.best_per_aspect {
        println!("best @aspect {}: {} tiles={} area={} mm2", p.aspect, p.tile, p.n_tiles, sig3(p.total_area_mm2));
    }
    let best = &mapping.best;
    println!(
        "\nOPTIMUM {} ({}): {} tiles, {} mm2, tile_eff {}",
        best.tile,
        mapping.engine,
        best.n_tiles,
        sig3(best.total_area_mm2),
        sig3(best.tile_eff)
    );
    Ok(())
}

fn cmd_pack(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "net", help: "zoo network", value: Some("NAME"), default: Some("lenet") },
        OptSpec { name: "rows", help: "tile word lines", value: Some("N"), default: Some("256") },
        OptSpec { name: "cols", help: "tile bit lines", value: Some("N"), default: Some("256") },
        OptSpec { name: "discipline", help: "dense|pipeline", value: Some("D"), default: Some("dense") },
        OptSpec { name: "engine", help: "simple|ffd|lps", value: Some("E"), default: Some("simple") },
        OptSpec { name: "ilp-nodes", help: "branch&bound node budget", value: Some("N"), default: Some("2000000") },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let discipline: Discipline =
        a.req("discipline").map_err(|e| anyhow!(e))?.parse().map_err(|e: String| anyhow!(e))?;
    let nodes = a.req_usize("ilp-nodes").map_err(|e| anyhow!(e))? as u64;
    let engine = Engine::parse_with_budget(a.req("engine").map_err(|e| anyhow!(e))?, nodes)
        .map_err(|e| anyhow!(e))?;

    let mapping = MapRequest::zoo(a.req("net").map_err(|e| anyhow!(e))?)
        .tile(
            a.req_usize("rows").map_err(|e| anyhow!(e))?,
            a.req_usize("cols").map_err(|e| anyhow!(e))?,
        )
        .discipline(discipline)
        .engine(engine)
        .placements(true)
        .build()?
        .plan()?;

    if matches!(mapping.engine, Engine::Ilp { .. }) {
        println!(
            "LPS: lower bound {} | optimal {} | nodes {}",
            mapping.provenance.lower_bound, mapping.provenance.optimal, mapping.provenance.nodes
        );
    }
    let best = &mapping.best;
    println!(
        "{} on {} [{discipline}/{engine}]: {} blocks -> {} tiles | packing eff {} | tile eff {} | total {} mm2",
        mapping.network,
        best.tile,
        best.n_blocks,
        best.n_tiles,
        sig3(best.packing_eff),
        sig3(best.tile_eff),
        sig3(best.total_area_mm2),
    );
    Ok(())
}

/// The design-service endpoint: JSONL requests in, JSONL plans out —
/// solved in-process by default, or forwarded to a running
/// `serve --plans` service with `--connect` (the retrying
/// [`plan::client`], so transient connection loss is absorbed).
fn cmd_plan(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "in", help: "JSONL request file ('-' = stdin)", value: Some("FILE"), default: Some("-") },
        OptSpec { name: "connect", help: "forward requests to a running planning service instead of solving in-process", value: Some("HOST:PORT"), default: None },
        OptSpec { name: "retries", help: "retry attempts after the first, connect mode", value: Some("N"), default: Some("4") },
        OptSpec { name: "timeout", help: "per-response read timeout in seconds, connect mode", value: Some("SECS"), default: Some("30") },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let source = a.req("in").map_err(|e| anyhow!(e))?;
    if let Some(addr) = a.get("connect") {
        let retries = a.req_usize("retries").map_err(|e| anyhow!(e))? as u32;
        let timeout_s = a.req_f64("timeout").map_err(|e| anyhow!(e))?;
        if !(timeout_s > 0.0 && timeout_s <= 1e9) {
            return Err(anyhow!("--timeout must be between 0 (exclusive) and 1e9 seconds"));
        }
        return cmd_plan_connect(addr, source, retries, timeout_s);
    }
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let summary = if source == "-" {
        let stdin = std::io::stdin();
        plan::serve_jsonl(stdin.lock(), &mut out)?
    } else {
        let file = std::fs::File::open(source)
            .map_err(|e| anyhow!("open {source}: {e}"))?;
        plan::serve_jsonl(std::io::BufReader::new(file), &mut out)?
    };
    out.flush()?;
    eprintln!("served {} request(s), {} error(s)", summary.requests, summary.errors);
    Ok(())
}

/// `plan --connect`: pump the JSONL request stream through a running
/// planning service, one lock-step round-trip per non-blank line, echoing
/// each response line to stdout.
fn cmd_plan_connect(addr: &str, source: &str, retries: u32, timeout_s: f64) -> Result<()> {
    use std::io::BufRead as _;
    use std::net::ToSocketAddrs as _;
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| anyhow!("resolve {addr}: {e}"))?
        .next()
        .ok_or_else(|| anyhow!("resolve {addr}: no addresses"))?;
    let cfg = plan::client::ClientConfig {
        read_timeout: std::time::Duration::from_secs_f64(timeout_s),
        retries,
        ..Default::default()
    };
    let mut client = plan::client::Client::with_config(sock, cfg);
    let input: Box<dyn std::io::BufRead> = if source == "-" {
        Box::new(std::io::BufReader::new(std::io::stdin()))
    } else {
        let file = std::fs::File::open(source).map_err(|e| anyhow!("open {source}: {e}"))?;
        Box::new(std::io::BufReader::new(file))
    };
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let (mut requests, mut errors) = (0u64, 0u64);
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = client.roundtrip_line(line.trim()).map_err(|e| anyhow!("{e}"))?;
        if json::parse(&response).map_or(false, |j| j.get("error").is_some()) {
            errors += 1;
        }
        requests += 1;
        writeln!(out, "{response}")?;
    }
    out.flush()?;
    eprintln!("served {requests} request(s), {errors} error(s) via {sock}");
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let specs = [OptSpec { name: "net", help: "zoo network", value: Some("NAME"), default: Some("resnet18") }];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let name = a.req("net").map_err(|e| anyhow!(e))?;
    let net = zoo::by_name(name)
        .ok_or_else(|| anyhow!("unknown network '{name}' (try {})", zoo::NAMES.join("|")))?;
    println!("{} — {} ({} layers, {} weights)", net.name, net.input_desc, net.n_layers(), net.total_weights());
    let mut t = Table::new(&["layer", "WM rows", "WM cols", "weights", "N_reuse"]);
    for l in &net.layers {
        let (r, c) = l.matrix_shape();
        t.row(&[
            l.name.clone(),
            r.to_string(),
            c.to_string(),
            l.weights().to_string(),
            l.reuse().to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    // `serve --plans` is the long-running planning service; plain `serve`
    // drives digit inference through the AOT crossbar artifact
    if argv.iter().any(|a| a == "--plans") {
        return cmd_serve_plans(argv);
    }
    let specs = [
        OptSpec { name: "requests", help: "number of synthetic requests", value: Some("N"), default: Some("2048") },
        OptSpec { name: "artifacts", help: "artifacts directory", value: Some("DIR"), default: None },
        OptSpec { name: "seed", help: "workload PRNG seed", value: Some("N"), default: Some("7") },
        OptSpec { name: "fp32", help: "serve the fp32 oracle instead of the crossbar model", value: None, default: None },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let n = a.req_usize("requests").map_err(|e| anyhow!(e))?;
    let seed = a.req_usize("seed").map_err(|e| anyhow!(e))? as u64;
    let cfg = CoordinatorConfig {
        artifacts: a.get("artifacts").map(|s| s.to_string()),
        crossbar: !a.flag("fp32"),
        ..Default::default()
    };
    let coordinator = Coordinator::new(&cfg)?;
    println!(
        "deployment: DigitsMLP on {} -> {} tiles, {} mm2, modeled latency {:.1} ns",
        coordinator.tile,
        coordinator.mapping.n_tiles(),
        sig3(coordinator.total_area_mm2),
        coordinator.modeled_latency_s * 1e9,
    );

    let (tx, rx) = std::sync::mpsc::channel();
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(seed);
        for s in digits::synth_digits(&mut rng, n, 0.35) {
            if tx.send(s).is_err() {
                break;
            }
        }
    });
    let stats = coordinator.serve(rx)?;
    producer.join().map_err(|_| anyhow!("producer thread panicked"))?;

    println!(
        "served {} requests in {} batches over {:.3}s -> {:.0} req/s | batch p50 {:.3} ms p95 {:.3} ms | accuracy {:.4}",
        stats.requests,
        stats.batches,
        stats.wall_s,
        stats.throughput_per_s,
        stats.batch_p50_s * 1e3,
        stats.batch_p95_s * 1e3,
        stats.accuracy,
    );
    if let Some(build_acc) = coordinator.build_time_accuracy() {
        println!("build-time crossbar accuracy (meta.json): {build_acc:.4}");
    }
    Ok(())
}

/// The always-on planning service: a TCP listener speaking the same JSONL
/// wire as `xbarmap plan`, with a bounded queue + worker pool, a
/// canonical-request LRU plan cache (optional TTL), per-connection quotas
/// and a service-wide in-flight admission cap (typed reject frames),
/// in-band `{"v":1,"cmd":"stats"|"metrics"}` requests, an optional
/// periodic metrics-file writer, per-solve wall-clock deadlines
/// (`--deadline-ms`, typed deadline rejects), panic containment (typed
/// internal rejects), and graceful drain on SIGINT/ctrl-C or SIGTERM.
fn cmd_serve_plans(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "plans", help: "serve mapping plans over TCP/JSONL", value: None, default: None },
        OptSpec { name: "addr", help: "listen address (':0' = ephemeral port)", value: Some("HOST:PORT"), default: Some("127.0.0.1:7878") },
        OptSpec { name: "workers", help: "planning worker threads (0 = auto)", value: Some("N"), default: Some("0") },
        OptSpec { name: "queue", help: "bounded request-queue capacity", value: Some("N"), default: Some("64") },
        OptSpec { name: "cache", help: "plan-cache entries (0 = disable)", value: Some("N"), default: Some("256") },
        OptSpec { name: "cache-ttl", help: "plan-cache entry lifetime, seconds (0 = never expires)", value: Some("SECS"), default: Some("0") },
        OptSpec { name: "cache-max-bytes", help: "plan-cache byte budget, keys + serialized plans (0 = unbounded)", value: Some("N"), default: Some("0") },
        OptSpec { name: "per-conn-quota", help: "requests per connection before a typed over-quota reject (0 = unlimited)", value: Some("N"), default: Some("0") },
        OptSpec { name: "max-inflight", help: "service-wide admitted-request cap before typed over-inflight rejects (0 = unlimited)", value: Some("N"), default: Some("0") },
        OptSpec { name: "deadline-ms", help: "wall-clock budget per solve in milliseconds before a typed deadline reject (0 = unbounded)", value: Some("MS"), default: Some("0") },
        OptSpec { name: "tenant-quota", help: "requests per tenant id across all its connections before typed over-quota rejects (0 = unlimited)", value: Some("N"), default: Some("0") },
        OptSpec { name: "admin-token", help: "shared secret authorizing the in-band {\"v\":1,\"cmd\":\"recalibrate\"} verb (absent = verb always rejected)", value: Some("TOKEN"), default: None },
        OptSpec { name: "metrics-out", help: "periodically write the gauge snapshot (BENCH_*.json schema) to FILE", value: Some("FILE"), default: None },
        OptSpec { name: "metrics-interval", help: "seconds between metrics-file rewrites", value: Some("SECS"), default: Some("10") },
        OptSpec { name: "warehouse", help: "persistent plan store directory (second cache tier behind the LRU)", value: Some("DIR"), default: None },
        OptSpec { name: "cluster", help: "shard across N supervised worker processes with replay-based failover (0 = single process)", value: Some("N"), default: Some("0") },
        OptSpec { name: "announce", help: "print one {\"v\":1,\"announce\":\"HOST:PORT\"} line on stdout once listening (cluster workers use this to report their ephemeral port)", value: None, default: None },
        OptSpec { name: "no-sigint", help: "ignore SIGINT/SIGTERM (cluster workers drain when the router asks, not on terminal signals)", value: None, default: None },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    // upper bound keeps Duration::from_secs_f64 panic-free (it aborts past
    // u64 seconds); 1e9 s ≈ 31 years, far beyond any sane deployment
    const MAX_SECS: f64 = 1e9;
    let ttl_s = a.req_f64("cache-ttl").map_err(|e| anyhow!(e))?;
    if !(ttl_s >= 0.0 && ttl_s <= MAX_SECS) {
        return Err(anyhow!("--cache-ttl must be between 0 and {MAX_SECS:e} seconds"));
    }
    let interval_s = a.req_f64("metrics-interval").map_err(|e| anyhow!(e))?;
    if !(interval_s > 0.0 && interval_s <= MAX_SECS) {
        return Err(anyhow!("--metrics-interval must be between 0 (exclusive) and {MAX_SECS:e} seconds"));
    }
    let cfg = ServiceConfig {
        addr: a.req("addr").map_err(|e| anyhow!(e))?.to_string(),
        workers: a.req_usize("workers").map_err(|e| anyhow!(e))?,
        queue_capacity: a.req_usize("queue").map_err(|e| anyhow!(e))?.max(1),
        cache_capacity: a.req_usize("cache").map_err(|e| anyhow!(e))?,
        cache_ttl: (ttl_s > 0.0).then(|| std::time::Duration::from_secs_f64(ttl_s)),
        cache_max_bytes: a.req_usize("cache-max-bytes").map_err(|e| anyhow!(e))?,
        per_conn_quota: a.req_usize("per-conn-quota").map_err(|e| anyhow!(e))?,
        max_inflight: a.req_usize("max-inflight").map_err(|e| anyhow!(e))?,
        metrics_out: a.get("metrics-out").map(std::path::PathBuf::from),
        metrics_interval: std::time::Duration::from_secs_f64(interval_s),
        deadline: {
            let ms = a.req_usize("deadline-ms").map_err(|e| anyhow!(e))?;
            (ms > 0).then(|| std::time::Duration::from_millis(ms as u64))
        },
        warehouse: a.get("warehouse").map(std::path::PathBuf::from),
        tenant_quota: a.req_usize("tenant-quota").map_err(|e| anyhow!(e))? as u64,
        admin_token: a.get("admin-token").map(|s| s.to_string()),
        watch_sigint: !a.flag("no-sigint"),
    };
    let shards = a.req_usize("cluster").map_err(|e| anyhow!(e))?;
    if shards > 0 {
        return cmd_serve_cluster(&a, &cfg, shards);
    }
    let service = Service::bind(&cfg).map_err(|e| anyhow!("bind {}: {e}", cfg.addr))?;
    if let Some(r) = service.warehouse_report() {
        eprintln!(
            "warehouse: {} plan(s) across {} segment(s) ({} bytes), {} superseded, {} corrupt line(s) skipped, {} torn tail(s) truncated ({} bytes)",
            r.records, r.segments, r.bytes, r.superseded, r.corrupt, r.truncated_tails, r.truncated_bytes,
        );
    }
    eprintln!(
        "xbarmap planning service listening on {} (queue {}, cache {}{}, quota {}, inflight cap {}, deadline {}, SIGINT/SIGTERM drain and exit)",
        service.local_addr()?,
        cfg.queue_capacity,
        cfg.cache_capacity,
        match cfg.cache_ttl {
            Some(ttl) => format!(" ttl {:.0}s", ttl.as_secs_f64()),
            None => String::new(),
        },
        if cfg.per_conn_quota == 0 { "off".to_string() } else { cfg.per_conn_quota.to_string() },
        if cfg.max_inflight == 0 { "off".to_string() } else { cfg.max_inflight.to_string() },
        match cfg.deadline {
            Some(d) => format!("{}ms", d.as_millis()),
            None => "off".to_string(),
        },
    );
    if a.flag("announce") {
        // one machine-readable line on stdout (the human banner above goes
        // to stderr) — the cluster supervisor parses this to learn the port
        println!("{{\"v\":{},\"announce\":\"{}\"}}", plan::WIRE_VERSION, service.local_addr()?);
        std::io::stdout().flush()?;
    }
    let stats = service.run()?;
    eprintln!(
        "served {} plan(s) ({} cache hit(s)), {} error(s) over {} connection(s) | plan p50 {:.3} ms p95 {:.3} ms",
        stats.served,
        stats.cache_hits,
        stats.errors,
        stats.connections,
        stats.plan_p50_s * 1e3,
        stats.plan_p95_s * 1e3,
    );
    Ok(())
}

/// `serve --plans --cluster N`: the self-healing sharded deployment. The
/// router re-execs this same binary N times as `serve --plans --addr
/// 127.0.0.1:0 --announce --no-sigint` workers, consistent-hashes each
/// request's canonical key to a shard, supervises the children (liveness
/// probes, capped-backoff respawn, per-shard circuit breaker), replays
/// the responses a dead shard still owed, and degrades to its embedded
/// planner when a shard stays down — per connection the merged stream is
/// byte-identical to a single process serving the same lines.
fn cmd_serve_cluster(a: &Args, cfg: &ServiceConfig, shards: usize) -> Result<()> {
    // solver-side flags travel to the workers verbatim; admission
    // (quota / in-flight cap) and metrics aggregation stay at the router
    let mut worker_args: Vec<String> = Vec::new();
    for flag in ["workers", "queue", "cache", "cache-ttl", "cache-max-bytes", "deadline-ms"] {
        worker_args.push(format!("--{flag}"));
        worker_args.push(a.req(flag).map_err(|e| anyhow!(e))?.to_string());
    }
    // the admin token also travels to the workers: a fanned-out recalibrate
    // re-authenticates on each shard. Tenant metering does NOT — the router
    // is the sole metering point, so workers never see --tenant-quota.
    if let Some(token) = a.get("admin-token") {
        worker_args.push("--admin-token".to_string());
        worker_args.push(token.to_string());
    }
    let ccfg = cluster::ClusterConfig {
        addr: cfg.addr.clone(),
        shards,
        exe: None,
        worker_args,
        warehouse: cfg.warehouse.clone(),
        per_conn_quota: cfg.per_conn_quota,
        max_inflight: cfg.max_inflight,
        tenant_quota: cfg.tenant_quota,
        admin_token: cfg.admin_token.clone(),
        deadline: cfg.deadline,
        metrics_out: cfg.metrics_out.clone(),
        metrics_interval: cfg.metrics_interval,
        watch_sigint: cfg.watch_sigint,
        ..cluster::ClusterConfig::default()
    };
    let addr = ccfg.addr.clone();
    let cluster = cluster::Cluster::bind(ccfg).map_err(|e| anyhow!("bind {addr}: {e}"))?;
    eprintln!(
        "xbarmap planning cluster listening on {} ({} shard(s), quota {}, inflight cap {}, SIGINT/SIGTERM drain and exit)",
        cluster.local_addr()?,
        shards,
        if cfg.per_conn_quota == 0 { "off".to_string() } else { cfg.per_conn_quota.to_string() },
        if cfg.max_inflight == 0 { "off".to_string() } else { cfg.max_inflight.to_string() },
    );
    let stats = cluster.run()?;
    eprintln!(
        "cluster served {} plan(s) ({} cache hit(s)), {} error(s) over {} connection(s) | {} respawn(s), {} replayed, {} degraded",
        stats.served,
        stats.cache_hits,
        stats.errors,
        stats.connections,
        stats.shard_respawns,
        stats.replayed,
        stats.degraded,
    );
    Ok(())
}

/// Offline management of the persistent plan store (`serve --plans
/// --warehouse DIR`): `precompute` prices a zoo × grid cross-product and
/// appends each plan under its canonical request key, `compact` rewrites
/// live records into fresh segments, `stat` reports what a boot would
/// load without touching the files.
fn cmd_warehouse(argv: &[String]) -> Result<()> {
    match argv.first().map(String::as_str) {
        Some("precompute") => cmd_warehouse_precompute(&argv[1..]),
        Some("compact") => cmd_warehouse_compact(&argv[1..]),
        Some("stat") => cmd_warehouse_stat(&argv[1..]),
        _ => Err(anyhow!(
            "usage: xbarmap warehouse <precompute|compact|stat> --dir DIR [options]"
        )),
    }
}

fn cmd_warehouse_precompute(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "dir", help: "warehouse directory (created if absent)", value: Some("DIR"), default: None },
        OptSpec { name: "nets", help: "comma-separated zoo networks (default: the whole zoo)", value: Some("CSV"), default: None },
        OptSpec { name: "disciplines", help: "comma-separated packing disciplines", value: Some("CSV"), default: Some("dense,pipeline") },
        OptSpec { name: "row-exp", help: "grid base-dimension exponents LO,HI (2^LO..2^HI)", value: Some("LO,HI"), default: Some("6,13") },
        OptSpec { name: "aspects", help: "max aspect ratio (1..=8)", value: Some("N"), default: Some("8") },
        OptSpec { name: "threads", help: "solver threads across requests (0 = auto)", value: Some("N"), default: Some("0") },
        OptSpec { name: "cluster", help: "partition plans into the shard-NN subdirectories a `serve --plans --cluster N` deployment reads (0 = one flat warehouse)", value: Some("N"), default: Some("0") },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let dir = a.req("dir").map_err(|e| anyhow!(e))?;
    let cluster_n = a.req_usize("cluster").map_err(|e| anyhow!(e))?;

    let nets: Vec<String> = match a.get("nets") {
        Some(csv) => csv.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        None => zoo::NAMES.iter().map(|s| s.to_string()).collect(),
    };
    for net in &nets {
        if zoo::by_name(net).is_none() {
            return Err(anyhow!("unknown network '{net}' (try {})", zoo::NAMES.join("|")));
        }
    }
    let disciplines: Vec<Discipline> = a
        .req("disciplines")
        .map_err(|e| anyhow!(e))?
        .split(',')
        .map(|s| s.trim().parse().map_err(|e: String| anyhow!(e)))
        .collect::<Result<_>>()?;
    let row_exp = {
        let spec = a.req("row-exp").map_err(|e| anyhow!(e))?;
        let parts: Vec<&str> = spec.split(',').collect();
        let parse = |s: &str| s.trim().parse::<u32>().map_err(|_| anyhow!("--row-exp expects LO,HI — got '{spec}'"));
        match parts.as_slice() {
            [lo, hi] => (parse(lo)?, parse(hi)?),
            _ => return Err(anyhow!("--row-exp expects LO,HI — got '{spec}'")),
        }
    };
    let max_aspect = a.req_usize("aspects").map_err(|e| anyhow!(e))?.clamp(1, 8);
    let threads = a.req_usize("threads").map_err(|e| anyhow!(e))?;

    // `threads(1)` is pinned, not defaulted: provenance.threads is part of
    // the serialized plan, and `threads:0` resolves against the solving
    // host's environment. Pinning makes every stored plan a pure function
    // of its canonical key, so a warm boot serves bytes identical to a
    // fresh solve of the same (threads:1) request on any machine.
    // Parallelism still comes from serve_batch fanning across requests.
    let requests: Vec<MapRequest> = nets
        .iter()
        .flat_map(|net| {
            disciplines.iter().map(move |d| {
                MapRequest::zoo(net)
                    .discipline(*d)
                    .grid(row_exp, (1..=max_aspect).collect())
                    .threads(1)
            })
        })
        .collect();

    // `--cluster 0` fills one flat warehouse at --dir; `--cluster N`
    // opens the same shard-NN subdirectories a `serve --plans --cluster N`
    // router's workers will open, and routes each key through the same
    // consistent-hash ring, so every shard boots warm with exactly the
    // plans it will be asked for
    let warehouses: Vec<Warehouse> = if cluster_n == 0 {
        let (wh, _) = Warehouse::open(&WarehouseConfig::at(dir))
            .map_err(|e| anyhow!("open warehouse {dir}: {e}"))?;
        vec![wh]
    } else {
        (0..cluster_n)
            .map(|i| {
                let sub = cluster::shard_warehouse_dir(Path::new(dir), i);
                Warehouse::open(&WarehouseConfig::at(&sub))
                    .map(|(wh, _)| wh)
                    .map_err(|e| anyhow!("open warehouse {}: {e}", sub.display()))
            })
            .collect::<Result<_>>()?
    };
    let ring = cluster::HashRing::for_cluster(cluster_n.max(1));
    let owner_of = |key: &str| if cluster_n == 0 { 0 } else { ring.owner(key) };
    let mut missing: Vec<(String, MapRequest)> = Vec::new();
    let mut skipped = 0usize;
    for req in requests {
        let key = PlanCache::key(&req);
        if warehouses[owner_of(&key)].contains(&key) {
            skipped += 1;
        } else {
            missing.push((key, req));
        }
    }

    let to_solve: Vec<MapRequest> = missing.iter().map(|(_, r)| r.clone()).collect();
    let results = plan::serve_batch_with_threads(&to_solve, threads);
    let (mut priced, mut failed) = (0usize, 0usize);
    for ((key, req), result) in missing.into_iter().zip(results) {
        match result {
            Ok(mut plan) => {
                plan.id.clear();
                warehouses[owner_of(&key)]
                    .append(&key, &plan.to_json().dumps())
                    .map_err(|e| anyhow!("append to warehouse {dir}: {e}"))?;
                priced += 1;
            }
            Err(e) => {
                failed += 1;
                let net = match &req.network {
                    plan::NetworkSpec::Zoo(name) => name.clone(),
                    plan::NetworkSpec::Inline(_) => "<inline>".to_string(),
                };
                eprintln!("precompute {net}: {e}");
            }
        }
    }
    let (live, segments, bytes) = warehouses
        .iter()
        .fold((0usize, 0usize, 0u64), |(l, s, b), wh| (l + wh.len(), s + wh.segments(), b + wh.bytes()));
    println!(
        "precomputed {priced} plan(s) ({skipped} already present, {failed} failed) -> {live} live across {segments} segment(s), {bytes} bytes{}",
        if cluster_n > 0 { format!(" in {cluster_n} shard warehouse(s)") } else { String::new() },
    );
    if failed > 0 {
        return Err(anyhow!("{failed} request(s) failed to price"));
    }
    Ok(())
}

fn cmd_warehouse_compact(argv: &[String]) -> Result<()> {
    let specs = [OptSpec { name: "dir", help: "warehouse directory", value: Some("DIR"), default: None }];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let dir = a.req("dir").map_err(|e| anyhow!(e))?;
    let (wh, _) = Warehouse::open(&WarehouseConfig::at(dir))
        .map_err(|e| anyhow!("open warehouse {dir}: {e}"))?;
    let r = wh.compact().map_err(|e| anyhow!("compact warehouse {dir}: {e}"))?;
    println!(
        "compacted {dir}: {} live record(s), {} superseded dropped | {} -> {} bytes | {} -> {} segment(s)",
        r.live, r.dropped, r.bytes_before, r.bytes_after, r.segments_before, r.segments_after,
    );
    Ok(())
}

fn cmd_warehouse_stat(argv: &[String]) -> Result<()> {
    let specs = [OptSpec { name: "dir", help: "warehouse directory", value: Some("DIR"), default: None }];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let dir = a.req("dir").map_err(|e| anyhow!(e))?;
    let r = Warehouse::stat(Path::new(dir)).map_err(|e| anyhow!("stat warehouse {dir}: {e}"))?;
    println!(
        "{dir}: {} live plan(s) across {} segment(s) ({} bytes), {} superseded, {} corrupt line(s), {} torn tail(s) ({} bytes) pending truncation",
        r.records, r.segments, r.bytes, r.superseded, r.corrupt, r.truncated_tails, r.truncated_bytes,
    );
    Ok(())
}

/// CI regression gate over `BENCH_*.json` medians (see
/// [`benchkit::gate_medians`]); fails when any shared benchmark regressed
/// past the tolerance.
fn cmd_bench_gate(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "baseline", help: "committed medians file", value: Some("FILE"), default: None },
        OptSpec { name: "current", help: "freshly measured medians file", value: Some("FILE"), default: None },
        OptSpec { name: "tol-pct", help: "max allowed regression, percent", value: Some("P"), default: Some("15") },
    ];
    let a = Args::parse(argv, &specs).map_err(|e| anyhow!(e))?;
    let tol = a.req_f64("tol-pct").map_err(|e| anyhow!(e))?;
    let load = |key: &str| -> Result<json::Json> {
        let path = a.req(key).map_err(|e| anyhow!(e))?;
        let text = std::fs::read_to_string(path).map_err(|e| anyhow!("read {path}: {e}"))?;
        json::parse(&text).map_err(|e| anyhow!("parse {path}: {e}"))
    };
    let report = benchkit::gate_medians(&load("baseline")?, &load("current")?, tol);
    for line in &report.compared {
        println!("{line}");
    }
    if report.compared.is_empty() {
        println!("bench-gate: no shared benchmarks between baseline and current");
    }
    if report.regressions.is_empty() {
        println!("bench-gate OK (tolerance {tol}%)");
        Ok(())
    } else {
        Err(anyhow!(
            "bench-gate: {} regression(s) past {tol}%:\n  {}",
            report.regressions.len(),
            report.regressions.join("\n  ")
        ))
    }
}
