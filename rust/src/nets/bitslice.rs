//! Bit slicing (paper §1/§2): NVM cells with few conductance levels store a
//! `weight_bits`-bit weight across `ceil(weight_bits / bits_per_cell)`
//! physical columns ("slices"), each holding one digit of the weight in
//! radix 2^bits_per_cell; the chip combines slice outputs digitally with
//! shift-and-add. As the paper notes, "this multiplies the number of
//! physical tiles per network layer and will impact the chip area
//! accordingly" — this module quantifies exactly that impact so the §3.1
//! optimizer can sweep it (the `ablation` repro experiment).

use super::Network;

/// Bit-slicing configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitSlice {
    /// logical weight precision required by the model
    pub weight_bits: u32,
    /// conductance levels one physical cell can hold, in bits
    pub bits_per_cell: u32,
}

impl BitSlice {
    /// A bit-slicing scheme; both operands must be at least 1 bit.
    pub fn new(weight_bits: u32, bits_per_cell: u32) -> BitSlice {
        assert!(weight_bits >= 1 && bits_per_cell >= 1, "bits must be positive");
        BitSlice { weight_bits, bits_per_cell }
    }

    /// Physical column copies per logical weight column.
    pub fn slices(&self) -> usize {
        self.weight_bits.div_ceil(self.bits_per_cell) as usize
    }

    /// No slicing needed (analog cell holds the full precision).
    pub fn none() -> BitSlice {
        BitSlice { weight_bits: 8, bits_per_cell: 8 }
    }
}

/// Logical WM shapes after slicing: each layer's column (bit-line) count is
/// multiplied by the slice count — every output neuron owns one column per
/// weight digit. Row (word-line) structure is unchanged: all slices see the
/// same activations.
pub fn sliced_shapes(net: &Network, cfg: BitSlice) -> Vec<(usize, usize)> {
    let s = cfg.slices();
    net.matrix_shapes()
        .into_iter()
        .map(|(rows, cols)| (rows, cols * s))
        .collect()
}

/// Weight-cell inflation factor (equals the slice count).
pub fn cell_inflation(cfg: BitSlice) -> f64 {
    cfg.slices() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::zoo;

    #[test]
    fn slice_counts() {
        assert_eq!(BitSlice::new(8, 8).slices(), 1);
        assert_eq!(BitSlice::new(8, 4).slices(), 2);
        assert_eq!(BitSlice::new(8, 3).slices(), 3);
        assert_eq!(BitSlice::new(8, 2).slices(), 4);
        assert_eq!(BitSlice::new(8, 1).slices(), 8);
        assert_eq!(BitSlice::none().slices(), 1);
    }

    #[test]
    fn shapes_scale_columns_only() {
        let net = zoo::lenet();
        let base = net.matrix_shapes();
        let sliced = sliced_shapes(&net, BitSlice::new(8, 2));
        for ((r0, c0), (r1, c1)) in base.iter().zip(&sliced) {
            assert_eq!(r0, r1);
            assert_eq!(c0 * 4, *c1);
        }
    }

    #[test]
    fn slicing_multiplies_tiles() {
        // the paper's statement, measured end to end
        use crate::frag;
        use crate::geom::Tile;
        use crate::pack::{self, Discipline};
        let net = zoo::lenet();
        let tile = Tile::new(256, 256);
        let count = |cfg: BitSlice| {
            let blocks: Vec<_> = sliced_shapes(&net, cfg)
                .into_iter()
                .enumerate()
                .flat_map(|(li, (r, c))| frag::fragment_matrix(r, c, tile, li, 0))
                .collect();
            pack::ffd::pack(&blocks, tile, Discipline::Dense).n_bins
        };
        let t1 = count(BitSlice::new(8, 8));
        let t4 = count(BitSlice::new(8, 2));
        assert!(t4 >= 3 * t1, "4 slices should ~4x the tiles: {t1} -> {t4}");
    }

    #[test]
    #[should_panic(expected = "bits must be positive")]
    fn zero_bits_rejected() {
        BitSlice::new(0, 1);
    }
}
