//! ANN layer descriptions and their crossbar weight-matrix shapes.
//!
//! A network layer maps to a logical weight matrix `L_i(m_inp, m_out)`:
//! * fully connected: `m_inp = fan_in (+1 bias row)`, `m_out = fan_out`;
//! * convolution: via the RAPA im2col construction (paper Fig. 3) the
//!   filter bank becomes `WM` with `m_inp = k²·d_in (+1)`, `m_out = d_out`,
//!   and the layer's **weight reuse factor** `N_reuse` is the number of
//!   input-matrix columns `((n_in − k + 2p)/s + 1)²` (Table 1).
//!
//! The zoo ([`zoo`]) provides the paper's workloads with standard geometry.

pub mod bitslice;
pub mod zoo;

use std::fmt;

/// Layer kind with the geometry needed to derive WM shape and reuse.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerKind {
    /// Fully connected fan_in -> fan_out.
    Fc {
        /// input features
        fan_in: usize,
        /// output features
        fan_out: usize,
    },
    /// 2-D convolution on square inputs.
    Conv {
        /// input channels
        in_ch: usize,
        /// output channels (filters)
        out_ch: usize,
        /// square kernel side k
        kernel: usize,
        /// spatial stride
        stride: usize,
        /// spatial zero-padding per side
        padding: usize,
        /// square spatial input size n_in
        in_size: usize,
    },
}

/// One network layer: kind + bias convention + optional reuse override
/// (used for sequence models where every FC is reused per token).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    /// layer name as reported in tables and plans
    pub name: String,
    /// geometry: FC or Conv with its shape parameters
    pub kind: LayerKind,
    /// whether the WM carries a bias row (the paper's +1 row convention)
    pub bias: bool,
    /// overrides the derived weight reuse (sequence models: reuse per token)
    pub reuse_override: Option<usize>,
}

impl Layer {
    /// A fully connected layer with the default bias convention.
    pub fn fc(name: &str, fan_in: usize, fan_out: usize) -> Self {
        Layer {
            name: name.into(),
            kind: LayerKind::Fc { fan_in, fan_out },
            bias: true,
            reuse_override: None,
        }
    }

    /// A 2-D convolution layer on square inputs with the default bias
    /// convention.
    pub fn conv(
        name: &str,
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        in_size: usize,
    ) -> Self {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv { in_ch, out_ch, kernel, stride, padding, in_size },
            bias: true,
            reuse_override: None,
        }
    }

    /// FC layer reused `n` times per inference (e.g. once per token).
    pub fn fc_reused(name: &str, fan_in: usize, fan_out: usize, n: usize) -> Self {
        let mut l = Layer::fc(name, fan_in, fan_out);
        l.reuse_override = Some(n);
        l
    }

    /// Spatial output size of a conv layer (square).
    pub fn out_size(&self) -> Option<usize> {
        match self.kind {
            LayerKind::Conv { kernel, stride, padding, in_size, .. } => {
                assert!(in_size + 2 * padding >= kernel, "conv geometry: {self:?}");
                Some((in_size + 2 * padding - kernel) / stride + 1)
            }
            LayerKind::Fc { .. } => None,
        }
    }

    /// Logical weight-matrix shape (rows = inputs(+bias), cols = outputs).
    pub fn matrix_shape(&self) -> (usize, usize) {
        let b = self.bias as usize;
        match self.kind {
            LayerKind::Fc { fan_in, fan_out } => (fan_in + b, fan_out),
            LayerKind::Conv { in_ch, out_ch, kernel, .. } => (kernel * kernel * in_ch + b, out_ch),
        }
    }

    /// Weight reuse factor N_reuse (Table 1): IM columns for conv, 1 for FC
    /// unless overridden.
    pub fn reuse(&self) -> usize {
        if let Some(r) = self.reuse_override {
            return r;
        }
        match self.kind {
            LayerKind::Fc { .. } => 1,
            LayerKind::Conv { .. } => {
                let o = self.out_size().unwrap();
                o * o
            }
        }
    }

    /// Number of weight parameters (incl. bias if present).
    pub fn weights(&self) -> usize {
        let (r, c) = self.matrix_shape();
        r * c
    }

    /// MACs per inference = weights x reuse.
    pub fn macs(&self) -> usize {
        self.weights() * self.reuse()
    }
}

impl fmt::Display for Layer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let (r, c) = self.matrix_shape();
        write!(f, "{} WM({r}x{c}) reuse={}", self.name, self.reuse())
    }
}

/// A network: ordered layers plus workload metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    /// model name as reported in tables and plans
    pub name: String,
    /// dataset / input description (shape source only, see DESIGN.md)
    pub input_desc: String,
    /// ordered mapped layers
    pub layers: Vec<Layer>,
}

impl Network {
    /// A network from its name, input description and ordered layers.
    pub fn new(name: &str, input_desc: &str, layers: Vec<Layer>) -> Self {
        Network { name: name.into(), input_desc: input_desc.into(), layers }
    }

    /// Number of mapped layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total weight count across layers (bias rows included).
    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(Layer::weights).sum()
    }

    /// Total multiply-accumulates for one inference.
    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Largest per-layer weight reuse (1 for a pure-FC feedforward net).
    pub fn max_reuse(&self) -> usize {
        self.layers.iter().map(Layer::reuse).max().unwrap_or(1)
    }

    /// Logical WM shapes in layer order.
    pub fn matrix_shapes(&self) -> Vec<(usize, usize)> {
        self.layers.iter().map(Layer::matrix_shape).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_shape_includes_bias_row() {
        let l = Layer::fc("fc", 784, 256);
        assert_eq!(l.matrix_shape(), (785, 256));
        assert_eq!(l.reuse(), 1);
        assert_eq!(l.weights(), 785 * 256);
    }

    #[test]
    fn conv_im2col_shape() {
        // paper Fig. 3: WM is d_out x (k^2 d_in (+1)); our (rows, cols)
        // convention stores the transpose: rows = k^2 d_in + 1.
        let l = Layer::conv("c", 3, 64, 7, 2, 3, 224);
        assert_eq!(l.matrix_shape(), (7 * 7 * 3 + 1, 64));
    }

    #[test]
    fn conv_out_size_and_reuse_table1() {
        // Table 1 geometries
        let resnet50 = Layer::conv("c1", 3, 64, 7, 2, 3, 224);
        assert_eq!(resnet50.out_size(), Some(112));
        assert_eq!(resnet50.reuse(), 12544);
        let alexnet = Layer::conv("c1", 3, 64, 11, 4, 2, 224);
        assert_eq!(alexnet.out_size(), Some(55));
        assert_eq!(alexnet.reuse(), 3025);
        let lenet = Layer::conv("c1", 1, 6, 5, 1, 2, 28);
        assert_eq!(lenet.reuse(), 784);
    }

    #[test]
    fn reuse_override_for_sequence_models() {
        let l = Layer::fc_reused("q", 768, 768, 64);
        assert_eq!(l.reuse(), 64);
        assert_eq!(l.matrix_shape(), (769, 768));
    }

    #[test]
    fn macs_are_weights_times_reuse() {
        let l = Layer::conv("c", 3, 8, 3, 1, 1, 8);
        assert_eq!(l.reuse(), 64);
        assert_eq!(l.macs(), l.weights() * 64);
    }

    #[test]
    fn network_aggregates() {
        let n = Network::new(
            "tiny",
            "test",
            vec![Layer::fc("a", 10, 20), Layer::conv("b", 1, 4, 3, 1, 1, 6)],
        );
        assert_eq!(n.n_layers(), 2);
        assert_eq!(n.total_weights(), 11 * 20 + 10 * 4);
        assert_eq!(n.max_reuse(), 36);
        assert_eq!(n.matrix_shapes(), vec![(11, 20), (10, 4)]);
    }

    #[test]
    #[should_panic(expected = "conv geometry")]
    fn bad_conv_geometry_panics() {
        Layer::conv("bad", 1, 1, 9, 1, 0, 4).out_size();
    }
}
