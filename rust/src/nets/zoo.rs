//! The paper's workload networks with standard geometry.
//!
//! Layer lists include every weight-bearing layer (convs incl. downsample
//! projections, final FCs). Pooling/activation layers carry no weights and
//! are represented only through the spatial sizes fed to subsequent convs.

use super::{Layer, Network};

/// LeNet-5-style network on MNIST 1x28x28 (Table 1: first-layer reuse 784).
pub fn lenet() -> Network {
    Network::new(
        "LeNet",
        "MNIST 1x28x28",
        vec![
            Layer::conv("conv1", 1, 6, 5, 1, 2, 28), // out 28 -> pool 14
            Layer::conv("conv2", 6, 16, 5, 1, 0, 14), // out 10 -> pool 5
            Layer::fc("fc1", 400, 120),
            Layer::fc("fc2", 120, 84),
            Layer::fc("fc3", 84, 10),
        ],
    )
}

/// AlexNet on ImageNet 3x224x224 (Table 1: first-layer reuse 3025).
pub fn alexnet() -> Network {
    Network::new(
        "AlexNet",
        "ImageNet 3x224x224",
        vec![
            Layer::conv("conv1", 3, 64, 11, 4, 2, 224), // out 55 -> pool 27
            Layer::conv("conv2", 64, 192, 5, 1, 2, 27), // out 27 -> pool 13
            Layer::conv("conv3", 192, 384, 3, 1, 1, 13),
            Layer::conv("conv4", 384, 256, 3, 1, 1, 13),
            Layer::conv("conv5", 256, 256, 3, 1, 1, 13), // out 13 -> pool 6
            Layer::fc("fc1", 9216, 4096),
            Layer::fc("fc2", 4096, 4096),
            Layer::fc("fc3", 4096, 1000),
        ],
    )
}

/// ResNet9 (DAWNBench-style) on CIFAR10 3x32x32.
///
/// Standard geometry gives first-layer reuse 32² = 1024; the paper's
/// Table 1 lists 729 = 27², implying k=6, p=0 on the first conv. Use
/// [`resnet9_paper_calib`] to reproduce the paper's number verbatim;
/// EXPERIMENTS.md documents the discrepancy.
pub fn resnet9() -> Network {
    Network::new(
        "ResNet9",
        "CIFAR10 3x32x32",
        vec![
            Layer::conv("conv1", 3, 64, 3, 1, 1, 32),
            Layer::conv("conv2", 64, 128, 3, 1, 1, 32), // pool -> 16
            Layer::conv("res1a", 128, 128, 3, 1, 1, 16),
            Layer::conv("res1b", 128, 128, 3, 1, 1, 16),
            Layer::conv("conv3", 128, 256, 3, 1, 1, 16), // pool -> 8
            Layer::conv("conv4", 256, 512, 3, 1, 1, 8), // pool -> 4
            Layer::conv("res2a", 512, 512, 3, 1, 1, 4),
            Layer::conv("res2b", 512, 512, 3, 1, 1, 4),
            Layer::fc("fc", 512, 10),
        ],
    )
}

/// ResNet9 variant whose first conv reproduces Table 1's N_reuse = 729.
pub fn resnet9_paper_calib() -> Network {
    let mut n = resnet9();
    n.name = "ResNet9(paper-calib)".into();
    n.layers[0] = Layer::conv("conv1", 3, 64, 6, 1, 0, 32); // out 27 -> 729
    n
}

fn basic_block(
    layers: &mut Vec<Layer>,
    stage: usize,
    block: usize,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
    in_size: usize,
) -> usize {
    let pfx = format!("l{stage}b{block}");
    layers.push(Layer::conv(&format!("{pfx}.conv1"), in_ch, out_ch, 3, stride, 1, in_size));
    let mid = (in_size + 2 - 3) / stride + 1;
    layers.push(Layer::conv(&format!("{pfx}.conv2"), out_ch, out_ch, 3, 1, 1, mid));
    if stride != 1 || in_ch != out_ch {
        layers.push(Layer::conv(&format!("{pfx}.down"), in_ch, out_ch, 1, stride, 0, in_size));
    }
    mid
}

fn bottleneck_block(
    layers: &mut Vec<Layer>,
    stage: usize,
    block: usize,
    in_ch: usize,
    width: usize,
    stride: usize,
    in_size: usize,
) -> (usize, usize) {
    let out_ch = width * 4;
    let pfx = format!("l{stage}b{block}");
    layers.push(Layer::conv(&format!("{pfx}.conv1"), in_ch, width, 1, 1, 0, in_size));
    layers.push(Layer::conv(&format!("{pfx}.conv2"), width, width, 3, stride, 1, in_size));
    let mid = (in_size + 2 - 3) / stride + 1;
    layers.push(Layer::conv(&format!("{pfx}.conv3"), width, out_ch, 1, 1, 0, mid));
    if stride != 1 || in_ch != out_ch {
        layers.push(Layer::conv(&format!("{pfx}.down"), in_ch, out_ch, 1, stride, 0, in_size));
    }
    (out_ch, mid)
}

fn resnet_basic(name: &str, blocks: [usize; 4]) -> Network {
    let mut layers = vec![Layer::conv("conv1", 3, 64, 7, 2, 3, 224)]; // out 112, pool -> 56
    let mut size = 56;
    let mut in_ch = 64;
    for (stage, (&n_blocks, out_ch)) in blocks.iter().zip([64usize, 128, 256, 512]).enumerate() {
        for b in 0..n_blocks {
            let stride = if b == 0 && stage > 0 { 2 } else { 1 };
            size = basic_block(&mut layers, stage + 1, b, in_ch, out_ch, stride, size);
            in_ch = out_ch;
        }
    }
    layers.push(Layer::fc("fc", 512, 1000));
    Network::new(name, "ImageNet 3x224x224", layers)
}

/// ResNet18 on ImageNet (the paper's main optimization workload).
pub fn resnet18() -> Network {
    resnet_basic("ResNet18", [2, 2, 2, 2])
}

/// ResNet34 on ImageNet.
pub fn resnet34() -> Network {
    resnet_basic("ResNet34", [3, 4, 6, 3])
}

/// ResNet50 on ImageNet (bottleneck blocks; Fig. 10 left workload).
pub fn resnet50() -> Network {
    let mut layers = vec![Layer::conv("conv1", 3, 64, 7, 2, 3, 224)];
    let mut size = 56;
    let mut in_ch = 64;
    for (stage, (&n_blocks, width)) in [3usize, 4, 6, 3].iter().zip([64usize, 128, 256, 512]).enumerate() {
        for b in 0..n_blocks {
            let stride = if b == 0 && stage > 0 { 2 } else { 1 };
            let (oc, sz) = bottleneck_block(&mut layers, stage + 1, b, in_ch, width, stride, size);
            in_ch = oc;
            size = sz;
        }
    }
    layers.push(Layer::fc("fc", 2048, 1000));
    Network::new("ResNet50", "ImageNet 3x224x224", layers)
}

/// One BERT encoder layer: 12 heads, sequence length S, embedding d=768
/// (Fig. 10 right workload). Weight matrices: Q, K, V, O projections and
/// the two FFN matrices; every FC is reused once per token (reuse = S).
pub fn bert_layer(seq_len: usize) -> Network {
    let d = 768;
    let ffn = 3072;
    Network::new(
        &format!("BERT-layer(S={seq_len})"),
        &format!("token sequence S={seq_len}, d={d}, 12 heads"),
        vec![
            Layer::fc_reused("attn.q", d, d, seq_len),
            Layer::fc_reused("attn.k", d, d, seq_len),
            Layer::fc_reused("attn.v", d, d, seq_len),
            Layer::fc_reused("attn.o", d, d, seq_len),
            Layer::fc_reused("ffn.w1", d, ffn, seq_len),
            Layer::fc_reused("ffn.w2", ffn, d, seq_len),
        ],
    )
}

/// The crossbar MLP served by the e2e example (mirrors python/compile/model.py).
pub fn digits_mlp() -> Network {
    Network::new(
        "DigitsMLP",
        "synthetic digits 28x28",
        vec![
            Layer::fc("fc1", 784, 256),
            Layer::fc("fc2", 256, 128),
            Layer::fc("fc3", 128, 10),
        ],
    )
}

/// Canonical tokens accepted by [`by_name`], for error messages and docs.
pub const NAMES: &[&str] = &[
    "lenet", "alexnet", "resnet9", "resnet9-paper", "resnet18", "resnet34", "resnet50", "bert",
    "digits-mlp",
];

/// All named zoo entries (used by the CLI).
pub fn by_name(name: &str) -> Option<Network> {
    match name.to_ascii_lowercase().as_str() {
        "lenet" => Some(lenet()),
        "alexnet" => Some(alexnet()),
        "resnet9" => Some(resnet9()),
        "resnet9-paper" => Some(resnet9_paper_calib()),
        "resnet18" => Some(resnet18()),
        "resnet34" => Some(resnet34()),
        "resnet50" => Some(resnet50()),
        "bert" => Some(bert_layer(64)),
        "digits-mlp" => Some(digits_mlp()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reuse_factors() {
        assert_eq!(resnet50().layers[0].reuse(), 12544);
        assert_eq!(alexnet().layers[0].reuse(), 3025);
        assert_eq!(lenet().layers[0].reuse(), 784);
        assert_eq!(resnet9_paper_calib().layers[0].reuse(), 729);
        assert_eq!(resnet9().layers[0].reuse(), 1024); // standard geometry
    }

    #[test]
    fn resnet18_weight_count_near_11_5m() {
        // paper §3.1: "ResNet18/ImageNet has 11.5M weight parameters"
        let w = resnet18().total_weights();
        assert!(
            (11_000_000..12_200_000).contains(&w),
            "ResNet18 weights {w} outside expected band"
        );
    }

    #[test]
    fn resnet9_weight_count_near_1_9m() {
        // paper Table 6 text: ResNet9/Cifar10 ~1.9M parameters... standard
        // DAWNBench ResNet9 has ~6.6M; the paper's 1.9M suggests a slimmer
        // variant. We assert our standard geometry is in the small-CNN range
        // and document the difference in EXPERIMENTS.md.
        let w = resnet9().total_weights();
        assert!(w > 1_000_000, "ResNet9 weights {w} implausibly small");
    }

    #[test]
    fn resnet18_layer_count() {
        // 1 stem + stages (2 blocks x 2 convs each + 1 downsample in stages
        // 2..4) + fc = 1 + (4 + 5 + 5 + 5) + 1 = 21 weight layers (17 named
        // convs + 3 downsample projections + fc)
        assert_eq!(resnet18().n_layers(), 21);
    }

    #[test]
    fn resnet50_structure() {
        let n = resnet50();
        // 1 stem + 3*3+1 + 4*3+1 + 6*3+1 + 3*3+1 (+downsample per stage) + fc
        assert_eq!(n.n_layers(), 1 + (9 + 1) + (12 + 1) + (18 + 1) + (9 + 1) + 1);
        assert_eq!(n.layers.last().unwrap().matrix_shape(), (2049, 1000));
        // ~25.5M params
        let w = n.total_weights();
        assert!((24_000_000..27_000_000).contains(&w), "ResNet50 weights {w}");
    }

    #[test]
    fn resnet_spatial_sizes_consistent() {
        // every conv's implied output feeds the next conv's in_size within
        // each stage; downsample convs mirror their block's input
        for net in [resnet18(), resnet34(), resnet50()] {
            for l in &net.layers {
                l.out_size(); // panics on inconsistent geometry
            }
        }
    }

    #[test]
    fn bert_layer_shapes() {
        let n = bert_layer(64);
        let shapes = n.matrix_shapes();
        assert_eq!(shapes[0], (769, 768));
        assert_eq!(shapes[4], (769, 3072));
        assert_eq!(shapes[5], (3073, 768));
        assert!(n.layers.iter().all(|l| l.reuse() == 64));
        // ~7M params for one encoder layer
        let w = n.total_weights();
        assert!((7_000_000..7_500_000).contains(&w), "BERT layer weights {w}");
    }

    #[test]
    fn zoo_by_name_roundtrip() {
        for name in NAMES {
            assert!(by_name(name).is_some(), "{name} missing from zoo");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn alexnet_fc1_geometry() {
        // conv5 out 13 -> pool 6 -> 256*36 = 9216 inputs
        assert_eq!(alexnet().layers[5].matrix_shape(), (9217, 4096));
    }
}
