//! Communication-aware optimization (paper §5 future work: "introduce
//! constraints related to tile communication"; §4: "an increase in the
//! number of tiles will lead to greater complexity in inter-tile
//! communication").
//!
//! The plain §3.1 objective minimizes total tile area. This extension
//! scores each sweep point with a combined cost
//!
//! ```text
//! cost = area_mm2 * (1 + lambda * messages / messages_min)
//! ```
//!
//! where `messages` is the per-inference inter-tile message count from the
//! cycle simulator ([`crate::sim`]) and `messages_min` the minimum across
//! the sweep — so `lambda` expresses how many relative area units one unit
//! of relative communication is worth. `lambda = 0` recovers the paper's
//! objective; large `lambda` drives the optimum toward fewer, larger tiles.

use super::{sweep, SweepConfig, SweepPoint};
use crate::nets::Network;
use crate::pack::Discipline;
use crate::perf::Execution;
use crate::sim::{map_and_simulate, SimConfig};

/// A sweep point extended with its communication load.
#[derive(Debug, Clone)]
pub struct CommPoint {
    /// the underlying area-model sweep point
    pub point: SweepPoint,
    /// inter-tile messages per inference
    pub messages: u64,
    /// combined area-communication cost
    pub cost: f64,
}

/// Evaluate the sweep under the combined objective.
pub fn comm_aware_sweep(net: &Network, cfg: &SweepConfig, lambda: f64) -> Vec<CommPoint> {
    assert!(lambda >= 0.0, "lambda must be non-negative");
    let exec = match cfg.discipline {
        Discipline::Dense => Execution::Sequential,
        Discipline::Pipeline => Execution::Pipelined,
    };
    let points = sweep(net, cfg);
    let mut sim_cfg = SimConfig::new(net, exec);
    if let Some(r) = &cfg.replication {
        sim_cfg.replication = r.clone();
    }
    let msgs: Vec<u64> = points
        .iter()
        .map(|p| map_and_simulate(net, p.tile, cfg.discipline, &sim_cfg, 1).1.messages)
        .collect();
    let msg_min = msgs.iter().copied().filter(|&m| m > 0).min().unwrap_or(1).max(1);
    points
        .into_iter()
        .zip(msgs)
        .map(|(point, messages)| {
            let rel = messages as f64 / msg_min as f64;
            let cost = point.total_area_mm2 * (1.0 + lambda * rel);
            CommPoint { point, messages, cost }
        })
        .collect()
}

/// Minimum-cost configuration under the combined objective (total-order
/// safe like [`crate::opt::optimum`]).
pub fn comm_aware_optimum(net: &Network, cfg: &SweepConfig, lambda: f64) -> Option<CommPoint> {
    comm_aware_sweep(net, cfg, lambda)
        .into_iter()
        .min_by(|a, b| a.cost.total_cmp(&b.cost))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::zoo;
    use crate::opt;

    #[test]
    fn lambda_zero_recovers_area_objective() {
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Pipeline);
        let plain = opt::optimum(&opt::sweep(&net, &cfg)).unwrap();
        let comm = comm_aware_optimum(&net, &cfg, 0.0).unwrap();
        assert_eq!(comm.point.tile, plain.tile);
        assert_eq!(comm.cost, plain.total_area_mm2);
    }

    #[test]
    fn messages_decrease_with_tile_capacity() {
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Pipeline);
        let pts = comm_aware_sweep(&net, &cfg, 0.0);
        let first = pts.first().unwrap(); // smallest tiles
        let last = pts.last().unwrap(); // largest tiles
        assert!(
            first.messages > last.messages,
            "messages {} @{} !> {} @{}",
            first.messages,
            first.point.tile,
            last.messages,
            last.point.tile
        );
    }

    #[test]
    fn high_lambda_pushes_optimum_to_larger_tiles() {
        // §4: communication complexity penalizes many-tile mappings
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Pipeline);
        let area_opt = comm_aware_optimum(&net, &cfg, 0.0).unwrap();
        let comm_opt = comm_aware_optimum(&net, &cfg, 5.0).unwrap();
        assert!(
            comm_opt.point.tile.capacity() >= area_opt.point.tile.capacity(),
            "comm-aware optimum {} should not be smaller than area optimum {}",
            comm_opt.point.tile,
            area_opt.point.tile
        );
        assert!(comm_opt.point.n_tiles <= area_opt.point.n_tiles);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn negative_lambda_rejected() {
        let net = zoo::lenet();
        comm_aware_sweep(&net, &SweepConfig::square(Discipline::Dense), -1.0);
    }
}
