//! The §3.1 optimization process: sweep tile array dimensions and aspect
//! ratios, find the minimum-total-tile-area configuration per aspect ratio,
//! and the global optimum across aspects (Figs. 7–10, Table 6).
//!
//! For each candidate tile `T(n_row, n_col = n_row·aspect)` the network is
//! re-fragmented (each tile dimension induces its own fragmentation, §2.1),
//! packed with the selected engine, and priced with the area model.

pub mod comm;

use crate::area::AreaModel;
use crate::frag;
use crate::geom::Tile;
use crate::ilp;
use crate::nets::Network;
use crate::pack::{self, Discipline};

/// Packing engine selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// the paper's simple (next-fit) algorithm
    Simple,
    /// first-fit-decreasing baseline
    Ffd,
    /// binary linear optimization (budgeted branch & bound)
    Ilp { max_nodes: u64 },
}

impl std::fmt::Display for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Engine::Simple => write!(f, "simple"),
            Engine::Ffd => write!(f, "ffd"),
            Engine::Ilp { .. } => write!(f, "lps"),
        }
    }
}

/// Sweep configuration (defaults follow §3.1: base dims 2^6..2^13 with
/// aspect ratios n_row/n_col = 1..8 — tall tiles, matching the paper's
/// winning rectangular configuration 2560x512 = 5x(512x512)).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub discipline: Discipline,
    pub engine: Engine,
    /// column dimension exponents: n_col = 2^k for k in this inclusive range
    pub row_exp: (u32, u32),
    /// aspect factors: n_row = n_col * aspect
    pub aspects: Vec<usize>,
    /// per-layer RAPA replication (None = no replication)
    pub replication: Option<Vec<usize>>,
    pub area: AreaModel,
}

impl SweepConfig {
    pub fn paper_default(discipline: Discipline) -> SweepConfig {
        SweepConfig {
            discipline,
            engine: Engine::Simple,
            row_exp: (6, 13),
            aspects: (1..=8).collect(),
            replication: None,
            area: AreaModel::paper_default(),
        }
    }

    /// Square-arrays-only variant (Fig. 8 / Fig. 10).
    pub fn square(discipline: Discipline) -> SweepConfig {
        SweepConfig { aspects: vec![1], ..SweepConfig::paper_default(discipline) }
    }
}

/// One evaluated tile configuration.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub tile: Tile,
    pub aspect: usize,
    pub n_blocks: usize,
    pub n_tiles: usize,
    /// tiles for a 1:1 mapping (every fragment its own tile)
    pub n_tiles_one_to_one: usize,
    pub tile_eff: f64,
    pub packing_eff: f64,
    pub total_area_mm2: f64,
    /// pure array area (the "100 % efficiency" area Fig. 7 plots)
    pub array_area_mm2: f64,
}

/// Evaluate a single tile configuration.
pub fn evaluate(net: &Network, tile: Tile, cfg: &SweepConfig) -> SweepPoint {
    let ones = vec![1usize; net.n_layers()];
    let replication = cfg.replication.as_ref().unwrap_or(&ones);
    let blocks = frag::fragment_network_replicated(net, tile, replication);
    let n_blocks = blocks.len();
    let packing = match cfg.engine {
        Engine::Simple => pack::simple::pack(&blocks, tile, cfg.discipline),
        Engine::Ffd => pack::ffd::pack(&blocks, tile, cfg.discipline),
        Engine::Ilp { max_nodes } => {
            ilp::solve_packing(&blocks, tile, cfg.discipline, ilp::Budget { max_nodes, ..Default::default() }).packing
        }
    };
    let n_tiles = packing.n_tiles();
    SweepPoint {
        tile,
        aspect: (tile.n_row / tile.n_col).max(1),
        n_blocks,
        n_tiles,
        n_tiles_one_to_one: n_blocks,
        tile_eff: cfg.area.efficiency(tile),
        packing_eff: packing.packing_efficiency(),
        total_area_mm2: cfg.area.total_area_mm2(n_tiles, tile),
        array_area_mm2: n_tiles as f64 * cfg.area.array_area_um2(tile) * 1e-6,
    }
}

/// Full sweep over base dimensions x aspect ratios.
pub fn sweep(net: &Network, cfg: &SweepConfig) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for k in cfg.row_exp.0..=cfg.row_exp.1 {
        let n_col = 1usize << k;
        for &aspect in &cfg.aspects {
            let tile = Tile::new(n_col * aspect, n_col);
            out.push(evaluate(net, tile, cfg));
        }
    }
    out
}

/// Minimum-area point for each aspect ratio (§3.1 step 2).
pub fn best_per_aspect(points: &[SweepPoint]) -> Vec<SweepPoint> {
    let mut aspects: Vec<usize> = points.iter().map(|p| p.aspect).collect();
    aspects.sort_unstable();
    aspects.dedup();
    aspects
        .into_iter()
        .filter_map(|a| {
            points
                .iter()
                .filter(|p| p.aspect == a)
                .min_by(|x, y| x.total_area_mm2.partial_cmp(&y.total_area_mm2).unwrap())
                .cloned()
        })
        .collect()
}

/// Global optimum (§3.1 step 3): minimum area across all points.
pub fn optimum(points: &[SweepPoint]) -> Option<SweepPoint> {
    points
        .iter()
        .min_by(|x, y| x.total_area_mm2.partial_cmp(&y.total_area_mm2).unwrap())
        .cloned()
}

impl crate::pack::Packing {
    /// Convenience alias used by the sweep (`n_bins` are physical tiles).
    pub fn n_tiles(&self) -> usize {
        self.n_bins
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::zoo;
    use crate::perf::rapa;

    #[test]
    fn square_sweep_shapes() {
        let net = zoo::lenet();
        let cfg = SweepConfig::square(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        assert_eq!(pts.len(), 8); // k = 6..=13
        assert!(pts.iter().all(|p| p.tile.is_square()));
        assert!(pts.iter().all(|p| p.n_tiles >= 1));
    }

    #[test]
    fn full_sweep_covers_paper_range() {
        let net = zoo::lenet();
        let cfg = SweepConfig::paper_default(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        assert_eq!(pts.len(), 64); // 8 sizes x 8 aspects
        let min_tile = pts.iter().map(|p| p.tile).min_by_key(|t| t.capacity()).unwrap();
        let max_tile = pts.iter().map(|p| p.tile).max_by_key(|t| t.capacity()).unwrap();
        assert_eq!((min_tile.n_row, min_tile.n_col), (64, 64));
        assert_eq!((max_tile.n_row, max_tile.n_col), (65536, 8192));
    }

    #[test]
    fn resnet18_dense_square_optimum_matches_fig8() {
        // Fig. 8 left: dense square optimum = 16 tiles of 1024x1024
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        let best = optimum(&pts).unwrap();
        // our calibration puts the dense square optimum on the flat part of
        // the area curve between 1024² (paper's 16 tiles) and 2048²; both
        // are within a few percent of area (documented in EXPERIMENTS.md)
        assert!(
            best.tile == Tile::new(1024, 1024) || best.tile == Tile::new(2048, 2048),
            "optimum tile {:?}",
            best.tile
        );
        assert!(
            (4..=18).contains(&best.n_tiles),
            "tiles {} vs paper's 16",
            best.n_tiles
        );
    }

    #[test]
    fn resnet18_pipeline_square_optimum_matches_fig8() {
        // Fig. 8 right: pipeline square optimum = 68 tiles of 512x512
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Pipeline);
        let pts = sweep(&net, &cfg);
        let best = optimum(&pts).unwrap();
        assert_eq!(best.tile.n_row, 512, "optimum tile {:?}", best.tile);
        assert!(
            (55..=90).contains(&best.n_tiles),
            "tiles {} vs paper's 68",
            best.n_tiles
        );
    }

    #[test]
    fn pipeline_area_roughly_double_dense() {
        // Fig. 8: "the area cost of the pipeline solution is about twice
        // that of the dense solution"
        let net = zoo::resnet18();
        let dense = optimum(&sweep(&net, &SweepConfig::square(Discipline::Dense))).unwrap();
        let pipe = optimum(&sweep(&net, &SweepConfig::square(Discipline::Pipeline))).unwrap();
        let ratio = pipe.total_area_mm2 / dense.total_area_mm2;
        assert!((1.3..=3.5).contains(&ratio), "pipeline/dense area ratio {ratio}");
    }

    #[test]
    fn rectangular_pipeline_cuts_tiles_vs_square() {
        // §3.1: "the area penalty of the pipeline solution can be cut
        // approximately in half with 17 rectangular arrays of 2560x512" —
        // our sweep uses power-of-two rows with col = rows*aspect; assert
        // the qualitative effect: fewer tiles at similar-or-better area.
        let net = zoo::resnet18();
        let sq = optimum(&sweep(&net, &SweepConfig::square(Discipline::Pipeline))).unwrap();
        let rect_cfg = SweepConfig::paper_default(Discipline::Pipeline);
        let rect_pts = sweep(&net, &rect_cfg);
        let rect = optimum(&rect_pts).unwrap();
        assert!(rect.total_area_mm2 <= sq.total_area_mm2 * 1.05);
        assert!(
            rect.n_tiles < sq.n_tiles,
            "rect {} tiles !< square {} tiles",
            rect.n_tiles,
            sq.n_tiles
        );
    }

    #[test]
    fn best_per_aspect_returns_one_point_per_aspect() {
        let net = zoo::lenet();
        let cfg = SweepConfig::paper_default(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        let best = best_per_aspect(&pts);
        assert_eq!(best.len(), 8);
        let mut aspects: Vec<usize> = best.iter().map(|p| p.aspect).collect();
        aspects.sort_unstable();
        assert_eq!(aspects, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn rapa_replication_inflates_area() {
        let net = zoo::resnet18();
        let mut cfg = SweepConfig::square(Discipline::Pipeline);
        let base = optimum(&sweep(&net, &cfg)).unwrap();
        cfg.replication = Some(rapa::plan_balanced(&net, 128));
        let rapa_best = optimum(&sweep(&net, &cfg)).unwrap();
        let ratio = rapa_best.total_area_mm2 / base.total_area_mm2;
        // paper Fig. 9: RAPA area cost ~5x vs the dense solution
        assert!((2.0..=12.0).contains(&ratio), "RAPA area ratio {ratio}");
    }

    #[test]
    fn min_tiles_not_min_area() {
        // the paper's key observation: the minimum number of tiles does not
        // necessarily give the minimum total tile area
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        let min_tiles = pts.iter().min_by_key(|p| p.n_tiles).unwrap();
        let min_area = optimum(&pts).unwrap();
        assert!(
            min_tiles.tile != min_area.tile,
            "expected distinct optima: tiles@{} area@{}",
            min_tiles.tile,
            min_area.tile
        );
        assert!(min_tiles.n_tiles <= min_area.n_tiles);
        assert!(min_area.total_area_mm2 <= min_tiles.total_area_mm2);
    }

    #[test]
    fn ilp_engine_never_more_tiles_than_simple() {
        let net = zoo::lenet();
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let mut cfg = SweepConfig::square(d);
            cfg.row_exp = (7, 9);
            let simple_pts = sweep(&net, &cfg);
            cfg.engine = Engine::Ilp { max_nodes: 200_000 };
            let lps_pts = sweep(&net, &cfg);
            for (s, l) in simple_pts.iter().zip(&lps_pts) {
                assert!(
                    l.n_tiles <= s.n_tiles,
                    "{} {d}: lps {} > simple {}",
                    s.tile,
                    l.n_tiles,
                    s.n_tiles
                );
            }
        }
    }
}
