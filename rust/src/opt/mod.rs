//! The §3.1 optimization process: sweep tile array dimensions and aspect
//! ratios, find the minimum-total-tile-area configuration per aspect ratio,
//! and the global optimum across aspects (Figs. 7–10, Table 6).
//!
//! For each candidate tile `T(n_row, n_col = n_row·aspect)` the network is
//! re-fragmented (each tile dimension induces its own fragmentation, §2.1),
//! packed with the selected engine, and priced with the area model.
//!
//! [`sweep`] is a parallel, **counted** evaluation engine: every grid point
//! is priced straight from the §2.1 shape-class census
//! ([`crate::frag::ShapeClass`], at most four classes per layer) through
//! the counted packing kernels ([`crate::pack::counted`]) — O(classes)
//! per point instead of O(blocks log blocks), and no block is materialized
//! unless an ILP point needs an actual tree search. Grid points fan out
//! over `std::thread::scope` workers with deterministic result ordering;
//! each worker reuses a [`SweepScratch`] arena across the points it
//! evaluates, and every `Engine::Ilp` point is an independent task that
//! warm-starts its branch & bound from a cheap counted-simple-engine hint
//! for the neighbouring (next smaller) configuration in its aspect column.
//! [`sweep_serial`] is the straightforward reference loop over the
//! owned-allocation per-block engines, kept for the determinism suite —
//! which therefore doubles as the counted-vs-materialized equivalence
//! gate.

pub mod comm;

use crate::area::AreaModel;
use crate::frag;
use crate::geom::Tile;
use crate::ilp;
use crate::nets::Network;
use crate::pack::{self, Discipline, SortOrder};
use crate::util::deadline::Deadline;

/// Packing engine selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// the paper's simple (next-fit) algorithm
    Simple,
    /// first-fit-decreasing baseline
    Ffd,
    /// binary linear optimization (budgeted branch & bound)
    Ilp {
        /// branch & bound node budget per grid point
        max_nodes: u64,
    },
}

impl Engine {
    /// Default branch & bound node budget (== `ilp::Budget::default()`),
    /// used when an engine is parsed from its bare token.
    pub const DEFAULT_ILP_NODES: u64 = 2_000_000;

    /// Canonical wire/CLI token. `Display` and `FromStr` round-trip through
    /// it: the ILP engine prints as the paper's `"lps"` and parses back
    /// from `"lps"` (with `"ilp"` kept as an input alias).
    pub fn canonical(&self) -> &'static str {
        match self {
            Engine::Simple => "simple",
            Engine::Ffd => "ffd",
            Engine::Ilp { .. } => "lps",
        }
    }

    /// Parse an engine token with an explicit branch & bound budget for the
    /// ILP engine (the greedy engines ignore it).
    pub fn parse_with_budget(s: &str, max_nodes: u64) -> Result<Engine, String> {
        match s {
            "simple" => Ok(Engine::Simple),
            "ffd" => Ok(Engine::Ffd),
            "lps" | "ilp" => Ok(Engine::Ilp { max_nodes }),
            _ => Err(format!("engine must be simple|ffd|lps (alias: ilp), got '{s}'")),
        }
    }
}

impl std::fmt::Display for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.canonical())
    }
}

impl std::str::FromStr for Engine {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        Engine::parse_with_budget(s, Engine::DEFAULT_ILP_NODES)
    }
}

/// Sweep configuration (defaults follow §3.1: base dims 2^6..2^13 with
/// aspect ratios n_row/n_col = 1..8 — tall tiles, matching the paper's
/// winning rectangular configuration 2560x512 = 5x(512x512)).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// bin-packing discipline (dense shelves vs pipeline staircases)
    pub discipline: Discipline,
    /// packing engine pricing each grid point
    pub engine: Engine,
    /// column dimension exponents: n_col = 2^k for k in this inclusive range
    pub row_exp: (u32, u32),
    /// aspect factors: n_row = n_col * aspect
    pub aspects: Vec<usize>,
    /// per-layer RAPA replication (None = no replication)
    pub replication: Option<Vec<usize>>,
    /// block placement order for the simple engine (§2.1 vs §3 wording)
    pub sort: SortOrder,
    /// area model pricing each configuration (§3.1 / Table 5)
    pub area: AreaModel,
    /// wall-clock budget for the whole sweep: checked before every grid
    /// point and inside the counted/ILP kernels; once expired, remaining
    /// points collapse to infinite-area placeholders so the sweep returns
    /// promptly and the caller (the planning front door) can map the
    /// expiry to a typed error. [`Deadline::NONE`] (the default) never
    /// reads the clock
    pub deadline: Deadline,
}

impl SweepConfig {
    /// The paper's §3.1 sweep: 2^6..2^13 base dims, aspects 1..8, simple
    /// engine, rows-descending placement, Table 5 area model, no deadline.
    pub fn paper_default(discipline: Discipline) -> SweepConfig {
        SweepConfig {
            discipline,
            engine: Engine::Simple,
            row_exp: (6, 13),
            aspects: (1..=8).collect(),
            replication: None,
            sort: SortOrder::RowsDesc,
            area: AreaModel::paper_default(),
            deadline: Deadline::NONE,
        }
    }

    /// Square-arrays-only variant (Fig. 8 / Fig. 10).
    pub fn square(discipline: Discipline) -> SweepConfig {
        SweepConfig { aspects: vec![1], ..SweepConfig::paper_default(discipline) }
    }
}

/// One evaluated tile configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    /// the candidate tile
    pub tile: Tile,
    /// aspect factor the tile was generated with (n_row / n_col)
    pub aspect: usize,
    /// fragments the network cuts into at this tile
    pub n_blocks: usize,
    /// tiles the packing engine needed
    pub n_tiles: usize,
    /// tiles for a 1:1 mapping (every fragment its own tile)
    pub n_tiles_one_to_one: usize,
    /// the area model's tile efficiency (array area / total tile area)
    pub tile_eff: f64,
    /// stored weights / packed tile capacity (Eq. 8)
    pub packing_eff: f64,
    /// total chip area of the mapping, mm²
    pub total_area_mm2: f64,
    /// pure array area (the "100 % efficiency" area Fig. 7 plots)
    pub array_area_mm2: f64,
}

/// Per-worker scratch arena for the counted sweep path: the shape-class
/// census and the counted kernels' run/bin buffers are reused across every
/// grid point a worker evaluates, so after warm-up a configuration is
/// priced without heap allocation on the simple path. The block buffer is
/// touched only when an ILP point needs an actual tree search (lazy
/// materialization inside [`crate::ilp::solve_bins_census`]).
#[derive(Debug, Default)]
pub struct SweepScratch {
    classes: Vec<frag::ShapeClass>,
    counted: pack::counted::CountedScratch,
    blocks: Vec<crate::geom::Block>,
}

impl SweepScratch {
    /// Empty scratch; buffers grow on first use and are reused after.
    pub fn new() -> SweepScratch {
        SweepScratch::default()
    }
}

/// Placeholder for a grid point the sweep never priced because the
/// wall-clock deadline expired: infinite area (so it can never win
/// [`optimum`]) and zero counts. Callers that pass a deadline re-check it
/// after the sweep and discard the whole result on expiry.
fn expired_point(tile: Tile, aspect: usize) -> SweepPoint {
    SweepPoint {
        tile,
        aspect,
        n_blocks: 0,
        n_tiles: 0,
        n_tiles_one_to_one: 0,
        tile_eff: 0.0,
        packing_eff: 0.0,
        total_area_mm2: f64::INFINITY,
        array_area_mm2: f64::INFINITY,
    }
}

/// Evaluate a single tile configuration (owned-allocation convenience
/// wrapper for the [`crate::plan`] front door and tests).
///
/// The aspect is taken **explicitly** and recorded verbatim in the returned
/// point. The old form derived it as `n_row / n_col`, which silently
/// rounded non-integer aspects (a 96×64 tile aliased into aspect 1); use
/// [`Tile::exact_aspect`] when you only have a tile, and pick a sentinel
/// (the planner uses 0 = "off-grid") for tiles with no integer aspect.
#[doc(hidden)]
pub fn evaluate(net: &Network, tile: Tile, aspect: usize, cfg: &SweepConfig) -> SweepPoint {
    let ones = vec![1usize; net.n_layers()];
    let replication = cfg.replication.as_deref().unwrap_or(&ones);
    let mut scratch = SweepScratch::default();
    evaluate_lean(net, tile, aspect, replication, cfg, None, &mut scratch)
}

/// Counted evaluation core shared by the sweep workers: censuses the
/// fragmentation in O(layers), counts bins through the counted kernels,
/// and prices the configuration — bit-identical to the per-block engines
/// (efficiencies are derived from the same integers through the same
/// shared formula). `warm` is the neighbouring configuration's counted
/// hint (`Engine::Ilp` warm-start; ignored by the greedy engines).
fn evaluate_lean(
    net: &Network,
    tile: Tile,
    aspect: usize,
    replication: &[usize],
    cfg: &SweepConfig,
    warm: Option<usize>,
    scratch: &mut SweepScratch,
) -> SweepPoint {
    evaluate_lean_full(net, tile, aspect, replication, cfg, warm, scratch).0
}

/// [`evaluate_lean`] keeping the ILP solver provenance (None for the
/// greedy engines) — the planner's counted fixed-tile path needs it.
fn evaluate_lean_full(
    net: &Network,
    tile: Tile,
    aspect: usize,
    replication: &[usize],
    cfg: &SweepConfig,
    warm: Option<usize>,
    scratch: &mut SweepScratch,
) -> (SweepPoint, Option<ilp::BinsResult>) {
    let SweepScratch { classes, counted, blocks } = scratch;
    frag::shape_classes_into(net, tile, replication, classes);
    let n_blocks = frag::total_class_blocks(classes);
    let (n_tiles, solve) = match cfg.engine {
        Engine::Simple => {
            let n = pack::counted::simple_bins_deadline(
                classes,
                tile,
                cfg.discipline,
                cfg.sort,
                counted,
                cfg.deadline,
            );
            match n {
                Some(n) => (n, None),
                None => return (expired_point(tile, aspect), None),
            }
        }
        Engine::Ffd => {
            let n = pack::counted::ffd_bins_deadline(
                classes,
                tile,
                cfg.discipline,
                counted,
                cfg.deadline,
            );
            match n {
                Some(n) => (n, None),
                None => return (expired_point(tile, aspect), None),
            }
        }
        Engine::Ilp { max_nodes } => {
            let r = ilp::solve_bins_census(
                classes,
                tile,
                cfg.discipline,
                ilp::Budget { max_nodes, deadline: cfg.deadline, ..Default::default() },
                warm,
                blocks,
                |out| frag::fragment_network_replicated_into(net, tile, replication, out),
                counted,
            );
            (r.n_bins, Some(r))
        }
    };
    let stored = frag::total_class_weights(classes);
    let point = SweepPoint {
        tile,
        aspect,
        n_blocks,
        n_tiles,
        n_tiles_one_to_one: n_blocks,
        tile_eff: cfg.area.efficiency(tile),
        packing_eff: pack::packing_efficiency(stored, n_tiles, tile.capacity()),
        total_area_mm2: cfg.area.total_area_mm2(n_tiles, tile),
        array_area_mm2: n_tiles as f64 * cfg.area.array_area_um2(tile) * 1e-6,
    };
    (point, solve)
}

/// Counted evaluation of one configuration with ILP solver provenance
/// (zeros for the greedy engines). Used by the [`crate::plan`] front door
/// to price fixed tiles without materializing blocks or placements.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub struct CountedEval {
    pub point: SweepPoint,
    pub nodes: u64,
    pub optimal: bool,
    pub lower_bound: usize,
}

/// See [`CountedEval`]. `warm` is an optional ILP warm-start hint.
#[doc(hidden)]
pub fn evaluate_counted(
    net: &Network,
    tile: Tile,
    aspect: usize,
    cfg: &SweepConfig,
    warm: Option<usize>,
) -> CountedEval {
    let ones = vec![1usize; net.n_layers()];
    let replication = cfg.replication.as_deref().unwrap_or(&ones);
    let mut scratch = SweepScratch::default();
    let (point, solve) =
        evaluate_lean_full(net, tile, aspect, replication, cfg, warm, &mut scratch);
    match solve {
        Some(r) => {
            CountedEval { point, nodes: r.nodes, optimal: r.optimal, lower_bound: r.lower_bound }
        }
        None => CountedEval { point, nodes: 0, optimal: false, lower_bound: 0 },
    }
}

/// The sweep's ILP warm-start hint for a grid point: the counted
/// simple-engine bin count of `prev_tile` (the next smaller configuration
/// in the same aspect column). O(shape classes) — no blocks, no search.
/// Exposed so the planner's placement solve can replay the exact hint the
/// sweep used and land on exactly the reported bin count.
#[doc(hidden)]
pub fn ilp_sweep_hint(
    net: &Network,
    prev_tile: Tile,
    replication: &[usize],
    discipline: Discipline,
) -> usize {
    let mut scratch = SweepScratch::default();
    counted_simple_hint(net, prev_tile, replication, discipline, &mut scratch)
}

fn counted_simple_hint(
    net: &Network,
    tile: Tile,
    replication: &[usize],
    discipline: Discipline,
    scratch: &mut SweepScratch,
) -> usize {
    let SweepScratch { classes, counted, .. } = scratch;
    frag::shape_classes_into(net, tile, replication, classes);
    pack::counted::simple_bins(classes, tile, discipline, SortOrder::RowsDesc, counted)
}

/// Worker-thread count for [`sweep`]: the `XBARMAP_SWEEP_THREADS`
/// environment variable when set (>= 1), else the machine's available
/// parallelism.
pub fn sweep_threads() -> usize {
    if let Ok(v) = std::env::var("XBARMAP_SWEEP_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Full sweep over base dimensions x aspect ratios — parallel across
/// [`sweep_threads`] workers, deterministic: point ordering and values are
/// identical to [`sweep_serial`] regardless of scheduling.
///
/// Internal engine behind [`crate::plan`] — build a
/// [`crate::plan::MapRequest`] instead of calling this directly.
#[doc(hidden)]
pub fn sweep(net: &Network, cfg: &SweepConfig) -> Vec<SweepPoint> {
    sweep_with_threads(net, cfg, sweep_threads())
}

/// [`sweep`] with an explicit worker count (1 = in-place, no threads).
///
/// Work decomposition: **every** grid point is an independent task — ILP
/// points included, so square (`aspects=[1]`) ILP sweeps now parallelize
/// across sizes instead of serializing one warm-start chain per aspect
/// column. Each ILP point warm-starts from the counted simple-engine bin
/// count of its smaller neighbour in the same aspect column (§3.1 capacity
/// monotonicity — a larger tile at the same aspect virtually never needs
/// more tiles; the hint is O(shape classes) to compute and the solver
/// treats it as a refutable bound, so the heuristic is free to be wrong).
/// Results are gathered per worker and re-ordered by grid index before
/// returning.
#[doc(hidden)]
pub fn sweep_with_threads(net: &Network, cfg: &SweepConfig, threads: usize) -> Vec<SweepPoint> {
    let ones = vec![1usize; net.n_layers()];
    let replication: &[usize] = cfg.replication.as_deref().unwrap_or(&ones);
    let sizes: Vec<usize> = (cfg.row_exp.0..=cfg.row_exp.1).map(|k| 1usize << k).collect();
    let n_aspects = cfg.aspects.len();
    let n_points = sizes.len() * n_aspects;
    if n_points == 0 {
        return Vec::new();
    }

    let out = crate::util::par::par_for_ordered(
        n_points,
        threads,
        SweepScratch::default,
        |scratch, t, local| {
            let (si, ai) = (t / n_aspects, t % n_aspects);
            let aspect = cfg.aspects[ai];
            let tile = Tile::new(sizes[si] * aspect, sizes[si]);
            // per-point deadline gate: once the request's wall-clock budget
            // is gone, the remaining points are placeholders — the worker
            // drains its queue in microseconds instead of pricing on
            if cfg.deadline.is_set() && cfg.deadline.expired() {
                local.push((t, expired_point(tile, aspect)));
                return;
            }
            let warm = if matches!(cfg.engine, Engine::Ilp { .. }) && si > 0 {
                let prev = Tile::new(sizes[si - 1] * aspect, sizes[si - 1]);
                Some(counted_simple_hint(net, prev, replication, cfg.discipline, scratch))
            } else {
                None
            };
            let p = evaluate_lean(net, tile, aspect, replication, cfg, warm, scratch);
            local.push((t, p));
        },
    );
    debug_assert_eq!(out.len(), n_points);
    out
}

/// Reference serial implementation: the straightforward per-config loop
/// over the owned-allocation **per-block** engines, with the same
/// per-point ILP warm-start hints as the parallel engine (derived here by
/// materializing and packing the neighbour, so the determinism suite
/// cross-checks the counted hint kernel as well). Kept as the oracle for
/// the determinism suite ([`sweep`], which runs fully counted, must match
/// it byte for byte) and as the baseline the sweep benches measure the
/// counted path's speedup against.
#[doc(hidden)]
pub fn sweep_serial(net: &Network, cfg: &SweepConfig) -> Vec<SweepPoint> {
    let ones = vec![1usize; net.n_layers()];
    let replication: &[usize] = cfg.replication.as_deref().unwrap_or(&ones);
    let mut out = Vec::new();
    for k in cfg.row_exp.0..=cfg.row_exp.1 {
        let n_col = 1usize << k;
        for &aspect in cfg.aspects.iter() {
            let tile = Tile::new(n_col * aspect, n_col);
            let blocks = frag::fragment_network_replicated(net, tile, replication);
            let n_blocks = blocks.len();
            let packing = match cfg.engine {
                Engine::Simple => {
                    pack::simple::pack_ordered(&blocks, tile, cfg.discipline, cfg.sort)
                }
                Engine::Ffd => pack::ffd::pack(&blocks, tile, cfg.discipline),
                Engine::Ilp { max_nodes } => {
                    let warm = (k > cfg.row_exp.0).then(|| {
                        let prev = Tile::new((n_col / 2) * aspect, n_col / 2);
                        let pblocks = frag::fragment_network_replicated(net, prev, replication);
                        pack::simple::pack(&pblocks, prev, cfg.discipline).n_bins
                    });
                    ilp::exact::solve_with_hint(
                        &blocks,
                        tile,
                        cfg.discipline,
                        ilp::Budget { max_nodes, ..Default::default() },
                        warm,
                    )
                    .packing
                }
            };
            let n_tiles = packing.n_tiles();
            out.push(SweepPoint {
                tile,
                aspect,
                n_blocks,
                n_tiles,
                n_tiles_one_to_one: n_blocks,
                tile_eff: cfg.area.efficiency(tile),
                packing_eff: packing.packing_efficiency(),
                total_area_mm2: cfg.area.total_area_mm2(n_tiles, tile),
                array_area_mm2: n_tiles as f64 * cfg.area.array_area_um2(tile) * 1e-6,
            });
        }
    }
    out
}

/// Minimum-area point for each aspect ratio (§3.1 step 2). Total-order
/// safe: NaN areas (degenerate area models) sort last instead of
/// panicking.
pub fn best_per_aspect(points: &[SweepPoint]) -> Vec<SweepPoint> {
    let mut aspects: Vec<usize> = points.iter().map(|p| p.aspect).collect();
    aspects.sort_unstable();
    aspects.dedup();
    aspects
        .into_iter()
        .filter_map(|a| {
            points
                .iter()
                .filter(|p| p.aspect == a)
                .min_by(|x, y| x.total_area_mm2.total_cmp(&y.total_area_mm2))
                .cloned()
        })
        .collect()
}

/// Global optimum (§3.1 step 3): minimum area across all points.
/// Total-order safe like [`best_per_aspect`].
pub fn optimum(points: &[SweepPoint]) -> Option<SweepPoint> {
    points
        .iter()
        .min_by(|x, y| x.total_area_mm2.total_cmp(&y.total_area_mm2))
        .cloned()
}

impl crate::pack::Packing {
    /// Convenience alias used by the sweep (`n_bins` are physical tiles).
    pub fn n_tiles(&self) -> usize {
        self.n_bins
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::zoo;
    use crate::perf::rapa;

    #[test]
    fn parallel_sweep_matches_serial_reference() {
        let net = zoo::lenet();
        for cfg in [
            SweepConfig::paper_default(Discipline::Dense),
            SweepConfig::square(Discipline::Pipeline),
        ] {
            let serial = sweep_serial(&net, &cfg);
            let par = sweep_with_threads(&net, &cfg, 4);
            assert_eq!(par.len(), serial.len());
            for (a, b) in par.iter().zip(&serial) {
                assert_eq!(a.tile, b.tile);
                assert_eq!(a.aspect, b.aspect);
                assert_eq!(a.n_tiles, b.n_tiles);
                assert_eq!(a.n_blocks, b.n_blocks);
                assert_eq!(a.total_area_mm2.to_bits(), b.total_area_mm2.to_bits());
                assert_eq!(a.packing_eff.to_bits(), b.packing_eff.to_bits());
            }
        }
    }

    #[test]
    fn expired_deadline_collapses_sweep_to_placeholders() {
        let net = zoo::lenet();
        let mut cfg = SweepConfig::paper_default(Discipline::Dense);
        cfg.deadline = Deadline::after(std::time::Duration::ZERO);
        let pts = sweep_with_threads(&net, &cfg, 2);
        // full grid shape is preserved, every point is an inert placeholder
        assert_eq!(pts.len(), 64);
        assert!(pts.iter().all(|p| p.total_area_mm2.is_infinite() && p.n_tiles == 0));
        // the caller's post-sweep expiry check is what rejects the result
        assert!(cfg.deadline.expired());
    }

    #[test]
    fn requested_aspect_is_propagated() {
        let net = zoo::lenet();
        let cfg = SweepConfig { aspects: vec![3], ..SweepConfig::paper_default(Discipline::Dense) };
        let pts = sweep(&net, &cfg);
        assert!(pts.iter().all(|p| p.aspect == 3));
        assert!(pts.iter().all(|p| p.tile.n_row == 3 * p.tile.n_col));
    }

    #[test]
    fn optimum_total_order_safe_on_nan() {
        let mk = |area: f64| SweepPoint {
            tile: Tile::new(64, 64),
            aspect: 1,
            n_blocks: 1,
            n_tiles: 1,
            n_tiles_one_to_one: 1,
            tile_eff: 0.5,
            packing_eff: 0.5,
            total_area_mm2: area,
            array_area_mm2: area,
        };
        let pts = vec![mk(f64::NAN), mk(2.0), mk(1.0)];
        let best = optimum(&pts).unwrap();
        assert_eq!(best.total_area_mm2, 1.0);
        let per_aspect = best_per_aspect(&pts);
        assert_eq!(per_aspect.len(), 1);
        assert_eq!(per_aspect[0].total_area_mm2, 1.0);
    }

    #[test]
    fn ilp_sweep_warm_chain_matches_cold_points() {
        // warm-started points (counted simple-engine hint from the smaller
        // neighbour) must agree with independently cold-solved points
        // (both prove optimality at this scale)
        let net = zoo::lenet();
        let mut cfg = SweepConfig::square(Discipline::Pipeline);
        cfg.row_exp = (7, 9);
        cfg.engine = Engine::Ilp { max_nodes: 200_000 };
        let chain = sweep(&net, &cfg);
        for p in &chain {
            let cold = evaluate(&net, p.tile, p.aspect, &cfg);
            assert_eq!(p.n_tiles, cold.n_tiles, "{}", p.tile);
        }
    }

    #[test]
    fn ilp_sweep_hint_matches_per_block_simple_engine() {
        // the counted hint the sweep feeds each ILP point must equal the
        // per-block simple engine's bin count for the same neighbour
        let net = zoo::resnet18();
        let ones = vec![1usize; net.n_layers()];
        for d in [Discipline::Dense, Discipline::Pipeline] {
            for tile in [Tile::new(128, 128), Tile::new(512, 256)] {
                let blocks = frag::fragment_network_replicated(&net, tile, &ones);
                let reference = pack::simple::pack(&blocks, tile, d).n_bins;
                assert_eq!(ilp_sweep_hint(&net, tile, &ones, d), reference, "{tile} {d}");
            }
        }
    }

    #[test]
    fn square_ilp_sweep_parallelizes_across_sizes() {
        // aspects=[1] ILP sweeps used to be one serial chain; per-point
        // tasks must still give byte-identical results at any worker count
        let net = zoo::lenet();
        let mut cfg = SweepConfig::square(Discipline::Pipeline);
        cfg.row_exp = (7, 10);
        cfg.engine = Engine::Ilp { max_nodes: 100_000 };
        let one = sweep_with_threads(&net, &cfg, 1);
        let many = sweep_with_threads(&net, &cfg, 4);
        assert_eq!(one.len(), many.len());
        for (a, b) in one.iter().zip(&many) {
            assert_eq!((a.tile, a.n_tiles), (b.tile, b.n_tiles));
            assert_eq!(a.packing_eff.to_bits(), b.packing_eff.to_bits());
        }
    }

    #[test]
    fn engine_display_fromstr_roundtrip() {
        for e in [Engine::Simple, Engine::Ffd, Engine::Ilp { max_nodes: Engine::DEFAULT_ILP_NODES }]
        {
            assert_eq!(e.to_string().parse::<Engine>().unwrap(), e);
        }
        // "ilp" stays an accepted input alias for the canonical "lps"
        assert_eq!("ilp".parse::<Engine>().unwrap().canonical(), "lps");
        assert_eq!(
            Engine::parse_with_budget("ilp", 7).unwrap(),
            Engine::Ilp { max_nodes: 7 }
        );
        assert!("lp".parse::<Engine>().is_err());
    }

    #[test]
    fn evaluate_takes_aspect_explicitly_no_rounding() {
        // the old signature derived aspect = n_row / n_col, so a 96x64 tile
        // (true aspect 1.5) silently aliased into aspect 1 — the aspect is
        // now the caller's, recorded verbatim
        let net = zoo::lenet();
        let cfg = SweepConfig::paper_default(Discipline::Dense);
        let off_grid = Tile::new(96, 64);
        assert_eq!(off_grid.exact_aspect(), None);
        let p = evaluate(&net, off_grid, 0, &cfg);
        assert_eq!(p.aspect, 0, "sentinel aspect preserved, not rounded to 1");
        let on_grid = Tile::new(2560, 512);
        let p = evaluate(&net, on_grid, on_grid.exact_aspect().unwrap(), &cfg);
        assert_eq!(p.aspect, 5);
    }

    #[test]
    fn single_thread_and_oversubscribed_agree() {
        let net = zoo::lenet();
        let cfg = SweepConfig::paper_default(Discipline::Pipeline);
        let one = sweep_with_threads(&net, &cfg, 1);
        let many = sweep_with_threads(&net, &cfg, 64); // more workers than tasks
        assert_eq!(one.len(), many.len());
        for (a, b) in one.iter().zip(&many) {
            assert_eq!((a.tile, a.n_tiles), (b.tile, b.n_tiles));
        }
    }

    #[test]
    fn square_sweep_shapes() {
        let net = zoo::lenet();
        let cfg = SweepConfig::square(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        assert_eq!(pts.len(), 8); // k = 6..=13
        assert!(pts.iter().all(|p| p.tile.is_square()));
        assert!(pts.iter().all(|p| p.n_tiles >= 1));
    }

    #[test]
    fn full_sweep_covers_paper_range() {
        let net = zoo::lenet();
        let cfg = SweepConfig::paper_default(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        assert_eq!(pts.len(), 64); // 8 sizes x 8 aspects
        let min_tile = pts.iter().map(|p| p.tile).min_by_key(|t| t.capacity()).unwrap();
        let max_tile = pts.iter().map(|p| p.tile).max_by_key(|t| t.capacity()).unwrap();
        assert_eq!((min_tile.n_row, min_tile.n_col), (64, 64));
        assert_eq!((max_tile.n_row, max_tile.n_col), (65536, 8192));
    }

    #[test]
    fn resnet18_dense_square_optimum_matches_fig8() {
        // Fig. 8 left: dense square optimum = 16 tiles of 1024x1024
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        let best = optimum(&pts).unwrap();
        // our calibration puts the dense square optimum on the flat part of
        // the area curve between 1024² (paper's 16 tiles) and 2048²; both
        // are within a few percent of area (documented in EXPERIMENTS.md)
        assert!(
            best.tile == Tile::new(1024, 1024) || best.tile == Tile::new(2048, 2048),
            "optimum tile {:?}",
            best.tile
        );
        assert!(
            (4..=18).contains(&best.n_tiles),
            "tiles {} vs paper's 16",
            best.n_tiles
        );
    }

    #[test]
    fn resnet18_pipeline_square_optimum_matches_fig8() {
        // Fig. 8 right: pipeline square optimum = 68 tiles of 512x512
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Pipeline);
        let pts = sweep(&net, &cfg);
        let best = optimum(&pts).unwrap();
        assert_eq!(best.tile.n_row, 512, "optimum tile {:?}", best.tile);
        assert!(
            (55..=90).contains(&best.n_tiles),
            "tiles {} vs paper's 68",
            best.n_tiles
        );
    }

    #[test]
    fn pipeline_area_roughly_double_dense() {
        // Fig. 8: "the area cost of the pipeline solution is about twice
        // that of the dense solution"
        let net = zoo::resnet18();
        let dense = optimum(&sweep(&net, &SweepConfig::square(Discipline::Dense))).unwrap();
        let pipe = optimum(&sweep(&net, &SweepConfig::square(Discipline::Pipeline))).unwrap();
        let ratio = pipe.total_area_mm2 / dense.total_area_mm2;
        assert!((1.3..=3.5).contains(&ratio), "pipeline/dense area ratio {ratio}");
    }

    #[test]
    fn rectangular_pipeline_cuts_tiles_vs_square() {
        // §3.1: "the area penalty of the pipeline solution can be cut
        // approximately in half with 17 rectangular arrays of 2560x512" —
        // our sweep uses power-of-two rows with col = rows*aspect; assert
        // the qualitative effect: fewer tiles at similar-or-better area.
        let net = zoo::resnet18();
        let sq = optimum(&sweep(&net, &SweepConfig::square(Discipline::Pipeline))).unwrap();
        let rect_cfg = SweepConfig::paper_default(Discipline::Pipeline);
        let rect_pts = sweep(&net, &rect_cfg);
        let rect = optimum(&rect_pts).unwrap();
        assert!(rect.total_area_mm2 <= sq.total_area_mm2 * 1.05);
        assert!(
            rect.n_tiles < sq.n_tiles,
            "rect {} tiles !< square {} tiles",
            rect.n_tiles,
            sq.n_tiles
        );
    }

    #[test]
    fn best_per_aspect_returns_one_point_per_aspect() {
        let net = zoo::lenet();
        let cfg = SweepConfig::paper_default(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        let best = best_per_aspect(&pts);
        assert_eq!(best.len(), 8);
        let mut aspects: Vec<usize> = best.iter().map(|p| p.aspect).collect();
        aspects.sort_unstable();
        assert_eq!(aspects, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn rapa_replication_inflates_area() {
        let net = zoo::resnet18();
        let mut cfg = SweepConfig::square(Discipline::Pipeline);
        let base = optimum(&sweep(&net, &cfg)).unwrap();
        cfg.replication = Some(rapa::plan_balanced(&net, 128));
        let rapa_best = optimum(&sweep(&net, &cfg)).unwrap();
        let ratio = rapa_best.total_area_mm2 / base.total_area_mm2;
        // paper Fig. 9: RAPA area cost ~5x vs the dense solution
        assert!((2.0..=12.0).contains(&ratio), "RAPA area ratio {ratio}");
    }

    #[test]
    fn min_tiles_not_min_area() {
        // the paper's key observation: the minimum number of tiles does not
        // necessarily give the minimum total tile area
        let net = zoo::resnet18();
        let cfg = SweepConfig::square(Discipline::Dense);
        let pts = sweep(&net, &cfg);
        let min_tiles = pts.iter().min_by_key(|p| p.n_tiles).unwrap();
        let min_area = optimum(&pts).unwrap();
        assert!(
            min_tiles.tile != min_area.tile,
            "expected distinct optima: tiles@{} area@{}",
            min_tiles.tile,
            min_area.tile
        );
        assert!(min_tiles.n_tiles <= min_area.n_tiles);
        assert!(min_area.total_area_mm2 <= min_tiles.total_area_mm2);
    }

    #[test]
    fn ilp_engine_never_more_tiles_than_simple() {
        let net = zoo::lenet();
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let mut cfg = SweepConfig::square(d);
            cfg.row_exp = (7, 9);
            let simple_pts = sweep(&net, &cfg);
            cfg.engine = Engine::Ilp { max_nodes: 200_000 };
            let lps_pts = sweep(&net, &cfg);
            for (s, l) in simple_pts.iter().zip(&lps_pts) {
                assert!(
                    l.n_tiles <= s.n_tiles,
                    "{} {d}: lps {} > simple {}",
                    s.tile,
                    l.n_tiles,
                    s.n_tiles
                );
            }
        }
    }
}
