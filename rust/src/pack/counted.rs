//! Counted packing kernels: price a tile configuration straight from the
//! §2.1 shape-class census ([`crate::frag::ShapeClass`]) without ever
//! materializing a block.
//!
//! Eq. 5 fragmentation produces at most **four** distinct block shapes per
//! layer (Fig. 4), yet the per-block engines sort and walk every block —
//! O(n log n) per sweep point for work that is closed-form over the
//! classes. This module is the closed form:
//!
//! * the placement order collapses to a sequence of [`Run`]s (maximal
//!   groups of identical `rows x cols` blocks) — O(classes) long for the
//!   sorted orders, O(grid rows) for the `as-given` ablation;
//! * a run of identical blocks places in closed form under both simple
//!   disciplines: dense next-fit shelves fill `floor(n_row/rows)` blocks
//!   per shelf and `floor(n_col/cols)` shelves per tile, pipeline
//!   staircases fill `min(n_row/rows, n_col/cols)` blocks per tile — the
//!   partial-shelf/tile cursor carries between runs so the bin count is
//!   **exactly** the per-block engine's, not an approximation;
//! * FFD processes runs against its open-bin state (O(runs x bins), still
//!   free of the per-block sort and scan).
//!
//! Equivalence with the per-block engines is property-tested in
//! `rust/tests/prop_counted.rs` and enforced sweep-wide by the determinism
//! suite (`opt::sweep` routes through this module, `opt::sweep_serial`
//! stays per-block).

use super::{Discipline, SortOrder};
use crate::frag::ShapeClass;
use crate::geom::Tile;
use crate::util::deadline::Deadline;

/// A run of `count` identical `rows x cols` blocks in placement order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Run {
    /// rows of every block in the run
    pub rows: usize,
    /// columns of every block in the run
    pub cols: usize,
    /// how many identical blocks the run stands for
    pub count: usize,
}

/// Reusable buffers for the counted path — one per sweep worker, so after
/// warm-up a grid point is priced without heap allocation on the simple
/// path (the FFD dense path keeps per-bin shelf lists).
#[derive(Debug, Default)]
pub struct CountedScratch {
    runs: Vec<Run>,
    ffd_dense: Vec<FfdBin>,
    pipe_rows: Vec<usize>,
    pipe_cols: Vec<usize>,
}

impl CountedScratch {
    /// Empty scratch; buffers grow on first use and are reused after.
    pub fn new() -> CountedScratch {
        CountedScratch::default()
    }
}

/// Collapse a shape-class census into the run sequence the per-block
/// engines would traverse under `order`:
///
/// * `rows-desc` / `rows-asc` — classes merged across layers by shape and
///   sorted by the [`super::order_indices`] key (provenance tie-breaks are
///   irrelevant: blocks of equal shape are interchangeable for counting);
/// * `as-given` — the fragmentation's layer/replica/row-major sequence,
///   reconstructed per grid row from the class provenance ranges (O(grid
///   rows) runs; only this ablation order needs them).
pub fn runs_from_census(classes: &[ShapeClass], order: SortOrder, out: &mut Vec<Run>) {
    out.clear();
    match order {
        SortOrder::RowsDesc | SortOrder::RowsAsc => {
            out.extend(classes.iter().map(|c| Run { rows: c.rows, cols: c.cols, count: c.count }));
            out.sort_unstable_by(|a, b| b.rows.cmp(&a.rows).then(b.cols.cmp(&a.cols)));
            merge_adjacent(out);
            if order == SortOrder::RowsAsc {
                out.reverse();
            }
        }
        SortOrder::AsGiven => {
            let mut i = 0;
            while i < classes.len() {
                let layer = classes[i].layer;
                let start = i;
                while i < classes.len() && classes[i].layer == layer {
                    i += 1;
                }
                as_given_layer_runs(&classes[start..i], out);
            }
        }
    }
}

/// Emit one layer's as-given (row-major, replica-by-replica) run sequence.
/// Relies on the census emitting at most one class per §2.1 kind per layer.
fn as_given_layer_runs(group: &[ShapeClass], out: &mut Vec<Run>) {
    use crate::geom::BlockKind;
    let by_kind = |k: BlockKind| group.iter().find(|c| c.kind == k);
    let full = by_kind(BlockKind::Full);
    let row_full = by_kind(BlockKind::RowFull);
    let col_full = by_kind(BlockKind::ColFull);
    let sparse = by_kind(BlockKind::Sparse);
    let fr = full.or(row_full).map_or(0, |c| c.grid_rows.1 - c.grid_rows.0);
    let fc = full.or(col_full).map_or(0, |c| c.grid_cols.1 - c.grid_cols.0);
    let replicas = group.first().map_or(0, |c| c.replicas);
    for _ in 0..replicas {
        // fr full-height grid rows: [Full x fc, RowFull x 1] each
        match (full, row_full) {
            (Some(f), Some(rf)) => {
                for _ in 0..fr {
                    emit(out, f.rows, f.cols, fc);
                    emit(out, rf.rows, rf.cols, 1);
                }
            }
            (Some(f), None) => emit(out, f.rows, f.cols, fr * fc),
            (None, Some(rf)) => emit(out, rf.rows, rf.cols, fr),
            (None, None) => debug_assert_eq!(fr, 0),
        }
        // the remainder row: [ColFull x fc, Sparse x 1]
        if let Some(cf) = col_full {
            emit(out, cf.rows, cf.cols, fc);
        }
        if let Some(sp) = sparse {
            emit(out, sp.rows, sp.cols, 1);
        }
    }
}

fn emit(out: &mut Vec<Run>, rows: usize, cols: usize, count: usize) {
    if count == 0 {
        return;
    }
    if let Some(last) = out.last_mut() {
        if last.rows == rows && last.cols == cols {
            last.count += count;
            return;
        }
    }
    out.push(Run { rows, cols, count });
}

fn merge_adjacent(runs: &mut Vec<Run>) {
    let mut w = 0;
    for i in 0..runs.len() {
        if w > 0 && runs[w - 1].rows == runs[i].rows && runs[w - 1].cols == runs[i].cols {
            runs[w - 1].count += runs[i].count;
        } else {
            runs[w] = runs[i];
            w += 1;
        }
    }
    runs.truncate(w);
}

fn assert_classes_fit(classes: &[ShapeClass], tile: Tile) {
    for c in classes {
        assert!(
            tile.fits(c.rows, c.cols),
            "class {c:?} larger than tile {tile}: fragment with this tile first"
        );
    }
}

/// Bin count of [`super::simple`] (the paper's next-fit algorithm) over a
/// shape-class census — identical to `simple::pack_ordered(...).n_bins` on
/// the materialized blocks, in O(runs) after the census.
pub fn simple_bins(
    classes: &[ShapeClass],
    tile: Tile,
    discipline: Discipline,
    order: SortOrder,
    scratch: &mut CountedScratch,
) -> usize {
    simple_bins_deadline(classes, tile, discipline, order, scratch, Deadline::NONE)
        .expect("unset deadline never expires")
}

/// [`simple_bins`] with a cooperative wall-clock budget: the run loop
/// checks `deadline` between runs and returns `None` on expiry (the
/// scratch state is abandoned — it is cleared on the next call anyway).
/// An unset deadline never reads the clock, so [`simple_bins`] simply
/// delegates here.
pub fn simple_bins_deadline(
    classes: &[ShapeClass],
    tile: Tile,
    discipline: Discipline,
    order: SortOrder,
    scratch: &mut CountedScratch,
    deadline: Deadline,
) -> Option<usize> {
    assert_classes_fit(classes, tile);
    runs_from_census(classes, order, &mut scratch.runs);
    let check = deadline.is_set();
    match discipline {
        Discipline::Dense => {
            let mut st = DenseNextFit::default();
            for run in &scratch.runs {
                if check && deadline.expired() {
                    return None;
                }
                st.place_run(tile, run.rows, run.cols, run.count);
            }
            Some(st.n_bins)
        }
        Discipline::Pipeline => {
            let mut st = PipeNextFit::default();
            for run in &scratch.runs {
                if check && deadline.expired() {
                    return None;
                }
                st.place_run(tile, run.rows, run.cols, run.count);
            }
            Some(st.n_bins)
        }
    }
}

/// Bin count of [`super::ffd`] over a shape-class census — identical to
/// `ffd::pack(...).n_bins` on the materialized blocks. O(runs x bins): the
/// per-block sort and first-fit scans collapse, the open-bin state remains.
pub fn ffd_bins(
    classes: &[ShapeClass],
    tile: Tile,
    discipline: Discipline,
    scratch: &mut CountedScratch,
) -> usize {
    ffd_bins_deadline(classes, tile, discipline, scratch, Deadline::NONE)
        .expect("unset deadline never expires")
}

/// [`ffd_bins`] with a cooperative wall-clock budget — `None` on expiry,
/// checked between runs (see [`simple_bins_deadline`]).
pub fn ffd_bins_deadline(
    classes: &[ShapeClass],
    tile: Tile,
    discipline: Discipline,
    scratch: &mut CountedScratch,
    deadline: Deadline,
) -> Option<usize> {
    assert_classes_fit(classes, tile);
    let CountedScratch { runs, ffd_dense, pipe_rows, pipe_cols } = scratch;
    runs_from_census(classes, SortOrder::RowsDesc, runs);
    let check = deadline.is_set();
    match discipline {
        Discipline::Dense => {
            ffd_dense.clear();
            for run in runs.iter() {
                if check && deadline.expired() {
                    return None;
                }
                ffd_dense_run(tile, run, ffd_dense);
            }
            Some(ffd_dense.len())
        }
        Discipline::Pipeline => {
            pipe_rows.clear();
            pipe_cols.clear();
            for run in runs.iter() {
                if check && deadline.expired() {
                    return None;
                }
                ffd_pipe_run(tile, run, pipe_rows, pipe_cols);
            }
            Some(pipe_rows.len())
        }
    }
}

// ---------------------------------------------------------------------------
// simple (next-fit) closed forms
// ---------------------------------------------------------------------------

/// Dense next-fit shelf cursor carried between runs. Mirrors
/// [`super::simple`]'s `dense_next_fit` decision for every block of a run:
/// join the current shelf while Eq. 6c/6d hold, open new shelves to the
/// right, open new bins — but a run of `k` identical blocks resolves in
/// O(1) instead of k iterations.
#[derive(Debug, Default)]
struct DenseNextFit {
    n_bins: usize,
    shelf_x: usize,
    shelf_width: usize,
    shelf_fill: usize,
}

impl DenseNextFit {
    fn place_run(&mut self, tile: Tile, r: usize, c: usize, mut k: usize) {
        if k == 0 {
            return;
        }
        if self.n_bins == 0 {
            self.n_bins = 1;
        }
        let per_shelf = tile.n_row / r;
        if self.shelf_fill > 0 {
            // 1) join the current shelf while rows fit (Eq. 6c) and the
            //    widened shelf stays inside the column budget (Eq. 6d);
            //    the shelf only widens if at least one block joins
            let widened = self.shelf_width.max(c);
            if self.shelf_x + widened <= tile.n_col {
                let t = ((tile.n_row - self.shelf_fill) / r).min(k);
                if t > 0 {
                    self.shelf_fill += t * r;
                    self.shelf_width = widened;
                    k -= t;
                    if k == 0 {
                        return;
                    }
                }
            }
            // 2) new shelves of width c to the right of the current one
            let next_x = self.shelf_x + self.shelf_width;
            let s_fit = (tile.n_col - next_x) / c;
            let cap = s_fit * per_shelf;
            if k <= cap {
                self.settle(next_x, r, c, per_shelf, k);
                return;
            }
            k -= cap;
            // 3) the remainder needs a fresh bin (next-fit never revisits)
            self.n_bins += 1;
        }
        // fresh bins: floor(n_col/c) shelves of per_shelf blocks each
        let bin_cap = (tile.n_col / c) * per_shelf;
        let extra = (k - 1) / bin_cap;
        self.n_bins += extra;
        self.settle(0, r, c, per_shelf, k - extra * bin_cap);
    }

    /// Leave the cursor exactly where the per-block loop would after laying
    /// `k >= 1` blocks into consecutive width-`c` shelves from `base_x`.
    fn settle(&mut self, base_x: usize, r: usize, c: usize, per_shelf: usize, k: usize) {
        debug_assert!(k >= 1);
        let full = k / per_shelf;
        let rem = k % per_shelf;
        if rem == 0 {
            self.shelf_x = base_x + (full - 1) * c;
            self.shelf_fill = per_shelf * r;
        } else {
            self.shelf_x = base_x + full * c;
            self.shelf_fill = rem * r;
        }
        self.shelf_width = c;
    }
}

/// Pipeline next-fit staircase cursor (Eq. 7c/7d): a tile takes
/// `min(n_row/rows, n_col/cols)` blocks of a shape along its diagonal.
#[derive(Debug, Default)]
struct PipeNextFit {
    n_bins: usize,
    row_used: usize,
    col_used: usize,
}

impl PipeNextFit {
    fn place_run(&mut self, tile: Tile, r: usize, c: usize, mut k: usize) {
        if k == 0 {
            return;
        }
        if self.n_bins > 0 {
            let t = ((tile.n_row - self.row_used) / r)
                .min((tile.n_col - self.col_used) / c)
                .min(k);
            self.row_used += t * r;
            self.col_used += t * c;
            k -= t;
            if k == 0 {
                return;
            }
        }
        let per_bin = (tile.n_row / r).min(tile.n_col / c);
        let new_bins = k.div_ceil(per_bin);
        self.n_bins += new_bins;
        let last = k - (new_bins - 1) * per_bin;
        self.row_used = last * r;
        self.col_used = last * c;
    }
}

// ---------------------------------------------------------------------------
// FFD over runs
// ---------------------------------------------------------------------------

#[derive(Debug, Default, Clone)]
struct FfdShelf {
    width: usize,
    fill: usize,
}

#[derive(Debug, Default, Clone)]
struct FfdBin {
    col_used: usize,
    shelves: Vec<FfdShelf>,
}

/// One run through FFD dense shelves: fill existing shelves first-fit in
/// (bin, shelf) order, then new shelves in the first bins with column
/// budget, then fresh bins. Identical blocks saturate each target before
/// moving on — exactly the per-block scan's behavior, since earlier
/// fit-failures can only be made worse by placing more blocks.
fn ffd_dense_run(tile: Tile, run: &Run, bins: &mut Vec<FfdBin>) {
    let (r, c) = (run.rows, run.cols);
    let mut k = run.count;
    // 1) existing shelves (width must fit: closed shelves cannot widen)
    for bin in bins.iter_mut() {
        for sh in bin.shelves.iter_mut() {
            if c <= sh.width && sh.fill + r <= tile.n_row {
                let t = ((tile.n_row - sh.fill) / r).min(k);
                sh.fill += t * r;
                k -= t;
                if k == 0 {
                    return;
                }
            }
        }
    }
    // 2) new shelves in existing bins
    let per_shelf = tile.n_row / r;
    for bin in bins.iter_mut() {
        while k > 0 && bin.col_used + c <= tile.n_col {
            let t = per_shelf.min(k);
            bin.shelves.push(FfdShelf { width: c, fill: t * r });
            bin.col_used += c;
            k -= t;
        }
        if k == 0 {
            return;
        }
    }
    // 3) fresh bins
    let bin_cap = (tile.n_col / c) * per_shelf;
    while k > 0 {
        let placed = bin_cap.min(k);
        k -= placed;
        let full = placed / per_shelf;
        let rem = placed % per_shelf;
        let mut bin = FfdBin::default();
        bin.shelves.reserve(full + (rem > 0) as usize);
        for _ in 0..full {
            bin.shelves.push(FfdShelf { width: c, fill: per_shelf * r });
        }
        if rem > 0 {
            bin.shelves.push(FfdShelf { width: c, fill: rem * r });
        }
        bin.col_used = (full + (rem > 0) as usize) * c;
        bins.push(bin);
    }
}

/// One run through FFD two-constraint vector packing: each open bin absorbs
/// its residual capacity in blocks, then fresh bins take
/// `min(n_row/rows, n_col/cols)` each.
fn ffd_pipe_run(tile: Tile, run: &Run, rows_used: &mut Vec<usize>, cols_used: &mut Vec<usize>) {
    let (r, c) = (run.rows, run.cols);
    let mut k = run.count;
    for i in 0..rows_used.len() {
        if rows_used[i] + r <= tile.n_row && cols_used[i] + c <= tile.n_col {
            let t = ((tile.n_row - rows_used[i]) / r)
                .min((tile.n_col - cols_used[i]) / c)
                .min(k);
            rows_used[i] += t * r;
            cols_used[i] += t * c;
            k -= t;
            if k == 0 {
                return;
            }
        }
    }
    let per_bin = (tile.n_row / r).min(tile.n_col / c);
    while k > 0 {
        let t = per_bin.min(k);
        rows_used.push(t * r);
        cols_used.push(t * c);
        k -= t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frag;
    use crate::nets::zoo;
    use crate::pack::{ffd, simple};

    const ORDERS: [SortOrder; 3] = [SortOrder::RowsDesc, SortOrder::RowsAsc, SortOrder::AsGiven];

    fn check_net(net: &crate::nets::Network, tile: Tile, reps: &[usize]) {
        let classes = frag::shape_classes(net, tile, reps);
        let blocks = frag::fragment_network_replicated(net, tile, reps);
        let mut scratch = CountedScratch::new();
        for d in [Discipline::Dense, Discipline::Pipeline] {
            for order in ORDERS {
                let counted = simple_bins(&classes, tile, d, order, &mut scratch);
                let reference = simple::pack_ordered(&blocks, tile, d, order).n_bins;
                assert_eq!(counted, reference, "{} {tile} {d} {order} simple", net.name);
            }
            let counted = ffd_bins(&classes, tile, d, &mut scratch);
            let reference = ffd::pack(&blocks, tile, d).n_bins;
            assert_eq!(counted, reference, "{} {tile} {d} ffd", net.name);
        }
    }

    #[test]
    fn counted_matches_per_block_across_zoo() {
        for net in [zoo::lenet(), zoo::alexnet(), zoo::resnet18(), zoo::bert_layer(64)] {
            let ones = vec![1usize; net.n_layers()];
            for tile in [Tile::new(64, 64), Tile::new(256, 256), Tile::new(1024, 256)] {
                check_net(&net, tile, &ones);
            }
        }
    }

    #[test]
    fn counted_matches_per_block_under_replication() {
        let net = zoo::lenet();
        check_net(&net, Tile::new(128, 128), &[4, 2, 1, 3, 1]);
        let net = zoo::resnet18();
        let reps = crate::perf::rapa::plan_balanced(&net, 128);
        check_net(&net, Tile::new(256, 256), &reps);
    }

    #[test]
    fn run_sequence_collapses_to_classes_for_sorted_orders() {
        let net = zoo::bert_layer(64);
        let tile = Tile::new(64, 64);
        let ones = vec![1usize; net.n_layers()];
        let classes = frag::shape_classes(&net, tile, &ones);
        let mut runs = Vec::new();
        runs_from_census(&classes, SortOrder::RowsDesc, &mut runs);
        // BERT's six layers share three distinct matrix shapes; at 64x64
        // their classes merge into a handful of runs despite ~10^3 blocks
        assert!(runs.len() <= classes.len());
        assert!(runs.len() < 16, "{} runs", runs.len());
        let total: usize = runs.iter().map(|r| r.count).sum();
        assert_eq!(total, frag::total_class_blocks(&classes));
        // descending order
        for w in runs.windows(2) {
            assert!(
                w[0].rows > w[1].rows || (w[0].rows == w[1].rows && w[0].cols > w[1].cols),
                "not strictly ordered: {:?}",
                w
            );
        }
    }

    #[test]
    fn as_given_runs_preserve_fragmentation_order() {
        let net = zoo::alexnet();
        let tile = Tile::new(512, 512);
        let ones = vec![1usize; net.n_layers()];
        let classes = frag::shape_classes(&net, tile, &ones);
        let mut runs = Vec::new();
        runs_from_census(&classes, SortOrder::AsGiven, &mut runs);
        // expanding the runs must reproduce the materialized block sequence
        let blocks = frag::fragment_network(&net, tile);
        let mut expanded = Vec::new();
        for r in &runs {
            for _ in 0..r.count {
                expanded.push((r.rows, r.cols));
            }
        }
        let reference: Vec<(usize, usize)> = blocks.iter().map(|b| (b.rows, b.cols)).collect();
        assert_eq!(expanded, reference);
    }

    #[test]
    fn empty_census_zero_bins() {
        let mut scratch = CountedScratch::new();
        for d in [Discipline::Dense, Discipline::Pipeline] {
            assert_eq!(simple_bins(&[], Tile::new(8, 8), d, SortOrder::RowsDesc, &mut scratch), 0);
            assert_eq!(ffd_bins(&[], Tile::new(8, 8), d, &mut scratch), 0);
        }
    }

    #[test]
    fn expired_deadline_aborts_counted_kernels() {
        let net = zoo::lenet();
        let tile = Tile::new(128, 128);
        let classes = frag::shape_classes(&net, tile, &[1; 5]);
        let mut scratch = CountedScratch::new();
        let expired = Deadline::after(std::time::Duration::ZERO);
        let aborted = simple_bins_deadline(
            &classes,
            tile,
            Discipline::Dense,
            SortOrder::RowsDesc,
            &mut scratch,
            expired,
        );
        assert_eq!(aborted, None);
        assert_eq!(ffd_bins_deadline(&classes, tile, Discipline::Pipeline, &mut scratch, expired), None);
        // abandoned scratch state must not poison the next (undeadlined) call
        let n = simple_bins(&classes, tile, Discipline::Dense, SortOrder::RowsDesc, &mut scratch);
        assert!(n > 0);
    }

    #[test]
    #[should_panic(expected = "larger than tile")]
    fn oversized_class_rejected() {
        let net = zoo::lenet();
        let classes = frag::shape_classes(&net, Tile::new(512, 512), &[1; 5]);
        // classes were cut for 512x512; pricing them against a smaller tile
        // must fail loudly, exactly like the per-block engines
        let mut scratch = CountedScratch::new();
        simple_bins(&classes, Tile::new(64, 64), Discipline::Dense, SortOrder::RowsDesc, &mut scratch);
    }
}
