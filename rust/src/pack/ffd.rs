//! First-fit-decreasing baselines (ablation against the paper's next-fit).
//!
//! * dense: first-fit shelf — each block tries every open shelf in every
//!   open bin before opening a new shelf/bin (classical FFD-Shelf of Lodi
//!   et al. 2002, the survey the paper cites as [38]);
//! * pipeline: first-fit 2-constraint vector packing — each block goes in
//!   the first open bin with enough residual word *and* bit lines.
//!
//! FFD dominates next-fit on quality at O(n²) worst case; the benches
//! quantify the quality/runtime trade against [`super::simple`].

use super::{order_indices, Discipline, PackScratch, Packing, SortOrder};
use crate::geom::{Block, Placement, Tile};

/// Pack with first-fit-decreasing.
#[doc(hidden)]
pub fn pack(blocks: &[Block], tile: Tile, discipline: Discipline) -> Packing {
    let mut scratch = PackScratch::default();
    let n_bins = pack_into(blocks, tile, discipline, &mut scratch);
    Packing {
        tile,
        discipline,
        blocks: blocks.to_vec(),
        placements: std::mem::take(&mut scratch.placements),
        n_bins,
    }
}

/// Allocation-lean core (see [`super::simple::pack_into`]): borrowed block
/// slice, placements in `scratch.placements` referencing original indices,
/// bin count returned. The pipeline engine's per-bin budgets also live in
/// `scratch`; the dense engine's shelf lists are the one remaining local
/// allocation (off the default sweep path, which uses the simple engine).
pub fn pack_into(
    blocks: &[Block],
    tile: Tile,
    discipline: Discipline,
    scratch: &mut PackScratch,
) -> usize {
    super::assert_blocks_fit(blocks, tile);
    let PackScratch { perm, placements, bin_rows, bin_cols } = scratch;
    order_indices(blocks, SortOrder::RowsDesc, perm);
    placements.clear();
    placements.reserve(blocks.len());
    match discipline {
        Discipline::Dense => dense_first_fit(blocks, perm, tile, placements),
        Discipline::Pipeline => {
            bin_rows.clear();
            bin_cols.clear();
            pipeline_first_fit(blocks, perm, tile, bin_rows, bin_cols, placements)
        }
    }
}

#[derive(Debug)]
struct Shelf {
    x: usize,
    width: usize,
    fill: usize, // rows used
}

#[derive(Debug, Default)]
struct DenseBin {
    shelves: Vec<Shelf>,
    col_used: usize,
    /// max over shelves of (n_row - fill): a block with more rows than this
    /// cannot join any shelf here — lets the first-fit scan skip whole bins
    /// (EXPERIMENTS.md §Perf #2)
    max_free_rows: usize,
    /// widest shelf: a block wider than this cannot join any shelf here
    max_width: usize,
}

impl DenseBin {
    fn refresh_max_free(&mut self, n_row: usize) {
        self.max_free_rows = self
            .shelves
            .iter()
            .map(|s| n_row - s.fill)
            .max()
            .unwrap_or(0);
    }
}

/// FFD shelf packing (see module docs).
fn dense_first_fit(
    blocks: &[Block],
    perm: &[u32],
    tile: Tile,
    placements: &mut Vec<Placement>,
) -> usize {
    let mut bins: Vec<DenseBin> = Vec::new();

    'blocks: for &oi in perm {
        let idx = oi as usize;
        let b = &blocks[idx];
        // 1) existing shelf anywhere. Unlike the next-fit engine (whose
        //    current shelf is always the rightmost and may widen into the
        //    bin's free space), closed shelves have neighbours to their
        //    right, so a block may only join if it fits the shelf's width.
        for (bi, bin) in bins.iter_mut().enumerate() {
            if b.rows > bin.max_free_rows || b.cols > bin.max_width {
                continue; // no shelf in this bin can host the block
            }
            for sh in bin.shelves.iter_mut() {
                if sh.fill + b.rows <= tile.n_row && b.cols <= sh.width {
                    placements.push(Placement { block: idx, bin: bi, x: sh.x, y: sh.fill });
                    sh.fill += b.rows;
                    bin.refresh_max_free(tile.n_row);
                    continue 'blocks;
                }
            }
        }
        // 2) new shelf in an existing bin
        for (bi, bin) in bins.iter_mut().enumerate() {
            if bin.col_used + b.cols <= tile.n_col {
                let x = bin.col_used;
                bin.shelves.push(Shelf { x, width: b.cols, fill: b.rows });
                bin.col_used += b.cols;
                bin.max_free_rows = bin.max_free_rows.max(tile.n_row - b.rows);
                bin.max_width = bin.max_width.max(b.cols);
                placements.push(Placement { block: idx, bin: bi, x, y: 0 });
                continue 'blocks;
            }
        }
        // 3) new bin
        bins.push(DenseBin {
            shelves: vec![Shelf { x: 0, width: b.cols, fill: b.rows }],
            col_used: b.cols,
            max_free_rows: tile.n_row - b.rows,
            max_width: b.cols,
        });
        placements.push(Placement { block: idx, bin: bins.len() - 1, x: 0, y: 0 });
    }

    bins.len()
}

/// FFD two-constraint staircase packing (see module docs). `rows_used` /
/// `cols_used` are caller-provided (cleared) scratch so the sweep reuses
/// their capacity across grid points.
fn pipeline_first_fit(
    blocks: &[Block],
    perm: &[u32],
    tile: Tile,
    rows_used: &mut Vec<usize>,
    cols_used: &mut Vec<usize>,
    placements: &mut Vec<Placement>,
) -> usize {
    for &oi in perm {
        let idx = oi as usize;
        let b = &blocks[idx];
        let slot = (0..rows_used.len()).find(|&i| {
            rows_used[i] + b.rows <= tile.n_row && cols_used[i] + b.cols <= tile.n_col
        });
        let bi = match slot {
            Some(i) => i,
            None => {
                rows_used.push(0);
                cols_used.push(0);
                rows_used.len() - 1
            }
        };
        placements.push(Placement { block: idx, bin: bi, x: cols_used[bi], y: rows_used[bi] });
        rows_used[bi] += b.rows;
        cols_used[bi] += b.cols;
    }

    rows_used.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::BlockKind;
    use crate::pack::placement::validate;
    use crate::pack::simple;

    fn blk(rows: usize, cols: usize, layer: usize) -> Block {
        Block { rows, cols, layer, replica: 0, grid: (0, 0), kind: BlockKind::Sparse }
    }

    fn paper_items() -> Vec<Block> {
        [
            (257, 256), (257, 256), (257, 256), (129, 256), (129, 128),
            (129, 128), (129, 128), (129, 128), (65, 128), (148, 64),
            (65, 64), (65, 64), (65, 64),
        ]
        .iter()
        .enumerate()
        .map(|(i, &(r, c))| blk(r, c, i))
        .collect()
    }

    #[test]
    fn ffd_dense_demo_two_bins() {
        let p = pack(&paper_items(), Tile::new(512, 512), Discipline::Dense);
        validate(&p).unwrap();
        assert_eq!(p.n_bins, 2);
    }

    #[test]
    fn ffd_pipeline_demo_near_optimum() {
        // exact optimum is 4 (ilp tests); greedy FFD lands at 5 here
        let p = pack(&paper_items(), Tile::new(512, 512), Discipline::Pipeline);
        validate(&p).unwrap();
        assert!((4..=5).contains(&p.n_bins), "bins {}", p.n_bins);
    }

    #[test]
    fn ffd_never_worse_than_next_fit() {
        use crate::frag::fragment_network;
        use crate::nets::zoo;
        let tile = Tile::new(256, 256);
        for net in [zoo::lenet(), zoo::alexnet(), zoo::resnet18()] {
            let blocks = fragment_network(&net, tile);
            for d in [Discipline::Dense, Discipline::Pipeline] {
                let nf = simple::pack(&blocks, tile, d);
                let ff = pack(&blocks, tile, d);
                validate(&ff).unwrap();
                assert!(
                    ff.n_bins <= nf.n_bins,
                    "{} {d}: ffd {} > next-fit {}",
                    net.name,
                    ff.n_bins,
                    nf.n_bins
                );
            }
        }
    }

    #[test]
    fn ffd_dense_respects_column_budget_when_widening() {
        let blocks = vec![blk(30, 10, 0), blk(30, 60, 1), blk(30, 60, 2), blk(5, 40, 3)];
        let p = pack(&blocks, Tile::new(64, 64), Discipline::Dense);
        validate(&p).unwrap();
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(pack(&[], Tile::new(8, 8), Discipline::Dense).n_bins, 0);
        let p = pack(&[blk(8, 8, 0)], Tile::new(8, 8), Discipline::Pipeline);
        assert_eq!(p.n_bins, 1);
        validate(&p).unwrap();
    }
}
