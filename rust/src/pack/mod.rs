//! Packing of fragmented blocks into physical tiles (bins).
//!
//! Two disciplines (paper §2.2):
//! * [`Discipline::Dense`] — shelf packing; blocks may share word/bit lines
//!   across network layers (Fig. 2a/b). Highest density, no pipelining.
//! * [`Discipline::Pipeline`] — staircase packing; blocks in one tile must
//!   share no word line and no bit line (Fig. 2c), enabling simultaneous
//!   operation of all layers.
//!
//! Engines: [`simple`] (the paper's §3 contribution), [`ffd`] (classical
//! first-fit-decreasing baselines), and the exact [`crate::ilp`] solver.
//! All return a [`Packing`] with explicit coordinates checked by
//! [`placement::validate`].

pub mod ffd;
pub mod placement;
pub mod simple;

use crate::geom::{Block, Placement, Tile};

/// Packing discipline (paper Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Discipline {
    Dense,
    Pipeline,
}

impl std::fmt::Display for Discipline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Discipline::Dense => write!(f, "dense"),
            Discipline::Pipeline => write!(f, "pipeline"),
        }
    }
}

/// Result of packing a block set into tiles of one dimension.
#[derive(Debug, Clone)]
pub struct Packing {
    pub tile: Tile,
    pub discipline: Discipline,
    /// the block set, in the order referenced by `placements[].block`
    pub blocks: Vec<Block>,
    pub placements: Vec<Placement>,
    pub n_bins: usize,
}

impl Packing {
    /// Weights stored across all blocks.
    pub fn stored_weights(&self) -> usize {
        self.blocks.iter().map(Block::weights).sum()
    }

    /// Packing efficiency: stored weights / provisioned cross-points.
    /// (Distinct from tile *array efficiency*, which is a circuit-area
    /// property — see paper §4 discussion.)
    pub fn packing_efficiency(&self) -> f64 {
        if self.n_bins == 0 {
            return 0.0;
        }
        self.stored_weights() as f64 / (self.n_bins * self.tile.capacity()) as f64
    }

    /// Blocks grouped by bin, for reports and the simulator.
    pub fn bins(&self) -> Vec<Vec<&Placement>> {
        let mut bins: Vec<Vec<&Placement>> = vec![Vec::new(); self.n_bins];
        for p in &self.placements {
            bins[p.bin].push(p);
        }
        bins
    }

    /// Map layer index -> bins hosting at least one of its blocks.
    pub fn layer_bins(&self, layer: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .placements
            .iter()
            .filter(|p| self.blocks[p.block].layer == layer)
            .map(|p| p.bin)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Block placement order used by the greedy engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SortOrder {
    /// descending row dimension (§2.1's convention; FFD-style, default)
    RowsDesc,
    /// ascending row dimension (§3's literal wording, for ablation)
    RowsAsc,
    /// input order (no sort)
    AsGiven,
}

pub(crate) fn order_blocks(blocks: &[Block], order: SortOrder) -> Vec<Block> {
    let mut v = blocks.to_vec();
    match order {
        SortOrder::AsGiven => {}
        SortOrder::RowsDesc => crate::frag::sort_for_packing(&mut v),
        SortOrder::RowsAsc => {
            crate::frag::sort_for_packing(&mut v);
            v.reverse();
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::BlockKind;

    fn blk(rows: usize, cols: usize, layer: usize) -> Block {
        Block { rows, cols, layer, replica: 0, grid: (0, 0), kind: BlockKind::Sparse }
    }

    #[test]
    fn packing_efficiency_full_bin() {
        let tile = Tile::new(10, 10);
        let blocks = vec![blk(10, 10, 0)];
        let p = Packing {
            tile,
            discipline: Discipline::Dense,
            blocks,
            placements: vec![Placement { block: 0, bin: 0, x: 0, y: 0 }],
            n_bins: 1,
        };
        assert_eq!(p.packing_efficiency(), 1.0);
        assert_eq!(p.stored_weights(), 100);
    }

    #[test]
    fn layer_bins_dedup() {
        let tile = Tile::new(10, 10);
        let blocks = vec![blk(2, 2, 5), blk(2, 2, 5), blk(2, 2, 6)];
        let p = Packing {
            tile,
            discipline: Discipline::Dense,
            blocks,
            placements: vec![
                Placement { block: 0, bin: 0, x: 0, y: 0 },
                Placement { block: 1, bin: 0, x: 2, y: 0 },
                Placement { block: 2, bin: 1, x: 0, y: 0 },
            ],
            n_bins: 2,
        };
        assert_eq!(p.layer_bins(5), vec![0]);
        assert_eq!(p.layer_bins(6), vec![1]);
        assert!(p.layer_bins(7).is_empty());
        assert_eq!(p.bins().len(), 2);
        assert_eq!(p.bins()[0].len(), 2);
    }

    #[test]
    fn order_blocks_modes() {
        let blocks = vec![blk(1, 1, 0), blk(9, 1, 1), blk(5, 1, 2)];
        let asc = order_blocks(&blocks, SortOrder::RowsAsc);
        assert_eq!(asc.iter().map(|b| b.rows).collect::<Vec<_>>(), vec![1, 5, 9]);
        let desc = order_blocks(&blocks, SortOrder::RowsDesc);
        assert_eq!(desc.iter().map(|b| b.rows).collect::<Vec<_>>(), vec![9, 5, 1]);
        let given = order_blocks(&blocks, SortOrder::AsGiven);
        assert_eq!(given.iter().map(|b| b.rows).collect::<Vec<_>>(), vec![1, 9, 5]);
    }
}
