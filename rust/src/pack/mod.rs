//! Packing of fragmented blocks into physical tiles (bins).
//!
//! Two disciplines (paper §2.2):
//! * [`Discipline::Dense`] — shelf packing; blocks may share word/bit lines
//!   across network layers (Fig. 2a/b). Highest density, no pipelining.
//! * [`Discipline::Pipeline`] — staircase packing; blocks in one tile must
//!   share no word line and no bit line (Fig. 2c), enabling simultaneous
//!   operation of all layers.
//!
//! Engines: [`simple`] (the paper's §3 contribution), [`ffd`] (classical
//! first-fit-decreasing baselines), and the exact [`crate::ilp`] solver.
//! All return a [`Packing`] with explicit coordinates checked by
//! [`placement::validate`].

pub mod counted;
pub mod ffd;
pub mod placement;
pub mod simple;

use crate::geom::{Block, Placement, Tile};

/// Packing discipline (paper Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Discipline {
    /// shelf packing; blocks may share word/bit lines across layers
    /// (Fig. 2a/b) — highest density, no pipelining
    Dense,
    /// staircase packing; blocks in one tile share no word line and no
    /// bit line (Fig. 2c), enabling simultaneous operation of all layers
    Pipeline,
}

impl Discipline {
    /// Canonical wire/CLI token; [`std::fmt::Display`] and
    /// [`std::str::FromStr`] round-trip through it.
    pub fn canonical(&self) -> &'static str {
        match self {
            Discipline::Dense => "dense",
            Discipline::Pipeline => "pipeline",
        }
    }
}

impl std::fmt::Display for Discipline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.canonical())
    }
}

impl std::str::FromStr for Discipline {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "dense" => Ok(Discipline::Dense),
            "pipeline" => Ok(Discipline::Pipeline),
            _ => Err(format!("discipline must be dense|pipeline, got '{s}'")),
        }
    }
}

/// Result of packing a block set into tiles of one dimension.
#[derive(Debug, Clone)]
pub struct Packing {
    /// the tile (bin) dimension everything was packed into
    pub tile: Tile,
    /// the discipline the engine enforced
    pub discipline: Discipline,
    /// the block set, in the order referenced by `placements[].block`
    pub blocks: Vec<Block>,
    /// one explicit coordinate per block ([`placement::validate`] checks
    /// bounds, overlap, and the discipline's line-sharing rules)
    pub placements: Vec<Placement>,
    /// number of tiles (bins) used
    pub n_bins: usize,
}

impl Packing {
    /// Weights stored across all blocks.
    pub fn stored_weights(&self) -> usize {
        self.blocks.iter().map(Block::weights).sum()
    }

    /// Packing efficiency: stored weights / provisioned cross-points.
    /// (Distinct from tile *array efficiency*, which is a circuit-area
    /// property — see paper §4 discussion.)
    pub fn packing_efficiency(&self) -> f64 {
        packing_efficiency(self.stored_weights(), self.n_bins, self.tile.capacity())
    }

    /// Blocks grouped by bin, for reports and the simulator.
    pub fn bins(&self) -> Vec<Vec<&Placement>> {
        let mut bins: Vec<Vec<&Placement>> = vec![Vec::new(); self.n_bins];
        for p in &self.placements {
            bins[p.bin].push(p);
        }
        bins
    }

    /// Map layer index -> bins hosting at least one of its blocks.
    pub fn layer_bins(&self, layer: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .placements
            .iter()
            .filter(|p| self.blocks[p.block].layer == layer)
            .map(|p| p.bin)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// [`Packing::layer_bins`] for every layer `0..n_layers` in one pass
    /// over the placements — O(placements + layers) instead of the
    /// O(layers x placements) of calling `layer_bins` per layer (the
    /// simulator's per-layer scan used to be quadratic at network scale).
    /// Blocks tagged with a layer `>= n_layers` are ignored, matching the
    /// per-layer queries.
    pub fn layer_bins_map(&self, n_layers: usize) -> Vec<Vec<usize>> {
        let mut map: Vec<Vec<usize>> = vec![Vec::new(); n_layers];
        for p in &self.placements {
            let l = self.blocks[p.block].layer;
            if l < n_layers {
                map[l].push(p.bin);
            }
        }
        for v in &mut map {
            v.sort_unstable();
            v.dedup();
        }
        map
    }
}

/// Block placement order used by the greedy engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SortOrder {
    /// descending row dimension (§2.1's convention; FFD-style, default)
    RowsDesc,
    /// ascending row dimension (§3's literal wording, for ablation)
    RowsAsc,
    /// input order (no sort)
    AsGiven,
}

impl SortOrder {
    /// Canonical wire/CLI token; `Display`/`FromStr` round-trip through it.
    pub fn canonical(&self) -> &'static str {
        match self {
            SortOrder::RowsDesc => "rows-desc",
            SortOrder::RowsAsc => "rows-asc",
            SortOrder::AsGiven => "as-given",
        }
    }
}

impl Default for SortOrder {
    fn default() -> Self {
        SortOrder::RowsDesc
    }
}

impl std::fmt::Display for SortOrder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.canonical())
    }
}

impl std::str::FromStr for SortOrder {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "rows-desc" => Ok(SortOrder::RowsDesc),
            "rows-asc" => Ok(SortOrder::RowsAsc),
            "as-given" => Ok(SortOrder::AsGiven),
            _ => Err(format!("sort order must be rows-desc|rows-asc|as-given, got '{s}'")),
        }
    }
}

/// Reusable buffers for the allocation-lean packing path. One instance per
/// sweep worker amortizes the permutation/placement/bin-state allocations
/// across every grid point the worker evaluates (EXPERIMENTS.md §Perf #1);
/// the block slice itself is only ever borrowed, never cloned.
#[derive(Debug, Default)]
pub struct PackScratch {
    /// index permutation into the borrowed block slice
    pub(crate) perm: Vec<u32>,
    /// placements produced by the last `pack_into` call
    /// (`Placement::block` indexes the original, un-sorted slice)
    pub placements: Vec<Placement>,
    /// per-bin word-line budget (pipeline engines)
    pub(crate) bin_rows: Vec<usize>,
    /// per-bin bit-line budget (pipeline engines)
    pub(crate) bin_cols: Vec<usize>,
}

impl PackScratch {
    /// Empty scratch; buffers grow on first use and are reused after.
    pub fn new() -> PackScratch {
        PackScratch::default()
    }
}

/// Fill `out` with the placement order as an index permutation into
/// `blocks`, without cloning or reordering the blocks themselves. Uses the
/// same key as [`crate::frag::sort_for_packing`] (provenance tie-breaks,
/// then original index via the stable sort), so results are deterministic.
pub(crate) fn order_indices(blocks: &[Block], order: SortOrder, out: &mut Vec<u32>) {
    debug_assert!(blocks.len() <= u32::MAX as usize);
    out.clear();
    out.extend(0..blocks.len() as u32);
    match order {
        SortOrder::AsGiven => {}
        SortOrder::RowsDesc => sort_indices_desc(blocks, out),
        SortOrder::RowsAsc => {
            // mirror the old owned-block behavior exactly: sort descending,
            // then reverse (equal keys end up reversed too)
            sort_indices_desc(blocks, out);
            out.reverse();
        }
    }
}

fn sort_indices_desc(blocks: &[Block], idx: &mut [u32]) {
    idx.sort_by(|&ia, &ib| {
        let (a, b) = (&blocks[ia as usize], &blocks[ib as usize]);
        b.rows
            .cmp(&a.rows)
            .then(b.cols.cmp(&a.cols))
            .then(a.layer.cmp(&b.layer))
            .then(a.replica.cmp(&b.replica))
            .then(a.grid.cmp(&b.grid))
    });
}

/// Packing-efficiency formula, defined once so the owned
/// ([`Packing::packing_efficiency`]) and allocation-lean
/// ([`crate::opt`] sweep) paths agree bit for bit.
pub fn packing_efficiency(stored_weights: usize, n_bins: usize, capacity: usize) -> f64 {
    if n_bins == 0 {
        return 0.0;
    }
    stored_weights as f64 / (n_bins * capacity) as f64
}

pub(crate) fn assert_blocks_fit(blocks: &[Block], tile: Tile) {
    for b in blocks {
        assert!(
            tile.fits(b.rows, b.cols),
            "block {b:?} larger than tile {tile}: fragment with this tile first"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::BlockKind;

    fn blk(rows: usize, cols: usize, layer: usize) -> Block {
        Block { rows, cols, layer, replica: 0, grid: (0, 0), kind: BlockKind::Sparse }
    }

    #[test]
    fn packing_efficiency_full_bin() {
        let tile = Tile::new(10, 10);
        let blocks = vec![blk(10, 10, 0)];
        let p = Packing {
            tile,
            discipline: Discipline::Dense,
            blocks,
            placements: vec![Placement { block: 0, bin: 0, x: 0, y: 0 }],
            n_bins: 1,
        };
        assert_eq!(p.packing_efficiency(), 1.0);
        assert_eq!(p.stored_weights(), 100);
    }

    #[test]
    fn layer_bins_dedup() {
        let tile = Tile::new(10, 10);
        let blocks = vec![blk(2, 2, 5), blk(2, 2, 5), blk(2, 2, 6)];
        let p = Packing {
            tile,
            discipline: Discipline::Dense,
            blocks,
            placements: vec![
                Placement { block: 0, bin: 0, x: 0, y: 0 },
                Placement { block: 1, bin: 0, x: 2, y: 0 },
                Placement { block: 2, bin: 1, x: 0, y: 0 },
            ],
            n_bins: 2,
        };
        assert_eq!(p.layer_bins(5), vec![0]);
        assert_eq!(p.layer_bins(6), vec![1]);
        assert!(p.layer_bins(7).is_empty());
        assert_eq!(p.bins().len(), 2);
        assert_eq!(p.bins()[0].len(), 2);
        // the one-pass map agrees with the per-layer queries
        let map = p.layer_bins_map(8);
        assert_eq!(map.len(), 8);
        for l in 0..8 {
            assert_eq!(map[l], p.layer_bins(l), "layer {l}");
        }
    }

    #[test]
    fn order_indices_modes() {
        let blocks = vec![blk(1, 1, 0), blk(9, 1, 1), blk(5, 1, 2)];
        let rows_in = |perm: &[u32]| -> Vec<usize> {
            perm.iter().map(|&i| blocks[i as usize].rows).collect()
        };
        let mut perm = Vec::new();
        order_indices(&blocks, SortOrder::RowsAsc, &mut perm);
        assert_eq!(rows_in(&perm), vec![1, 5, 9]);
        order_indices(&blocks, SortOrder::RowsDesc, &mut perm);
        assert_eq!(rows_in(&perm), vec![9, 5, 1]);
        order_indices(&blocks, SortOrder::AsGiven, &mut perm);
        assert_eq!(rows_in(&perm), vec![1, 9, 5]);
    }

    #[test]
    fn discipline_and_sort_order_roundtrip() {
        for d in [Discipline::Dense, Discipline::Pipeline] {
            assert_eq!(d.to_string().parse::<Discipline>().unwrap(), d);
        }
        for o in [SortOrder::RowsDesc, SortOrder::RowsAsc, SortOrder::AsGiven] {
            assert_eq!(o.to_string().parse::<SortOrder>().unwrap(), o);
        }
        assert!("fancy".parse::<Discipline>().is_err());
        assert!("rows".parse::<SortOrder>().is_err());
    }

    #[test]
    fn order_indices_matches_owned_sort() {
        // the permutation must visit blocks in exactly the order the old
        // owned-block sort produced
        let blocks: Vec<Block> = (0..20)
            .map(|i| blk(1 + (i * 7) % 13, 1 + (i * 5) % 11, i))
            .collect();
        let mut owned = blocks.clone();
        crate::frag::sort_for_packing(&mut owned);
        let mut perm = Vec::new();
        order_indices(&blocks, SortOrder::RowsDesc, &mut perm);
        let via_perm: Vec<Block> = perm.iter().map(|&i| blocks[i as usize]).collect();
        assert_eq!(via_perm, owned);
    }
}
