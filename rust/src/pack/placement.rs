//! Geometric validation of packings.
//!
//! Every packing engine's output is checked against the physical rules the
//! paper's Fig. 2 imposes:
//!
//! * every block placed exactly once, inside tile bounds;
//! * no two blocks in a bin overlap geometrically;
//! * **pipeline** additionally: no two blocks in a bin share any word line
//!   (row span) or any bit line (column span) — the Fig. 2c condition that
//!   makes simultaneous layer operation possible.

use super::{Discipline, Packing};
use crate::geom::Span;

/// Validate a packing; returns a descriptive error on the first violation.
pub fn validate(p: &Packing) -> Result<(), String> {
    // every block exactly once
    let mut seen = vec![false; p.blocks.len()];
    for pl in &p.placements {
        if pl.block >= p.blocks.len() {
            return Err(format!("placement references unknown block {}", pl.block));
        }
        if seen[pl.block] {
            return Err(format!("block {} placed twice", pl.block));
        }
        seen[pl.block] = true;
        if pl.bin >= p.n_bins {
            return Err(format!("placement bin {} out of range ({})", pl.bin, p.n_bins));
        }
    }
    if let Some(missing) = seen.iter().position(|s| !s) {
        return Err(format!("block {missing} not placed"));
    }

    // bounds
    for pl in &p.placements {
        let b = &p.blocks[pl.block];
        if pl.y + b.rows > p.tile.n_row || pl.x + b.cols > p.tile.n_col {
            return Err(format!(
                "block {} ({}x{}) at ({},{}) exceeds tile {}",
                pl.block, b.rows, b.cols, pl.x, pl.y, p.tile
            ));
        }
    }

    // per-bin pairwise checks
    let mut by_bin: Vec<Vec<usize>> = vec![Vec::new(); p.n_bins];
    for (i, pl) in p.placements.iter().enumerate() {
        by_bin[pl.bin].push(i);
    }
    for bin in &by_bin {
        for (ai, &a) in bin.iter().enumerate() {
            for &b in &bin[ai + 1..] {
                let (pa, pb) = (&p.placements[a], &p.placements[b]);
                let (ba, bb) = (&p.blocks[pa.block], &p.blocks[pb.block]);
                let rows_a = Span::new(pa.y, ba.rows);
                let rows_b = Span::new(pb.y, bb.rows);
                let cols_a = Span::new(pa.x, ba.cols);
                let cols_b = Span::new(pb.x, bb.cols);
                let row_overlap = rows_a.overlaps(&rows_b);
                let col_overlap = cols_a.overlaps(&cols_b);
                if row_overlap && col_overlap {
                    return Err(format!(
                        "blocks {} and {} overlap in bin {}",
                        pa.block, pb.block, pa.bin
                    ));
                }
                if p.discipline == Discipline::Pipeline && (row_overlap || col_overlap) {
                    return Err(format!(
                        "pipeline violation: blocks {} and {} share {} lines in bin {}",
                        pa.block,
                        pb.block,
                        if row_overlap { "word (input)" } else { "bit (output)" },
                        pa.bin
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Count of used bins that actually host at least one block (diagnostic —
/// engines should not report empty bins).
pub fn occupied_bins(p: &Packing) -> usize {
    let mut used = vec![false; p.n_bins];
    for pl in &p.placements {
        used[pl.bin] = true;
    }
    used.iter().filter(|u| **u).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::{Block, BlockKind, Placement, Tile};

    fn blk(rows: usize, cols: usize, layer: usize) -> Block {
        Block { rows, cols, layer, replica: 0, grid: (0, 0), kind: BlockKind::Sparse }
    }

    fn packing(
        discipline: Discipline,
        blocks: Vec<Block>,
        placements: Vec<Placement>,
        n_bins: usize,
    ) -> Packing {
        Packing { tile: Tile::new(10, 10), discipline, blocks, placements, n_bins }
    }

    #[test]
    fn valid_dense_shelf_accepted() {
        let p = packing(
            Discipline::Dense,
            vec![blk(5, 4, 0), blk(5, 4, 1), blk(10, 6, 2)],
            vec![
                Placement { block: 0, bin: 0, x: 0, y: 0 },
                Placement { block: 1, bin: 0, x: 0, y: 5 },
                Placement { block: 2, bin: 0, x: 4, y: 0 },
            ],
            1,
        );
        validate(&p).unwrap();
        assert_eq!(occupied_bins(&p), 1);
    }

    #[test]
    fn overlap_rejected() {
        let p = packing(
            Discipline::Dense,
            vec![blk(5, 5, 0), blk(5, 5, 1)],
            vec![
                Placement { block: 0, bin: 0, x: 0, y: 0 },
                Placement { block: 1, bin: 0, x: 4, y: 4 },
            ],
            1,
        );
        assert!(validate(&p).unwrap_err().contains("overlap"));
    }

    #[test]
    fn shared_rows_ok_dense_fatal_pipeline() {
        let blocks = vec![blk(5, 5, 0), blk(5, 5, 1)];
        let placements = vec![
            Placement { block: 0, bin: 0, x: 0, y: 0 },
            Placement { block: 1, bin: 0, x: 5, y: 0 }, // same rows, distinct cols
        ];
        let dense = packing(Discipline::Dense, blocks.clone(), placements.clone(), 1);
        validate(&dense).unwrap();
        let pipe = packing(Discipline::Pipeline, blocks, placements, 1);
        let err = validate(&pipe).unwrap_err();
        assert!(err.contains("word (input)"), "{err}");
    }

    #[test]
    fn shared_cols_fatal_pipeline() {
        let blocks = vec![blk(5, 5, 0), blk(5, 5, 1)];
        let placements = vec![
            Placement { block: 0, bin: 0, x: 0, y: 0 },
            Placement { block: 1, bin: 0, x: 0, y: 5 }, // same cols, distinct rows
        ];
        let pipe = packing(Discipline::Pipeline, blocks, placements, 1);
        assert!(validate(&pipe).unwrap_err().contains("bit (output)"));
    }

    #[test]
    fn staircase_accepted_pipeline() {
        let p = packing(
            Discipline::Pipeline,
            vec![blk(4, 4, 0), blk(4, 4, 1)],
            vec![
                Placement { block: 0, bin: 0, x: 0, y: 0 },
                Placement { block: 1, bin: 0, x: 4, y: 4 },
            ],
            1,
        );
        validate(&p).unwrap();
    }

    #[test]
    fn out_of_bounds_rejected() {
        let p = packing(
            Discipline::Dense,
            vec![blk(5, 5, 0)],
            vec![Placement { block: 0, bin: 0, x: 6, y: 0 }],
            1,
        );
        assert!(validate(&p).unwrap_err().contains("exceeds tile"));
    }

    #[test]
    fn unplaced_and_double_placed_rejected() {
        let p = packing(Discipline::Dense, vec![blk(1, 1, 0)], vec![], 0);
        assert!(validate(&p).unwrap_err().contains("not placed"));
        let p = packing(
            Discipline::Dense,
            vec![blk(1, 1, 0)],
            vec![
                Placement { block: 0, bin: 0, x: 0, y: 0 },
                Placement { block: 0, bin: 0, x: 2, y: 2 },
            ],
            1,
        );
        assert!(validate(&p).unwrap_err().contains("twice"));
    }

    #[test]
    fn bad_bin_index_rejected() {
        let p = packing(
            Discipline::Dense,
            vec![blk(1, 1, 0)],
            vec![Placement { block: 0, bin: 3, x: 0, y: 0 }],
            1,
        );
        assert!(validate(&p).unwrap_err().contains("out of range"));
    }
}
