//! The paper's simple packing algorithm (§3).
//!
//! Blocks are sorted by row dimension and placed in sequence:
//!
//! * **dense** — next-fit shelf: the first block starts a shelf in the
//!   lower-left corner of the first tile; subsequent blocks stack along the
//!   word-line (row) direction while `Σ rows <= n_row` (Eq. 6c). When a
//!   block does not fit, a new shelf opens to the right of the previous one
//!   (shelf width = widest member, `Σ widths <= n_col`, Eq. 6d); when no
//!   shelf fits, a new tile opens. This reproduces the layered structure of
//!   paper Fig. 5.
//! * **pipeline** — next-fit staircase: blocks are placed corner-to-corner
//!   along the tile diagonal so no two blocks share a word line or a bit
//!   line (Fig. 2c); a block that would exceed either `Σ rows <= n_row` or
//!   `Σ cols <= n_col` (Eq. 7c/7d) opens a new tile. This reproduces the
//!   staircase structure of paper Fig. 6.

use super::{order_indices, Discipline, PackScratch, Packing, SortOrder};
use crate::geom::{Block, Placement, Tile};

/// Pack with the paper's defaults (descending row order).
///
/// Engine internal of the [`crate::plan`] front door — build a
/// [`crate::plan::MapRequest`] instead of calling engines directly.
#[doc(hidden)]
pub fn pack(blocks: &[Block], tile: Tile, discipline: Discipline) -> Packing {
    pack_ordered(blocks, tile, discipline, SortOrder::RowsDesc)
}

/// Pack with an explicit placement order (ablation hook).
#[doc(hidden)]
pub fn pack_ordered(
    blocks: &[Block],
    tile: Tile,
    discipline: Discipline,
    order: SortOrder,
) -> Packing {
    let mut scratch = PackScratch::default();
    let n_bins = pack_into(blocks, tile, discipline, order, &mut scratch);
    Packing {
        tile,
        discipline,
        blocks: blocks.to_vec(),
        placements: std::mem::take(&mut scratch.placements),
        n_bins,
    }
}

/// Allocation-lean core shared by [`pack`] and the sweep hot path: the block
/// slice is only borrowed (placement order is an index permutation held in
/// `scratch`), placements land in `scratch.placements` with
/// [`Placement::block`] indexing the original slice, and the bin count is
/// returned. After the scratch buffers warm up, evaluating a new tile
/// configuration allocates nothing on this path.
pub fn pack_into(
    blocks: &[Block],
    tile: Tile,
    discipline: Discipline,
    order: SortOrder,
    scratch: &mut PackScratch,
) -> usize {
    super::assert_blocks_fit(blocks, tile);
    let PackScratch { perm, placements, .. } = scratch;
    order_indices(blocks, order, perm);
    placements.clear();
    placements.reserve(blocks.len());
    match discipline {
        Discipline::Dense => dense_next_fit(blocks, perm, tile, placements),
        Discipline::Pipeline => pipeline_next_fit(blocks, perm, tile, placements),
    }
}

/// Dense next-fit shelf packing (see module docs).
fn dense_next_fit(
    blocks: &[Block],
    perm: &[u32],
    tile: Tile,
    placements: &mut Vec<Placement>,
) -> usize {
    let mut n_bins = 0usize;

    // Current shelf state within the current bin.
    let mut shelf_x = 0usize; // column offset of current shelf
    let mut shelf_width = 0usize; // widest member of current shelf
    let mut shelf_fill = 0usize; // rows used in current shelf

    for &oi in perm {
        let idx = oi as usize;
        let b = &blocks[idx];
        if n_bins == 0 {
            n_bins = 1;
        }
        // 1) try current shelf: must fit in rows and not widen the shelf
        //    beyond the bin's remaining column budget.
        let widened = shelf_width.max(b.cols);
        if shelf_fill > 0 && shelf_fill + b.rows <= tile.n_row && shelf_x + widened <= tile.n_col
        {
            placements.push(Placement { block: idx, bin: n_bins - 1, x: shelf_x, y: shelf_fill });
            shelf_fill += b.rows;
            shelf_width = widened;
            continue;
        }
        // 2) open a new shelf to the right (next-fit: never revisit old shelves)
        let next_x = shelf_x + shelf_width;
        if shelf_fill > 0 && next_x + b.cols <= tile.n_col {
            shelf_x = next_x;
            shelf_width = b.cols;
            shelf_fill = b.rows;
            placements.push(Placement { block: idx, bin: n_bins - 1, x: shelf_x, y: 0 });
            continue;
        }
        // 3) open a new bin (or place the very first block)
        if shelf_fill > 0 {
            n_bins += 1;
        }
        shelf_x = 0;
        shelf_width = b.cols;
        shelf_fill = b.rows;
        placements.push(Placement { block: idx, bin: n_bins - 1, x: 0, y: 0 });
    }

    n_bins
}

/// Pipeline next-fit staircase packing (see module docs).
fn pipeline_next_fit(
    blocks: &[Block],
    perm: &[u32],
    tile: Tile,
    placements: &mut Vec<Placement>,
) -> usize {
    let mut n_bins = 0usize;
    let mut row_used = 0usize;
    let mut col_used = 0usize;

    for &oi in perm {
        let idx = oi as usize;
        let b = &blocks[idx];
        let fits = row_used + b.rows <= tile.n_row && col_used + b.cols <= tile.n_col;
        if n_bins == 0 || !fits {
            n_bins += 1;
            row_used = 0;
            col_used = 0;
        }
        placements.push(Placement { block: idx, bin: n_bins - 1, x: col_used, y: row_used });
        row_used += b.rows;
        col_used += b.cols;
    }

    n_bins
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::BlockKind;
    use crate::pack::placement::validate;

    fn blk(rows: usize, cols: usize, layer: usize) -> Block {
        Block { rows, cols, layer, replica: 0, grid: (0, 0), kind: BlockKind::Sparse }
    }

    /// The paper's 13-item demo list (Eq. 7 text), layers tagged by index.
    pub fn paper_items() -> Vec<Block> {
        [
            (257, 256),
            (257, 256),
            (257, 256),
            (129, 256),
            (129, 128),
            (129, 128),
            (129, 128),
            (129, 128),
            (65, 128),
            (148, 64),
            (65, 64),
            (65, 64),
            (65, 64),
        ]
        .iter()
        .enumerate()
        .map(|(i, &(r, c))| blk(r, c, i))
        .collect()
    }

    #[test]
    fn dense_demo_within_one_of_optimum() {
        // Paper Table 3 / Fig. 5: the binary-linear-optimization optimum for
        // the demo list is 2 bins (asserted in the ilp tests). The greedy
        // next-fit simple algorithm lands within one bin of it — the
        // "good correlation, not equality" of paper Fig. 7.
        let p = pack(&paper_items(), Tile::new(512, 512), Discipline::Dense);
        validate(&p).unwrap();
        assert_eq!(p.n_bins, 3, "placements: {:?}", p.placements);
    }

    #[test]
    fn pipeline_demo_within_one_of_optimum() {
        // Paper Table 5 / Fig. 6: pipeline optimum is 4 bins; next-fit
        // staircase uses 6 (it cannot revisit earlier bins).
        let p = pack(&paper_items(), Tile::new(512, 512), Discipline::Pipeline);
        validate(&p).unwrap();
        assert_eq!(p.n_bins, 6, "placements: {:?}", p.placements);
    }

    #[test]
    fn single_block_single_bin() {
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let p = pack(&[blk(10, 10, 0)], Tile::new(64, 64), d);
            assert_eq!(p.n_bins, 1);
            assert_eq!(p.placements[0], Placement { block: 0, bin: 0, x: 0, y: 0 });
        }
    }

    #[test]
    fn empty_input_zero_bins() {
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let p = pack(&[], Tile::new(64, 64), d);
            assert_eq!(p.n_bins, 0);
            assert!(p.placements.is_empty());
        }
    }

    #[test]
    fn full_blocks_one_bin_each() {
        let blocks = vec![blk(64, 64, 0), blk(64, 64, 1), blk(64, 64, 2)];
        for d in [Discipline::Dense, Discipline::Pipeline] {
            let p = pack(&blocks, Tile::new(64, 64), d);
            validate(&p).unwrap();
            assert_eq!(p.n_bins, 3, "{d}");
        }
    }

    #[test]
    fn pipeline_uses_at_least_dense_bins() {
        let blocks = paper_items();
        let tile = Tile::new(512, 512);
        let dense = pack(&blocks, tile, Discipline::Dense);
        let pipe = pack(&blocks, tile, Discipline::Pipeline);
        assert!(pipe.n_bins >= dense.n_bins);
    }

    #[test]
    #[should_panic(expected = "larger than tile")]
    fn oversized_block_rejected() {
        pack(&[blk(100, 1, 0)], Tile::new(64, 64), Discipline::Dense);
    }

    #[test]
    fn dense_shelves_never_overlap_even_with_mixed_widths() {
        // regression: a wide block joining a narrow shelf must account for
        // the shelf's widened footprint against the column budget
        let blocks = vec![blk(30, 10, 0), blk(30, 60, 1), blk(30, 60, 2), blk(5, 40, 3)];
        let p = pack_ordered(&blocks, Tile::new(64, 64), Discipline::Dense, SortOrder::AsGiven);
        validate(&p).unwrap();
    }

    #[test]
    fn ascending_order_ablation_still_valid() {
        let p = pack_ordered(
            &paper_items(),
            Tile::new(512, 512),
            Discipline::Dense,
            SortOrder::RowsAsc,
        );
        validate(&p).unwrap();
        // ascending order wastes shelves; expect >= the optimum's bins
        assert!(p.n_bins >= 2);
    }

    #[test]
    fn packing_efficiency_in_unit_interval() {
        let p = pack(&paper_items(), Tile::new(512, 512), Discipline::Dense);
        let e = p.packing_efficiency();
        assert!(e > 0.0 && e <= 1.0, "efficiency {e}");
    }
}
