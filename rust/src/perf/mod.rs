//! Latency and throughput models (paper Eq. 3–4) and the RAPA replication
//! planner (Fig. 3).

pub mod rapa;

use crate::nets::Network;

/// Timing parameters (seconds). The tile time is dominated by bit-line
/// integration (t_tile ≈ t_int, §2); digital post-processing and inter-tile
/// communication are modelled as lump terms exactly as in Eq. 3/4.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingModel {
    /// per-tile execution (integration) time
    pub t_tile: f64,
    /// additional digital processing per inference
    pub t_dig: f64,
    /// inter-tile communication per inference
    pub t_com: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        // 100 ns integration (typical for PCM/ReRAM readout), communication
        // and digital lumps well hidden below it.
        TimingModel { t_tile: 100e-9, t_dig: 20e-9, t_com: 20e-9 }
    }
}

/// Execution style for the latency model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Execution {
    /// one layer at a time, signal traverses all layers (Eq. 3)
    Sequential,
    /// all layers active simultaneously, staged on the slowest (Eq. 4)
    Pipelined,
}

/// Effective per-layer reuse after replication: ceil(N_reuse / N_rapa).
pub fn effective_reuse(net: &Network, replication: &[usize]) -> Vec<usize> {
    assert_eq!(replication.len(), net.n_layers(), "replication arity");
    net.layers
        .iter()
        .zip(replication)
        .map(|(l, &r)| l.reuse().div_ceil(r.max(1)))
        .collect()
}

/// Latency of one inference (seconds) under the paper's model.
pub fn latency(
    net: &Network,
    replication: &[usize],
    timing: &TimingModel,
    exec: Execution,
) -> f64 {
    let reuse = effective_reuse(net, replication);
    match exec {
        Execution::Sequential => {
            // Eq. 3: t = t_tile * Σ_k N_reuse^k + t_dig + t_com
            timing.t_tile * reuse.iter().sum::<usize>() as f64 + timing.t_dig + timing.t_com
        }
        Execution::Pipelined => {
            // Eq. 4: t = max(t_tile * N_reuse^max, t_com, t_dig)
            let slowest = reuse.iter().copied().max().unwrap_or(0) as f64;
            (timing.t_tile * slowest).max(timing.t_com).max(timing.t_dig)
        }
    }
}

/// Steady-state throughput (inferences/second).
///
/// Sequential execution admits one inference per full latency; a pipeline
/// accepts a new inference every pipeline beat (its Eq. 4 latency).
pub fn throughput(
    net: &Network,
    replication: &[usize],
    timing: &TimingModel,
    exec: Execution,
) -> f64 {
    1.0 / latency(net, replication, timing, exec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{zoo, Layer, Network};

    fn fc_net(n: usize) -> Network {
        Network::new(
            "fc",
            "t",
            (0..n).map(|i| Layer::fc(&format!("l{i}"), 64, 64)).collect(),
        )
    }

    #[test]
    fn fc_sequential_latency_is_nl_tiles() {
        // Eq. 3 with N_reuse == 1 for all k: t = N_L * t_tile + t_dig + t_com
        let net = fc_net(5);
        let tm = TimingModel { t_tile: 100e-9, t_dig: 7e-9, t_com: 3e-9 };
        let t = latency(&net, &vec![1; 5], &tm, Execution::Sequential);
        assert!((t - (5.0 * 100e-9 + 10e-9)).abs() < 1e-15);
    }

    #[test]
    fn fc_pipeline_latency_is_single_tile() {
        let net = fc_net(5);
        let tm = TimingModel::default();
        let t = latency(&net, &vec![1; 5], &tm, Execution::Pipelined);
        assert!((t - 100e-9).abs() < 1e-15);
    }

    #[test]
    fn pipeline_floor_is_communication() {
        let net = fc_net(2);
        let tm = TimingModel { t_tile: 1e-9, t_dig: 0.0, t_com: 50e-9 };
        let t = latency(&net, &vec![1; 2], &tm, Execution::Pipelined);
        assert_eq!(t, 50e-9);
    }

    #[test]
    fn cnn_pipeline_dominated_by_first_layer_reuse() {
        // §2: "most of the execution time is spent in the first layers"
        let net = zoo::resnet18();
        let tm = TimingModel::default();
        let ones = vec![1; net.n_layers()];
        let t = latency(&net, &ones, &tm, Execution::Pipelined);
        assert!((t - tm.t_tile * net.max_reuse() as f64).abs() < 1e-12);
        assert_eq!(net.max_reuse(), 12544); // conv1 on 224²
    }

    #[test]
    fn rapa_replication_cuts_pipeline_latency() {
        let net = zoo::resnet18();
        let tm = TimingModel::default();
        let ones = vec![1; net.n_layers()];
        let base = latency(&net, &ones, &tm, Execution::Pipelined);
        let plan = rapa::plan_balanced(&net, 128);
        let accel = latency(&net, &plan, &tm, Execution::Pipelined);
        let speedup = base / accel;
        // paper Fig. 9: RAPA 128/4 gives ~100x throughput improvement
        assert!(
            (50.0..=128.0).contains(&speedup),
            "RAPA speedup {speedup} outside expected band"
        );
    }

    #[test]
    fn effective_reuse_ceils() {
        let net = fc_net(1);
        let mut n2 = net.clone();
        n2.layers[0].reuse_override = Some(10);
        assert_eq!(effective_reuse(&n2, &[3]), vec![4]); // ceil(10/3)
        assert_eq!(effective_reuse(&n2, &[1]), vec![10]);
    }

    #[test]
    fn throughput_is_reciprocal() {
        let net = fc_net(3);
        let tm = TimingModel::default();
        let lat = latency(&net, &vec![1; 3], &tm, Execution::Pipelined);
        let thr = throughput(&net, &vec![1; 3], &tm, Execution::Pipelined);
        assert!((thr * lat - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sequential_slower_than_pipeline() {
        let net = zoo::alexnet();
        let tm = TimingModel::default();
        let ones = vec![1; net.n_layers()];
        assert!(
            latency(&net, &ones, &tm, Execution::Sequential)
                > latency(&net, &ones, &tm, Execution::Pipelined)
        );
    }
}
