//! RAPA (Replicated Arrays with Permuted Assignment) planning — Fig. 3.
//!
//! Replicating a layer's weight matrix N_rapa times lets N_rapa columns of
//! its im2col input matrix be processed in parallel, dividing the layer's
//! effective reuse by N_rapa.  The planner chooses per-layer factors so
//! that the computational load `ceil(N_reuse / N_rapa)` is similar across
//! the network ("load balance... otherwise the slowest layer will be the
//! performance bottleneck").

use crate::nets::{LayerKind, Network};

/// The paper's "n0/f" notation (e.g. 128/4 for ResNet): the first layer
/// gets `n0`, and the factor divides by `f` every time the spatial
/// resolution drops (each CNN stage), clamped to >= 1. FC layers get 1.
pub fn plan_geometric(net: &Network, n0: usize, f: usize) -> Vec<usize> {
    assert!(n0 >= 1 && f >= 1);
    let mut out = Vec::with_capacity(net.n_layers());
    let mut current = n0;
    let mut last_out_size: Option<usize> = None;
    for layer in &net.layers {
        match layer.kind {
            LayerKind::Fc { .. } => out.push(1),
            LayerKind::Conv { .. } => {
                let o = layer.out_size().unwrap();
                if let Some(prev) = last_out_size {
                    if o < prev {
                        current = (current / f).max(1);
                    }
                }
                last_out_size = Some(o);
                out.push(current.max(1));
            }
        }
    }
    out
}

/// Load-balanced plan: replicate each layer proportionally to its reuse so
/// every layer's effective reuse matches the first layer's after `n0`-fold
/// replication. `r_l = clamp(round(reuse_l * n0 / reuse_max), 1, n0)`.
pub fn plan_balanced(net: &Network, n0: usize) -> Vec<usize> {
    assert!(n0 >= 1);
    let reuse_max = net.max_reuse().max(1);
    net.layers
        .iter()
        .map(|l| {
            let r = (l.reuse() * n0 + reuse_max / 2) / reuse_max;
            r.clamp(1, n0)
        })
        .collect()
}

/// Uniform replication (BERT's "replicate by the sequence length S").
pub fn plan_uniform(net: &Network, s: usize) -> Vec<usize> {
    vec![s.max(1); net.n_layers()]
}

/// Total weight inflation factor of a plan (area cost of replication).
pub fn weight_inflation(net: &Network, plan: &[usize]) -> f64 {
    assert_eq!(plan.len(), net.n_layers());
    let base: usize = net.total_weights();
    let replicated: usize = net
        .layers
        .iter()
        .zip(plan)
        .map(|(l, &r)| l.weights() * r.max(1))
        .sum();
    replicated as f64 / base as f64
}

/// Load imbalance of a plan: max over layers of effective reuse divided by
/// the mean (1.0 = perfectly balanced).
pub fn imbalance(net: &Network, plan: &[usize]) -> f64 {
    let eff = super::effective_reuse(net, plan);
    let max = *eff.iter().max().unwrap_or(&1) as f64;
    let mean = eff.iter().sum::<usize>() as f64 / eff.len().max(1) as f64;
    max / mean.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::zoo;

    #[test]
    fn geometric_resnet18_starts_at_n0_and_decays() {
        let net = zoo::resnet18();
        let plan = plan_geometric(&net, 128, 4);
        assert_eq!(plan[0], 128); // conv1
        // monotone non-increasing over conv layers
        let conv_plan: Vec<usize> = plan
            .iter()
            .zip(&net.layers)
            .filter(|(_, l)| matches!(l.kind, crate::nets::LayerKind::Conv { .. }))
            .map(|(r, _)| *r)
            .collect();
        for w in conv_plan.windows(2) {
            assert!(w[0] >= w[1], "{conv_plan:?}");
        }
        // fc gets 1
        assert_eq!(*plan.last().unwrap(), 1);
        // four stages of downsampling after conv1 -> 128/4^4 -> 1 at the end
        assert_eq!(*conv_plan.last().unwrap(), 1);
    }

    #[test]
    fn balanced_reduces_imbalance() {
        let net = zoo::resnet18();
        let ones = vec![1; net.n_layers()];
        let plan = plan_balanced(&net, 128);
        assert!(imbalance(&net, &plan) < imbalance(&net, &ones));
        assert!(plan.iter().all(|&r| (1..=128).contains(&r)));
        assert_eq!(plan[0], 128); // max-reuse layer gets the full factor
    }

    #[test]
    fn uniform_plan_for_bert() {
        let net = zoo::bert_layer(64);
        let plan = plan_uniform(&net, 64);
        assert_eq!(plan, vec![64; 6]);
        // uniform replication perfectly balances a uniform-reuse network
        assert!((imbalance(&net, &plan) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weight_inflation_bounds() {
        let net = zoo::resnet18();
        let ones = vec![1; net.n_layers()];
        assert_eq!(weight_inflation(&net, &ones), 1.0);
        let plan = plan_balanced(&net, 128);
        let infl = weight_inflation(&net, &plan);
        // paper Fig. 9: RAPA area cost ~5x for ResNet18 128/4
        assert!((1.5..=12.0).contains(&infl), "inflation {infl}");
    }

    #[test]
    fn geometric_f1_never_decays() {
        let net = zoo::resnet18();
        let plan = plan_geometric(&net, 8, 1);
        let conv_replication: Vec<usize> = plan
            .iter()
            .zip(&net.layers)
            .filter(|(_, l)| matches!(l.kind, crate::nets::LayerKind::Conv { .. }))
            .map(|(r, _)| *r)
            .collect();
        assert!(conv_replication.iter().all(|&r| r == 8));
    }
}
