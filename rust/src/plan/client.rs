//! Retrying JSONL client for the TCP planning service.
//!
//! The service ([`crate::service`]) is fault-isolated but the network is
//! not: connects race the listener coming up, connections die mid-line,
//! reads stall. Ad-hoc callers (benches, smoke tests, scripts) each grew
//! their own retry loop; this module is the one shared client with the
//! failure envelope handled once:
//!
//! * **connect timeout** and **read timeout** on the socket, so a dead
//!   peer costs bounded time instead of hanging the caller;
//! * **capped exponential backoff with deterministic jitter** (seeded
//!   [`crate::util::prng::Rng`] — a test's retry schedule replays
//!   bit-for-bit) between attempts;
//! * **reconnect-and-resend** on transport errors: planning is a pure
//!   function of the request, so replaying a line onto a fresh connection
//!   is safe — the worst case is wasted solver work, never a wrong or
//!   duplicated side effect.
//!
//! One request/response round-trip per call keeps the client stateless
//! between calls apart from the reusable connection; the in-band
//! `{"cmd":...}` control frames ride the same path ([`Client::command`]).

use super::{MapPlan, MapRequest, PlanError};
use crate::util::json::{self, Json, JsonObj};
use crate::util::prng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Timeouts and retry policy for a [`Client`].
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// per-attempt TCP connect timeout
    pub connect_timeout: Duration,
    /// socket read timeout: how long one response may take end to end
    /// before the attempt counts as failed
    pub read_timeout: Duration,
    /// additional attempts after the first (0 = fail fast)
    pub retries: u32,
    /// backoff before retry k (0-based) is `base * 2^k`, capped at
    /// [`ClientConfig::backoff_cap`], then jittered to 50–100 % of that
    pub backoff_base: Duration,
    /// upper bound on the un-jittered backoff
    pub backoff_cap: Duration,
    /// seed for the jitter PRNG — same seed, same retry schedule
    pub seed: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(30),
            retries: 4,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            seed: 0x5eed,
        }
    }
}

/// Un-jittered, capped exponential backoff for 0-based attempt `k`.
fn backoff_raw(cfg: &ClientConfig, attempt: u32) -> Duration {
    let factor = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
    cfg.backoff_base
        .checked_mul(factor)
        .map_or(cfg.backoff_cap, |d| d.min(cfg.backoff_cap))
}

/// Jittered backoff: 50–100 % of [`backoff_raw`], drawn from `rng` so the
/// schedule is a pure function of the config seed.
fn backoff_delay(cfg: &ClientConfig, attempt: u32, rng: &mut Rng) -> Duration {
    backoff_raw(cfg, attempt).mul_f64(0.5 + 0.5 * rng.f64())
}

/// Ceiling on the persistent backoff level: [`backoff_raw`] saturates at
/// the configured cap long before 2^32, so the level only needs enough
/// headroom to stay pinned at the cap while a peer flaps.
const LEVEL_CAP: u32 = 32;

/// A reusable connection to one service address with retry-on-failure
/// round-trips. Cheap to construct — no I/O happens until the first call.
#[derive(Debug)]
pub struct Client {
    addr: SocketAddr,
    cfg: ClientConfig,
    rng: Rng,
    conn: Option<Conn>,
    /// persistent backoff level, carried **across** round-trip calls: a
    /// peer that accepts the reconnect and then dies mid-stream must not
    /// reset the schedule to the floor interval (see
    /// [`Client::roundtrip_line`]).
    level: u32,
}

#[derive(Debug)]
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// A client for `addr` with the default [`ClientConfig`].
    pub fn new(addr: SocketAddr) -> Client {
        Client::with_config(addr, ClientConfig::default())
    }

    /// A client for `addr` with an explicit config.
    pub fn with_config(addr: SocketAddr, cfg: ClientConfig) -> Client {
        let rng = Rng::new(cfg.seed);
        Client { addr, cfg, rng, conn: None, level: 0 }
    }

    /// The address this client talks to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The current persistent backoff level: 0 after a response proved the
    /// connection stable, raised by one per failed attempt (and only
    /// halved by a response on a *freshly dialed* connection — a
    /// successful reconnect is not yet evidence of stability). Exposed so
    /// supervisors can see how unhealthy a link looks to its client.
    pub fn backoff_level(&self) -> u32 {
        self.level
    }

    fn dial(&self) -> std::io::Result<Conn> {
        let stream = TcpStream::connect_timeout(&self.addr, self.cfg.connect_timeout)?;
        stream.set_read_timeout(Some(self.cfg.read_timeout))?;
        stream.set_write_timeout(Some(self.cfg.read_timeout))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Conn { reader, writer: stream })
    }

    fn conn(&mut self) -> std::io::Result<&mut Conn> {
        if self.conn.is_none() {
            self.conn = Some(self.dial()?);
        }
        self.conn.as_mut().ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::NotConnected,
                "connection slot empty after dial",
            )
        })
    }

    /// One attempt: ensure a connection, send `line`, read one response
    /// line. EOF before a response is an error (the peer shed or died).
    fn attempt(&mut self, line: &str) -> std::io::Result<String> {
        let conn = self.conn()?;
        conn.writer.write_all(line.as_bytes())?;
        conn.writer.write_all(b"\n")?;
        conn.writer.flush()?;
        let mut response = String::new();
        let n = conn.reader.read_line(&mut response)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before a response arrived",
            ));
        }
        Ok(response.trim_end().to_string())
    }

    /// Send one request line, return the raw response line. Transport
    /// failures (connect refused, timeout, mid-line disconnect) drop the
    /// connection, back off, and replay the line on a fresh one — safe
    /// because planning has no side effects — up to `retries` extra
    /// attempts, then the last I/O error surfaces as a [`PlanError`].
    ///
    /// The backoff schedule is driven by a **persistent** level rather
    /// than a per-call attempt counter. A flapping server — one that
    /// accepts every reconnect and then dies mid-stream — used to reset
    /// the schedule to the floor interval on each call, hammering the
    /// peer at `backoff_base` forever. Now each failed attempt raises the
    /// level (wherever it failed in whichever call), a response on a
    /// freshly dialed connection only *halves* it (one reconnect is not
    /// yet stability), and only a response on an already-established
    /// connection resets it to zero.
    pub fn roundtrip_line(&mut self, line: &str) -> Result<String, PlanError> {
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..=self.cfg.retries {
            // an elevated level also delays the *first* attempt of a new
            // call: that is exactly the state a flapping peer leaves
            // behind, and per-call-only sleeping is what let retries:0
            // callers hammer the floor interval
            if attempt > 0 || self.level > 0 {
                let delay = backoff_delay(&self.cfg, self.level.saturating_sub(1), &mut self.rng);
                std::thread::sleep(delay);
            }
            let established = self.conn.is_some();
            match self.attempt(line) {
                Ok(response) => {
                    if established {
                        self.level = 0;
                    } else {
                        self.level /= 2;
                    }
                    return Ok(response);
                }
                Err(e) => {
                    self.conn = None; // the transport is suspect: redial
                    self.level = (self.level + 1).min(LEVEL_CAP);
                    last = Some(e);
                }
            }
        }
        let detail = last.map(|e| format!(": {e}")).unwrap_or_default();
        Err(PlanError(format!(
            "service at {} unreachable after {} attempts{detail}",
            self.addr,
            self.cfg.retries + 1
        )))
    }

    /// Round-trip an already-parsed response: decode the line, reject
    /// non-objects, and surface service error frames as [`PlanError`]s.
    fn roundtrip_json(&mut self, line: &str) -> Result<Json, PlanError> {
        let response = self.roundtrip_line(line)?;
        let j = json::parse(&response)
            .map_err(|e| PlanError(format!("malformed response from {}: {e}", self.addr)))?;
        if let Some(msg) = j.get("error").and_then(|v| v.as_str()) {
            return Err(PlanError(msg.to_string()));
        }
        Ok(j)
    }

    /// Submit one [`MapRequest`] and decode the [`MapPlan`]. Typed
    /// rejections and error frames come back as the frame's `"error"`
    /// message (so a `"reject":"deadline"` response surfaces as a
    /// [`PlanError`] with the stable [`super::DEADLINE_ERROR_PREFIX`]).
    pub fn plan(&mut self, req: &MapRequest) -> Result<MapPlan, PlanError> {
        let j = self.roundtrip_json(&req.to_json().dumps())?;
        MapPlan::from_json(&j)
    }

    /// Send an in-band control frame (`{"v":1,"cmd":"stats"}` /
    /// `"metrics"`) and return the response object.
    pub fn command(&mut self, cmd: &str) -> Result<Json, PlanError> {
        let mut obj = JsonObj::new();
        obj.set("v", super::WIRE_VERSION);
        obj.set("cmd", cmd);
        self.roundtrip_json(&Json::from(obj).dumps())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpListener;

    fn cfg_fast() -> ClientConfig {
        ClientConfig {
            connect_timeout: Duration::from_millis(500),
            read_timeout: Duration::from_secs(5),
            retries: 3,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(8),
            seed: 7,
        }
    }

    #[test]
    fn backoff_grows_and_caps() {
        let cfg = ClientConfig {
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            ..ClientConfig::default()
        };
        assert_eq!(backoff_raw(&cfg, 0), Duration::from_millis(50));
        assert_eq!(backoff_raw(&cfg, 1), Duration::from_millis(100));
        assert_eq!(backoff_raw(&cfg, 2), Duration::from_millis(200));
        assert_eq!(backoff_raw(&cfg, 5), Duration::from_millis(1600));
        assert_eq!(backoff_raw(&cfg, 6), Duration::from_secs(2), "capped");
        assert_eq!(backoff_raw(&cfg, 63), Duration::from_secs(2), "shift overflow capped");
        // jitter stays within 50-100 % and replays from the seed
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for k in 0..8 {
            let d = backoff_delay(&cfg, k, &mut a);
            assert!(d >= backoff_raw(&cfg, k).mul_f64(0.5) && d <= backoff_raw(&cfg, k));
            assert_eq!(d, backoff_delay(&cfg, k, &mut b));
        }
    }

    #[test]
    fn roundtrips_against_an_echo_peer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            for _ in 0..2 {
                line.clear();
                reader.read_line(&mut line).unwrap();
                let mut w = &stream;
                w.write_all(line.as_bytes()).unwrap();
            }
        });
        let mut c = Client::with_config(addr, cfg_fast());
        assert_eq!(c.roundtrip_line("{\"ping\":1}").unwrap(), "{\"ping\":1}");
        assert_eq!(c.roundtrip_line("{\"ping\":2}").unwrap(), "{\"ping\":2}");
        server.join().unwrap();
    }

    #[test]
    fn reconnects_and_resends_after_a_mid_stream_disconnect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            // first connection: read the request, then slam the door
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            drop(reader);
            // second connection: behave
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            line.clear();
            reader.read_line(&mut line).unwrap();
            let mut w = &stream;
            w.write_all(line.as_bytes()).unwrap();
        });
        let mut c = Client::with_config(addr, cfg_fast());
        assert_eq!(c.roundtrip_line("{\"once\":1}").unwrap(), "{\"once\":1}");
        server.join().unwrap();
    }

    #[test]
    fn flapping_peer_keeps_the_backoff_level_raised_across_calls() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            // two connections that accept, read the request, then slam the
            // door — the flap pattern that used to reset the schedule to
            // the floor interval on every roundtrip call
            for _ in 0..2 {
                let (stream, _) = listener.accept().unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
            }
            // third connection: behave, twice
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            for _ in 0..2 {
                line.clear();
                reader.read_line(&mut line).unwrap();
                let mut w = &stream;
                w.write_all(line.as_bytes()).unwrap();
            }
        });
        let mut c = Client::with_config(addr, ClientConfig { retries: 1, ..cfg_fast() });
        // call 1: both attempts die mid-stream — the failures must
        // accumulate into the persistent level, not a per-call counter
        assert!(c.roundtrip_line("{\"a\":1}").is_err());
        assert_eq!(c.backoff_level(), 2);
        // call 2: the reconnect succeeds, but one response on a freshly
        // dialed connection only halves the level — a server that accepts
        // reconnects readily is exactly the flapping case
        assert_eq!(c.roundtrip_line("{\"b\":2}").unwrap(), "{\"b\":2}");
        assert_eq!(c.backoff_level(), 1);
        // call 3: a response on the already-established connection is
        // proof of stability — only now does the schedule reset
        assert_eq!(c.roundtrip_line("{\"c\":3}").unwrap(), "{\"c\":3}");
        assert_eq!(c.backoff_level(), 0);
        server.join().unwrap();
    }

    #[test]
    fn backoff_schedule_replays_bit_for_bit_from_the_seed() {
        // the delays a flapping client sleeps are a pure function of the
        // config seed: same seed, same jittered schedule, and every draw
        // stays inside the 50-100 % jitter band of its level's raw value
        let cfg = ClientConfig { seed: 0xfeed, ..ClientConfig::default() };
        let mut a = Rng::new(cfg.seed);
        let mut b = Rng::new(cfg.seed);
        // the level trace a peer failing 6 straight attempts produces
        // (level k-1 is what the k-th failed attempt sleeps on)
        for level in 0..6u32 {
            let d = backoff_delay(&cfg, level, &mut a);
            assert_eq!(d, backoff_delay(&cfg, level, &mut b), "level {level} diverged");
            let raw = backoff_raw(&cfg, level);
            assert!(d >= raw.mul_f64(0.5) && d <= raw, "level {level} outside jitter band");
        }
        // the persistent level saturates instead of overflowing the shift
        assert_eq!(backoff_raw(&cfg, LEVEL_CAP), cfg.backoff_cap);
    }

    #[test]
    fn gives_up_after_the_retry_budget() {
        // bind, learn the port, close — nothing listens there afterwards
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let mut c = Client::with_config(addr, cfg_fast());
        let e = c.roundtrip_line("{}").unwrap_err();
        assert!(e.0.contains("after 4 attempts"), "{e}");
    }

    #[test]
    fn error_frames_surface_as_plan_errors() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let mut w = &stream;
            w.write_all(b"{\"v\":1,\"line\":1,\"error\":\"deadline exceeded: too slow\",\"reject\":\"deadline\"}\n")
                .unwrap();
            // drain until the client hangs up so the write is not raced
            let mut sink = Vec::new();
            let _ = reader.read_to_end(&mut sink);
        });
        let mut c = Client::with_config(addr, cfg_fast());
        let e = c.roundtrip_json("{\"v\":1}").unwrap_err();
        assert!(e.is_deadline(), "{e}");
        server.join().unwrap();
    }
}
