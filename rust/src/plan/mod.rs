//! The crate's front door: one typed, serializable planning API over the
//! paper's whole workflow.
//!
//! A [`MapRequest`] describes *what* to map — a network (zoo name or inline
//! layer spec), a tile space (one fixed tile or the §3.1 grid), a packing
//! engine and discipline, a design objective, RAPA replication, an ILP
//! budget and a worker count. [`MapRequest::build`] validates it into a
//! [`Planner`]; [`Planner::plan`] runs fragmentation, packing, pricing and
//! the tile-dimension sweep and returns a [`MapPlan`]: every evaluated
//! point, the per-aspect minima, the objective's chosen optimum, optional
//! per-tile placements, Eq. 3/4 latency/throughput, and provenance (budget
//! spent, warm-start hits, proof status).
//!
//! Both ends are wire-stable: [`wire`] gives `MapRequest`/`MapPlan` a
//! versioned (`"v":1`) JSON encoding, [`serve_jsonl`] streams JSONL
//! requests to JSONL plans (the `xbarmap plan` endpoint), and
//! [`serve_batch`] prices many decoded requests concurrently with
//! deterministic, request-ordered results — the multi-tenant design
//! service the coordinator fronts.
//!
//! ```
//! use xbarmap::plan::MapRequest;
//! use xbarmap::pack::Discipline;
//!
//! let plan = MapRequest::zoo("lenet")
//!     .discipline(Discipline::Pipeline)
//!     .build()
//!     .unwrap()
//!     .plan()
//!     .unwrap();
//! assert_eq!(plan.points.len(), 64); // 8 sizes x 8 aspects
//! println!("optimum: {} at {} mm2", plan.best.tile, plan.best.total_area_mm2);
//! ```
//!
//! The per-stage free functions (`frag::fragment_network`, the
//! `pack::*`/`ilp` engines, `opt::sweep`, `coordinator::batched_sweep`)
//! remain available as `#[doc(hidden)]` internals the planner calls.

pub mod client;
pub mod wire;

use crate::area::AreaModel;
use crate::frag;
use crate::geom::{Placement, Tile};
use crate::ilp;
use crate::nets::{zoo, Network};
use crate::opt::{self, Engine, SweepConfig, SweepPoint};
use crate::pack::{self, Discipline, Packing, SortOrder};
use crate::perf::{self, rapa, Execution, TimingModel};
use crate::sim::{self, SimConfig};
use crate::util::deadline::Deadline;
use std::io::{BufRead, Write};

/// Wire-format version stamped into (and required of) every serialized
/// request and plan.
pub const WIRE_VERSION: u64 = 1;

/// Aspect recorded for fixed tiles that sit off the §3.1 integer-aspect
/// grid (e.g. 96x64 or wide tiles) — never rounded into a real bucket.
pub const OFF_GRID_ASPECT: usize = 0;

/// Inferences simulated per candidate when ranking by the max-throughput
/// objective (cycle-level model, deterministic).
const SIM_INFERENCES: usize = 32;

/// Planning/validation error (also the wire-decode error type).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanError(
    /// human-readable description of what failed (the `"error"` field of
    /// wire error frames)
    pub String,
);

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for PlanError {}

/// Stable message prefix of wall-clock deadline-expiry errors. The
/// planning service matches on it ([`PlanError::is_deadline`]) to emit the
/// typed `"reject":"deadline"` frame instead of a plain error frame, so
/// the prefix is part of the crate's error contract.
pub const DEADLINE_ERROR_PREFIX: &str = "deadline exceeded";

impl PlanError {
    /// A deadline-expiry error: `"deadline exceeded: <detail>"`, carrying
    /// the stable [`DEADLINE_ERROR_PREFIX`].
    pub fn deadline(detail: impl std::fmt::Display) -> PlanError {
        PlanError(format!("{DEADLINE_ERROR_PREFIX}: {detail}"))
    }

    /// Whether this error reports a wall-clock deadline expiry
    /// ([`Planner::plan_with_deadline`]).
    pub fn is_deadline(&self) -> bool {
        self.0.starts_with(DEADLINE_ERROR_PREFIX)
    }
}

fn err(msg: impl Into<String>) -> PlanError {
    PlanError(msg.into())
}

/// The network a request maps: a zoo name resolved at build time, or an
/// inline layer spec carried on the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum NetworkSpec {
    /// a [`crate::nets::zoo`] network by name (resolved by
    /// [`MapRequest::build`])
    Zoo(String),
    /// an explicit layer list carried inline on the wire
    Inline(Network),
}

/// The tile configurations a request prices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TileSpace {
    /// one explicit tile dimension
    Fixed(Tile),
    /// the §3.1 grid: `n_col = 2^k` for `k` in `row_exp`, `n_row = n_col *
    /// aspect` for each aspect factor
    Grid { row_exp: (u32, u32), aspects: Vec<usize> },
}

impl TileSpace {
    /// The paper's §3.1 default grid: 2^6..2^13 base dims, aspects 1..=8.
    pub fn paper_grid() -> TileSpace {
        TileSpace::Grid { row_exp: (6, 13), aspects: (1..=8).collect() }
    }
}

/// Design objective selecting the plan's optimum among evaluated points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// minimum total tile area (the paper's §3.1 criterion)
    MinArea,
    /// fewest physical tiles (area breaks ties)
    MinTiles,
    /// highest cycle-level simulated throughput among the per-aspect area
    /// winners (area breaks ties); Eq. 3/4 latency alone cannot rank tiles
    MaxThroughput,
}

impl Objective {
    /// Canonical wire/CLI token; `Display`/`FromStr` round-trip through it.
    pub fn canonical(&self) -> &'static str {
        match self {
            Objective::MinArea => "min-area",
            Objective::MinTiles => "min-tiles",
            Objective::MaxThroughput => "max-throughput",
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.canonical())
    }
}

impl std::str::FromStr for Objective {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "min-area" => Ok(Objective::MinArea),
            "min-tiles" => Ok(Objective::MinTiles),
            "max-throughput" => Ok(Objective::MaxThroughput),
            _ => Err(format!(
                "objective must be min-area|min-tiles|max-throughput, got '{s}'"
            )),
        }
    }
}

/// RAPA replication request, resolved to a per-layer factor vector at
/// build time (`perf::rapa` planners).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Replication {
    /// no replication (every factor 1)
    None,
    /// reuse-balanced plan with first-layer factor `n0`
    Balanced(usize),
    /// geometric plan `n0, n0/f, n0/f², ...` (paper Fig. 9's "128/4")
    Geometric(usize, usize),
    /// the same factor for every layer (BERT "max parallelism xS")
    Uniform(usize),
    /// explicit per-layer factors (arity checked against the network)
    Explicit(Vec<usize>),
}

/// A validated, typed, serializable mapping request — the single entry
/// point for packing one tile, sweeping the §3.1 grid, and serving both.
#[derive(Debug, Clone, PartialEq)]
pub struct MapRequest {
    /// caller-chosen correlation id, echoed into the plan ("" = none)
    pub id: String,
    /// the network to map (zoo name or inline spec)
    pub network: NetworkSpec,
    /// the tile configurations to price (one fixed tile or the §3.1 grid)
    pub tiles: TileSpace,
    /// packing engine: the paper's simple algorithm, FFD, or exact BILP
    pub engine: Engine,
    /// packing discipline (§2.2): dense shelves or pipeline staircases
    pub discipline: Discipline,
    /// which evaluated point the plan reports as its optimum
    pub objective: Objective,
    /// RAPA replication request, resolved per layer at build time
    pub replication: Replication,
    /// sweep worker threads (0 = auto via [`opt::sweep_threads`])
    pub threads: usize,
    /// include the chosen configuration's per-tile placements in the plan
    pub include_placements: bool,
    /// simple-engine block placement order (ablation hook)
    pub sort: SortOrder,
    /// area/pricing model (defaults to the paper calibration)
    pub area: AreaModel,
}

impl MapRequest {
    /// Start a request for a zoo network (resolved and validated by
    /// [`MapRequest::build`]).
    pub fn zoo(name: &str) -> MapRequest {
        MapRequest::with_network(NetworkSpec::Zoo(name.to_string()))
    }

    /// Start a request for an inline network description.
    pub fn inline(net: Network) -> MapRequest {
        MapRequest::with_network(NetworkSpec::Inline(net))
    }

    /// Start a request from an already-built [`NetworkSpec`] with the
    /// paper's defaults: §3.1 grid, simple engine, dense discipline,
    /// min-area objective, no replication.
    pub fn with_network(network: NetworkSpec) -> MapRequest {
        MapRequest {
            id: String::new(),
            network,
            tiles: TileSpace::paper_grid(),
            engine: Engine::Simple,
            discipline: Discipline::Dense,
            objective: Objective::MinArea,
            replication: Replication::None,
            threads: 0,
            include_placements: false,
            sort: SortOrder::RowsDesc,
            area: AreaModel::paper_default(),
        }
    }

    /// Set the correlation id echoed back in the plan.
    pub fn id(mut self, id: &str) -> Self {
        self.id = id.to_string();
        self
    }

    /// Price one fixed tile dimension instead of sweeping the grid.
    pub fn tile(mut self, rows: usize, cols: usize) -> Self {
        self.tiles = TileSpace::Fixed(Tile::new(rows, cols));
        self
    }

    /// Sweep a §3.1 grid: `n_col = 2^k` for `k` in `row_exp` (inclusive),
    /// `n_row = n_col * aspect` for each aspect factor.
    pub fn grid(mut self, row_exp: (u32, u32), aspects: Vec<usize>) -> Self {
        self.tiles = TileSpace::Grid { row_exp, aspects };
        self
    }

    /// Select the packing engine.
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Select the ILP engine with an explicit branch & bound node budget.
    pub fn ilp(mut self, max_nodes: u64) -> Self {
        self.engine = Engine::Ilp { max_nodes };
        self
    }

    /// Select the packing discipline (dense or pipeline).
    pub fn discipline(mut self, discipline: Discipline) -> Self {
        self.discipline = discipline;
        self
    }

    /// Select the design objective choosing the plan's optimum.
    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Request RAPA replication (resolved per layer at build time).
    pub fn replication(mut self, replication: Replication) -> Self {
        self.replication = replication;
        self
    }

    /// Set the sweep worker-thread count (0 = auto).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Include the chosen configuration's per-tile placements in the plan.
    pub fn placements(mut self, include: bool) -> Self {
        self.include_placements = include;
        self
    }

    /// Set the simple engine's block placement order (ablation hook).
    pub fn sort(mut self, sort: SortOrder) -> Self {
        self.sort = sort;
        self
    }

    /// Price with a custom area model instead of the paper calibration.
    pub fn area(mut self, area: AreaModel) -> Self {
        self.area = area;
        self
    }

    /// Validate into a [`Planner`]: resolves the network, checks the tile
    /// space, engine budget and replication arity.
    pub fn build(self) -> Result<Planner, PlanError> {
        let net = match &self.network {
            NetworkSpec::Zoo(name) => zoo::by_name(name).ok_or_else(|| {
                err(format!("unknown network '{name}' (try {})", zoo::NAMES.join("|")))
            })?,
            NetworkSpec::Inline(net) => {
                if net.layers.is_empty() {
                    return Err(err("inline network has no layers"));
                }
                net.clone()
            }
        };
        match &self.tiles {
            TileSpace::Fixed(t) => {
                if t.n_row == 0 || t.n_col == 0 {
                    return Err(err(format!("degenerate tile {t}")));
                }
            }
            TileSpace::Grid { row_exp, aspects } => {
                if row_exp.0 > row_exp.1 {
                    return Err(err(format!(
                        "empty grid: row_exp {}..={}",
                        row_exp.0, row_exp.1
                    )));
                }
                if row_exp.1 > 20 {
                    return Err(err(format!("row exponent {} too large (max 20)", row_exp.1)));
                }
                if aspects.is_empty() {
                    return Err(err("grid has no aspect factors"));
                }
                if let Some(a) = aspects.iter().find(|&&a| a == 0 || a > 64) {
                    return Err(err(format!("aspect factor {a} outside 1..=64")));
                }
            }
        }
        if let Engine::Ilp { max_nodes } = self.engine {
            if max_nodes == 0 {
                return Err(err("ILP node budget must be >= 1"));
            }
        }
        let replication = match &self.replication {
            Replication::None => vec![1; net.n_layers()],
            Replication::Balanced(n0) => {
                if *n0 == 0 {
                    return Err(err("balanced replication n0 must be >= 1"));
                }
                rapa::plan_balanced(&net, *n0)
            }
            Replication::Geometric(n0, f) => {
                if *n0 == 0 || *f == 0 {
                    return Err(err("geometric replication needs n0 >= 1 and factor >= 1"));
                }
                rapa::plan_geometric(&net, *n0, *f)
            }
            Replication::Uniform(s) => {
                if *s == 0 {
                    return Err(err("uniform replication factor must be >= 1"));
                }
                rapa::plan_uniform(&net, *s)
            }
            Replication::Explicit(v) => {
                if v.len() != net.n_layers() {
                    return Err(err(format!(
                        "replication arity {} != {} layers",
                        v.len(),
                        net.n_layers()
                    )));
                }
                if v.iter().any(|&r| r == 0) {
                    return Err(err("replication factors must be >= 1"));
                }
                v.clone()
            }
        };
        Ok(Planner { request: self, net, replication })
    }

    /// Encode to the v1 wire object ([`wire::request_to_json`]).
    pub fn to_json(&self) -> crate::util::json::Json {
        wire::request_to_json(self)
    }

    /// Decode from a v1 wire object ([`wire::request_from_json`]).
    pub fn from_json(j: &crate::util::json::Json) -> Result<MapRequest, PlanError> {
        wire::request_from_json(j)
    }
}

/// A validated request plus its resolved network and per-layer replication
/// factors — ready to produce [`MapPlan`]s and [`Packing`]s.
#[derive(Debug, Clone)]
pub struct Planner {
    request: MapRequest,
    net: Network,
    replication: Vec<usize>,
}

/// Packing of one tile configuration with solver provenance.
#[derive(Debug, Clone)]
pub struct PackOutcome {
    /// the validated placement of every block onto tiles
    pub packing: Packing,
    /// branch & bound nodes spent (0 for the greedy engines)
    pub nodes: u64,
    /// true when the ILP engine proved optimality
    pub optimal: bool,
    /// ILP lower bound on the bin count (0 for the greedy engines)
    pub lower_bound: usize,
}

impl Planner {
    /// The validated request this planner was built from.
    pub fn request(&self) -> &MapRequest {
        &self.request
    }

    /// The resolved network this planner maps.
    pub fn network(&self) -> &Network {
        &self.net
    }

    /// The resolved per-layer RAPA replication factors.
    pub fn replication(&self) -> &[usize] {
        &self.replication
    }

    fn sweep_config(&self, deadline: Deadline) -> SweepConfig {
        let (row_exp, aspects) = match &self.request.tiles {
            TileSpace::Grid { row_exp, aspects } => (*row_exp, aspects.clone()),
            // unused by the fixed-tile path
            TileSpace::Fixed(_) => ((0, 0), Vec::new()),
        };
        SweepConfig {
            discipline: self.request.discipline,
            engine: self.request.engine,
            row_exp,
            aspects,
            replication: Some(self.replication.clone()),
            sort: self.request.sort,
            area: self.request.area,
            deadline,
        }
    }

    fn execution(&self) -> Execution {
        match self.request.discipline {
            Discipline::Dense => Execution::Sequential,
            Discipline::Pipeline => Execution::Pipelined,
        }
    }

    /// Fragment and pack the network onto one tile dimension with the
    /// request's engine, validating the placement. This is the exact
    /// owned-allocation engine path (ILP solved cold), so placements are
    /// byte-identical to calling the engines directly. An engine emitting
    /// an invalid packing surfaces as an error, not a panic.
    pub fn pack(&self, tile: Tile) -> Result<PackOutcome, PlanError> {
        self.pack_with_hint(tile, None, Deadline::NONE)
    }

    /// [`Planner::pack`] with an ILP warm-start hint (the counted
    /// simple-engine bin count of the neighbouring configuration, as the
    /// §3.1 sweep passes it). [`Planner::plan`] reconstructs the chosen
    /// point's hint so the packed placements land on exactly the bin count
    /// the sweep reported, even when the budget is too small to prove
    /// optimality.
    fn pack_with_hint(
        &self,
        tile: Tile,
        hint: Option<usize>,
        deadline: Deadline,
    ) -> Result<PackOutcome, PlanError> {
        let req = &self.request;
        let blocks = frag::fragment_network_replicated(&self.net, tile, &self.replication);
        let (packing, nodes, optimal, lower_bound) = match req.engine {
            Engine::Simple => {
                (pack::simple::pack_ordered(&blocks, tile, req.discipline, req.sort), 0, false, 0)
            }
            Engine::Ffd => (pack::ffd::pack(&blocks, tile, req.discipline), 0, false, 0),
            Engine::Ilp { max_nodes } => {
                let r = ilp::exact::solve_with_hint(
                    &blocks,
                    tile,
                    req.discipline,
                    ilp::Budget { max_nodes, deadline, ..Default::default() },
                    hint,
                );
                (r.packing, r.nodes, r.optimal, r.lower_bound)
            }
        };
        pack::placement::validate(&packing)
            .map_err(|e| err(format!("{} produced an invalid packing on {tile}: {e}", req.engine)))?;
        Ok(PackOutcome { packing, nodes, optimal, lower_bound })
    }

    /// Price a packed configuration exactly as the sweep's evaluation core
    /// does — same formulas in the same operand order, so the values are
    /// bitwise equal to a sweep over the same tile.
    fn point_from_packing(&self, tile: Tile, aspect: usize, packing: &Packing) -> SweepPoint {
        let area = &self.request.area;
        let n_blocks = packing.blocks.len();
        let n_tiles = packing.n_bins;
        let stored = frag::total_block_weights(&packing.blocks);
        SweepPoint {
            tile,
            aspect,
            n_blocks,
            n_tiles,
            n_tiles_one_to_one: n_blocks,
            tile_eff: area.efficiency(tile),
            packing_eff: pack::packing_efficiency(stored, n_tiles, tile.capacity()),
            total_area_mm2: area.total_area_mm2(n_tiles, tile),
            array_area_mm2: n_tiles as f64 * area.array_area_um2(tile) * 1e-6,
        }
    }

    /// The counted warm-start hint the §3.1 sweep fed the chosen ILP grid
    /// point (None for greedy engines, fixed tiles, or the smallest size).
    fn grid_replay_hint(&self, points: &[SweepPoint], best: &SweepPoint) -> Option<usize> {
        match (&self.request.engine, &self.request.tiles) {
            (Engine::Ilp { .. }, TileSpace::Grid { aspects, .. }) => points
                .iter()
                .position(|p| p.tile == best.tile)
                .and_then(|i| i.checked_sub(aspects.len()))
                .map(|prev| {
                    opt::ilp_sweep_hint(
                        &self.net,
                        points[prev].tile,
                        &self.replication,
                        self.request.discipline,
                    )
                }),
            _ => None,
        }
    }

    /// Evaluate the request's tile space, choose the objective's optimum,
    /// attach provenance (and placements when requested), and price
    /// latency/throughput.
    ///
    /// Point pricing runs on the **counted** shape-class path
    /// (`provenance.counted` records this): grid sweeps always, and fixed
    /// tiles unless their placements are requested — no per-block state is
    /// materialized for pricing, so large or RAPA-replicated requests cost
    /// O(shape classes) per point instead of O(blocks log blocks).
    /// Placements, when requested, always come from the exact per-block
    /// engines (identical numbers, plus coordinates), solved once for the
    /// chosen tile.
    pub fn plan(&self) -> Result<MapPlan, PlanError> {
        self.plan_with_deadline(Deadline::NONE)
    }

    /// [`Planner::plan`] under a cooperative wall-clock budget: the
    /// deadline is threaded by value through the sweep, the counted
    /// kernels and the branch & bound checkpoints, so a runaway solve
    /// bails out within one checkpoint stride instead of pinning its
    /// thread. On expiry the partial result is discarded and a
    /// [`PlanError::deadline`] (stable [`DEADLINE_ERROR_PREFIX`]) comes
    /// back — the planning service maps it to the typed
    /// `"reject":"deadline"` frame. [`Deadline::NONE`] is exactly
    /// [`Planner::plan`]: no clock reads, bit-identical results.
    pub fn plan_with_deadline(&self, deadline: Deadline) -> Result<MapPlan, PlanError> {
        self.plan_with_outcome(deadline).map(|(plan, _)| plan)
    }

    /// Plan a fixed-tile deployment with **one** solve: the returned
    /// [`MapPlan`] and the returned [`Packing`] come from the same
    /// materialized per-block pack, so the mapping a server adopts and the
    /// pricing it reports can never diverge (and startup pays a single
    /// fragmentation + packing pass, not two). The serving coordinator is
    /// the intended caller. Errors on grid requests — a deployment is one
    /// physical tile dimension.
    pub fn plan_deployment(&self) -> Result<(MapPlan, Packing), PlanError> {
        if !matches!(self.request.tiles, TileSpace::Fixed(_)) {
            return Err(err("plan_deployment requires a fixed tile — a deployment is one physical tile dimension, not a grid"));
        }
        let (mut plan, outcome) = if self.request.include_placements {
            self.plan_with_outcome(Deadline::NONE)?
        } else {
            // force materialization so the point, the provenance and the
            // returned packing all come from this one solve
            let mut forced = self.clone();
            forced.request.include_placements = true;
            forced.plan_with_outcome(Deadline::NONE)?
        };
        let Some(outcome) = outcome else {
            return Err(err("internal: fixed-tile placement plan did not materialize a packing"));
        };
        if !self.request.include_placements {
            plan.placements = None; // the packing carries them instead
        }
        Ok((plan, outcome.packing))
    }

    /// [`Planner::plan`] keeping the materialized [`PackOutcome`] (when one
    /// was solved) alongside the plan it priced.
    fn plan_with_outcome(
        &self,
        deadline: Deadline,
    ) -> Result<(MapPlan, Option<PackOutcome>), PlanError> {
        let req = &self.request;
        let threads = if req.threads == 0 { opt::sweep_threads() } else { req.threads };
        // whether the `points` array is priced through the counted path:
        // grid sweeps always are (placements, when requested, come from a
        // separate per-block solve of the chosen tile); a fixed tile is
        // counted unless its placements are requested, in which case the
        // one per-block pack also serves as the point
        let counted_mode = match &req.tiles {
            TileSpace::Grid { .. } => true,
            TileSpace::Fixed(_) => !req.include_placements,
        };
        // `fixed_solve` carries counted ILP provenance; `fixed_outcome` a
        // materialized packing (placement requests)
        let (points, fixed_solve, fixed_outcome) = match &req.tiles {
            TileSpace::Grid { .. } => {
                let cfg = self.sweep_config(deadline);
                (opt::sweep_with_threads(&self.net, &cfg, threads), None, None)
            }
            TileSpace::Fixed(tile) => {
                let aspect = tile.exact_aspect().unwrap_or(OFF_GRID_ASPECT);
                if counted_mode {
                    let eval = opt::evaluate_counted(
                        &self.net,
                        *tile,
                        aspect,
                        &self.sweep_config(deadline),
                        None,
                    );
                    (vec![eval.point.clone()], Some(eval), None)
                } else {
                    // one fragment + pack serves the point, the placements
                    // and the provenance
                    let outcome = self.pack_with_hint(*tile, None, deadline)?;
                    let point = self.point_from_packing(*tile, aspect, &outcome.packing);
                    (vec![point], None, Some(outcome))
                }
            }
        };
        // an expired budget invalidates everything above (the sweep and
        // the solvers degrade to placeholders/unfinished incumbents once
        // the deadline passes) — discard and report the typed error
        if deadline.expired() {
            return Err(PlanError::deadline("the wall-clock budget expired during the solve"));
        }
        let best_per_aspect = opt::best_per_aspect(&points);
        let best = self.choose(&points, &best_per_aspect, deadline)?;
        let (outcome, solve) = match (fixed_outcome, fixed_solve) {
            (Some(o), _) => (Some(o), None),
            (None, Some(s)) => (None, Some(s)),
            (None, None) if req.include_placements => {
                // the sweep solved the chosen ILP point warm-started from
                // the counted hint of its smaller neighbour; replay that
                // hint so the placement solve reproduces the reported bin
                // count
                let hint = self.grid_replay_hint(&points, &best);
                (Some(self.pack_with_hint(best.tile, hint, deadline)?), None)
            }
            (None, None) if matches!(req.engine, Engine::Ilp { .. }) => {
                // ILP provenance for the chosen grid point without
                // materializing placements: re-run the counted solve with
                // the replayed hint (identical numbers to the sweep's own)
                let hint = self.grid_replay_hint(&points, &best);
                let eval = opt::evaluate_counted(
                    &self.net,
                    best.tile,
                    best.aspect,
                    &self.sweep_config(deadline),
                    hint,
                );
                (None, Some(eval))
            }
            (None, None) => (None, None),
        };
        // the replay stage above re-solves the chosen point; re-check so a
        // budget that died inside it is reported, not returned as a plan
        if deadline.expired() {
            return Err(PlanError::deadline("the wall-clock budget expired during the solve"));
        }
        let (nodes, optimal, lower_bound) = match (&outcome, &solve) {
            (Some(o), _) => (o.nodes, o.optimal, o.lower_bound),
            (None, Some(s)) => (s.nodes, s.optimal, s.lower_bound),
            (None, None) => (0, false, 0),
        };
        let timing = TimingModel::default();
        let exec = self.execution();
        let warm_hits = match (&req.engine, &req.tiles) {
            (Engine::Ilp { .. }, TileSpace::Grid { aspects, .. }) => {
                count_warm_hits(&points, aspects.len())
            }
            _ => 0,
        };
        let plan = MapPlan {
            id: req.id.clone(),
            network: self.net.name.clone(),
            discipline: req.discipline,
            engine: req.engine,
            objective: req.objective,
            placements: if req.include_placements {
                outcome.as_ref().map(|o| o.packing.placements.clone())
            } else {
                None
            },
            best,
            best_per_aspect,
            points,
            latency_s: perf::latency(&self.net, &self.replication, &timing, exec),
            throughput_per_s: perf::throughput(&self.net, &self.replication, &timing, exec),
            provenance: Provenance {
                budget_nodes: match req.engine {
                    Engine::Ilp { max_nodes } => max_nodes,
                    _ => 0,
                },
                nodes,
                optimal,
                lower_bound,
                warm_hits,
                threads,
                counted: counted_mode,
            },
        };
        Ok((plan, outcome))
    }

    fn choose(
        &self,
        points: &[SweepPoint],
        per_aspect: &[SweepPoint],
        deadline: Deadline,
    ) -> Result<SweepPoint, PlanError> {
        match self.request.objective {
            Objective::MinArea => opt::optimum(points)
                .ok_or_else(|| err("internal: validated tile space swept to no points")),
            Objective::MinTiles => points
                .iter()
                .min_by(|x, y| {
                    x.n_tiles
                        .cmp(&y.n_tiles)
                        .then(x.total_area_mm2.total_cmp(&y.total_area_mm2))
                })
                .cloned()
                .ok_or_else(|| err("internal: validated tile space swept to no points")),
            Objective::MaxThroughput => {
                // area-prune to the per-aspect winners, then rank by the
                // cycle-level simulator (deterministic)
                let candidates = if per_aspect.is_empty() { points } else { per_aspect };
                let sim_cfg = SimConfig {
                    timing: TimingModel::default(),
                    exec: self.execution(),
                    replication: self.replication.clone(),
                };
                let mut best: Option<(f64, &SweepPoint)> = None;
                for p in candidates {
                    if deadline.is_set() && deadline.expired() {
                        return Err(PlanError::deadline(
                            "the wall-clock budget expired while ranking throughput candidates",
                        ));
                    }
                    let packing = self.pack_with_hint(p.tile, None, deadline)?.packing;
                    let rep = sim::simulate(&self.net, &packing, &sim_cfg, SIM_INFERENCES);
                    let better = match &best {
                        None => true,
                        Some((t, b)) => {
                            rep.throughput_per_s > *t
                                || (rep.throughput_per_s == *t
                                    && p.total_area_mm2.total_cmp(&b.total_area_mm2).is_lt())
                        }
                    };
                    if better {
                        best = Some((rep.throughput_per_s, p));
                    }
                }
                match best {
                    Some((_, p)) => Ok(p.clone()),
                    None => Err(err("internal: validated tile space swept to no points")),
                }
            }
        }
    }
}

/// Count capacity-monotonicity plateaus in an ILP grid sweep: points
/// whose bin count equals their smaller neighbour's in the same aspect
/// column. This is the structure the warm-start hints exploit (each point
/// is hinted with the neighbour's counted simple-engine count, an upper
/// bound on the neighbour's ILP count), not a literal count of
/// hint-value matches.
fn count_warm_hits(points: &[SweepPoint], n_aspects: usize) -> usize {
    if n_aspects == 0 {
        return 0;
    }
    points
        .iter()
        .enumerate()
        .filter(|(i, p)| *i >= n_aspects && p.n_tiles == points[i - n_aspects].n_tiles)
        .count()
}

/// The planner's result: everything a tenant needs to adopt (or audit) a
/// mapping, in a wire-stable shape.
#[derive(Debug, Clone, PartialEq)]
pub struct MapPlan {
    /// the request's correlation id, echoed back
    pub id: String,
    /// resolved network name
    pub network: String,
    /// the discipline the request was packed under
    pub discipline: Discipline,
    /// the engine that produced the packing counts
    pub engine: Engine,
    /// the objective that chose `best`
    pub objective: Objective,
    /// every evaluated tile configuration, in grid order
    pub points: Vec<SweepPoint>,
    /// minimum-area point per aspect ratio (§3.1 step 2)
    pub best_per_aspect: Vec<SweepPoint>,
    /// the objective's chosen optimum
    pub best: SweepPoint,
    /// per-tile placements of the chosen configuration (when requested).
    /// For ILP grid sweeps the placement solve replays the chosen point's
    /// warm-start hint, so these always realize exactly `best.n_tiles`
    /// bins — even under budgets too small to prove optimality.
    pub placements: Option<Vec<Placement>>,
    /// Eq. 3/4 modeled latency of one inference, seconds
    pub latency_s: f64,
    /// Eq. 3/4 steady-state inferences per second
    pub throughput_per_s: f64,
    /// how the mapping was produced (budget, proof status, parallelism)
    pub provenance: Provenance,
}

impl MapPlan {
    /// Encode to the v1 wire object ([`wire::plan_to_json`]).
    pub fn to_json(&self) -> crate::util::json::Json {
        wire::plan_to_json(self)
    }

    /// Decode from a v1 wire object ([`wire::plan_from_json`]).
    pub fn from_json(j: &crate::util::json::Json) -> Result<MapPlan, PlanError> {
        wire::plan_from_json(j)
    }
}

/// How a mapping was produced: engine budget, search effort, proof status
/// and sweep parallelism — enough to reproduce or audit the plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance {
    /// branch & bound node budget (0 for the greedy engines)
    pub budget_nodes: u64,
    /// nodes spent packing the chosen configuration
    pub nodes: u64,
    /// chosen configuration proven optimal by the ILP engine
    pub optimal: bool,
    /// ILP lower bound on the chosen configuration's bin count
    pub lower_bound: usize,
    /// ILP grid points sitting on a capacity-monotonicity plateau (bin
    /// count equal to the smaller neighbour's in the same aspect column —
    /// the structure the warm-start hints exploit)
    pub warm_hits: usize,
    /// sweep worker threads used
    pub threads: usize,
    /// the `points` array was priced through the counted shape-class path
    /// (grid sweeps always; fixed tiles unless placements were requested,
    /// where the one per-block pack doubles as the point). Placements
    /// themselves always come from the per-block engines; on the counted
    /// path per-block state is materialized only where an ILP search
    /// demanded it, never for pricing.
    pub counted: bool,
}

/// Plan many requests concurrently (the design-service entry point behind
/// `coordinator::batched_sweep` and `xbarmap plan`). Parallelism is across
/// requests — each plan runs single-worker — and results come back in
/// request order, identical to a serial run.
pub fn serve_batch(requests: &[MapRequest]) -> Vec<Result<MapPlan, PlanError>> {
    serve_batch_with_threads(requests, opt::sweep_threads())
}

/// [`serve_batch`] with an explicit worker count.
pub fn serve_batch_with_threads(
    requests: &[MapRequest],
    threads: usize,
) -> Vec<Result<MapPlan, PlanError>> {
    crate::util::par::par_for_ordered(requests.len(), threads, || (), |_, i, local| {
        let mut req = requests[i].clone();
        req.threads = 1; // parallelism is across requests
        local.push((i, req.build().and_then(|p| p.plan())));
    })
}

/// Outcome of a [`serve_jsonl`] run.
///
/// `requests` counts the non-blank lines that were served (one response
/// line each); `errors` counts how many of those responded with an error
/// frame. Neither is a line *number*: error frames carry the physical
/// 1-based input line in their `"line"` field (blank lines included), so
/// with blank lines in the input an error's `"line"` can exceed
/// `requests` — that is the documented contract, not a miscount.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeSummary {
    /// non-blank input lines served (one response line each)
    pub requests: usize,
    /// how many of those responses were error frames
    pub errors: usize,
}

/// The v1 JSONL service loop: read one JSON [`MapRequest`] per input line,
/// stream one JSON line per request — a [`MapPlan`] on success, else the
/// [`wire::error_frame`] `{"v":1,"line":N,"error":"..."}` where `N` is the
/// **physical** 1-based input line number (blank lines count toward `N`
/// but produce no response and are excluded from
/// [`ServeSummary::requests`]) — flushing after every line so downstream
/// consumers see plans as they are produced. A malformed line is reported
/// and does not stop the stream.
pub fn serve_jsonl<R: BufRead, W: Write>(input: R, out: &mut W) -> std::io::Result<ServeSummary> {
    let mut summary = ServeSummary { requests: 0, errors: 0 };
    for (idx, line) in input.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        summary.requests += 1;
        match plan_line(line) {
            Ok(plan) => writeln!(out, "{}", plan.to_json().dumps())?,
            Err(e) => {
                summary.errors += 1;
                writeln!(out, "{}", wire::error_frame(idx + 1, &e).dumps())?;
            }
        }
        out.flush()?;
    }
    Ok(summary)
}

/// Parse one JSONL line into a decoded [`MapRequest`] — the first stage
/// of [`serve_jsonl`]. The network service ([`crate::service`]) decodes
/// the same wire via [`MapRequest::from_json`] on its already-parsed
/// document (it must inspect the JSON before deciding the line is a
/// request), with the identical `parse request:` error prefix.
pub fn parse_request_line(line: &str) -> Result<MapRequest, PlanError> {
    let j = crate::util::json::parse(line).map_err(|e| err(format!("parse request: {e}")))?;
    MapRequest::from_json(&j)
}

fn plan_line(line: &str) -> Result<MapPlan, PlanError> {
    parse_request_line(line)?.build()?.plan()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_validates_requests() {
        assert!(MapRequest::zoo("lenet").build().is_ok());
        let msg = |r: MapRequest| r.build().unwrap_err().0;
        assert!(msg(MapRequest::zoo("nope")).contains("unknown network"));
        assert!(msg(MapRequest::zoo("lenet").tile(0, 64)).contains("degenerate"));
        assert!(msg(MapRequest::zoo("lenet").grid((8, 6), vec![1])).contains("empty grid"));
        assert!(msg(MapRequest::zoo("lenet").grid((6, 8), vec![])).contains("no aspect"));
        assert!(msg(MapRequest::zoo("lenet").grid((6, 8), vec![0])).contains("aspect factor"));
        assert!(msg(MapRequest::zoo("lenet").ilp(0)).contains("budget"));
        assert!(
            msg(MapRequest::zoo("lenet").replication(Replication::Explicit(vec![1, 2])))
                .contains("arity")
        );
        assert!(
            msg(MapRequest::inline(Network::new("empty", "none", vec![])))
                .contains("no layers")
        );
    }

    #[test]
    fn replication_specs_resolve_to_rapa_plans() {
        let net = zoo::resnet18();
        let p = MapRequest::zoo("resnet18")
            .replication(Replication::Balanced(128))
            .build()
            .unwrap();
        assert_eq!(p.replication(), rapa::plan_balanced(&net, 128).as_slice());
        let p = MapRequest::zoo("resnet18")
            .replication(Replication::Geometric(128, 4))
            .build()
            .unwrap();
        assert_eq!(p.replication(), rapa::plan_geometric(&net, 128, 4).as_slice());
        let p = MapRequest::zoo("bert").replication(Replication::Uniform(64)).build().unwrap();
        assert_eq!(p.replication(), rapa::plan_uniform(p.network(), 64).as_slice());
    }

    #[test]
    fn fixed_tile_plan_matches_direct_engine() {
        let tile = Tile::new(256, 256);
        let planner = MapRequest::zoo("lenet")
            .tile(tile.n_row, tile.n_col)
            .discipline(Discipline::Pipeline)
            .placements(true)
            .build()
            .unwrap();
        let plan = planner.plan().unwrap();
        let blocks = frag::fragment_network(planner.network(), tile);
        let direct = pack::simple::pack(&blocks, tile, Discipline::Pipeline);
        assert_eq!(plan.points.len(), 1);
        assert_eq!(plan.best.n_tiles, direct.n_bins);
        assert_eq!(plan.placements.as_deref(), Some(direct.placements.as_slice()));
        assert_eq!(plan.best.aspect, 1);
    }

    #[test]
    fn off_grid_fixed_tile_gets_sentinel_aspect() {
        let plan = MapRequest::zoo("lenet").tile(96, 64).build().unwrap().plan().unwrap();
        assert_eq!(plan.best.aspect, OFF_GRID_ASPECT);
    }

    #[test]
    fn grid_plan_equals_hidden_sweep() {
        let planner = MapRequest::zoo("lenet").discipline(Discipline::Pipeline).build().unwrap();
        let plan = planner.plan().unwrap();
        let cfg = SweepConfig::paper_default(Discipline::Pipeline);
        let direct = opt::sweep_serial(planner.network(), &cfg);
        assert_eq!(plan.points.len(), direct.len());
        for (a, b) in plan.points.iter().zip(&direct) {
            assert_eq!((a.tile, a.n_tiles), (b.tile, b.n_tiles));
            assert_eq!(a.total_area_mm2.to_bits(), b.total_area_mm2.to_bits());
        }
        assert_eq!(plan.best, opt::optimum(&direct).unwrap());
        assert_eq!(plan.best_per_aspect.len(), 8);
    }

    #[test]
    fn objectives_pick_distinct_optima() {
        // paper observation: min tiles != min area on resnet18 dense/square
        let base = MapRequest::zoo("resnet18").grid((6, 13), vec![1]);
        let by_area = base.clone().objective(Objective::MinArea).build().unwrap().plan().unwrap();
        let by_tiles = base.clone().objective(Objective::MinTiles).build().unwrap().plan().unwrap();
        assert!(by_tiles.best.n_tiles <= by_area.best.n_tiles);
        assert!(by_area.best.total_area_mm2 <= by_tiles.best.total_area_mm2);
        assert_ne!(by_area.best.tile, by_tiles.best.tile);
    }

    #[test]
    fn max_throughput_objective_selects_a_per_aspect_winner() {
        let plan = MapRequest::zoo("lenet")
            .grid((7, 9), vec![1, 2])
            .discipline(Discipline::Pipeline)
            .objective(Objective::MaxThroughput)
            .build()
            .unwrap()
            .plan()
            .unwrap();
        assert!(plan.best_per_aspect.iter().any(|p| p.tile == plan.best.tile));
        assert!(plan.throughput_per_s > 0.0);
    }

    #[test]
    fn counted_mode_prices_identically_to_placement_mode() {
        // without a placement request the planner prices through the
        // counted shape-class path; numbers must match the per-block
        // engines bit for bit, and the mode is recorded in provenance
        for engine in [Engine::Simple, Engine::Ffd, Engine::Ilp { max_nodes: 200_000 }] {
            let base = MapRequest::zoo("lenet").tile(256, 256).discipline(Discipline::Pipeline).engine(engine);
            let counted = base.clone().build().unwrap().plan().unwrap();
            let placed = base.placements(true).build().unwrap().plan().unwrap();
            assert!(counted.provenance.counted, "{engine}");
            assert!(!placed.provenance.counted, "{engine}");
            assert!(counted.placements.is_none());
            assert_eq!(counted.best.n_tiles, placed.best.n_tiles, "{engine}");
            assert_eq!(
                counted.best.packing_eff.to_bits(),
                placed.best.packing_eff.to_bits(),
                "{engine}"
            );
            assert_eq!(
                counted.best.total_area_mm2.to_bits(),
                placed.best.total_area_mm2.to_bits(),
                "{engine}"
            );
        }
    }

    #[test]
    fn ilp_provenance_records_budget_and_warm_hits() {
        let plan = MapRequest::zoo("lenet")
            .grid((7, 9), vec![1])
            .ilp(200_000)
            .discipline(Discipline::Pipeline)
            .build()
            .unwrap()
            .plan()
            .unwrap();
        assert_eq!(plan.provenance.budget_nodes, 200_000);
        assert!(plan.provenance.optimal, "lenet at this scale proves optimality");
        assert!(plan.provenance.lower_bound >= 1);
        // capacity monotonicity: the 3-point column confirms some hints
        assert!(plan.provenance.warm_hits <= 2);
    }

    #[test]
    fn expired_deadline_yields_typed_plan_error() {
        let planner = MapRequest::zoo("lenet").build().unwrap();
        let e = planner
            .plan_with_deadline(Deadline::after(std::time::Duration::ZERO))
            .unwrap_err();
        assert!(e.is_deadline(), "{e}");
        assert!(e.0.starts_with(DEADLINE_ERROR_PREFIX));
        // non-deadline errors are not misclassified
        assert!(!MapRequest::zoo("nope").build().unwrap_err().is_deadline());
        // an unset deadline is plan() exactly
        let a = planner.plan().unwrap();
        let b = planner.plan_with_deadline(Deadline::NONE).unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(a.points, b.points);
    }

    #[test]
    fn serve_batch_preserves_request_order_and_reports_errors() {
        let reqs = vec![
            MapRequest::zoo("lenet").id("a").grid((6, 13), vec![1]),
            MapRequest::zoo("ghost-net").id("b"),
            MapRequest::zoo("lenet").id("c").grid((6, 13), vec![1]).discipline(Discipline::Pipeline),
        ];
        let out = serve_batch_with_threads(&reqs, 3);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].as_ref().unwrap().id, "a");
        assert!(out[1].as_ref().unwrap_err().0.contains("unknown network"));
        assert_eq!(out[2].as_ref().unwrap().id, "c");
        let serial = serve_batch_with_threads(&reqs, 1);
        assert_eq!(out[0].as_ref().unwrap().points, serial[0].as_ref().unwrap().points);
    }

    #[test]
    fn plan_deployment_solves_once_and_matches_the_engine() {
        let tile = Tile::new(256, 256);
        let planner = MapRequest::zoo("lenet")
            .tile(tile.n_row, tile.n_col)
            .discipline(Discipline::Pipeline)
            .build()
            .unwrap();
        let (plan, mapping) = planner.plan_deployment().unwrap();
        // the mapping is the exact per-block engine pack, and the plan
        // prices it: same bin count, and the latency the fixed-tile plan
        // path reports
        let direct = planner.pack(tile).unwrap().packing;
        assert_eq!(mapping.placements, direct.placements);
        assert_eq!(mapping.n_bins, direct.n_bins);
        assert_eq!(plan.best.n_tiles, mapping.n_bins);
        let solo = planner.plan().unwrap();
        assert_eq!(plan.best.total_area_mm2.to_bits(), solo.best.total_area_mm2.to_bits());
        assert_eq!(plan.latency_s.to_bits(), solo.latency_s.to_bits());
        // placements live on the packing unless the request asked for them
        assert!(plan.placements.is_none());
        let (plan2, mapping2) = MapRequest::zoo("lenet")
            .tile(tile.n_row, tile.n_col)
            .discipline(Discipline::Pipeline)
            .placements(true)
            .build()
            .unwrap()
            .plan_deployment()
            .unwrap();
        assert_eq!(plan2.placements.as_deref(), Some(mapping2.placements.as_slice()));
        // a deployment is one physical tile dimension — grids are rejected
        let grid = MapRequest::zoo("lenet").build().unwrap();
        assert!(grid.plan_deployment().unwrap_err().0.contains("fixed tile"));
    }

    #[test]
    fn serve_jsonl_error_lines_are_physical_line_numbers() {
        // two blank lines precede the malformed request: the error frame
        // points at physical line 4 of the input while the summary counts
        // only the two non-blank requests — the documented contract
        let input = concat!(
            "\n\n",
            r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#,
            "\n",
            "not json\n",
        );
        let mut out = Vec::new();
        let summary = serve_jsonl(input.as_bytes(), &mut out).unwrap();
        assert_eq!(summary, ServeSummary { requests: 2, errors: 1 });
        let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
        assert_eq!(lines.len(), 2);
        let err_line = crate::util::json::parse(lines[1]).unwrap();
        assert_eq!(err_line.get("line").and_then(|v| v.as_usize()), Some(4));
        assert!(err_line.get("error").is_some());
    }

    #[test]
    fn serve_jsonl_streams_plans_and_inline_errors() {
        let input = concat!(
            r#"{"v":1,"id":"q1","net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#,
            "\n\n",
            "not json\n",
            r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"grid":{"row_exp":[6,8],"aspects":[1]}}}"#,
            "\n",
        );
        let mut out = Vec::new();
        let summary = serve_jsonl(input.as_bytes(), &mut out).unwrap();
        assert_eq!(summary, ServeSummary { requests: 3, errors: 1 });
        let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
        assert_eq!(lines.len(), 3);
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.get("id").and_then(|v| v.as_str()), Some("q1"));
        assert_eq!(first.get("v").and_then(|v| v.as_usize()), Some(1));
        let err_line = crate::util::json::parse(lines[1]).unwrap();
        assert!(err_line.get("error").is_some());
        assert_eq!(err_line.get("line").and_then(|v| v.as_usize()), Some(3));
        let third = crate::util::json::parse(lines[2]).unwrap();
        assert_eq!(third.get("points").and_then(|v| v.as_arr()).map(|a| a.len()), Some(3));
    }
}
