//! v1 JSON wire format for [`MapRequest`] / [`MapPlan`].
//!
//! Every document carries a `"v": 1` version tag and is rejected on
//! mismatch, so the JSONL service endpoints can evolve the schema without
//! silently misreading old clients. Serialization is canonical (fixed key
//! order, optional fields omitted when they hold their defaults), and
//! `parse -> serialize -> parse` is the identity — enforced by the
//! property suite in `rust/tests/integration_plan.rs`.
//!
//! Request schema (minimal form: `{"v":1,"net":{"zoo":"resnet18"}}`):
//!
//! ```json
//! {"v":1, "id":"tenant-42",
//!  "net": {"zoo":"resnet18"} | {"name":..,"input":..,"layers":[
//!          {"name":"fc1","fc":[784,256]} |
//!          {"name":"c1","conv":[3,64,7,2,3,224],"bias":false,"reuse":64}]},
//!  "discipline":"dense|pipeline", "engine":"simple|ffd|lps", "ilp_nodes":N,
//!  "tiles": {"fixed":[rows,cols]} | {"grid":{"row_exp":[6,13],"aspects":[1,..,8]}},
//!  "objective":"min-area|min-tiles|max-throughput",
//!  "replication": {"balanced":128} | {"geometric":[128,4]} | {"uniform":64}
//!               | {"explicit":[..]},
//!  "threads":0, "placements":true, "sort":"rows-desc|rows-asc|as-given",
//!  "area": {"d_unit_in":..,"d_unit_out":..,"d_cnt":..,"periph_gamma":..,"ref_edge":..}}
//! ```
//!
//! Plan schema: see [`plan_to_json`] (points/best/best_per_aspect as
//! sweep-point objects, placements as `[block,bin,x,y]` rows, and a
//! `provenance` object with budget, nodes, proof status, warm-start hits,
//! worker count, and whether the plan was priced through the counted
//! shape-class path).
//!
//! Service frames (the planning service's side channel on the same wire):
//! [`error_frame`], the typed admission [`reject_frame`], the
//! [`stats_frame`]/[`metrics_frame`] pair (one shared counter serializer,
//! so field names cannot drift), and the [`metrics_medians`] flat gauge
//! export the `--metrics-out` writer emits. The normative spec with
//! worked, test-pinned examples is `docs/WIRE.md` at the repo root.
//!
//! Numbers ride on the `util::json` f64 value model, so integers are exact
//! only up to 2^53 — ILP node budgets beyond that (quadrillions of nodes,
//! far past any practical solve) would round on the wire.
//!
//! The [`scan`] submodule is the hot-path companion to this codec: a
//! byte-level scanner that extracts the request discriminators (`v`,
//! `cmd`, `net`, `id`) and the candidate cache key without building a
//! JSON tree, declaring fallback to the full parse on anything outside
//! its modeled subset. This module stays the source of truth; the
//! differential suite in `tests/prop_wire_scan.rs` pins their agreement.

pub mod scan;

use super::{
    MapPlan, MapRequest, NetworkSpec, Objective, PlanError, Provenance, Replication, TileSpace,
    WIRE_VERSION,
};
use crate::area::AreaModel;
use crate::geom::{Placement, Tile};
use crate::nets::{Layer, LayerKind, Network};
use crate::opt::{Engine, SweepPoint};
use crate::pack::SortOrder;
use crate::util::json::{Json, JsonObj};

fn err(msg: impl Into<String>) -> PlanError {
    PlanError(msg.into())
}

// ---- small typed accessors over the Json value model ----

fn obj<'a>(j: &'a Json, what: &str) -> Result<&'a JsonObj, PlanError> {
    j.as_obj().ok_or_else(|| err(format!("{what} must be a JSON object")))
}

/// Exact non-negative integer, or `None`: fractional values are rejected
/// (`256.9` must not silently plan a 256-row tile) and the f64 mantissa
/// bound (2^53) caps what can ride the wire losslessly.
fn exact_int(j: &Json) -> Option<u64> {
    let n = j.as_f64()?;
    if n < 0.0 || n != n.trunc() || n > 9_007_199_254_740_992.0 {
        return None;
    }
    Some(n as u64)
}

fn exact_usize(j: &Json) -> Option<usize> {
    exact_int(j).map(|n| n as usize)
}

fn get_usize(o: &JsonObj, k: &str) -> Result<usize, PlanError> {
    o.get(k)
        .and_then(exact_usize)
        .ok_or_else(|| err(format!("missing/invalid integer '{k}'")))
}

fn get_u64(o: &JsonObj, k: &str) -> Result<u64, PlanError> {
    o.get(k).and_then(exact_int).ok_or_else(|| err(format!("missing/invalid integer '{k}'")))
}

fn get_f64(o: &JsonObj, k: &str) -> Result<f64, PlanError> {
    o.get(k).and_then(Json::as_f64).ok_or_else(|| err(format!("missing/invalid number '{k}'")))
}

fn get_str<'a>(o: &'a JsonObj, k: &str) -> Result<&'a str, PlanError> {
    o.get(k).and_then(Json::as_str).ok_or_else(|| err(format!("missing/invalid string '{k}'")))
}

fn usize_arr(j: &Json, what: &str) -> Result<Vec<usize>, PlanError> {
    let a = j.as_arr().ok_or_else(|| err(format!("{what} must be an array of integers")))?;
    let v: Vec<usize> = a.iter().filter_map(exact_usize).collect();
    if v.len() != a.len() {
        return Err(err(format!("{what} must be an array of integers")));
    }
    Ok(v)
}

pub(crate) fn check_version(o: &JsonObj, what: &str) -> Result<(), PlanError> {
    match o.get("v").and_then(Json::as_f64) {
        // exact integral match: "v":1.9 is a mismatch, not a v1 document
        Some(v) if v == v.trunc() && v as u64 == WIRE_VERSION => Ok(()),
        Some(v) => Err(err(format!("unsupported {what} wire version {v} (expected {WIRE_VERSION})"))),
        None => Err(err(format!("{what} missing wire version tag \"v\""))),
    }
}

// ---- MapRequest ----

/// Encode a request as a canonical v1 wire object.
pub fn request_to_json(r: &MapRequest) -> Json {
    let mut o = JsonObj::new();
    o.set("v", WIRE_VERSION);
    if !r.id.is_empty() {
        o.set("id", r.id.as_str());
    }
    o.set("net", net_spec_to_json(&r.network));
    o.set("discipline", r.discipline.canonical());
    o.set("engine", r.engine.canonical());
    if let Engine::Ilp { max_nodes } = r.engine {
        o.set("ilp_nodes", max_nodes);
    }
    o.set("tiles", tiles_to_json(&r.tiles));
    o.set("objective", r.objective.canonical());
    match &r.replication {
        Replication::None => {}
        Replication::Balanced(n0) => {
            let mut m = JsonObj::new();
            m.set("balanced", *n0);
            o.set("replication", m);
        }
        Replication::Geometric(n0, f) => {
            let mut m = JsonObj::new();
            m.set("geometric", vec![Json::from(*n0), Json::from(*f)]);
            o.set("replication", m);
        }
        Replication::Uniform(s) => {
            let mut m = JsonObj::new();
            m.set("uniform", *s);
            o.set("replication", m);
        }
        Replication::Explicit(v) => {
            let mut m = JsonObj::new();
            m.set("explicit", v.iter().map(|&x| Json::from(x)).collect::<Vec<_>>());
            o.set("replication", m);
        }
    }
    if r.threads != 0 {
        o.set("threads", r.threads);
    }
    if r.include_placements {
        o.set("placements", true);
    }
    if r.sort != SortOrder::RowsDesc {
        o.set("sort", r.sort.canonical());
    }
    if r.area != AreaModel::paper_default() {
        o.set("area", area_to_json(&r.area));
    }
    Json::Obj(o)
}

/// Decode a v1 wire object into a request. Omitted optional fields take
/// the paper defaults, so `{"v":1,"net":{"zoo":"resnet18"}}` is a complete
/// request.
pub fn request_from_json(j: &Json) -> Result<MapRequest, PlanError> {
    let o = obj(j, "request")?;
    check_version(o, "request")?;
    let net = net_spec_from_json(o.get("net").ok_or_else(|| err("request missing 'net'"))?)?;
    let mut r = MapRequest::with_network(net);
    if let Some(id) = o.get("id") {
        r.id = id.as_str().ok_or_else(|| err("'id' must be a string"))?.to_string();
    }
    if let Some(d) = o.get("discipline") {
        r.discipline = d
            .as_str()
            .ok_or_else(|| err("'discipline' must be a string"))?
            .parse()
            .map_err(PlanError)?;
    }
    if let Some(e) = o.get("engine") {
        let token = e.as_str().ok_or_else(|| err("'engine' must be a string"))?;
        let nodes = match o.get("ilp_nodes") {
            Some(n) => exact_int(n).ok_or_else(|| err("'ilp_nodes' must be an integer"))?,
            None => Engine::DEFAULT_ILP_NODES,
        };
        r.engine = Engine::parse_with_budget(token, nodes).map_err(PlanError)?;
    }
    if let Some(t) = o.get("tiles") {
        r.tiles = tiles_from_json(t)?;
    }
    if let Some(ob) = o.get("objective") {
        r.objective = ob
            .as_str()
            .ok_or_else(|| err("'objective' must be a string"))?
            .parse()
            .map_err(PlanError)?;
    }
    if let Some(rep) = o.get("replication") {
        r.replication = replication_from_json(rep)?;
    }
    if let Some(t) = o.get("threads") {
        r.threads = exact_usize(t).ok_or_else(|| err("'threads' must be an integer"))?;
    }
    if let Some(p) = o.get("placements") {
        r.include_placements = p.as_bool().ok_or_else(|| err("'placements' must be a bool"))?;
    }
    if let Some(s) = o.get("sort") {
        r.sort =
            s.as_str().ok_or_else(|| err("'sort' must be a string"))?.parse().map_err(PlanError)?;
    }
    if let Some(a) = o.get("area") {
        r.area = area_from_json(a)?;
    }
    Ok(r)
}

fn net_spec_to_json(spec: &NetworkSpec) -> JsonObj {
    let mut o = JsonObj::new();
    match spec {
        NetworkSpec::Zoo(name) => {
            o.set("zoo", name.as_str());
        }
        NetworkSpec::Inline(net) => {
            o.set("name", net.name.as_str());
            o.set("input", net.input_desc.as_str());
            o.set(
                "layers",
                net.layers.iter().map(|l| Json::Obj(layer_to_json(l))).collect::<Vec<_>>(),
            );
        }
    }
    o
}

fn net_spec_from_json(j: &Json) -> Result<NetworkSpec, PlanError> {
    let o = obj(j, "'net'")?;
    if let Some(z) = o.get("zoo") {
        return Ok(NetworkSpec::Zoo(
            z.as_str().ok_or_else(|| err("'net.zoo' must be a string"))?.to_string(),
        ));
    }
    let name = get_str(o, "name")?;
    let input = o.get("input").and_then(Json::as_str).unwrap_or("");
    let layers = o
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| err("inline 'net' needs a 'layers' array"))?
        .iter()
        .map(layer_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(NetworkSpec::Inline(Network::new(name, input, layers)))
}

fn layer_to_json(l: &Layer) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("name", l.name.as_str());
    match l.kind {
        LayerKind::Fc { fan_in, fan_out } => {
            o.set("fc", vec![Json::from(fan_in), Json::from(fan_out)]);
        }
        LayerKind::Conv { in_ch, out_ch, kernel, stride, padding, in_size } => {
            o.set(
                "conv",
                [in_ch, out_ch, kernel, stride, padding, in_size]
                    .iter()
                    .map(|&x| Json::from(x))
                    .collect::<Vec<_>>(),
            );
        }
    }
    if !l.bias {
        o.set("bias", false);
    }
    if let Some(r) = l.reuse_override {
        o.set("reuse", r);
    }
    o
}

fn layer_from_json(j: &Json) -> Result<Layer, PlanError> {
    let o = obj(j, "layer")?;
    let name = get_str(o, "name")?;
    let mut layer = if let Some(fc) = o.get("fc") {
        let dims = usize_arr(fc, "'fc'")?;
        if dims.len() != 2 {
            return Err(err("'fc' must be [fan_in, fan_out]"));
        }
        // lint: allow(panic) length checked to be exactly 2 above
        Layer::fc(name, dims[0], dims[1])
    } else if let Some(conv) = o.get("conv") {
        let d = usize_arr(conv, "'conv'")?;
        if d.len() != 6 {
            return Err(err("'conv' must be [in_ch,out_ch,kernel,stride,padding,in_size]"));
        }
        // lint: allow(panic) length checked to be exactly 6 above
        Layer::conv(name, d[0], d[1], d[2], d[3], d[4], d[5])
    } else {
        return Err(err(format!("layer '{name}' needs an 'fc' or 'conv' shape")));
    };
    if let Some(b) = o.get("bias") {
        layer.bias = b.as_bool().ok_or_else(|| err("'bias' must be a bool"))?;
    }
    if let Some(r) = o.get("reuse") {
        layer.reuse_override =
            Some(exact_usize(r).ok_or_else(|| err("'reuse' must be an integer"))?);
    }
    Ok(layer)
}

fn tiles_to_json(t: &TileSpace) -> JsonObj {
    let mut o = JsonObj::new();
    match t {
        TileSpace::Fixed(tile) => {
            o.set("fixed", vec![Json::from(tile.n_row), Json::from(tile.n_col)]);
        }
        TileSpace::Grid { row_exp, aspects } => {
            let mut g = JsonObj::new();
            g.set("row_exp", vec![Json::from(row_exp.0), Json::from(row_exp.1)]);
            g.set("aspects", aspects.iter().map(|&a| Json::from(a)).collect::<Vec<_>>());
            o.set("grid", g);
        }
    }
    o
}

fn tiles_from_json(j: &Json) -> Result<TileSpace, PlanError> {
    let o = obj(j, "'tiles'")?;
    if let Some(f) = o.get("fixed") {
        let d = usize_arr(f, "'tiles.fixed'")?;
        if d.len() != 2 {
            return Err(err("'tiles.fixed' must be [rows, cols]"));
        }
        // lint: allow(panic) length checked to be exactly 2 above
        return Ok(TileSpace::Fixed(Tile::new(d[0], d[1])));
    }
    let g = obj(
        o.get("grid").ok_or_else(|| err("'tiles' needs 'fixed' or 'grid'"))?,
        "'tiles.grid'",
    )?;
    let re = usize_arr(
        g.get("row_exp").ok_or_else(|| err("'tiles.grid' missing 'row_exp'"))?,
        "'row_exp'",
    )?;
    if re.len() != 2 {
        return Err(err("'row_exp' must be [lo, hi]"));
    }
    let exp = |v: usize| u32::try_from(v).map_err(|_| err(format!("row exponent {v} out of range")));
    let aspects = usize_arr(
        g.get("aspects").ok_or_else(|| err("'tiles.grid' missing 'aspects'"))?,
        "'aspects'",
    )?;
    // lint: allow(panic) length checked to be exactly 2 above
    Ok(TileSpace::Grid { row_exp: (exp(re[0])?, exp(re[1])?), aspects })
}

fn replication_from_json(j: &Json) -> Result<Replication, PlanError> {
    if matches!(j, Json::Null) {
        return Ok(Replication::None);
    }
    let o = obj(j, "'replication'")?;
    if let Some(n) = o.get("balanced") {
        return Ok(Replication::Balanced(
            exact_usize(n).ok_or_else(|| err("'balanced' must be an integer"))?,
        ));
    }
    if let Some(g) = o.get("geometric") {
        let d = usize_arr(g, "'geometric'")?;
        if d.len() != 2 {
            return Err(err("'geometric' must be [n0, factor]"));
        }
        // lint: allow(panic) length checked to be exactly 2 above
        return Ok(Replication::Geometric(d[0], d[1]));
    }
    if let Some(u) = o.get("uniform") {
        return Ok(Replication::Uniform(
            exact_usize(u).ok_or_else(|| err("'uniform' must be an integer"))?,
        ));
    }
    if let Some(e) = o.get("explicit") {
        return Ok(Replication::Explicit(usize_arr(e, "'explicit'")?));
    }
    Err(err("'replication' needs balanced|geometric|uniform|explicit"))
}

fn area_to_json(a: &AreaModel) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("d_unit_in", a.d_unit_in)
        .set("d_unit_out", a.d_unit_out)
        .set("d_cnt", a.d_cnt)
        .set("periph_gamma", a.periph_gamma)
        .set("ref_edge", a.ref_edge);
    o
}

fn area_from_json(j: &Json) -> Result<AreaModel, PlanError> {
    let o = obj(j, "'area'")?;
    Ok(AreaModel {
        d_unit_in: get_f64(o, "d_unit_in")?,
        d_unit_out: get_f64(o, "d_unit_out")?,
        d_cnt: get_f64(o, "d_cnt")?,
        periph_gamma: get_f64(o, "periph_gamma")?,
        ref_edge: get_f64(o, "ref_edge")?,
    })
}

// ---- MapPlan ----

/// Encode a plan as a canonical v1 wire object.
pub fn plan_to_json(p: &MapPlan) -> Json {
    let mut o = JsonObj::new();
    o.set("v", WIRE_VERSION);
    if !p.id.is_empty() {
        o.set("id", p.id.as_str());
    }
    o.set("net", p.network.as_str());
    o.set("discipline", p.discipline.canonical());
    o.set("engine", p.engine.canonical());
    if let Engine::Ilp { max_nodes } = p.engine {
        o.set("ilp_nodes", max_nodes);
    }
    o.set("objective", p.objective.canonical());
    o.set("points", p.points.iter().map(|pt| Json::Obj(point_to_json(pt))).collect::<Vec<_>>());
    o.set(
        "best_per_aspect",
        p.best_per_aspect.iter().map(|pt| Json::Obj(point_to_json(pt))).collect::<Vec<_>>(),
    );
    o.set("best", point_to_json(&p.best));
    if let Some(placements) = &p.placements {
        o.set(
            "placements",
            placements
                .iter()
                .map(|pl| {
                    Json::Arr(vec![
                        Json::from(pl.block),
                        Json::from(pl.bin),
                        Json::from(pl.x),
                        Json::from(pl.y),
                    ])
                })
                .collect::<Vec<_>>(),
        );
    }
    o.set("latency_s", p.latency_s);
    o.set("throughput_per_s", p.throughput_per_s);
    let mut prov = JsonObj::new();
    prov.set("budget_nodes", p.provenance.budget_nodes)
        .set("nodes", p.provenance.nodes)
        .set("optimal", p.provenance.optimal)
        .set("lower_bound", p.provenance.lower_bound)
        .set("warm_hits", p.provenance.warm_hits)
        .set("threads", p.provenance.threads)
        .set("counted", p.provenance.counted);
    o.set("provenance", prov);
    Json::Obj(o)
}

/// Decode a v1 wire object into a plan.
pub fn plan_from_json(j: &Json) -> Result<MapPlan, PlanError> {
    let o = obj(j, "plan")?;
    check_version(o, "plan")?;
    let engine = {
        let token = get_str(o, "engine")?;
        let nodes = match o.get("ilp_nodes") {
            Some(n) => exact_int(n).ok_or_else(|| err("'ilp_nodes' must be an integer"))?,
            None => Engine::DEFAULT_ILP_NODES,
        };
        Engine::parse_with_budget(token, nodes).map_err(PlanError)?
    };
    let points_of = |k: &str| -> Result<Vec<SweepPoint>, PlanError> {
        o.get(k)
            .and_then(Json::as_arr)
            .ok_or_else(|| err(format!("plan missing '{k}' array")))?
            .iter()
            .map(point_from_json)
            .collect()
    };
    let placements = match o.get("placements") {
        None | Some(Json::Null) => None,
        Some(arr) => Some(
            arr.as_arr()
                .ok_or_else(|| err("'placements' must be an array"))?
                .iter()
                .map(|row| {
                    let d = usize_arr(row, "placement")?;
                    if d.len() != 4 {
                        return Err(err("placement must be [block,bin,x,y]"));
                    }
                    // lint: allow(panic) length checked to be exactly 4 above
                    Ok(Placement { block: d[0], bin: d[1], x: d[2], y: d[3] })
                })
                .collect::<Result<Vec<_>, _>>()?,
        ),
    };
    let prov = obj(
        o.get("provenance").ok_or_else(|| err("plan missing 'provenance'"))?,
        "'provenance'",
    )?;
    Ok(MapPlan {
        id: o.get("id").and_then(Json::as_str).unwrap_or("").to_string(),
        network: get_str(o, "net")?.to_string(),
        discipline: get_str(o, "discipline")?.parse().map_err(PlanError)?,
        engine,
        objective: get_str(o, "objective")?.parse().map_err(PlanError)?,
        points: points_of("points")?,
        best_per_aspect: points_of("best_per_aspect")?,
        best: point_from_json(o.get("best").ok_or_else(|| err("plan missing 'best'"))?)?,
        placements,
        latency_s: get_f64(o, "latency_s")?,
        throughput_per_s: get_f64(o, "throughput_per_s")?,
        provenance: Provenance {
            budget_nodes: get_u64(prov, "budget_nodes")?,
            nodes: get_u64(prov, "nodes")?,
            optimal: prov
                .get("optimal")
                .and_then(Json::as_bool)
                .ok_or_else(|| err("provenance missing 'optimal'"))?,
            lower_bound: get_usize(prov, "lower_bound")?,
            warm_hits: get_usize(prov, "warm_hits")?,
            threads: get_usize(prov, "threads")?,
            // absent in pre-counted-kernel documents (those plans were
            // priced per-block); present-but-mistyped is a decode error
            // like every other provenance field
            counted: match prov.get("counted") {
                None => false,
                Some(v) => {
                    v.as_bool().ok_or_else(|| err("provenance 'counted' must be a bool"))?
                }
            },
        },
    })
}

// ---- service frames ----

/// The JSONL error frame shared by every request-path loop:
/// `{"v":1,"line":N,"error":"..."}`. `line` is the **physical** 1-based
/// line number within the input stream or connection — blank lines count,
/// so the number always points at the offending line of whatever the
/// client actually sent (it is *not* the request ordinal; see
/// [`super::ServeSummary`]).
pub fn error_frame(line: usize, e: &PlanError) -> Json {
    Json::Obj(error_obj(line, e))
}

/// Shared `v`/`line`/`error` body of [`error_frame`] and
/// [`reject_frame`] — one builder, so the two frame shapes cannot drift.
fn error_obj(line: usize, e: &PlanError) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("v", WIRE_VERSION).set("line", line).set("error", e.0.as_str());
    o
}

/// Why the planning service refused to plan a request it could have
/// parsed: admission control, not a malformed or unsolvable request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectKind {
    /// the connection exhausted its `--per-conn-quota` request budget;
    /// the service answers this frame and then closes the connection
    OverQuota,
    /// the service is at its `--max-inflight` admission cap; transient —
    /// the connection stays open and the client may retry
    OverInflight,
    /// the planner panicked while solving this request; the panic was
    /// contained to the request, the worker survived, and the connection
    /// stays open — retrying the *same* request will likely panic again,
    /// but the service itself is healthy
    Internal,
    /// the solve exceeded the service's `--deadline-ms` wall-clock budget
    /// and was cooperatively cancelled; transient in the sense that the
    /// connection stays open, but the same request will time out again
    /// unless the service is less loaded or reconfigured
    Deadline,
    /// an admin command (`recalibrate`) arrived without the service's
    /// `--admin-token` shared secret; the connection stays open — only
    /// the privileged verb is refused
    Unauthorized,
}

impl RejectKind {
    /// The machine-readable token carried in the frame's `"reject"` field.
    pub fn token(self) -> &'static str {
        match self {
            RejectKind::OverQuota => "over-quota",
            RejectKind::OverInflight => "over-inflight",
            RejectKind::Internal => "internal",
            RejectKind::Deadline => "deadline",
            RejectKind::Unauthorized => "unauthorized",
        }
    }
}

/// A typed planning-service rejection: an [`error_frame`] (same `v`,
/// `line`, `error` fields, so clients that only understand error frames
/// degrade gracefully) extended with a machine-readable
/// `"reject":"over-quota"|"over-inflight"|"internal"|"deadline"|"unauthorized"`
/// discriminator. Emitted only by the planning service — the file
/// endpoint has no admission control, panic containment, or deadlines.
pub fn reject_frame(line: usize, kind: RejectKind, e: &PlanError) -> Json {
    let mut o = error_obj(line, e);
    o.set("reject", kind.token());
    Json::Obj(o)
}

/// Counters and plan-latency percentiles reported by the planning
/// service's in-band `{"v":1,"cmd":"stats"}` request.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StatsSnapshot {
    /// plan responses served (cache hits included; error frames excluded)
    pub served: u64,
    /// error frames served
    pub errors: u64,
    /// plan responses answered from the canonical-request cache
    pub cache_hits: u64,
    /// connections accepted since startup
    pub connections: u64,
    /// planner panics contained by the worker pool (each also counts as
    /// an error and as an `internal` rejection)
    pub panics: u64,
    /// solves cancelled by the per-request `--deadline-ms` wall-clock
    /// budget (each also counts as an error)
    pub timeouts: u64,
    /// requests refused with the `"reject":"internal"` frame (contained
    /// panics plus coalesced followers of a panicking leader; kept as its
    /// own counter so the reject taxonomy stays 1:1 with the wire tokens)
    pub rejected_internal: u64,
    /// plan responses answered from the on-disk warehouse (the cache tier
    /// behind the LRU; each also counts as served, not as a cache hit)
    pub warehouse_hits: u64,
    /// solved plans durably appended to the warehouse by the background
    /// writer (admission never blocks on disk; a full writer queue sheds
    /// the append, so this can lag served misses)
    pub warehouse_writes: u64,
    /// responses delivered to single-flight followers — requests that
    /// arrived while an identical canonical request was already solving
    /// and were answered by the leader's outcome without a second solve
    pub coalesced: u64,
    /// cluster worker processes respawned by the supervisor after a crash
    /// or a missed liveness probe (always 0 on a single-process service
    /// and on the shard workers themselves — only the cluster router
    /// counts respawns)
    pub shard_respawns: u64,
    /// requests re-sent to a respawned shard after the shard that owed
    /// them died mid-solve (planning is pure, so replay is safe; each
    /// replayed request still counts served/errors exactly once)
    pub replayed: u64,
    /// requests answered by the cluster router's own embedded planner
    /// because the owning shard's circuit breaker was open (byte-identical
    /// to a shard answer — the degradation is visible only here)
    pub degraded: u64,
    /// requests refused by tenant policy: plan requests over the
    /// `--tenant-quota` per-tenant budget (`"reject":"over-quota"`, the
    /// budget survives reconnects — unlike the per-connection quota) plus
    /// `recalibrate` commands refused for a missing or wrong
    /// `--admin-token` (`"reject":"unauthorized"`); each also counts as
    /// an error
    pub tenant_rejects: u64,
    /// nearest-rank p50 of plan *solve* latency, seconds (cache hits and
    /// error frames don't contribute samples)
    pub plan_p50_s: f64,
    /// nearest-rank p95 of plan solve latency, seconds
    pub plan_p95_s: f64,
}

/// Serialize the counter/percentile set shared **verbatim** by the
/// `stats` and `metrics` frames. Both frames build their payload through
/// this one function (and decode through [`counters_from_obj`]), so the
/// shared field names can never drift between the two — the metrics frame
/// is always a strict superset of the stats frame.
fn counters_to_obj(s: &StatsSnapshot) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("served", s.served)
        .set("errors", s.errors)
        .set("cache_hits", s.cache_hits)
        .set("connections", s.connections)
        .set("panics", s.panics)
        .set("timeouts", s.timeouts)
        .set("rejected_internal", s.rejected_internal)
        .set("warehouse_hits", s.warehouse_hits)
        .set("warehouse_writes", s.warehouse_writes)
        .set("coalesced", s.coalesced)
        .set("shard_respawns", s.shard_respawns)
        .set("replayed", s.replayed)
        .set("degraded", s.degraded)
        .set("tenant_rejects", s.tenant_rejects)
        .set("plan_p50_s", s.plan_p50_s)
        .set("plan_p95_s", s.plan_p95_s);
    o
}

/// Decode partner of [`counters_to_obj`] — one field list, used by both
/// frame decoders.
fn counters_from_obj(s: &JsonObj) -> Result<StatsSnapshot, PlanError> {
    Ok(StatsSnapshot {
        served: get_u64(s, "served")?,
        errors: get_u64(s, "errors")?,
        cache_hits: get_u64(s, "cache_hits")?,
        connections: get_u64(s, "connections")?,
        panics: get_u64(s, "panics")?,
        timeouts: get_u64(s, "timeouts")?,
        rejected_internal: get_u64(s, "rejected_internal")?,
        warehouse_hits: get_u64(s, "warehouse_hits")?,
        warehouse_writes: get_u64(s, "warehouse_writes")?,
        coalesced: get_u64(s, "coalesced")?,
        shard_respawns: get_u64(s, "shard_respawns")?,
        replayed: get_u64(s, "replayed")?,
        degraded: get_u64(s, "degraded")?,
        tenant_rejects: get_u64(s, "tenant_rejects")?,
        plan_p50_s: get_f64(s, "plan_p50_s")?,
        plan_p95_s: get_f64(s, "plan_p95_s")?,
    })
}

/// Acknowledgement of a successful `{"v":1,"cmd":"recalibrate"}` admin
/// command: `{"v":1,"recalibrated":{"cache_entries":N}}` where `N` is
/// how many LRU plan entries the flush dropped (summed across shards
/// when a cluster router answers). The tenant ledger is deliberately
/// untouched — recalibration resets cached *answers*, not spent budgets.
pub fn recalibrate_frame(flushed: u64) -> Json {
    let mut inner = JsonObj::new();
    inner.set("cache_entries", flushed);
    let mut o = JsonObj::new();
    o.set("v", WIRE_VERSION).set("recalibrated", inner);
    Json::Obj(o)
}

/// Encode a stats snapshot as the v1 `{"v":1,"stats":{...}}` frame.
pub fn stats_frame(s: &StatsSnapshot) -> Json {
    let mut o = JsonObj::new();
    o.set("v", WIRE_VERSION).set("stats", counters_to_obj(s));
    Json::Obj(o)
}

/// Decode a v1 stats frame (the client-side partner of [`stats_frame`]).
pub fn stats_from_json(j: &Json) -> Result<StatsSnapshot, PlanError> {
    let o = obj(j, "stats frame")?;
    check_version(o, "stats frame")?;
    counters_from_obj(obj(o.get("stats").ok_or_else(|| err("frame missing 'stats'"))?, "'stats'")?)
}

/// The full observability snapshot reported by the planning service's
/// in-band `{"v":1,"cmd":"metrics"}` request and by the `--metrics-out`
/// periodic file writer: the [`StatsSnapshot`] counters plus admission /
/// cache / queue gauges. The stats fields are serialized through the same
/// helper as [`stats_frame`], so the two frames cannot diverge on shared
/// field names.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MetricsSnapshot {
    /// the counters the `stats` frame reports, field for field
    pub stats: StatsSnapshot,
    /// requests admitted but not yet answered (queued + being planned)
    pub inflight: u64,
    /// requests refused with the `"reject":"over-quota"` frame
    pub rejected_over_quota: u64,
    /// requests refused with the `"reject":"over-inflight"` frame
    pub rejected_over_inflight: u64,
    /// requests sitting in the bounded queue right now
    pub queue_depth: u64,
    /// plans currently held by the canonical-request cache
    pub cache_entries: u64,
    /// approximate bytes held by the cache (keys + serialized plans)
    pub cache_bytes: u64,
    /// cache entries dropped by TTL expiry since startup
    pub cache_expired: u64,
    /// bytes held on disk by the plan warehouse across its segments
    /// (0 when no warehouse is configured)
    pub warehouse_bytes: u64,
    /// seconds since the service bound its listener
    pub uptime_s: f64,
}

/// Encode a metrics snapshot as the v1 `{"v":1,"metrics":{...}}` frame —
/// the [`stats_frame`] counter set (shared serializer) followed by the
/// admission/cache/queue gauges.
pub fn metrics_frame(m: &MetricsSnapshot) -> Json {
    let mut inner = counters_to_obj(&m.stats);
    inner
        .set("inflight", m.inflight)
        .set("rejected_over_quota", m.rejected_over_quota)
        .set("rejected_over_inflight", m.rejected_over_inflight)
        .set("queue_depth", m.queue_depth)
        .set("cache_entries", m.cache_entries)
        .set("cache_bytes", m.cache_bytes)
        .set("cache_expired", m.cache_expired)
        .set("warehouse_bytes", m.warehouse_bytes)
        .set("uptime_s", m.uptime_s);
    let mut o = JsonObj::new();
    o.set("v", WIRE_VERSION).set("metrics", inner);
    Json::Obj(o)
}

/// Decode a v1 metrics frame (the client-side partner of
/// [`metrics_frame`]).
pub fn metrics_from_json(j: &Json) -> Result<MetricsSnapshot, PlanError> {
    let o = obj(j, "metrics frame")?;
    check_version(o, "metrics frame")?;
    let m = obj(o.get("metrics").ok_or_else(|| err("frame missing 'metrics'"))?, "'metrics'")?;
    Ok(MetricsSnapshot {
        stats: counters_from_obj(m)?,
        inflight: get_u64(m, "inflight")?,
        rejected_over_quota: get_u64(m, "rejected_over_quota")?,
        rejected_over_inflight: get_u64(m, "rejected_over_inflight")?,
        queue_depth: get_u64(m, "queue_depth")?,
        cache_entries: get_u64(m, "cache_entries")?,
        cache_bytes: get_u64(m, "cache_bytes")?,
        cache_expired: get_u64(m, "cache_expired")?,
        warehouse_bytes: get_u64(m, "warehouse_bytes")?,
        uptime_s: get_f64(m, "uptime_s")?,
    })
}

/// Flatten a metrics snapshot into the `BENCH_*.json` medians schema
/// (flat name → number object) — what `xbarmap serve --metrics-out FILE`
/// writes. **Gauges** are emitted (latency in ns, occupancy) plus the
/// **fault counters** (`panics`, `timeouts`, `rejected_internal`) and the
/// cluster **failover counters** (`shard_respawns`, `replayed`,
/// `degraded`);
/// throughput counters (`served`, `errors`, …) are excluded so two
/// snapshots of the same service can be compared with `xbarmap
/// bench-gate` without ever-growing counters reading as regressions —
/// those ride the in-band `metrics` frame. The fault counters are safe
/// under the gate: `bench-gate` skips any key whose baseline is zero,
/// which is what a healthy baseline records, and a *non*-zero fault
/// baseline that grows is exactly the regression the gate should flag.
pub fn metrics_medians(m: &MetricsSnapshot) -> Json {
    let mut o = JsonObj::new();
    o.set(
        "_schema",
        "gauges + fault counters, BENCH_*.json-compatible (name -> number); \
         throughput counters ride the in-band {\"v\":1,\"cmd\":\"metrics\"} frame",
    )
    .set("serve/plan_p50_ns", m.stats.plan_p50_s * 1e9)
    .set("serve/plan_p95_ns", m.stats.plan_p95_s * 1e9)
    .set("serve/inflight", m.inflight)
    .set("serve/queue_depth", m.queue_depth)
    .set("serve/cache_entries", m.cache_entries)
    .set("serve/cache_bytes", m.cache_bytes)
    .set("serve/warehouse_bytes", m.warehouse_bytes)
    .set("serve/panics", m.stats.panics)
    .set("serve/timeouts", m.stats.timeouts)
    .set("serve/rejected_internal", m.stats.rejected_internal)
    .set("serve/shard_respawns", m.stats.shard_respawns)
    .set("serve/replayed", m.stats.replayed)
    .set("serve/degraded", m.stats.degraded);
    Json::Obj(o)
}

fn point_to_json(p: &SweepPoint) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("tile", vec![Json::from(p.tile.n_row), Json::from(p.tile.n_col)])
        .set("aspect", p.aspect)
        .set("blocks", p.n_blocks)
        .set("tiles", p.n_tiles)
        .set("one_to_one", p.n_tiles_one_to_one)
        .set("tile_eff", p.tile_eff)
        .set("pack_eff", p.packing_eff)
        .set("area_mm2", p.total_area_mm2)
        .set("array_area_mm2", p.array_area_mm2);
    o
}

fn point_from_json(j: &Json) -> Result<SweepPoint, PlanError> {
    let o = obj(j, "sweep point")?;
    let t = usize_arr(o.get("tile").ok_or_else(|| err("point missing 'tile'"))?, "'tile'")?;
    if t.len() != 2 {
        return Err(err("'tile' must be [rows, cols]"));
    }
    Ok(SweepPoint {
        // lint: allow(panic) length checked to be exactly 2 above
        tile: Tile::new(t[0], t[1]),
        aspect: get_usize(o, "aspect")?,
        n_blocks: get_usize(o, "blocks")?,
        n_tiles: get_usize(o, "tiles")?,
        n_tiles_one_to_one: get_usize(o, "one_to_one")?,
        tile_eff: get_f64(o, "tile_eff")?,
        packing_eff: get_f64(o, "pack_eff")?,
        total_area_mm2: get_f64(o, "area_mm2")?,
        array_area_mm2: get_f64(o, "array_area_mm2")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pack::Discipline;

    #[test]
    fn minimal_request_parses_with_paper_defaults() {
        let j = crate::util::json::parse(r#"{"v":1,"net":{"zoo":"resnet18"}}"#).unwrap();
        let r = request_from_json(&j).unwrap();
        assert_eq!(r, MapRequest::zoo("resnet18"));
        assert_eq!(r.tiles, TileSpace::paper_grid());
        assert_eq!(r.engine, Engine::Simple);
        assert_eq!(r.objective, Objective::MinArea);
    }

    #[test]
    fn version_tag_is_required_and_checked() {
        let missing = crate::util::json::parse(r#"{"net":{"zoo":"lenet"}}"#).unwrap();
        assert!(request_from_json(&missing).unwrap_err().0.contains("version"));
        let wrong = crate::util::json::parse(r#"{"v":2,"net":{"zoo":"lenet"}}"#).unwrap();
        assert!(request_from_json(&wrong).unwrap_err().0.contains("unsupported"));
        // fractional versions are mismatches, not truncated to v1
        let frac = crate::util::json::parse(r#"{"v":1.9,"net":{"zoo":"lenet"}}"#).unwrap();
        assert!(request_from_json(&frac).unwrap_err().0.contains("unsupported"));
    }

    #[test]
    fn full_request_roundtrips() {
        let r = MapRequest::zoo("resnet18")
            .id("tenant-7")
            .grid((7, 10), vec![1, 2, 4])
            .ilp(50_000)
            .discipline(Discipline::Pipeline)
            .objective(Objective::MinTiles)
            .replication(Replication::Geometric(128, 4))
            .threads(3)
            .placements(true)
            .sort(SortOrder::RowsAsc)
            .area(AreaModel::calibrated(2.0, 128, 0.3));
        let j = request_to_json(&r);
        let back = request_from_json(&crate::util::json::parse(&j.dumps()).unwrap()).unwrap();
        assert_eq!(back, r);
        assert_eq!(request_to_json(&back).dumps(), j.dumps());
    }

    #[test]
    fn inline_network_roundtrips() {
        let net = Network::new(
            "custom",
            "test 8x8",
            vec![
                Layer::conv("c1", 3, 8, 3, 1, 1, 8),
                {
                    let mut l = Layer::fc("fc", 32, 10);
                    l.bias = false;
                    l
                },
                Layer::fc_reused("q", 16, 16, 7),
            ],
        );
        let r = MapRequest::inline(net.clone()).tile(64, 64);
        let j = request_to_json(&r);
        let back = request_from_json(&crate::util::json::parse(&j.dumps()).unwrap()).unwrap();
        match &back.network {
            NetworkSpec::Inline(n) => assert_eq!(n, &net),
            other => panic!("expected inline network, got {other:?}"),
        }
    }

    #[test]
    fn planned_lenet_plan_roundtrips() {
        let plan = MapRequest::zoo("lenet")
            .tile(256, 256)
            .discipline(Discipline::Pipeline)
            .placements(true)
            .build()
            .unwrap()
            .plan()
            .unwrap();
        let j = plan_to_json(&plan);
        let back = plan_from_json(&crate::util::json::parse(&j.dumps()).unwrap()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(plan_to_json(&back).dumps(), j.dumps());
    }

    #[test]
    fn bad_layer_and_tiles_are_rejected() {
        for (src, needle) in [
            (r#"{"v":1,"net":{"name":"x","layers":[{"name":"l"}]}}"#, "'fc' or 'conv'"),
            (r#"{"v":1,"net":{"name":"x","layers":[{"name":"l","fc":[1]}]}}"#, "fan_in"),
            (r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{}}"#, "'fixed' or 'grid'"),
            (r#"{"v":1,"net":{"zoo":"lenet"},"engine":"magic"}"#, "engine"),
            (r#"{"v":1,"net":{"zoo":"lenet"},"replication":{}}"#, "replication"),
        ] {
            let j = crate::util::json::parse(src).unwrap();
            let e = request_from_json(&j).unwrap_err();
            assert!(e.0.contains(needle), "{src}: {e}");
        }
    }

    #[test]
    fn error_frame_carries_physical_line_number() {
        let f = error_frame(7, &PlanError("boom".into()));
        assert_eq!(f.dumps(), r#"{"v":1,"line":7,"error":"boom"}"#);
    }

    #[test]
    fn reject_frame_extends_the_error_frame_with_a_typed_discriminator() {
        let e = PlanError("connection exceeded its 8-request quota".into());
        let f = reject_frame(9, RejectKind::OverQuota, &e);
        assert_eq!(
            f.dumps(),
            r#"{"v":1,"line":9,"error":"connection exceeded its 8-request quota","reject":"over-quota"}"#
        );
        let f = reject_frame(3, RejectKind::OverInflight, &PlanError("full".into()));
        assert_eq!(f.get("reject").and_then(Json::as_str), Some("over-inflight"));
        // the v/line/error prefix is the error frame byte for byte, so
        // clients that only understand error frames degrade gracefully
        assert_eq!(f.get("line").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(f.get("error").and_then(Json::as_str), Some("full"));
    }

    #[test]
    fn fault_reject_tokens_are_pinned() {
        // the service's fault-containment frames: exact bytes, like the
        // admission frames above, so clients can match on the token
        let e = PlanError("planner panicked: boom".into());
        let f = reject_frame(2, RejectKind::Internal, &e);
        assert_eq!(
            f.dumps(),
            r#"{"v":1,"line":2,"error":"planner panicked: boom","reject":"internal"}"#
        );
        let e = PlanError("deadline exceeded: solve passed the 50ms budget".into());
        let f = reject_frame(5, RejectKind::Deadline, &e);
        assert_eq!(
            f.dumps(),
            r#"{"v":1,"line":5,"error":"deadline exceeded: solve passed the 50ms budget","reject":"deadline"}"#
        );
        // the tenant-policy frames: same exact-byte discipline
        let e = PlanError("tenant 'acme' exceeded its 3-request quota".into());
        let f = reject_frame(4, RejectKind::OverQuota, &e);
        assert_eq!(
            f.dumps(),
            r#"{"v":1,"line":4,"error":"tenant 'acme' exceeded its 3-request quota","reject":"over-quota"}"#
        );
        let e = PlanError("recalibrate requires a valid admin token".into());
        let f = reject_frame(6, RejectKind::Unauthorized, &e);
        assert_eq!(
            f.dumps(),
            r#"{"v":1,"line":6,"error":"recalibrate requires a valid admin token","reject":"unauthorized"}"#
        );
        // the five tokens stay distinct
        let tokens: Vec<&str> = [
            RejectKind::OverQuota,
            RejectKind::OverInflight,
            RejectKind::Internal,
            RejectKind::Deadline,
            RejectKind::Unauthorized,
        ]
        .iter()
        .map(|k| k.token())
        .collect();
        let mut dedup = tokens.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), tokens.len());
    }

    #[test]
    fn recalibrate_frame_is_pinned() {
        assert_eq!(recalibrate_frame(12).dumps(), r#"{"v":1,"recalibrated":{"cache_entries":12}}"#);
        assert_eq!(recalibrate_frame(0).dumps(), r#"{"v":1,"recalibrated":{"cache_entries":0}}"#);
    }

    #[test]
    fn metrics_frame_roundtrips_and_supersets_the_stats_frame() {
        let m = MetricsSnapshot {
            stats: StatsSnapshot {
                served: 41,
                errors: 2,
                cache_hits: 17,
                connections: 5,
                panics: 1,
                timeouts: 2,
                rejected_internal: 1,
                warehouse_hits: 9,
                warehouse_writes: 22,
                coalesced: 6,
                shard_respawns: 1,
                replayed: 3,
                degraded: 2,
                tenant_rejects: 4,
                plan_p50_s: 0.0125,
                plan_p95_s: 0.25,
            },
            inflight: 3,
            rejected_over_quota: 1,
            rejected_over_inflight: 7,
            queue_depth: 2,
            cache_entries: 12,
            cache_bytes: 51_234,
            cache_expired: 4,
            warehouse_bytes: 204_800,
            uptime_s: 86.5,
        };
        let j = metrics_frame(&m);
        let back = metrics_from_json(&crate::util::json::parse(&j.dumps()).unwrap()).unwrap();
        assert_eq!(back, m);
        // drift pin: every field of the stats payload appears, same name,
        // in the metrics payload (both serialize through counters_to_obj)
        let stats_obj = stats_frame(&m.stats);
        let stats_inner = stats_obj.get("stats").and_then(Json::as_obj).unwrap();
        let metrics_inner = j.get("metrics").and_then(Json::as_obj).unwrap();
        for (k, v) in stats_inner.iter() {
            assert_eq!(metrics_inner.get(k), Some(v), "stats field '{k}' drifted");
        }
        // version tag enforced like every other frame
        let unversioned = crate::util::json::parse(r#"{"metrics":{}}"#).unwrap();
        assert!(metrics_from_json(&unversioned).unwrap_err().0.contains("version"));
    }

    #[test]
    fn metrics_medians_emit_gauges_in_the_bench_schema() {
        let m = MetricsSnapshot {
            stats: StatsSnapshot {
                plan_p50_s: 0.002,
                plan_p95_s: 0.03,
                panics: 1,
                timeouts: 2,
                rejected_internal: 1,
                shard_respawns: 2,
                replayed: 5,
                degraded: 1,
                ..Default::default()
            },
            inflight: 1,
            queue_depth: 4,
            cache_entries: 9,
            cache_bytes: 1000,
            warehouse_bytes: 4096,
            ..Default::default()
        };
        let j = metrics_medians(&m);
        assert_eq!(j.get("serve/plan_p50_ns").and_then(Json::as_f64), Some(2e6));
        assert_eq!(j.get("serve/plan_p95_ns").and_then(Json::as_f64), Some(3e7));
        assert_eq!(j.get("serve/queue_depth").and_then(|v| v.as_usize()), Some(4));
        // fault counters are snapshot rows: a healthy baseline records
        // zero (which bench-gate skips), a non-zero one growing is a
        // regression worth flagging
        assert_eq!(j.get("serve/panics").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("serve/timeouts").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("serve/rejected_internal").and_then(|v| v.as_usize()), Some(1));
        // cluster failover counters are snapshot rows on the same terms:
        // zero on a healthy (or single-process) baseline, growth under a
        // non-zero baseline flags a flapping shard
        assert_eq!(j.get("serve/shard_respawns").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("serve/replayed").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(j.get("serve/degraded").and_then(|v| v.as_usize()), Some(1));
        // warehouse_bytes is a gauge (live bytes on disk), so it's safe
        // under the gate like cache_bytes
        assert_eq!(j.get("serve/warehouse_bytes").and_then(|v| v.as_usize()), Some(4096));
        // no throughput counters: two snapshots must be bench-gate safe
        for absent in [
            "serve/served",
            "serve/errors",
            "serve/cache_hits",
            "serve/uptime_s",
            "serve/warehouse_hits",
            "serve/warehouse_writes",
            "serve/coalesced",
            "serve/tenant_rejects",
        ] {
            assert!(j.get(absent).is_none(), "{absent} must not be a medians row");
        }
        // string rows (the _schema marker) never gate (benchkit contract)
        assert!(j.get("_schema").and_then(Json::as_str).is_some());
    }

    #[test]
    fn stats_frame_roundtrips() {
        let s = StatsSnapshot {
            served: 41,
            errors: 2,
            cache_hits: 17,
            connections: 5,
            panics: 3,
            timeouts: 1,
            rejected_internal: 3,
            warehouse_hits: 8,
            warehouse_writes: 19,
            coalesced: 2,
            shard_respawns: 1,
            replayed: 4,
            degraded: 2,
            tenant_rejects: 3,
            plan_p50_s: 0.0125,
            plan_p95_s: 0.25,
        };
        let j = stats_frame(&s);
        let back = stats_from_json(&crate::util::json::parse(&j.dumps()).unwrap()).unwrap();
        assert_eq!(back, s);
        // version tag is enforced like every other frame
        let unversioned = crate::util::json::parse(r#"{"stats":{}}"#).unwrap();
        assert!(stats_from_json(&unversioned).unwrap_err().0.contains("version"));
    }

    #[test]
    fn fractional_and_oversized_integers_are_rejected_not_truncated() {
        for src in [
            // a 256.9-row tile must not silently plan a 256-row one
            r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"fixed":[256.9,64]}}"#,
            r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"grid":{"row_exp":[6,9],"aspects":[1.5]}}}"#,
            r#"{"v":1,"net":{"zoo":"lenet"},"threads":2.7}"#,
            r#"{"v":1,"net":{"zoo":"lenet"},"engine":"lps","ilp_nodes":1.5}"#,
            r#"{"v":1,"net":{"zoo":"lenet"},"replication":{"balanced":-3}}"#,
            // u32 narrowing must not wrap row exponents into the valid range
            r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"grid":{"row_exp":[4294967302,4294967305],"aspects":[1]}}}"#,
        ] {
            let j = crate::util::json::parse(src).unwrap();
            assert!(request_from_json(&j).is_err(), "accepted: {src}");
        }
    }
}
