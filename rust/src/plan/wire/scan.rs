//! Byte-level lazy scanner for the v1 request line discriminators.
//!
//! The serve hot path used to build a full JSON tree per input line just
//! to learn three things: is this a command frame, which tenant sent it,
//! and which cache key does it probe. [`scan`] answers all three with a
//! single forward walk over the raw bytes — no tree, no per-member
//! allocation beyond the returned id/key strings — and *declares
//! fallback* ([`Scan::Fallback`]) on anything it does not model exactly:
//! escape sequences, duplicate top-level keys, a non-string `id`, a `v`
//! token other than the literal `1`, structural errors, or pathological
//! nesting. The full parser ([`crate::util::json::parse`] +
//! [`super::request_from_json`]) remains the single source of truth; the
//! scanner is only ever a conservative prefilter, pinned by the
//! differential suite in `tests/prop_wire_scan.rs`.
//!
//! Soundness argument ("a hit proves canonical"): the service's plan
//! cache is keyed exclusively by the canonical id-stripped serialization
//! produced from a *fully parsed* request. [`ScanRequest::key`] is the
//! line's own bytes with the top-level `"id"` member spliced out. If
//! that candidate key equals a cached canonical key, the line *is* the
//! canonical serialization of the cached request plus an inserted `id`
//! member — so serving the cached plan with the id restamped is
//! byte-identical to planning the line from scratch. Any non-canonical
//! line simply misses and takes the full-parse path; the scanner never
//! has to normalize whitespace, key order, or number spellings.
//!
//! The [`Scan::Command`] verdict deliberately reproduces the legacy
//! substring sniff (`contains("\"cmd\"") && !contains("\"net\"")`)
//! rather than improving on it: a line whose bytes contain `"net"` in a
//! nested position is declared [`Scan::Fallback`] even when the
//! top-level shape is a clean command, so the scanned service answers
//! every line byte-identically to the unscanned one.

/// Maximum nesting depth the scanner walks before declaring fallback;
/// matches no real request (inline nets nest 4 deep) and bounds stack
/// use against adversarial `[[[[…` lines.
const MAX_DEPTH: u32 = 128;

/// The scanner's verdict on one raw input line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Scan {
    /// A command frame: structurally valid JSON object with a top-level
    /// `cmd` member and no `"net"` bytes anywhere (the legacy sniff's
    /// exact predicate). The command dispatcher parses the line itself.
    Command,
    /// A plan request with extracted tenant id and candidate cache key.
    Request(ScanRequest),
    /// Anything else — take the full-parse path. Never wrong, only slow.
    Fallback,
}

/// The discriminators extracted from a fast-pathed request line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScanRequest {
    /// the top-level `id` string, verbatim ("" when absent) — equal to
    /// the parsed request's id because lines whose id carries escape
    /// sequences are declared fallback
    pub id: String,
    /// the line's object bytes with the `id` member spliced out: equal
    /// to the canonical cache key iff the line is the canonical
    /// serialization, so an LRU hit on it proves byte-identity
    pub key: String,
}

/// Scan one raw request line without building a JSON tree. Returns
/// [`Scan::Fallback`] on any shape outside the modeled subset; every
/// accepted line is structurally valid JSON that the full parser also
/// accepts, with identical `id`/discriminator views.
pub fn scan(line: &str) -> Scan {
    scan_bytes(line.as_bytes()).unwrap_or(Scan::Fallback)
}

/// `None` = fallback. Split from [`scan`] so `?` can thread rejects.
fn scan_bytes(b: &[u8]) -> Option<Scan> {
    let mut c = Cursor { b, i: 0 };
    c.ws();
    if c.peek() != Some(b'{') {
        return None;
    }
    let obj_start = c.i;
    c.i += 1;
    let mut keys: Vec<(usize, usize)> = Vec::new();
    let mut v_token: Option<(usize, usize)> = None;
    let mut has_cmd = false;
    let mut has_net = false;
    // (member start, member end, id content range)
    let mut id_member: Option<(usize, usize, (usize, usize))> = None;
    c.ws();
    if c.peek() == Some(b'}') {
        c.i += 1;
    } else {
        loop {
            c.ws();
            let mstart = c.i;
            let (ks, ke) = c.string()?;
            // duplicate top-level keys would make "first member seen"
            // diverge from the parser's last-wins view — fall back.
            // Raw-byte comparison is sound because escapes already fell
            // back inside `string`.
            if keys.iter().any(|&(ps, pe)| b.get(ps..pe) == b.get(ks..ke)) {
                return None;
            }
            keys.push((ks, ke));
            c.ws();
            if c.peek() != Some(b':') {
                return None;
            }
            c.i += 1;
            c.ws();
            let vstart = c.i;
            if b.get(ks..ke) == Some(b"id") {
                if c.peek() != Some(b'"') {
                    return None; // non-string id: not modeled
                }
                let content = c.string()?;
                id_member = Some((mstart, c.i, content));
            } else {
                c.value(0)?;
                match b.get(ks..ke) {
                    Some(b"v") => v_token = Some((vstart, c.i)),
                    Some(b"cmd") => has_cmd = true,
                    Some(b"net") => has_net = true,
                    _ => {}
                }
            }
            c.ws();
            match c.peek() {
                Some(b',') => c.i += 1,
                Some(b'}') => {
                    c.i += 1;
                    break;
                }
                _ => return None,
            }
        }
    }
    let obj_end = c.i;
    c.ws();
    if c.i != b.len() {
        return None; // trailing data after the object
    }
    if has_cmd && !has_net {
        // legacy-sniff parity: `"net"` bytes in a nested key or string
        // value would have routed this line to the request path
        if contains(b, b"\"net\"") {
            return None;
        }
        return Some(Scan::Command);
    }
    if has_net {
        let (vs, ve) = v_token?;
        if b.get(vs..ve) != Some(b"1") {
            return None; // only the canonical version spelling fast-paths
        }
        let (id, key) = match id_member {
            None => (String::new(), utf8(b, obj_start, obj_end)?.to_string()),
            Some((ms, me, (cs, ce))) => {
                let id = utf8(b, cs, ce)?.to_string();
                let (cut_s, cut_e) = splice_range(b, obj_start, obj_end, ms, me);
                let mut key = String::with_capacity(obj_end - obj_start - (cut_e - cut_s));
                key.push_str(utf8(b, obj_start, cut_s)?);
                key.push_str(utf8(b, cut_e, obj_end)?);
                (id, key)
            }
        };
        return Some(Scan::Request(ScanRequest { id, key }));
    }
    None
}

/// The byte range to cut when removing the `id` member `[ms, me)` from
/// the top-level object `[obj_start, obj_end)`: the member plus its
/// following comma (and intervening whitespace) when one exists, else
/// the member plus its preceding comma, else the member alone — exactly
/// inverse to inserting a member into a canonical serialization.
fn splice_range(b: &[u8], obj_start: usize, obj_end: usize, ms: usize, me: usize) -> (usize, usize) {
    let mut j = me;
    while j + 1 < obj_end && b.get(j).is_some_and(|c| is_ws(*c)) {
        j += 1;
    }
    if b.get(j) == Some(&b',') {
        return (ms, j + 1);
    }
    let mut k = ms;
    while k > obj_start + 1 && b.get(k - 1).is_some_and(|c| is_ws(*c)) {
        k -= 1;
    }
    if k > obj_start && b.get(k - 1) == Some(&b',') {
        return (k - 1, me);
    }
    (ms, me)
}

fn is_ws(c: u8) -> bool {
    matches!(c, b' ' | b'\t' | b'\n' | b'\r')
}

/// Naive substring search (the line is one bounded request).
fn contains(haystack: &[u8], needle: &[u8]) -> bool {
    haystack.windows(needle.len()).any(|w| w == needle)
}

/// Checked UTF-8 view of `b[s..e)`; boundaries are always ASCII quotes
/// or braces, so this only fails on ranges that cannot occur.
fn utf8(b: &[u8], s: usize, e: usize) -> Option<&str> {
    std::str::from_utf8(b.get(s..e)?).ok()
}

/// Forward-only byte walker; every method returns `None` to declare
/// fallback rather than erroring.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl Cursor<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while self.peek().is_some_and(is_ws) {
            self.i += 1;
        }
    }

    /// Walk a string literal, returning its content byte range. Any
    /// escape sequence (the scanner does not model them) and any
    /// unterminated string declare fallback. Non-ASCII UTF-8 bytes are
    /// all ≥ 0x80 and can never alias `"` or `\`, so a byte walk is
    /// exact.
    fn string(&mut self) -> Option<(usize, usize)> {
        if self.peek() != Some(b'"') {
            return None;
        }
        self.i += 1;
        let start = self.i;
        loop {
            match self.peek()? {
                b'"' => {
                    let end = self.i;
                    self.i += 1;
                    return Some((start, end));
                }
                b'\\' => return None,
                _ => self.i += 1,
            }
        }
    }

    /// Walk a number matching `-?digits(.digits)?([eE][+-]?digits)?` —
    /// strictly tighter than the full parser's tokenizer, so every
    /// accepted spelling is one `f64::from_str` also accepts.
    fn number(&mut self) -> Option<()> {
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        self.digits()?;
        if self.peek() == Some(b'.') {
            self.i += 1;
            self.digits()?;
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            self.digits()?;
        }
        Some(())
    }

    fn digits(&mut self) -> Option<()> {
        if !self.peek().is_some_and(|c| c.is_ascii_digit()) {
            return None;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        Some(())
    }

    fn lit(&mut self, word: &[u8]) -> Option<()> {
        if self.b.get(self.i..self.i + word.len()) == Some(word) {
            self.i += word.len();
            Some(())
        } else {
            None
        }
    }

    /// Walk any JSON value; `depth` guards recursion.
    fn value(&mut self, depth: u32) -> Option<()> {
        if depth > MAX_DEPTH {
            return None;
        }
        match self.peek()? {
            b'{' => self.object(depth),
            b'[' => self.array(depth),
            b'"' => self.string().map(|_| ()),
            b't' => self.lit(b"true"),
            b'f' => self.lit(b"false"),
            b'n' => self.lit(b"null"),
            b'-' | b'0'..=b'9' => self.number(),
            _ => None,
        }
    }

    fn object(&mut self, depth: u32) -> Option<()> {
        self.i += 1; // past '{'
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Some(());
        }
        loop {
            self.ws();
            self.string()?;
            self.ws();
            if self.peek() != Some(b':') {
                return None;
            }
            self.i += 1;
            self.ws();
            self.value(depth + 1)?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Some(());
                }
                _ => return None,
            }
        }
    }

    fn array(&mut self, depth: u32) -> Option<()> {
        self.i += 1; // past '['
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Some(());
        }
        loop {
            self.ws();
            self.value(depth + 1)?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Some(());
                }
                _ => return None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(line: &str) -> ScanRequest {
        match scan(line) {
            Scan::Request(r) => r,
            other => panic!("expected Request for {line:?}, got {other:?}"),
        }
    }

    #[test]
    fn canonical_request_without_id_keys_to_itself() {
        let line = r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#;
        let r = req(line);
        assert_eq!(r.id, "");
        assert_eq!(r.key, line);
    }

    #[test]
    fn id_member_is_spliced_with_its_following_comma() {
        let line = r#"{"v":1,"id":"t-9","net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#;
        let r = req(line);
        assert_eq!(r.id, "t-9");
        assert_eq!(r.key, r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#);
    }

    #[test]
    fn trailing_id_member_takes_its_preceding_comma() {
        let r = req(r#"{"v":1,"net":{"zoo":"lenet"},"id":"z"}"#);
        assert_eq!(r.id, "z");
        assert_eq!(r.key, r#"{"v":1,"net":{"zoo":"lenet"}}"#);
    }

    #[test]
    fn whitespace_around_tokens_is_accepted_but_keys_stay_verbatim() {
        let r = req("{ \"v\" : 1 , \"net\" : { } }");
        assert_eq!(r.id, "");
        // the key is the line's own (non-canonical) bytes: it will miss
        // the cache and take the full-parse path, never mis-hit
        assert_eq!(r.key, "{ \"v\" : 1 , \"net\" : { } }");
    }

    #[test]
    fn command_verdict_matches_the_legacy_sniff() {
        assert_eq!(scan(r#"{"v":1,"cmd":"stats"}"#), Scan::Command);
        // nested "net" bytes: the legacy sniff routed these to the
        // request path, so the scanner must not call them commands
        assert_eq!(scan(r#"{"v":1,"cmd":"stats","pad":"net"}"#), Scan::Fallback);
        // cmd alongside a real net member is request-shaped
        assert!(matches!(
            scan(r#"{"v":1,"cmd":"stats","net":{"zoo":"lenet"}}"#),
            Scan::Request(_)
        ));
    }

    #[test]
    fn escapes_duplicates_and_non_scalars_fall_back() {
        for line in [
            // escape anywhere in any string
            r#"{"v":1,"id":"a\nb","net":{"zoo":"lenet"}}"#,
            r#"{"v":1,"net":{"zoo":"len\u0065t"}}"#,
            // duplicate top-level key
            r#"{"v":1,"v":1,"net":{"zoo":"lenet"}}"#,
            r#"{"v":1,"id":"a","id":"b","net":{"zoo":"lenet"}}"#,
            // non-string id
            r#"{"v":1,"id":7,"net":{"zoo":"lenet"}}"#,
            // non-canonical version token
            r#"{"v":1.0,"net":{"zoo":"lenet"}}"#,
            r#"{"v":2,"net":{"zoo":"lenet"}}"#,
            r#"{"net":{"zoo":"lenet"}}"#,
            // structural rejects
            r#"{"v":1,"net":{"zoo":"lenet"}"#,
            r#"{"v":1,"net":{"zoo":"lenet"}} extra"#,
            r#"{"v":1,"net":{"zoo":"lenet"},}"#,
            r#"[1,2,3]"#,
            "",
            "not json",
        ] {
            assert_eq!(scan(line), Scan::Fallback, "line {line:?}");
        }
    }

    #[test]
    fn accepted_lines_also_parse_under_the_full_parser() {
        for line in [
            r#"{"v":1,"net":{"zoo":"lenet"},"tiles":{"fixed":[256,256]}}"#,
            r#"{"v":1,"id":"x","net":{"zoo":"bert"},"threads":2}"#,
            "{ \"v\"\t:\t1 , \"net\" : [true,false,null,-1.5e3] }",
            r#"{"v":1,"cmd":"metrics"}"#,
        ] {
            assert_ne!(scan(line), Scan::Fallback, "line {line:?}");
            assert!(crate::util::json::parse(line).is_ok(), "line {line:?}");
        }
    }

    #[test]
    fn deep_nesting_falls_back_instead_of_recursing_away() {
        let mut line = String::from(r#"{"v":1,"net":"#);
        for _ in 0..200 {
            line.push('[');
        }
        for _ in 0..200 {
            line.push(']');
        }
        line.push('}');
        assert_eq!(scan(&line), Scan::Fallback);
    }

    #[test]
    fn spliced_key_matches_the_codec_canonical_key() {
        // the candidate key of a canonical line with id equals the
        // canonical serialization without id — the cache-hit soundness
        // contract, checked at scale by tests/prop_wire_scan.rs
        let anon = crate::plan::MapRequest::zoo("lenet").tile(256, 256);
        let with_id = anon.clone().id("tenant-1");
        let line = with_id.to_json().dumps();
        let r = req(&line);
        assert_eq!(r.id, "tenant-1");
        assert_eq!(r.key, anon.to_json().dumps());
    }
}
