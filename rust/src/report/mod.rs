//! Repro harness: regenerate every table and figure of the paper's
//! evaluation (see DESIGN.md §4 for the experiment index).
//!
//! Each `table*`/`fig*` function computes the paper artifact from first
//! principles through the library and returns a [`Table`]; [`run`] renders
//! them to stdout and writes `.txt`/`.csv` files under an output directory.
//! Paper-vs-measured comparisons are recorded in EXPERIMENTS.md.

use crate::area::AreaModel;
use crate::frag::{self, Census};
use crate::geom::{Block, BlockKind, Tile};
use crate::ilp;
use crate::nets::zoo;
use crate::pack::{self, Discipline};
use crate::perf::{self, Execution, TimingModel};
use crate::plan::{MapRequest, Replication};
use crate::sim::{self, SimConfig};
use crate::util::table::{sig3, Table};
use std::path::Path;

/// The paper's 13-item demo list (§2.2, "Equation 7" item list).
pub fn paper_demo_items() -> Vec<Block> {
    [
        (257, 256),
        (257, 256),
        (257, 256),
        (129, 256),
        (129, 128),
        (129, 128),
        (129, 128),
        (129, 128),
        (65, 128),
        (148, 64),
        (65, 64),
        (65, 64),
        (65, 64),
    ]
    .iter()
    .enumerate()
    .map(|(i, &(r, c))| Block {
        rows: r,
        cols: c,
        layer: i,
        replica: 0,
        grid: (0, 0),
        kind: BlockKind::Sparse,
    })
    .collect()
}

/// ILP budget used across the harness (reduced by `fast`).
fn budget(fast: bool) -> ilp::Budget {
    if fast {
        ilp::Budget { max_nodes: 20_000, max_items: 120, ..Default::default() }
    } else {
        ilp::Budget::default()
    }
}

/// Table 1: weight reuse of the first conv layer for selected CNNs.
pub fn table1() -> Table {
    let mut t = Table::new(&["Network", "Input", "Input size", "N_reuse 1st layer", "paper"]);
    let rows = [
        (zoo::resnet50(), "ImageNet (1.2M)", "3 x 224 x 224", 12544usize),
        (zoo::resnet9(), "Cifar10 (60k)", "3 x 32 x 32", 729),
        (zoo::resnet9_paper_calib(), "Cifar10 (60k)", "3 x 32 x 32", 729),
        (zoo::alexnet(), "ImageNet", "3 x 224 x 224", 3025),
        (zoo::lenet(), "MNIST (60k)", "1 x 28 x 28", 784),
    ];
    for (net, input, size, paper) in rows {
        t.row(&[
            net.name.clone(),
            input.into(),
            size.into(),
            net.layers[0].reuse().to_string(),
            paper.to_string(),
        ]);
    }
    t
}

/// Tables 3 & 5: dense and pipeline BILP packing of the 13-item demo list
/// into T(512,512) — bin memberships and counts (paper: 2 and 4 bins).
pub fn table3_5(fast: bool) -> (Table, Table) {
    let tile = Tile::new(512, 512);
    let items = paper_demo_items();
    let mut out = Vec::new();
    for discipline in [Discipline::Dense, Discipline::Pipeline] {
        let r = ilp::solve_packing(&items, tile, discipline, budget(fast));
        pack::placement::validate(&r.packing).expect("solver output valid");
        let mut t = Table::new(&["Bin", "Items (1-based)", "rows used", "cols used"]);
        for (bin, placements) in r.packing.bins().iter().enumerate() {
            let mut ids: Vec<usize> = placements.iter().map(|p| p.block + 1).collect();
            ids.sort_unstable();
            let rows: usize = match discipline {
                // dense: max over shelves is geometric; report sum of block rows
                _ => placements.iter().map(|p| r.packing.blocks[p.block].rows).sum(),
            };
            let cols: usize = placements.iter().map(|p| r.packing.blocks[p.block].cols).sum();
            t.row(&[
                format!("Bin {}", bin + 1),
                ids.iter().map(|i| format!("Item {i}")).collect::<Vec<_>>().join(", "),
                rows.to_string(),
                cols.to_string(),
            ]);
        }
        t.row(&[
            "TOTAL".into(),
            format!("{} bins (paper: {})", r.packing.n_bins, match discipline {
                Discipline::Dense => 2,
                Discipline::Pipeline => 4,
            }),
            format!("optimal={}", r.optimal),
            format!("lb={}", r.lower_bound),
        ]);
        out.push(t);
    }
    let mut it = out.into_iter();
    (it.next().unwrap(), it.next().unwrap())
}

/// Figure 4: fragmentation census of ResNet18/ImageNet on square arrays.
pub fn fig4() -> Table {
    let net = zoo::resnet18();
    let mut t = Table::new(&[
        "array", "total blocks", "full", "row-full", "col-full", "sparse",
    ]);
    for k in 6..=13 {
        let tile = Tile::new(1 << k, 1 << k);
        let blocks = frag::fragment_network(&net, tile);
        let c = Census::of(&blocks);
        t.row(&[
            tile.to_string(),
            c.total.to_string(),
            c.full.to_string(),
            c.row_full.to_string(),
            c.col_full.to_string(),
            c.sparse.to_string(),
        ]);
    }
    t
}

/// Figure 7: simple packing vs binary linear optimization — minimum total
/// tile area (at 100 % array efficiency, like the paper's fig) vs number
/// of tiles, for dense/square and pipeline/rectangular ResNet18 mappings.
pub fn fig7(fast: bool) -> Table {
    let mut t = Table::new(&[
        "scenario", "engine", "tile", "tiles", "array area mm2", "total area mm2",
    ]);
    let scenarios: [(&str, Discipline, Vec<usize>); 2] = [
        ("dense/square", Discipline::Dense, vec![1]),
        ("pipeline/rect", Discipline::Pipeline, (1..=8).collect()),
    ];
    for (name, discipline, aspects) in scenarios {
        for ilp_nodes in [None, Some(budget(fast).max_nodes)] {
            let mut req = MapRequest::zoo("resnet18")
                .discipline(discipline)
                .grid(if fast { (8, 11) } else { (6, 13) }, aspects.clone());
            if let Some(nodes) = ilp_nodes {
                req = req.ilp(nodes);
            }
            let plan = req.build().and_then(|p| p.plan()).expect("fig7 plan");
            for p in &plan.best_per_aspect {
                t.row(&[
                    name.into(),
                    plan.engine.to_string(),
                    p.tile.to_string(),
                    p.n_tiles.to_string(),
                    sig3(p.array_area_mm2),
                    sig3(p.total_area_mm2),
                ]);
            }
        }
    }
    t
}

/// Figure 8: ResNet18 square-array optimization curves (dense & pipeline):
/// total tile area, tile count, mapping efficiency, tile dimension.
pub fn fig8() -> Table {
    let mut t = Table::new(&[
        "discipline", "tile", "tiles", "total area mm2", "mapping eff", "tile eff", "optimum",
    ]);
    for discipline in [Discipline::Dense, Discipline::Pipeline] {
        let plan = MapRequest::zoo("resnet18")
            .discipline(discipline)
            .grid((6, 13), vec![1])
            .build()
            .and_then(|p| p.plan())
            .expect("fig8 plan");
        for p in &plan.points {
            t.row(&[
                discipline.to_string(),
                p.tile.to_string(),
                p.n_tiles.to_string(),
                sig3(p.total_area_mm2),
                sig3(p.packing_eff),
                sig3(p.tile_eff),
                if p.tile == plan.best.tile { "*".into() } else { "".into() },
            ]);
        }
    }
    t
}

/// Figure 9: optimum configurations for ResNet18/ImageNet across the six
/// groups (dense/pipeline/RAPA x square/rect), with simulated throughput.
pub fn fig9() -> Table {
    let mut t = Table::new(&[
        "group", "tile", "tiles", "tile eff", "total area mm2", "throughput inf/s",
    ]);
    // the paper's "N_rapa = 128 for 1st layer and successive reduction by 4"
    let rapa = Replication::Geometric(128, 4);
    let groups: [(&str, Discipline, Vec<usize>, Replication); 6] = [
        ("dense square", Discipline::Dense, vec![1], Replication::None),
        ("dense rect", Discipline::Dense, (1..=8).collect(), Replication::None),
        ("pipeline square", Discipline::Pipeline, vec![1], Replication::None),
        ("pipeline rect", Discipline::Pipeline, (1..=8).collect(), Replication::None),
        ("RAPA square", Discipline::Pipeline, vec![1], rapa.clone()),
        ("RAPA rect", Discipline::Pipeline, (1..=8).collect(), rapa.clone()),
    ];
    for (name, discipline, aspects, replication) in groups {
        let planner = MapRequest::zoo("resnet18")
            .discipline(discipline)
            .grid((6, 13), aspects)
            .replication(replication)
            .build()
            .expect("valid fig9 request");
        let plan = planner.plan().expect("fig9 plan");
        let best = &plan.best;
        // simulate the chosen configuration
        let sim_cfg = SimConfig {
            timing: TimingModel::default(),
            exec: match discipline {
                Discipline::Dense => Execution::Sequential,
                Discipline::Pipeline => Execution::Pipelined,
            },
            replication: planner.replication().to_vec(),
        };
        let packing = planner.pack(best.tile).expect("fig9 pack").packing;
        let rep = sim::simulate(planner.network(), &packing, &sim_cfg, 100);
        t.row(&[
            name.into(),
            best.tile.to_string(),
            best.n_tiles.to_string(),
            sig3(best.tile_eff),
            sig3(best.total_area_mm2),
            sig3(rep.throughput_per_s),
        ]);
    }
    t
}

/// Table 6: large vs small networks (dense, square): tiles (total area)
/// for 1:1, LPS and the simple approach at 256² and 1024².
pub fn table6(fast: bool) -> Table {
    let area = AreaModel::paper_default();
    let mut t = Table::new(&["Array", "Network", "option", "tiles", "area mm2"]);
    for net in ["resnet18", "resnet9"] {
        for tile in [Tile::new(256, 256), Tile::new(1024, 1024)] {
            let request = MapRequest::zoo(net).tile(tile.n_row, tile.n_col);
            let simple =
                request.clone().build().and_then(|p| p.plan()).expect("table6 plan");
            let lps = request
                .ilp(budget(fast).max_nodes)
                .build()
                .and_then(|p| p.plan())
                .expect("table6 plan");
            for (option, tiles) in [
                ("Mapping 1:1", simple.best.n_tiles_one_to_one),
                ("LPS", lps.best.n_tiles),
                ("Simple approach", simple.best.n_tiles),
            ] {
                t.row(&[
                    tile.to_string(),
                    simple.network.clone(),
                    option.into(),
                    tiles.to_string(),
                    sig3(area.total_area_mm2(tiles, tile)),
                ]);
            }
        }
    }
    t
}

/// Figure 10: packing optimization for square arrays — ResNet50 (plain and
/// RAPA 128/4) and one BERT layer (plain and replicated by S=64), comparing
/// optimized packing against 1:1 mapping across tile sizes.
pub fn fig10(fast: bool) -> Table {
    let mut t = Table::new(&[
        "workload", "variant", "tile", "tiles opt", "tiles 1:1", "area opt mm2", "area 1:1 mm2",
    ]);
    let workloads: [(&str, &str, Vec<(&str, Replication)>); 2] = [
        (
            "ResNet50/ImageNet",
            "resnet50",
            vec![
                ("plain", Replication::None),
                ("RAPA 128/4", Replication::Geometric(128, 4)),
            ],
        ),
        (
            "BERT layer S=64",
            "bert",
            vec![
                ("plain", Replication::None),
                ("max parallel xS", Replication::Uniform(64)),
            ],
        ),
    ];
    let area = AreaModel::paper_default();
    let exps = if fast { 8..=11u32 } else { 6..=13u32 };
    for (wname, zoo_name, variants) in workloads {
        for (vname, replication) in variants {
            for k in exps.clone() {
                let tile = Tile::new(1 << k, 1 << k);
                let best = MapRequest::zoo(zoo_name)
                    .tile(tile.n_row, tile.n_col)
                    .discipline(Discipline::Pipeline)
                    .replication(replication.clone())
                    .build()
                    .and_then(|p| p.plan())
                    .expect("fig10 plan")
                    .best;
                t.row(&[
                    wname.into(),
                    vname.into(),
                    tile.to_string(),
                    best.n_tiles.to_string(),
                    best.n_tiles_one_to_one.to_string(),
                    sig3(area.total_area_mm2(best.n_tiles, tile)),
                    sig3(area.total_area_mm2(best.n_tiles_one_to_one, tile)),
                ]);
            }
        }
    }
    t
}

/// Latency-model table (Eq. 3/4 cross-checked against the simulator) —
/// supplementary output used by EXPERIMENTS.md.
pub fn latency_table() -> Table {
    let mut t = Table::new(&[
        "network", "exec", "Eq.3/4 latency", "sim latency", "sim throughput/s",
    ]);
    let timing = TimingModel::default();
    for net in [zoo::lenet(), zoo::resnet18()] {
        for exec in [Execution::Sequential, Execution::Pipelined] {
            let discipline = match exec {
                Execution::Sequential => Discipline::Dense,
                Execution::Pipelined => Discipline::Pipeline,
            };
            let cfg = SimConfig { timing, exec, replication: vec![1; net.n_layers()] };
            let (_, rep) = sim::map_and_simulate(&net, Tile::new(512, 512), discipline, &cfg, 100);
            let analytic = perf::latency(&net, &cfg.replication, &timing, exec);
            t.row(&[
                net.name.clone(),
                format!("{exec:?}"),
                format!("{:.3e}", analytic),
                format!("{:.3e}", rep.first_latency_s),
                sig3(rep.throughput_per_s),
            ]);
        }
    }
    t
}

/// Extension ablations (paper §4/§5 future-work items built as features):
/// bit slicing, manufacturing yield, and the simple algorithm's sort order.
pub fn ablation() -> Table {
    use crate::area::yield_model::{yield_ranked, YieldModel};
    use crate::nets::bitslice::{sliced_shapes, BitSlice};
    let net = zoo::resnet18();
    let area = AreaModel::paper_default();
    let tile = Tile::new(256, 256);
    let mut t = Table::new(&["study", "setting", "tiles", "area mm2", "note"]);

    // 1) bit slicing: 8-bit weights across cells of b bits — the sliced
    //    WM shapes become a bias-free inline network, so the study runs
    //    through the same front door as everything else
    for bits_per_cell in [8u32, 4, 2, 1] {
        let cfg = BitSlice::new(8, bits_per_cell);
        let layers = sliced_shapes(&net, cfg)
            .into_iter()
            .enumerate()
            .map(|(li, (r, c))| {
                let mut l = crate::nets::Layer::fc(&format!("sliced{li}"), r, c);
                l.bias = false; // shapes are exact, no implicit bias row
                l
            })
            .collect();
        let sliced_net = crate::nets::Network::new("resnet18-sliced", "bit-sliced WMs", layers);
        let best = MapRequest::inline(sliced_net)
            .tile(tile.n_row, tile.n_col)
            .engine(crate::opt::Engine::Ffd)
            .build()
            .and_then(|p| p.plan())
            .expect("bit-slicing plan")
            .best;
        t.row(&[
            "bit-slicing".into(),
            format!("8b weights / {bits_per_cell}b cells ({} slices)", cfg.slices()),
            best.n_tiles.to_string(),
            sig3(best.total_area_mm2),
            "§2: slices multiply tiles per layer".into(),
        ]);
    }

    // 2) manufacturing yield: optimum under rising defect density
    let pts = MapRequest::zoo("resnet18")
        .grid((6, 13), vec![1])
        .build()
        .and_then(|p| p.plan())
        .expect("yield sweep plan")
        .points;
    for d0 in [0.0f64, 0.02, 0.1, 0.3] {
        let ym = YieldModel::new(d0);
        let ranked = yield_ranked(&pts, &area, &ym);
        let (best, eff_area) = ranked[0];
        t.row(&[
            "yield".into(),
            format!("D0={d0}/mm2"),
            format!("{} @ {}", best.n_tiles, best.tile),
            sig3(*&eff_area),
            "§5: defects push the optimum to smaller tiles".into(),
        ]);
    }

    // 3) communication-aware objective (§4/§5): lambda trades relative
    //    area against relative inter-tile message count
    for lambda in [0.0f64, 1.0, 5.0] {
        let cfg = crate::opt::SweepConfig::square(Discipline::Pipeline);
        let best = crate::opt::comm::comm_aware_optimum(&net, &cfg, lambda).unwrap();
        t.row(&[
            "comm-aware".into(),
            format!("lambda={lambda}"),
            format!("{} @ {}", best.point.n_tiles, best.point.tile),
            sig3(best.point.total_area_mm2),
            format!("{} msgs/inference", best.messages),
        ]);
    }

    // 4) simple-algorithm sort order (§2.1 descending vs §3 ascending text)
    for (name, order) in [
        ("rows desc (§2.1)", crate::pack::SortOrder::RowsDesc),
        ("rows asc (§3 literal)", crate::pack::SortOrder::RowsAsc),
        ("unsorted", crate::pack::SortOrder::AsGiven),
    ] {
        let p = MapRequest::zoo("resnet18")
            .tile(tile.n_row, tile.n_col)
            .sort(order)
            .build()
            .and_then(|p| p.plan())
            .expect("sort-order plan");
        t.row(&[
            "sort-order".into(),
            name.into(),
            p.best.n_tiles.to_string(),
            sig3(p.best.total_area_mm2),
            "sorting helps; direction is a wash at this size".into(),
        ]);
    }
    t
}

/// All experiments by id.
pub const EXPERIMENTS: &[&str] = &[
    "table1", "table3", "table5", "fig4", "fig7", "fig8", "fig9", "table6", "fig10", "latency",
    "ablation",
];

/// Run one experiment by id, returning (title, table).
pub fn run_one(id: &str, fast: bool) -> Option<(String, Table)> {
    let t = match id {
        "table1" => ("Table 1 — weight reuse of first conv layer".to_string(), table1()),
        "table3" => (
            "Table 3 / Fig. 5 — dense BILP packing of the demo list (paper: 2 bins)".to_string(),
            table3_5(fast).0,
        ),
        "table5" => (
            "Table 5 / Fig. 6 — pipeline BILP packing of the demo list (paper: 4 bins)"
                .to_string(),
            table3_5(fast).1,
        ),
        "fig4" => ("Figure 4 — ResNet18 fragmentation census vs square array".to_string(), fig4()),
        "fig7" => (
            "Figure 7 — simple packing vs linear programming (min area vs tiles)".to_string(),
            fig7(fast),
        ),
        "fig8" => ("Figure 8 — ResNet18 square-array optimization curves".to_string(), fig8()),
        "fig9" => ("Figure 9 — optimum mapping configurations (6 groups)".to_string(), fig9()),
        "table6" => ("Table 6 — large vs small networks (dense, square)".to_string(), table6(fast)),
        "fig10" => ("Figure 10 — ResNet50 & BERT packing optimization".to_string(), fig10(fast)),
        "latency" => (
            "Supplementary — Eq. 3/4 latency vs cycle-level simulator".to_string(),
            latency_table(),
        ),
        "ablation" => (
            "Extensions — bit slicing, manufacturing yield, sort order (paper §2/§4/§5)"
                .to_string(),
            ablation(),
        ),
        _ => return None,
    };
    Some(t)
}

/// Run experiments (all ids, or the given subset), print and persist.
pub fn run(ids: &[String], out_dir: &Path, fast: bool) -> std::io::Result<Vec<String>> {
    std::fs::create_dir_all(out_dir)?;
    let selected: Vec<String> = if ids.is_empty() || ids.iter().any(|i| i == "all") {
        EXPERIMENTS.iter().map(|s| s.to_string()).collect()
    } else {
        ids.to_vec()
    };
    let mut written = Vec::new();
    for id in &selected {
        let (title, table) = match run_one(id, fast) {
            Some(x) => x,
            None => {
                eprintln!("unknown experiment id: {id} (known: {EXPERIMENTS:?})");
                continue;
            }
        };
        println!("\n=== {title}\n{}", table.render());
        let txt = out_dir.join(format!("{id}.txt"));
        std::fs::write(&txt, format!("{title}\n\n{}", table.render()))?;
        let csv = out_dir.join(format!("{id}.csv"));
        std::fs::write(&csv, table.to_csv())?;
        written.push(id.clone());
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reproduces_paper_column() {
        let t = table1();
        // ResNet50, AlexNet and LeNet match the paper exactly; ResNet9 has
        // the documented discrepancy (standard 1024 vs paper 729) and its
        // paper-calib variant matches.
        let rows = t.rows();
        let find = |name: &str| rows.iter().find(|r| r[0] == name).unwrap();
        assert_eq!(find("ResNet50")[3], find("ResNet50")[4]);
        assert_eq!(find("AlexNet")[3], find("AlexNet")[4]);
        assert_eq!(find("LeNet")[3], find("LeNet")[4]);
        assert_eq!(find("ResNet9(paper-calib)")[3], "729");
        assert_eq!(find("ResNet9")[3], "1024");
    }

    #[test]
    fn tables_3_and_5_headline_bin_counts() {
        let (t3, t5) = table3_5(false);
        let total3 = &t3.rows().last().unwrap()[1];
        let total5 = &t5.rows().last().unwrap()[1];
        assert!(total3.starts_with("2 bins"), "{total3}");
        assert!(total5.starts_with("4 bins"), "{total5}");
    }

    #[test]
    fn fig4_counts_monotone() {
        let t = fig4();
        let totals: Vec<usize> =
            t.rows().iter().map(|r| r[1].parse().unwrap()).collect();
        for w in totals.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert_eq!(t.rows().len(), 8);
    }

    #[test]
    fn fig7_lps_never_worse() {
        let t = fig7(true);
        // group rows by (scenario, tile): lps tiles <= simple tiles
        use std::collections::BTreeMap;
        let mut by_key: BTreeMap<(String, String), BTreeMap<String, usize>> = BTreeMap::new();
        for r in t.rows() {
            by_key
                .entry((r[0].clone(), r[2].clone()))
                .or_default()
                .insert(r[1].clone(), r[3].parse().unwrap());
        }
        for ((scenario, tile), engines) in by_key {
            if let (Some(&s), Some(&l)) = (engines.get("simple"), engines.get("lps")) {
                assert!(l <= s, "{scenario} {tile}: lps {l} > simple {s}");
            }
        }
    }

    #[test]
    fn table6_orderings_hold() {
        // 1:1 >= simple >= LPS for every (net, tile) group
        let t = table6(true);
        let rows = t.rows();
        for chunk in rows.chunks(3) {
            let get = |opt: &str| {
                chunk
                    .iter()
                    .find(|r| r[2] == opt)
                    .map(|r| r[3].parse::<usize>().unwrap())
                    .unwrap()
            };
            let (one, lps, simple) = (get("Mapping 1:1"), get("LPS"), get("Simple approach"));
            assert!(one >= simple, "1:1 {one} < simple {simple}");
            assert!(simple >= lps, "simple {simple} < lps {lps}");
        }
    }

    #[test]
    fn run_writes_files() {
        let dir = std::env::temp_dir().join("xbarmap_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let written = run(&["table1".to_string()], &dir, true).unwrap();
        assert_eq!(written, vec!["table1"]);
        assert!(dir.join("table1.txt").exists());
        assert!(dir.join("table1.csv").exists());
    }
}
