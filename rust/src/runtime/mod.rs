//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the only module that touches the `xla` crate, and that crate is
//! only present on hosts with the vendored xla stack — so the PJRT client
//! is gated behind the `pjrt` cargo feature. Without it (the offline
//! default) [`Runtime`] and [`LoadedModel`] keep their full API but every
//! execution path returns a descriptive error; [`Tensor`] and
//! [`artifacts_dir`] are always available, so the mapping/packing/serving
//! bookkeeping (and its tests) never depend on the feature.
//!
//! With `pjrt`: artifacts are the HLO-text lowerings produced once by
//! `python/compile/aot.py` (HLO *text* rather than serialized protos
//! because xla_extension 0.5.1 rejects jax >= 0.5's 64-bit instruction
//! ids; the text parser reassigns them). Python never runs at request
//! time: the rust binary is self-contained once `artifacts/` exists.

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// An f32 tensor (row-major) crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {shape:?} wants {n} elements, got {}", data.len()));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major argmax along the last axis (batch of logits -> classes).
    pub fn argmax_rows(&self) -> Vec<usize> {
        let cols = *self.shape.last().unwrap_or(&1);
        self.data
            .chunks(cols)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// PJRT CPU runtime holding compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled HLO artifact.
#[cfg(feature = "pjrt")]
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub path: PathBuf,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(LoadedModel {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            path: path.to_path_buf(),
        })
    }
}

#[cfg(feature = "pjrt")]
impl LoadedModel {
    /// Execute with f32 inputs; returns the first element of the result
    /// tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Tensor> {
        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            let shape: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&shape)
                .map_err(|e| anyhow!("reshape input to {shape:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffer"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple output: {e:?}"))?;
        let shape = out
            .array_shape()
            .map_err(|e| anyhow!("output shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = out.to_vec::<f32>().map_err(|e| anyhow!("output data: {e:?}"))?;
        Tensor::new(dims, data)
    }
}

/// Message every stubbed execution path returns when the crate is built
/// without the `pjrt` feature (the offline default — the xla crate is not
/// in the image's crate set).
#[cfg(not(feature = "pjrt"))]
pub const PJRT_UNAVAILABLE: &str = "xbarmap was built without the `pjrt` feature (the offline \
image does not vendor the xla crate); rebuild with `--features pjrt` on a host with the \
vendored xla stack to execute AOT artifacts";

/// Stub PJRT runtime: full API, every execution path errors (see module
/// docs).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _private: (),
}

/// Stub compiled-artifact handle (never constructed at runtime).
#[cfg(not(feature = "pjrt"))]
pub struct LoadedModel {
    pub name: String,
    pub path: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Err(anyhow!("PJRT cpu client: {PJRT_UNAVAILABLE}"))
    }

    pub fn platform(&self) -> String {
        "unavailable (built without pjrt)".to_string()
    }

    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
        Err(anyhow!("parse {path:?}: {PJRT_UNAVAILABLE}"))
    }
}

#[cfg(not(feature = "pjrt"))]
impl LoadedModel {
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Tensor> {
        Err(anyhow!("execute {}: {PJRT_UNAVAILABLE}", self.name))
    }
}

/// Locate the artifacts directory: explicit argument, `XBARMAP_ARTIFACTS`,
/// or `./artifacts` relative to the current directory / crate root.
pub fn artifacts_dir(explicit: Option<&str>) -> PathBuf {
    if let Some(p) = explicit {
        return PathBuf::from(p);
    }
    if let Ok(p) = std::env::var("XBARMAP_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checked() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn tensor_argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 1.0, 0.2, 0.3]).unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn artifacts_dir_explicit_wins() {
        assert_eq!(artifacts_dir(Some("/tmp/a")), PathBuf::from("/tmp/a"));
    }

    // PJRT-touching tests live in rust/tests/integration_runtime.rs so the
    // unit suite stays free of the (slow) client construction.
}
