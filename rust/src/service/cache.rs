//! Canonical-request plan cache.
//!
//! Multi-tenant traffic repeats itself: zoo networks under the default
//! §3.1 grid, the same fixed-tile pricing question from every replica of a
//! design loop. Plans are deterministic functions of the request, so the
//! service memoizes them keyed by the request's **canonical v1
//! serialization** ([`crate::plan::wire::request_to_json`] emits a fixed
//! key order with defaults omitted, so any two requests that decode equal
//! serialize equal). The correlation id is cleared out of the key — and
//! out of the cached plan — because it only echoes back to the caller:
//! tenants asking the same design question under different ids share one
//! entry, and the hit path re-stamps the incoming id before serializing.
//!
//! Eviction is FIFO with a fixed entry capacity — the goal is a bounded
//! memory footprint for an always-on service, not a perfect hit rate.

use crate::plan::{MapPlan, MapRequest};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

struct Inner {
    map: HashMap<String, Arc<MapPlan>>,
    /// insertion order, oldest first (FIFO eviction)
    order: VecDeque<String>,
}

/// Bounded memoization of canonical request → plan. Capacity 0 disables
/// caching entirely (every lookup misses, inserts are dropped).
pub struct PlanCache {
    capacity: usize,
    inner: Mutex<Inner>,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            capacity,
            inner: Mutex::new(Inner { map: HashMap::new(), order: VecDeque::new() }),
        }
    }

    /// Whether lookups can ever hit — callers skip [`PlanCache::key`]'s
    /// clone + serialization when not.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// The cache key: the request's canonical serialization with the
    /// correlation id cleared (the id is an echo, not an input to
    /// planning).
    ///
    /// An id-carrying request pays one request clone here, and a hit pays
    /// one plan clone to restamp the id — both deliberate: canonical
    /// serialization owns the equality rule (no hand-rolled field
    /// stripping to drift), and a hit's clone+serialize is still orders
    /// of magnitude cheaper than the solve it avoids.
    pub fn key(req: &MapRequest) -> String {
        if req.id.is_empty() {
            return req.to_json().dumps();
        }
        let mut anon = req.clone();
        anon.id = String::new();
        anon.to_json().dumps()
    }

    /// Look up a cached plan. The returned plan carries an empty id — the
    /// caller re-stamps the incoming request's id before serializing.
    pub fn get(&self, key: &str) -> Option<Arc<MapPlan>> {
        if self.capacity == 0 {
            return None;
        }
        self.inner.lock().unwrap().map.get(key).cloned()
    }

    /// Insert a plan (id already cleared by the caller). Replaces an
    /// existing entry for the same key without consuming extra capacity;
    /// otherwise evicts the oldest entry once full.
    pub fn insert(&self, key: String, plan: Arc<MapPlan>) {
        if self.capacity == 0 {
            return;
        }
        debug_assert!(plan.id.is_empty(), "cached plans must be anonymous");
        let mut inner = self.inner.lock().unwrap();
        if inner.map.insert(key.clone(), plan).is_none() {
            inner.order.push_back(key);
            if inner.order.len() > self.capacity {
                if let Some(oldest) = inner.order.pop_front() {
                    inner.map.remove(&oldest);
                }
            }
        }
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::MapRequest;

    fn plan_for(req: &MapRequest) -> Arc<MapPlan> {
        let mut plan = req.clone().build().unwrap().plan().unwrap();
        plan.id.clear();
        Arc::new(plan)
    }

    #[test]
    fn key_ignores_the_correlation_id_only() {
        let a = MapRequest::zoo("lenet").id("tenant-a").tile(256, 256);
        let b = MapRequest::zoo("lenet").id("tenant-b").tile(256, 256);
        let c = MapRequest::zoo("lenet").id("tenant-a").tile(256, 128);
        assert_eq!(PlanCache::key(&a), PlanCache::key(&b));
        assert_ne!(PlanCache::key(&a), PlanCache::key(&c));
        // and the key of an id-less request matches the anonymized form
        assert_eq!(PlanCache::key(&a), PlanCache::key(&MapRequest::zoo("lenet").tile(256, 256)));
    }

    #[test]
    fn fifo_eviction_bounds_the_entry_count() {
        let cache = PlanCache::new(2);
        let reqs: Vec<MapRequest> = [64, 128, 256]
            .iter()
            .map(|&r| MapRequest::zoo("lenet").tile(r, 64))
            .collect();
        for req in &reqs {
            cache.insert(PlanCache::key(req), plan_for(req));
        }
        assert_eq!(cache.len(), 2);
        // the oldest entry was evicted, the two newest remain
        assert!(cache.get(&PlanCache::key(&reqs[0])).is_none());
        assert!(cache.get(&PlanCache::key(&reqs[1])).is_some());
        assert!(cache.get(&PlanCache::key(&reqs[2])).is_some());
    }

    #[test]
    fn replacing_a_key_does_not_consume_capacity() {
        let cache = PlanCache::new(2);
        let a = MapRequest::zoo("lenet").tile(64, 64);
        let b = MapRequest::zoo("lenet").tile(128, 64);
        cache.insert(PlanCache::key(&a), plan_for(&a));
        cache.insert(PlanCache::key(&a), plan_for(&a));
        cache.insert(PlanCache::key(&b), plan_for(&b));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&PlanCache::key(&a)).is_some());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let cache = PlanCache::new(0);
        let a = MapRequest::zoo("lenet").tile(64, 64);
        cache.insert(PlanCache::key(&a), plan_for(&a));
        assert!(cache.get(&PlanCache::key(&a)).is_none());
        assert!(cache.is_empty());
    }
}
