//! Canonical-request plan cache: LRU eviction, optional TTL, byte-size
//! accounting.
//!
//! Multi-tenant traffic repeats itself: zoo networks under the default
//! §3.1 grid, the same fixed-tile pricing question from every replica of a
//! design loop. Plans are deterministic functions of the request, so the
//! service memoizes them keyed by the request's **canonical v1
//! serialization** ([`crate::plan::wire::request_to_json`] emits a fixed
//! key order with defaults omitted, so any two requests that decode equal
//! serialize equal). The correlation id is cleared out of the key — and
//! out of the cached plan — because it only echoes back to the caller:
//! tenants asking the same design question under different ids share one
//! entry, and the hit path re-stamps the incoming id before serializing.
//!
//! Every key in this cache is such a canonical serialization — nothing
//! else ever inserts. The byte-level wire fast path
//! ([`crate::plan::wire::scan`]) leans on that exclusivity: it probes
//! with a candidate key spliced straight out of the raw line, and a hit
//! *proves* the line was canonical, so the cached plan answers it
//! byte-identically to a full parse. Insert/promote take the key as
//! `&str` and copy it only when an entry actually lands, so the hit
//! path (and the reader handing over an already-computed key) never
//! clones a key just to look one up.
//!
//! Eviction policy (per [`PlanCache::with_policy`]):
//!
//! * **LRU** within a fixed entry capacity — repeated design questions
//!   stay resident while one-off sweeps age out (the PR-4 cache was FIFO,
//!   which evicted the hottest entry as readily as the coldest);
//! * an optional **TTL**: once the area model (or any pricing input)
//!   becomes mutable at runtime, a bounded entry lifetime guarantees no
//!   client is served a plan priced under parameters older than the TTL;
//! * **byte accounting**: every entry is charged its key length plus its
//!   serialized plan length, so the cache's real memory footprint is
//!   observable (`metrics` frame) and optionally bounded (`max_bytes`),
//!   not just its entry count — one BERT grid plan is ~1000x the bytes of
//!   a LeNet fixed-tile plan.

use crate::plan::{MapPlan, MapRequest};
use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Entry {
    plan: Arc<MapPlan>,
    /// bytes charged to this entry: key length + serialized plan length
    bytes: usize,
    inserted: Instant,
    /// logical clock value of the last hit (or the insert) — the LRU
    /// victim is the entry with the smallest value
    last_used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    /// recency order: `last_used` tick → key, kept in lockstep with
    /// `map` (ticks are unique, so this is a total order and the first
    /// entry is always the LRU victim — eviction is O(log n) instead of
    /// the O(entries) scan it replaced)
    by_tick: BTreeMap<u64, String>,
    /// logical clock: bumped on every insert and hit
    tick: u64,
    /// total bytes charged across live entries
    bytes: usize,
    /// entries dropped because their TTL elapsed (cumulative)
    expired: u64,
}

impl Inner {
    /// Remove `key` from both sides of the lockstep pair, adjusting the
    /// byte charge. Every removal path (expiry, eviction, replacement)
    /// funnels through here so the pair cannot drift.
    fn remove_entry(&mut self, key: &str) -> Option<Entry> {
        let e = self.map.remove(key)?;
        self.by_tick.remove(&e.last_used);
        self.bytes -= e.bytes;
        Some(e)
    }
}

/// Bounded memoization of canonical request → plan. Capacity 0 disables
/// caching entirely (every lookup misses, inserts are dropped).
pub struct PlanCache {
    capacity: usize,
    ttl: Option<Duration>,
    /// byte budget across entries (0 = unbounded; the entry capacity
    /// still bounds memory)
    max_bytes: usize,
    inner: Mutex<Inner>,
}

impl PlanCache {
    /// An LRU cache of `capacity` entries with no TTL and no byte cap.
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache::with_policy(capacity, None, 0)
    }

    /// An LRU cache of at most `capacity` entries, each living at most
    /// `ttl` (None = forever), charged against a `max_bytes` budget
    /// (0 = unbounded). Eviction removes least-recently-used entries
    /// until both bounds hold.
    pub fn with_policy(capacity: usize, ttl: Option<Duration>, max_bytes: usize) -> PlanCache {
        PlanCache {
            capacity,
            ttl,
            max_bytes,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                by_tick: BTreeMap::new(),
                tick: 0,
                bytes: 0,
                expired: 0,
            }),
        }
    }

    /// Whether lookups can ever hit — callers skip [`PlanCache::key`]'s
    /// clone + serialization when not.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// The cache key: the request's canonical serialization with the
    /// correlation id cleared (the id is an echo, not an input to
    /// planning).
    ///
    /// An id-carrying request pays one request clone here, and a hit pays
    /// one plan clone to restamp the id — both deliberate: canonical
    /// serialization owns the equality rule (no hand-rolled field
    /// stripping to drift), and a hit's clone+serialize is still orders
    /// of magnitude cheaper than the solve it avoids.
    pub fn key(req: &MapRequest) -> String {
        if req.id.is_empty() {
            return req.to_json().dumps();
        }
        let mut anon = req.clone();
        anon.id = String::new();
        anon.to_json().dumps()
    }

    /// Look up a cached plan, refreshing its recency. An entry past its
    /// TTL is dropped (counted in [`PlanCache::expired_total`]) and
    /// reported as a miss — the caller re-solves and re-inserts, so no
    /// plan older than the TTL is ever served. The returned plan carries
    /// an empty id — the caller re-stamps the incoming request's id
    /// before serializing.
    pub fn get(&self, key: &str) -> Option<Arc<MapPlan>> {
        self.get_at(key, Instant::now())
    }

    fn get_at(&self, key: &str, now: Instant) -> Option<Arc<MapPlan>> {
        if self.capacity == 0 {
            return None;
        }
        let mut inner = self.lock();
        let expired = match (inner.map.get(key), self.ttl) {
            (Some(e), Some(ttl)) => now.saturating_duration_since(e.inserted) >= ttl,
            _ => false,
        };
        if expired {
            if inner.remove_entry(key).is_some() {
                inner.expired += 1;
            }
            return None;
        }
        inner.tick += 1;
        let tick = inner.tick;
        let Inner { map, by_tick, .. } = &mut *inner;
        map.get_mut(key).map(|e| {
            // re-file under the fresh tick so the ordered index tracks
            // recency (the hit pays one BTreeMap move + key clone; the
            // eviction it buys is O(log n) instead of a full scan)
            by_tick.remove(&e.last_used);
            by_tick.insert(tick, key.to_string());
            e.last_used = tick;
            Arc::clone(&e.plan)
        })
    }

    /// Insert a plan (id already cleared by the caller), charging
    /// `key.len()` plus the plan's serialized length against the byte
    /// budget, then evicting least-recently-used entries until both the
    /// entry and byte bounds hold. Replacing an existing key re-charges
    /// its bytes; a plan too large for `max_bytes` on its own simply
    /// doesn't stay resident (bounded memory wins over hit rate).
    pub fn insert(&self, key: &str, plan: Arc<MapPlan>) {
        if self.capacity == 0 {
            return; // don't pay the serialization below just to drop it
        }
        let plan_len = plan.to_json().dumps().len();
        self.insert_at(key, plan, plan_len, Instant::now())
    }

    /// [`PlanCache::insert`] with the plan's serialized length already in
    /// hand — the service serializes the anonymized plan anyway, so the
    /// accounting charge costs no second serialization.
    pub fn insert_serialized(&self, key: &str, plan: Arc<MapPlan>, plan_len: usize) {
        self.insert_at(key, plan, plan_len, Instant::now())
    }

    /// Insert a plan recovered from the **warehouse** (the service's
    /// on-disk second tier) into the LRU. Promotion must be
    /// indistinguishable from a solved insert: it charges `key + plan`
    /// bytes against the budget and stamps a fresh tick and TTL epoch —
    /// the entry's lifetime runs from the promotion, not from whenever
    /// the plan was originally solved — so it goes through the exact
    /// insert path rather than touching the maps directly.
    pub fn promote_serialized(&self, key: &str, plan: Arc<MapPlan>, plan_len: usize) {
        self.promote_at(key, plan, plan_len, Instant::now())
    }

    /// Clock-injection point for [`PlanCache::promote_serialized`] — the
    /// TTL-schedule unit test drives this with explicit instants.
    fn promote_at(&self, key: &str, plan: Arc<MapPlan>, plan_len: usize, now: Instant) {
        self.insert_at(key, plan, plan_len, now)
    }

    fn insert_at(&self, key: &str, plan: Arc<MapPlan>, plan_len: usize, now: Instant) {
        if self.capacity == 0 {
            return;
        }
        debug_assert!(plan.id.is_empty(), "cached plans must be anonymous");
        let bytes = key.len() + plan_len;
        let mut inner = self.lock();
        // purge everything already past its TTL — expiry is otherwise only
        // discovered by a lookup of the same key, which would let a
        // never-requested-again entry hold memory (and inflate the
        // cache_bytes gauge) forever
        if let Some(ttl) = self.ttl {
            let dead: Vec<String> = inner
                .map
                .iter()
                .filter(|(_, e)| now.saturating_duration_since(e.inserted) >= ttl)
                .map(|(k, _)| k.clone())
                .collect();
            for k in dead {
                if inner.remove_entry(&k).is_some() {
                    inner.expired += 1;
                }
            }
        }
        inner.tick += 1;
        let tick = inner.tick;
        let entry = Entry { plan, bytes, inserted: now, last_used: tick };
        // the only points where the borrowed key becomes owned — an
        // entry that actually lands pays its two copies (map + index);
        // callers that merely probe or replace never clone
        inner.by_tick.insert(tick, key.to_string());
        if let Some(old) = inner.map.insert(key.to_string(), entry) {
            inner.by_tick.remove(&old.last_used);
            inner.bytes -= old.bytes;
        }
        inner.bytes += bytes;
        // the ordered tick index makes the victim lookup O(log n): ticks
        // are unique and refreshed on every hit, so the index's smallest
        // tick always names the least-recently-used entry
        while (inner.map.len() > self.capacity
            || (self.max_bytes > 0 && inner.bytes > self.max_bytes))
            && !inner.map.is_empty()
        {
            let Some((_, victim)) = inner.by_tick.pop_first() else {
                break; // index drained: the lockstep debug_assert below reports drift
            };
            if let Some(e) = inner.map.remove(&victim) {
                inner.bytes -= e.bytes;
            }
        }
        debug_assert_eq!(
            inner.map.len(),
            inner.by_tick.len(),
            "tick index out of lockstep with the entry map"
        );
    }

    /// Lock the cache state, recovering from poisoning: every removal
    /// path funnels through [`Inner::remove_entry`] and every mutation
    /// keeps the map/index lockstep valid at each step, so a panicking
    /// holder leaves consistent state behind — recover like the
    /// service's stats lock rather than wedging every later lookup.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// Whether the cache currently holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently charged across live entries (keys + serialized
    /// plans — the footprint the `metrics` frame reports).
    pub fn bytes(&self) -> usize {
        self.lock().bytes
    }

    /// Entries dropped by TTL expiry since construction.
    pub fn expired_total(&self) -> u64 {
        self.lock().expired
    }

    /// Drop every entry, returning how many were flushed — the
    /// `recalibrate` admin verb (pricing inputs changed, so every cached
    /// answer is suspect). The byte gauge falls to zero with the map;
    /// the TTL-expiry counter is untouched (a flush is not an expiry)
    /// and the logical clock keeps running, so recency ordering stays
    /// correct across the flush.
    pub fn clear(&self) -> usize {
        let mut inner = self.lock();
        let flushed = inner.map.len();
        inner.map.clear();
        inner.by_tick.clear();
        inner.bytes = 0;
        flushed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::MapRequest;

    fn plan_for(req: &MapRequest) -> Arc<MapPlan> {
        let mut plan = req.clone().build().unwrap().plan().unwrap();
        plan.id.clear();
        Arc::new(plan)
    }

    /// A plan plus its serialized length, for the explicit-clock inserts.
    fn sized_plan(req: &MapRequest) -> (Arc<MapPlan>, usize) {
        let plan = plan_for(req);
        let len = plan.to_json().dumps().len();
        (plan, len)
    }

    fn req(rows: usize) -> MapRequest {
        MapRequest::zoo("lenet").tile(rows, 64)
    }

    #[test]
    fn key_ignores_the_correlation_id_only() {
        let a = MapRequest::zoo("lenet").id("tenant-a").tile(256, 256);
        let b = MapRequest::zoo("lenet").id("tenant-b").tile(256, 256);
        let c = MapRequest::zoo("lenet").id("tenant-a").tile(256, 128);
        assert_eq!(PlanCache::key(&a), PlanCache::key(&b));
        assert_ne!(PlanCache::key(&a), PlanCache::key(&c));
        // and the key of an id-less request matches the anonymized form
        assert_eq!(PlanCache::key(&a), PlanCache::key(&MapRequest::zoo("lenet").tile(256, 256)));
    }

    #[test]
    fn eviction_is_lru_not_fifo() {
        let cache = PlanCache::new(2);
        let (a, b, c) = (req(64), req(128), req(256));
        cache.insert(&PlanCache::key(&a), plan_for(&a));
        cache.insert(&PlanCache::key(&b), plan_for(&b));
        // touch the older entry: under FIFO it would still be the victim,
        // under LRU the untouched one is
        assert!(cache.get(&PlanCache::key(&a)).is_some());
        cache.insert(&PlanCache::key(&c), plan_for(&c));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&PlanCache::key(&a)).is_some(), "recently used entry evicted");
        assert!(cache.get(&PlanCache::key(&b)).is_none(), "LRU entry survived");
        assert!(cache.get(&PlanCache::key(&c)).is_some());
    }

    #[test]
    fn replacing_a_key_does_not_consume_capacity() {
        let cache = PlanCache::new(2);
        let (a, b) = (req(64), req(128));
        cache.insert(&PlanCache::key(&a), plan_for(&a));
        cache.insert(&PlanCache::key(&a), plan_for(&a));
        cache.insert(&PlanCache::key(&b), plan_for(&b));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&PlanCache::key(&a)).is_some());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let cache = PlanCache::new(0);
        let a = req(64);
        cache.insert(&PlanCache::key(&a), plan_for(&a));
        assert!(cache.get(&PlanCache::key(&a)).is_none());
        assert!(cache.is_empty());
        assert!(!cache.enabled());
        assert_eq!(cache.bytes(), 0);
    }

    #[test]
    fn ttl_expires_entries_without_wall_clock_sleeps() {
        let ttl = Duration::from_secs(60);
        let cache = PlanCache::with_policy(4, Some(ttl), 0);
        let a = req(64);
        let key = PlanCache::key(&a);
        let (plan, len) = sized_plan(&a);
        let t0 = Instant::now();
        cache.insert_at(&key, plan.clone(), len, t0);
        // young entry hits; the hit does NOT extend the lifetime (TTL is
        // from insert, so a hot entry still refreshes after the TTL)
        assert!(cache.get_at(&key, t0 + ttl / 2).is_some());
        assert!(cache.get_at(&key, t0 + ttl).is_none(), "entry outlived its TTL");
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.expired_total(), 1);
        // re-inserting after expiry restarts the clock
        cache.insert_at(&key, plan, len, t0 + ttl);
        assert!(cache.get_at(&key, t0 + ttl + ttl / 2).is_some());
    }

    #[test]
    fn inserts_purge_expired_entries_of_other_keys() {
        // a one-off entry that is never looked up again must not hold
        // memory (or inflate the gauges) past its TTL: any later insert
        // sweeps it out
        let ttl = Duration::from_secs(60);
        let cache = PlanCache::with_policy(8, Some(ttl), 0);
        let (a, b) = (req(64), req(128));
        let (plan_a, len_a) = sized_plan(&a);
        let (plan_b, len_b) = sized_plan(&b);
        let t0 = Instant::now();
        cache.insert_at(&PlanCache::key(&a), plan_a, len_a, t0);
        cache.insert_at(&PlanCache::key(&b), plan_b, len_b, t0 + ttl);
        assert_eq!(cache.len(), 1, "expired entry must be purged by the insert");
        assert_eq!(cache.expired_total(), 1);
        assert_eq!(cache.bytes(), PlanCache::key(&b).len() + len_b);
        assert!(cache.get_at(&PlanCache::key(&b), t0 + ttl).is_some());
    }

    #[test]
    fn no_ttl_means_entries_never_expire() {
        let cache = PlanCache::new(2);
        let a = req(64);
        let key = PlanCache::key(&a);
        let (plan, len) = sized_plan(&a);
        let t0 = Instant::now();
        cache.insert_at(&key, plan, len, t0);
        assert!(cache.get_at(&key, t0 + Duration::from_secs(1 << 20)).is_some());
        assert_eq!(cache.expired_total(), 0);
    }

    #[test]
    fn byte_accounting_tracks_live_entries() {
        let cache = PlanCache::new(4);
        let (a, b) = (req(64), req(128));
        assert_eq!(cache.bytes(), 0);
        cache.insert(&PlanCache::key(&a), plan_for(&a));
        let after_one = cache.bytes();
        assert!(after_one > PlanCache::key(&a).len(), "charge must include the plan body");
        cache.insert(&PlanCache::key(&b), plan_for(&b));
        assert!(cache.bytes() > after_one);
        // replacing re-charges instead of double-counting
        cache.insert(&PlanCache::key(&a), plan_for(&a));
        assert_eq!(cache.len(), 2);
        let two = cache.bytes();
        cache.insert(&PlanCache::key(&a), plan_for(&a));
        assert_eq!(cache.bytes(), two);
    }

    /// Reference LRU: the O(entries) eviction scan the ordered tick index
    /// replaced, kept here as the parity oracle for the randomized test.
    struct ScanModel {
        capacity: usize,
        entries: Vec<(String, u64, usize)>, // (key, last_used, bytes)
        tick: u64,
    }

    impl ScanModel {
        fn get(&mut self, key: &str) -> bool {
            self.tick += 1;
            let tick = self.tick;
            match self.entries.iter_mut().find(|(k, _, _)| k == key) {
                Some(e) => {
                    e.1 = tick;
                    true
                }
                None => false,
            }
        }

        fn insert(&mut self, key: &str, bytes: usize) {
            self.tick += 1;
            let tick = self.tick;
            match self.entries.iter_mut().find(|(k, _, _)| k == key) {
                Some(e) => {
                    e.1 = tick;
                    e.2 = bytes;
                }
                None => self.entries.push((key.to_string(), tick, bytes)),
            }
            while self.entries.len() > self.capacity {
                let victim = self
                    .entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, (_, used, _))| *used)
                    .map(|(i, _)| i)
                    .expect("non-empty");
                self.entries.remove(victim);
            }
        }

        fn bytes(&self) -> usize {
            self.entries.iter().map(|(k, _, b)| k.len() + b).sum()
        }
    }

    #[test]
    fn ordered_index_eviction_matches_the_scan_model_on_random_ops() {
        // drive the real cache and the reference scan implementation with
        // one randomized op sequence; every hit/miss outcome and the full
        // resident set must agree at each step
        let mut rng = crate::util::prng::Rng::new(0x5eed_cac4e);
        for capacity in [1usize, 3, 8] {
            let cache = PlanCache::new(capacity);
            let mut model = ScanModel { capacity, entries: Vec::new(), tick: 0 };
            let plan = plan_for(&req(64));
            let t0 = Instant::now();
            for step in 0..600 {
                let key = format!("k{}", rng.below(12));
                if rng.chance(0.5) {
                    let bytes = 50 + rng.below(50) as usize;
                    cache.insert_at(&key, Arc::clone(&plan), bytes, t0);
                    model.insert(&key, bytes);
                } else {
                    let got = cache.get_at(&key, t0).is_some();
                    let want = model.get(&key);
                    assert_eq!(got, want, "cap {capacity} step {step}: hit/miss diverged on {key}");
                }
                assert_eq!(cache.len(), model.entries.len(), "cap {capacity} step {step}");
                assert_eq!(cache.bytes(), model.bytes(), "cap {capacity} step {step}");
                let resident: Vec<String> =
                    model.entries.iter().map(|(k, _, _)| k.clone()).collect();
                for k in resident {
                    // probe residency by getting on both sides, which
                    // bumps recency identically and keeps them in lockstep
                    assert_eq!(cache.get_at(&k, t0).is_some(), model.get(&k), "cap {capacity}");
                }
            }
        }
    }

    #[test]
    fn warehouse_promotion_charges_bytes_and_expires_on_the_solved_schedule() {
        // a plan recovered from the on-disk warehouse must be a
        // first-class citizen of the LRU: same byte charge as a solved
        // insert, and a TTL running from the *promotion* instant
        let ttl = Duration::from_secs(60);
        let cache = PlanCache::with_policy(8, Some(ttl), 0);
        let a = req(64);
        let key = PlanCache::key(&a);
        let (plan, len) = sized_plan(&a);
        let t0 = Instant::now();

        // solved insert: record its byte charge, then clear the cache by
        // letting it expire
        cache.insert_at(&key, Arc::clone(&plan), len, t0);
        let solved_bytes = cache.bytes();
        assert!(cache.get_at(&key, t0 + ttl).is_none());
        assert_eq!(cache.len(), 0);

        // warehouse promotion at t1: identical charge, fresh TTL epoch
        let t1 = t0 + ttl + ttl;
        cache.promote_at(&key, plan, len, t1);
        assert_eq!(cache.bytes(), solved_bytes, "promotion must charge key+plan bytes");
        assert!(cache.get_at(&key, t1 + ttl / 2).is_some(), "young promoted entry must hit");
        assert!(
            cache.get_at(&key, t1 + ttl).is_none(),
            "promoted entry must expire one TTL after promotion, not live forever"
        );
        assert_eq!(cache.expired_total(), 2);
    }

    #[test]
    fn byte_budget_evicts_lru_until_under() {
        let a = req(64);
        let one_entry = PlanCache::key(&a).len() + plan_for(&a).to_json().dumps().len();
        // budget fits roughly one entry of this shape
        let cache = PlanCache::with_policy(16, None, one_entry + one_entry / 2);
        let b = req(128);
        cache.insert(&PlanCache::key(&a), plan_for(&a));
        cache.insert(&PlanCache::key(&b), plan_for(&b));
        assert_eq!(cache.len(), 1, "byte budget must evict despite free entry slots");
        assert!(cache.get(&PlanCache::key(&b)).is_some(), "newest entry must survive");
        assert!(cache.bytes() <= one_entry + one_entry / 2);
    }

    #[test]
    fn insert_accepts_borrowed_keys_without_a_caller_side_clone() {
        // pins the &str-key API: the wire fast path hands the cache a key
        // sliced out of a larger buffer (the scanner's candidate key, or
        // the reader's already-computed canonical key) and must never be
        // forced to clone it just to probe or insert. Reverting any
        // signature to `String` breaks this test at compile time.
        let cache = PlanCache::new(4);
        let a = req(64);
        let owned = PlanCache::key(&a);
        let buffer = format!("{owned}\n");
        let borrowed: &str = buffer.trim_end();
        let (plan, len) = sized_plan(&a);
        cache.insert_serialized(borrowed, Arc::clone(&plan), len);
        assert!(cache.get(borrowed).is_some());
        cache.promote_serialized(borrowed, plan, len);
        assert_eq!(cache.len(), 1, "same borrowed key must replace, not duplicate");
        assert_eq!(cache.bytes(), owned.len() + len);
    }

    #[test]
    fn clear_flushes_everything_but_preserves_history_counters() {
        let ttl = Duration::from_secs(60);
        let cache = PlanCache::with_policy(8, Some(ttl), 0);
        let (a, b) = (req(64), req(128));
        let key_a = PlanCache::key(&a);
        let (plan_a, len_a) = sized_plan(&a);
        let (plan_b, len_b) = sized_plan(&b);
        let t0 = Instant::now();
        cache.insert_at(&key_a, Arc::clone(&plan_a), len_a, t0);
        // expire one entry first so the expiry counter has history
        assert!(cache.get_at(&key_a, t0 + ttl).is_none());
        assert_eq!(cache.expired_total(), 1);
        cache.insert_at(&key_a, plan_a, len_a, t0 + ttl);
        cache.insert_at(&PlanCache::key(&b), plan_b, len_b, t0 + ttl);
        assert_eq!(cache.clear(), 2, "clear must report how many entries it flushed");
        assert!(cache.is_empty());
        assert_eq!(cache.bytes(), 0, "the byte gauge must fall with the map");
        assert_eq!(cache.expired_total(), 1, "a flush is not an expiry");
        assert_eq!(cache.clear(), 0, "a second flush finds nothing");
        // the cache stays usable after a flush
        let (plan_a2, len_a2) = sized_plan(&a);
        cache.insert_at(&key_a, plan_a2, len_a2, t0 + ttl);
        assert!(cache.get_at(&key_a, t0 + ttl).is_some());
    }
}
