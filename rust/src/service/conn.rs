//! Per-connection response ordering.
//!
//! Requests from one connection fan out across the shared worker pool and
//! complete in any order, but the JSONL contract (and byte-identity with
//! [`crate::plan::serve_jsonl`]) requires responses in request order. Each
//! connection therefore owns a [`Conn`]: workers deliver `(seq, line)`
//! pairs, and the writer emits a line the moment it becomes the next one
//! in sequence, parking out-of-order completions until their turn. When
//! the reader side signals how many responses are owed in total
//! ([`Conn::finish_input`] at EOF or shutdown), the write half shuts down
//! as soon as the last one is out — the client sees every response, then
//! EOF.

use std::collections::BTreeMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::{Mutex, MutexGuard};

struct Writer {
    /// write half; `None` once closed (all responses out) or broken
    stream: Option<TcpStream>,
    /// next sequence number to emit
    next_seq: usize,
    /// out-of-order completions parked until their turn
    parked: BTreeMap<usize, String>,
    /// total responses owed, known once the reader side is done
    total: Option<usize>,
    /// a flusher is currently writing outside the lock (single-flusher
    /// discipline: everyone else just parks and leaves)
    writing: bool,
}

/// The write half of one client connection (shared `Arc<Conn>` between the
/// connection's reader thread and every worker holding one of its jobs).
pub(crate) struct Conn {
    writer: Mutex<Writer>,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn {
            writer: Mutex::new(Writer {
                stream: Some(stream),
                next_seq: 0,
                parked: BTreeMap::new(),
                total: None,
                writing: false,
            }),
        }
    }

    /// Deliver response `seq` (one JSON document, no trailing newline).
    /// Emitted as soon as it is next in request order — along with any
    /// parked successors it unblocks — otherwise parked. A client that
    /// disappeared mid-stream degrades to discarding: the write error
    /// closes the stream and later deliveries drain silently.
    pub fn deliver(&self, seq: usize, line: String) {
        let mut w = self.lock();
        w.parked.insert(seq, line);
        self.pump(w);
    }

    /// Lock the writer state, recovering from poisoning: the parked map,
    /// sequence counter and flusher flag are valid at every step (socket
    /// writes happen outside the lock on a moved-out stream), so a
    /// panicking holder leaves consistent state — recover like the
    /// service's stats lock rather than silently dropping every later
    /// response on this connection.
    fn lock(&self) -> MutexGuard<'_, Writer> {
        self.writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// The reader side is done (EOF, shutdown, or a read error): exactly
    /// `total` responses are owed in all. Closes the write half once the
    /// last one is out — immediately, if everything was already delivered.
    pub fn finish_input(&self, total: usize) {
        let mut w = self.lock();
        w.total = Some(total);
        self.pump(w);
    }

    /// Drain every in-order line. Socket writes happen **outside** the
    /// lock so a stalled client blocks only its own connection, never the
    /// workers delivering to other connections; the `writing` flag keeps
    /// a single flusher active at a time (others park and leave), which
    /// preserves sequence order. Flushed per batch so clients see
    /// responses as they are produced, like serve_jsonl.
    fn pump(&self, mut w: MutexGuard<'_, Writer>) {
        if w.writing {
            return; // the active flusher will pick our lines up
        }
        w.writing = true;
        loop {
            let mut batch = Vec::new();
            while let Some(line) = w.parked.remove(&w.next_seq) {
                w.next_seq += 1;
                batch.push(line);
            }
            if batch.is_empty() {
                break;
            }
            let mut stream = w.stream.take();
            drop(w);
            let broken = match stream.as_mut() {
                Some(s) => {
                    let mut wrote = batch.iter().try_for_each(|line| writeln!(s, "{line}"));
                    wrote = wrote.and_then(|()| s.flush());
                    wrote.is_err()
                }
                None => false,
            };
            if broken {
                // client gone (or stalled past the write timeout): keep
                // draining sequence numbers, stop writing
                stream = None;
            }
            w = self.lock();
            w.stream = stream;
        }
        // lock held: no new lines can arrive between the last drain and
        // the close decision / releasing the flusher role
        if w.total == Some(w.next_seq) {
            if let Some(stream) = w.stream.take() {
                let _ = stream.shutdown(Shutdown::Write);
            }
            w.parked.clear();
        }
        w.writing = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};
    use std::net::TcpListener;

    /// A loopback socket pair: (service-side stream, client-side stream).
    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (server, client)
    }

    #[test]
    fn out_of_order_deliveries_emerge_in_sequence() {
        let (server, client) = pair();
        let conn = Conn::new(server);
        conn.deliver(2, "third".into());
        conn.deliver(0, "first".into());
        conn.deliver(1, "second".into());
        conn.finish_input(3);
        let lines: Vec<String> =
            BufReader::new(client).lines().collect::<Result<_, _>>().unwrap();
        assert_eq!(lines, ["first", "second", "third"]);
    }

    #[test]
    fn finish_before_delivery_still_flushes_everything_then_eof() {
        let (server, client) = pair();
        let conn = Conn::new(server);
        conn.finish_input(2);
        conn.deliver(1, "b".into());
        conn.deliver(0, "a".into());
        let lines: Vec<String> =
            BufReader::new(client).lines().collect::<Result<_, _>>().unwrap();
        assert_eq!(lines, ["a", "b"]);
    }

    #[test]
    fn zero_requests_closes_immediately() {
        let (server, client) = pair();
        let conn = Conn::new(server);
        conn.finish_input(0);
        let mut buf = String::new();
        assert_eq!(BufReader::new(client).read_line(&mut buf).unwrap(), 0);
    }

    #[test]
    fn concurrent_deliveries_keep_sequence_order() {
        let (server, client) = pair();
        let conn = std::sync::Arc::new(Conn::new(server));
        let n = 64usize;
        let handles: Vec<_> = (0..n)
            .rev()
            .map(|seq| {
                let conn = std::sync::Arc::clone(&conn);
                std::thread::spawn(move || conn.deliver(seq, format!("line-{seq}")))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        conn.finish_input(n);
        let lines: Vec<String> =
            BufReader::new(client).lines().collect::<Result<_, _>>().unwrap();
        let expect: Vec<String> = (0..n).map(|i| format!("line-{i}")).collect();
        assert_eq!(lines, expect);
    }

    #[test]
    fn a_vanished_client_drains_without_panicking() {
        let (server, client) = pair();
        drop(client);
        let conn = Conn::new(server);
        // big payloads so the kernel buffer can't absorb them silently
        for seq in 0..64 {
            conn.deliver(seq, "x".repeat(1 << 16));
        }
        conn.finish_input(64);
    }
}
