//! Always-on planning service: `xbarmap serve --plans` — a TCP/JSONL
//! listener over the [`crate::plan`] front door.
//!
//! Each connection speaks the same v1 wire protocol as
//! [`crate::plan::serve_jsonl`]: one JSON [`MapRequest`] per line in, one
//! JSON line back per request — a [`crate::plan::MapPlan`] on success,
//! else the [`wire::error_frame`] with the connection's physical line
//! number — in request order, byte-identical to piping the same stream
//! through `xbarmap plan`. The one deliberate divergence: a document with
//! a `"cmd"` key and no `"net"` key (never a decodable request; the file
//! endpoint answers it with a missing-`'net'` error frame) is claimed by
//! the in-band command extension below. On top of that file-endpoint
//! contract the service adds what an always-on deployment needs:
//!
//! * a **shared worker pool** behind a **bounded request queue**
//!   ([`crate::util::mpmc`]): requests from all connections interleave in
//!   arrival order, and a flood backpressures the sockets (readers block
//!   pushing, TCP windows fill) instead of buffering without limit;
//! * a **canonical-request plan cache** ([`cache::PlanCache`]): identical
//!   requests — across connections, with the correlation id ignored — are
//!   answered from memory (eviction walks an ordered tick index, so it's
//!   O(log entries), not a scan);
//! * a persistent **plan warehouse** ([`crate::store`], `--warehouse
//!   DIR`): a second cache tier behind the LRU. An LRU miss that hits
//!   the on-disk store is answered without a solve (counted as
//!   `warehouse_hits`) and promoted into the LRU; every fresh solve is
//!   appended *behind* the response by a dedicated writer thread fed
//!   from a bounded channel, so the request path never blocks on disk —
//!   a full writer queue sheds the append, never the reply;
//! * **single-flight coalescing** ([`singleflight::SingleFlight`]):
//!   concurrent misses on one canonical key park on the leader's solve.
//!   Followers hold their admission slot but no queue slot or worker,
//!   and all receive id-restamped copies of the same outcome — one
//!   solve, N responses, counted by `coalesced`;
//! * **graceful shutdown**: SIGINT/ctrl-C or SIGTERM (or
//!   [`ServiceHandle::shutdown`]) stops accepting and reading, drains
//!   every request already read, and closes each connection only after
//!   its last owed response;
//! * **panic containment**: each solve runs under
//!   [`std::panic::catch_unwind`], so a panicking planner answers its one
//!   request with the typed [`wire::reject_frame`] `"reject":"internal"`
//!   and the worker thread survives to take the next job — one poisoned
//!   request can't take down the pool (or, via a poisoned stats lock,
//!   wedge every later counter update: the stats mutex recovers from
//!   poisoning, since its plain-integer state is valid at every step);
//! * **per-request deadlines**: `--deadline-ms` arms a wall-clock
//!   [`crate::util::deadline::Deadline`] per solve, threaded through the
//!   sweep and kernel checkpoints, so a runaway request answers with the
//!   typed `"reject":"deadline"` frame instead of pinning a worker;
//! * an **in-band `{"v":1,"cmd":"stats"}` request** answered with the
//!   [`wire::stats_frame`]: served/errored/cache-hit counts and
//!   nearest-rank p50/p95 plan-solve latency;
//! * **admission control** for sustained multi-tenant traffic:
//!   `--per-conn-quota` bounds how many requests one connection may
//!   submit (the quota-exceeding line is answered with the typed
//!   [`wire::reject_frame`] `"reject":"over-quota"` and the connection is
//!   closed), and `--max-inflight` caps requests admitted service-wide
//!   (queued + being planned); past it a request is shed with
//!   `"reject":"over-inflight"` — transient, the connection stays open —
//!   instead of deepening the backlog. In-band commands are exempt from
//!   the cap (a saturated service must stay observable), and in-quota
//!   connections are byte-unaffected either way;
//! * **per-tenant accounting** (`--tenant-quota`): request budgets keyed
//!   by the request `id` — the tenant token on this wire — in a ledger
//!   ([`TenantLedger`]) that survives reconnects, closing the re-dial
//!   loophole in the per-connection quota. An over-budget tenant's
//!   request is answered with the typed `over-quota` frame — the
//!   connection stays open, other tenants are byte-unaffected — and
//!   counted by the `tenant_rejects` counter;
//! * a **byte-level wire fast path** ([`wire::scan`]): the reader
//!   classifies every line with one lazy byte scan — no JSON tree — and
//!   a cache hit under the scanner's candidate key is answered without
//!   ever parsing the line. The scanner declares `Fallback` on any
//!   ambiguity (escapes, duplicate keys, non-scalar discriminators),
//!   which takes the historical full-parse path, so responses stay
//!   byte-identical to [`crate::plan::serve_jsonl`] — an equivalence the
//!   differential fuzz suite (`tests/prop_wire_scan.rs`) pins;
//! * the **in-band `{"v":1,"cmd":"recalibrate"}` admin verb**: flushes
//!   the plan LRU (for when pricing inputs change and cached answers go
//!   stale) behind a shared-secret token (`--admin-token`); a missing or
//!   wrong token answers the typed `"reject":"unauthorized"` frame, and
//!   a service started without a token treats every attempt as
//!   unauthorized;
//! * **observability**: an in-band `{"v":1,"cmd":"metrics"}` request
//!   answered with the [`wire::metrics_frame`] (the stats counters plus
//!   inflight/rejection/queue/cache gauges, one shared serializer so
//!   field names cannot drift), and `--metrics-out FILE` periodically
//!   writing the [`wire::metrics_medians`] gauge snapshot in the
//!   `BENCH_*.json` schema so serve latency joins the bench trajectory.

mod cache;
pub(crate) mod conn;
mod singleflight;
mod tenant;

pub use cache::PlanCache;
pub use singleflight::{Role, SingleFlight};
pub use tenant::TenantLedger;

use crate::plan::{self, wire, PlanError};
use crate::store::{LoadReport, Warehouse, WarehouseConfig};
use crate::util::deadline::Deadline;
use crate::util::json::Json;
use crate::util::mpmc::Queue;
use crate::util::stats::{percentile_nearest_rank, sort_samples};
use conn::Conn;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How often blocked reads and the accept loop re-check the shutdown flag.
pub(crate) const POLL: Duration = Duration::from_millis(50);

/// Cap on how long one response write may stall on a client that stopped
/// reading. The per-connection writer holds that connection's lock while
/// writing, so without a cap one dead-slow client could pin workers;
/// on timeout the write errors and the connection degrades to discarding.
pub(crate) const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Plan-solve latency samples kept for the percentile report (a bounded
/// window so an always-on service's memory stays flat; the `stats` frame
/// reports percentiles over the most recent window).
const LATENCY_WINDOW: usize = 4096;

/// Largest accepted request line. Inline-network requests are the big
/// ones (a few KB per layer); anything past this is a client outside the
/// protocol, answered with an error frame and disconnected so a
/// never-newlining stream can't grow the line buffer without limit.
pub(crate) const MAX_LINE_BYTES: usize = 8 << 20;

/// Capacity of the bounded channel feeding the warehouse writer thread.
/// Workers `try_push` solved plans and shed the append when the writer
/// can't keep up — durability lags under sustained disk slowness, but
/// the response path never blocks on it.
const WAREHOUSE_QUEUE: usize = 256;

/// Configuration for [`Service::bind`].
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// listen address, `HOST:PORT` (`:0` picks an ephemeral port)
    pub addr: String,
    /// planning worker threads (0 = available parallelism)
    pub workers: usize,
    /// bounded request-queue capacity (the backpressure horizon)
    pub queue_capacity: usize,
    /// plan-cache entries (0 disables caching)
    pub cache_capacity: usize,
    /// plan-cache entry lifetime (None = entries never expire); set this
    /// once pricing inputs can change at runtime so no stale plan outlives
    /// the TTL
    pub cache_ttl: Option<Duration>,
    /// plan-cache byte budget across entries, keys + serialized plans
    /// (0 = unbounded; the entry capacity still bounds the count) — one
    /// BERT grid plan is ~1000x the bytes of a LeNet fixed-tile plan, so
    /// entry counts alone don't bound memory
    pub cache_max_bytes: usize,
    /// requests one connection may submit before the service answers with
    /// the typed `over-quota` reject frame and closes it (0 = unlimited)
    pub per_conn_quota: usize,
    /// requests one tenant — the request `id` field, which doubles as
    /// the tenant token on this wire — may submit across all its
    /// connections for the life of the process (0 = unmetered). Past it
    /// the tenant's requests are answered with the typed `over-quota`
    /// frame (the connection stays open) and counted by
    /// `tenant_rejects`; anonymous requests (empty id) are never metered
    pub tenant_quota: u64,
    /// shared secret for the in-band `recalibrate` admin verb (None =
    /// the verb always answers the typed `unauthorized` reject)
    pub admin_token: Option<String>,
    /// service-wide cap on admitted requests — queued plus being planned;
    /// past it new requests are shed with the typed `over-inflight`
    /// reject frame instead of queueing (0 = unlimited)
    pub max_inflight: usize,
    /// file to periodically overwrite with the [`wire::metrics_medians`]
    /// gauge snapshot (None = no metrics file)
    pub metrics_out: Option<PathBuf>,
    /// how often the metrics file is rewritten (also written once at
    /// shutdown, so short-lived runs still leave a snapshot)
    pub metrics_interval: Duration,
    /// wall-clock budget for one plan solve; past it the request is
    /// answered with the typed `deadline` reject frame and the worker
    /// moves on (None = solves may run as long as the search budget
    /// allows). Cache hits and in-band commands are not subject to it.
    pub deadline: Option<Duration>,
    /// directory of the persistent plan warehouse (None = memory-only).
    /// Opened — torn tails repaired — at bind time; LRU misses consult it
    /// before solving, and fresh solves are appended behind the response
    pub warehouse: Option<PathBuf>,
    /// also shut down on SIGINT/ctrl-C and SIGTERM (the CLI sets this;
    /// tests drive shutdown through [`ServiceHandle`] instead)
    pub watch_sigint: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:7878".into(),
            workers: 0,
            queue_capacity: 64,
            cache_capacity: 256,
            cache_ttl: None,
            cache_max_bytes: 0,
            per_conn_quota: 0,
            tenant_quota: 0,
            admin_token: None,
            max_inflight: 0,
            metrics_out: None,
            metrics_interval: Duration::from_secs(10),
            deadline: None,
            warehouse: None,
            watch_sigint: false,
        }
    }
}

/// One unit of work: a non-blank line read from a connection, owed the
/// response with sequence number `seq` on that connection.
struct Job {
    conn: Arc<Conn>,
    seq: usize,
    /// physical 1-based line number within the connection (blank lines
    /// count), echoed into error frames
    line_no: usize,
    text: String,
    /// the reader's decode of `text`, when it succeeded: the flight this
    /// job leads is keyed by `parsed.key`, and the worker reuses the
    /// decoded request instead of re-parsing. None for in-band commands
    /// and undecodable lines (the worker re-parses those and answers with
    /// the same error frames serve_jsonl would).
    parsed: Option<ParsedReq>,
    /// the reader's byte-scan of `text` ([`wire::scan`]) when the line
    /// was fast-pathed without a JSON tree: the flight this job leads is
    /// keyed by `scanned.key`, and the worker probes the LRU under that
    /// key before parsing anything — a miss falls back to the full
    /// parse. Mutually exclusive with `parsed`.
    scanned: Option<wire::scan::ScanRequest>,
}

/// A request the connection reader already decoded — every decodable
/// request is, so identical canonical requests can coalesce before they
/// cost a queue slot.
struct ParsedReq {
    req: plan::MapRequest,
    /// the canonical cache key ([`PlanCache::key`]); also the flight key
    key: String,
}

/// A single-flight follower: a request parked on an open flight, holding
/// its admission slot and response sequence number but no queue slot and
/// no worker. The leader's worker delivers its response.
struct Waiter {
    conn: Arc<Conn>,
    seq: usize,
    /// the follower's own physical line number — error frames echo it
    line_no: usize,
    /// the follower's correlation id, restamped onto the shared plan
    id: String,
}

/// One solved plan bound for the warehouse writer thread.
struct WhWrite {
    /// canonical request key
    key: String,
    /// anonymized serialized plan line
    line: String,
}

struct StatsInner {
    served: u64,
    errors: u64,
    cache_hits: u64,
    connections: u64,
    panics: u64,
    timeouts: u64,
    rejected_internal: u64,
    rejected_over_quota: u64,
    rejected_over_inflight: u64,
    warehouse_hits: u64,
    warehouse_writes: u64,
    coalesced: u64,
    tenant_rejects: u64,
    latencies: VecDeque<f64>,
}

impl StatsInner {
    fn new() -> StatsInner {
        StatsInner {
            served: 0,
            errors: 0,
            cache_hits: 0,
            connections: 0,
            panics: 0,
            timeouts: 0,
            rejected_internal: 0,
            rejected_over_quota: 0,
            rejected_over_inflight: 0,
            warehouse_hits: 0,
            warehouse_writes: 0,
            coalesced: 0,
            tenant_rejects: 0,
            latencies: VecDeque::new(),
        }
    }
}

/// State shared by the accept loop, connection readers and workers.
struct Shared {
    shutdown: AtomicBool,
    sigint: Option<&'static AtomicBool>,
    queue: Queue<Job>,
    cache: PlanCache,
    stats: Mutex<StatsInner>,
    /// requests admitted but not yet answered (queued + being planned);
    /// readers increment before pushing, workers decrement after
    /// delivering — the gauge the `--max-inflight` admission cap tests
    inflight: AtomicUsize,
    /// admission cap copied out of the config (0 = unlimited)
    max_inflight: usize,
    /// per-connection request quota copied out of the config (0 = none)
    per_conn_quota: usize,
    /// per-tenant request budgets keyed by the request `id`; survives
    /// reconnects (that is its whole point — see [`TenantLedger`])
    tenants: TenantLedger,
    /// shared secret the `recalibrate` admin verb must present (None =
    /// every attempt answers the typed `unauthorized` reject)
    admin_token: Option<String>,
    /// wall-clock budget armed per solve (None = unbounded)
    deadline: Option<Duration>,
    /// the persistent second cache tier (None = memory-only service)
    warehouse: Option<Warehouse>,
    /// open single-flights: canonical key → followers parked on the
    /// leader's solve
    flights: SingleFlight<Waiter>,
    /// bounded channel feeding the warehouse writer thread (None exactly
    /// when `warehouse` is None); workers `try_push`, never block
    wh_queue: Option<Queue<WhWrite>>,
    /// when the listener bound, for the uptime gauge
    started: Instant,
}

impl Shared {
    fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
            || self.sigint.map(|f| f.load(Ordering::SeqCst)).unwrap_or(false)
    }

    /// Lock the stats, recovering from poisoning: every update keeps the
    /// plain-integer counters valid at every step, so a worker that
    /// panicked while holding the lock left consistent state behind —
    /// propagating the poison would instead wedge every later counter
    /// update and stats/metrics response on an unwrap.
    fn lock_stats(&self) -> std::sync::MutexGuard<'_, StatsInner> {
        self.stats.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn snapshot(&self) -> wire::StatsSnapshot {
        let s = self.lock_stats();
        Self::stats_of(&s)
    }

    fn stats_of(s: &StatsInner) -> wire::StatsSnapshot {
        let mut lat: Vec<f64> = s.latencies.iter().copied().collect();
        sort_samples(&mut lat);
        wire::StatsSnapshot {
            served: s.served,
            errors: s.errors,
            cache_hits: s.cache_hits,
            connections: s.connections,
            panics: s.panics,
            timeouts: s.timeouts,
            rejected_internal: s.rejected_internal,
            warehouse_hits: s.warehouse_hits,
            warehouse_writes: s.warehouse_writes,
            coalesced: s.coalesced,
            // cluster failover counters: always zero on a single-process
            // service (and on the shard workers a cluster spawns) — only
            // the cluster router ([`crate::cluster`]) counts failovers
            shard_respawns: 0,
            replayed: 0,
            degraded: 0,
            tenant_rejects: s.tenant_rejects,
            plan_p50_s: percentile_nearest_rank(&lat, 0.50),
            plan_p95_s: percentile_nearest_rank(&lat, 0.95),
        }
    }

    /// The full observability snapshot: the stats counters plus the
    /// admission, queue and cache gauges (in-band `metrics` command and
    /// the `--metrics-out` writer).
    fn metrics(&self) -> wire::MetricsSnapshot {
        let (stats, rejected_over_quota, rejected_over_inflight) = {
            let s = self.lock_stats();
            (Self::stats_of(&s), s.rejected_over_quota, s.rejected_over_inflight)
        };
        wire::MetricsSnapshot {
            stats,
            inflight: self.inflight.load(Ordering::SeqCst) as u64,
            rejected_over_quota,
            rejected_over_inflight,
            queue_depth: self.queue.len() as u64,
            cache_entries: self.cache.len() as u64,
            cache_bytes: self.cache.bytes() as u64,
            cache_expired: self.cache.expired_total(),
            warehouse_bytes: self.warehouse.as_ref().map(Warehouse::bytes).unwrap_or(0),
            uptime_s: self.started.elapsed().as_secs_f64(),
        }
    }

    /// Count one typed rejection. Rejects are error frames on the wire,
    /// so they bump `errors` too — a client watching only the stats
    /// frame still sees the shedding — plus their own counter.
    fn note_reject(&self, kind: wire::RejectKind) {
        let mut s = self.lock_stats();
        s.errors += 1;
        match kind {
            wire::RejectKind::OverQuota => s.rejected_over_quota += 1,
            wire::RejectKind::OverInflight => s.rejected_over_inflight += 1,
            wire::RejectKind::Internal => s.rejected_internal += 1,
            wire::RejectKind::Deadline => s.timeouts += 1,
            wire::RejectKind::Unauthorized => s.tenant_rejects += 1,
        }
    }

    /// Count one tenant-budget refusal. On the wire it is the same typed
    /// `over-quota` frame the per-connection quota uses (one vocabulary
    /// for "you asked for more than your share"), but it is counted by
    /// `tenant_rejects` — not `rejected_over_quota`, which meters
    /// connections — so operators can tell re-dialing tenants from
    /// chatty sockets.
    fn note_tenant_reject(&self) {
        let mut s = self.lock_stats();
        s.errors += 1;
        s.tenant_rejects += 1;
    }
}

/// A bound (but not yet running) planning service.
pub struct Service {
    listener: TcpListener,
    workers: usize,
    metrics_out: Option<PathBuf>,
    metrics_interval: Duration,
    /// the warehouse boot report, kept for [`Service::warehouse_report`]
    warehouse_report: Option<LoadReport>,
    shared: Arc<Shared>,
}

/// Remote control for a running [`Service`]: trip shutdown, read stats.
#[derive(Clone)]
pub struct ServiceHandle {
    shared: Arc<Shared>,
}

impl ServiceHandle {
    /// Begin graceful shutdown: stop accepting and reading, drain every
    /// request already read, close connections after their last response.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// A point-in-time copy of the service counters and latency
    /// percentiles (the same numbers the in-band `stats` command reports).
    pub fn stats(&self) -> wire::StatsSnapshot {
        self.shared.snapshot()
    }

    /// The full observability snapshot (the same numbers the in-band
    /// `metrics` command reports): the stats counters plus admission,
    /// rejection, queue and cache gauges.
    pub fn metrics(&self) -> wire::MetricsSnapshot {
        self.shared.metrics()
    }
}

impl Service {
    /// Bind the listener (the service starts accepting only on
    /// [`Service::run`]).
    pub fn bind(cfg: &ServiceConfig) -> std::io::Result<Service> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let workers = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            cfg.workers
        };
        // open (and repair) the warehouse before accepting: a torn tail
        // from a previous crash is truncated here, and every intact
        // record is indexed — the report says what boot found
        let (warehouse, warehouse_report) = match &cfg.warehouse {
            Some(dir) => {
                let (wh, report) = Warehouse::open(&WarehouseConfig::at(dir))?;
                (Some(wh), Some(report))
            }
            None => (None, None),
        };
        Ok(Service {
            listener,
            workers,
            metrics_out: cfg.metrics_out.clone(),
            metrics_interval: cfg.metrics_interval,
            warehouse_report,
            shared: Arc::new(Shared {
                shutdown: AtomicBool::new(false),
                sigint: if cfg.watch_sigint { Some(sigint_flag()) } else { None },
                queue: Queue::bounded(cfg.queue_capacity),
                cache: PlanCache::with_policy(
                    cfg.cache_capacity,
                    cfg.cache_ttl,
                    cfg.cache_max_bytes,
                ),
                stats: Mutex::new(StatsInner::new()),
                inflight: AtomicUsize::new(0),
                max_inflight: cfg.max_inflight,
                per_conn_quota: cfg.per_conn_quota,
                tenants: TenantLedger::new(cfg.tenant_quota),
                admin_token: cfg.admin_token.clone(),
                deadline: cfg.deadline,
                wh_queue: warehouse.as_ref().map(|_| Queue::bounded(WAREHOUSE_QUEUE)),
                warehouse,
                flights: SingleFlight::new(),
                started: Instant::now(),
            }),
        })
    }

    /// The bound address — read this after binding to `:0`.
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A [`ServiceHandle`] for remote control (shutdown, stats, metrics)
    /// while [`Service::run`] blocks another thread.
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle { shared: Arc::clone(&self.shared) }
    }

    /// What the warehouse loader found at bind time (records indexed,
    /// torn tails truncated, corrupt lines skipped) — None when the
    /// service runs memory-only. The CLI prints this at startup.
    pub fn warehouse_report(&self) -> Option<LoadReport> {
        self.warehouse_report
    }

    /// Serve until shutdown (signal or handle), then drain and return the
    /// final stats. Blocks the calling thread; connection readers and the
    /// worker pool run on their own threads.
    pub fn run(self) -> std::io::Result<wire::StatsSnapshot> {
        let shared = self.shared;
        let mut workers = Vec::with_capacity(self.workers);
        for _ in 0..self.workers {
            let sh = Arc::clone(&shared);
            workers.push(std::thread::spawn(move || {
                while let Some(job) = sh.queue.pop() {
                    // Contain a panicking solve to the one request that
                    // triggered it: the client gets the typed `internal`
                    // reject frame and this worker survives to take the
                    // next job. AssertUnwindSafe is sound here because
                    // every shared structure the closure touches stays
                    // consistent under unwind: the queue and cache update
                    // under their own locks, and the stats mutex recovers
                    // from poisoning ([`Shared::lock_stats`]).
                    let response = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || respond(&sh, &job),
                    ))
                    .unwrap_or_else(|payload| {
                        sh.lock_stats().panics += 1;
                        sh.note_reject(wire::RejectKind::Internal);
                        let e = PlanError(format!(
                            "planner panicked: {}",
                            panic_message(payload.as_ref())
                        ));
                        // a panicking leader still owes its parked
                        // followers: each gets the same typed reject with
                        // its own line number (counted like any internal
                        // reject — `panics` counts the one real panic).
                        // Scanned jobs lead flights keyed by the
                        // scanner's candidate key, parsed jobs by the
                        // canonical key — settle whichever was joined.
                        let flight_key = job
                            .parsed
                            .as_ref()
                            .map(|p| p.key.as_str())
                            .or_else(|| job.scanned.as_ref().map(|s| s.key.as_str()));
                        settle_flight_error(
                            &sh,
                            flight_key,
                            Some(wire::RejectKind::Internal),
                            &e,
                        );
                        wire::reject_frame(job.line_no, wire::RejectKind::Internal, &e).dumps()
                    });
                    job.conn.deliver(job.seq, response);
                    // admitted at read time; answered now
                    sh.inflight.fetch_sub(1, Ordering::SeqCst);
                }
            }));
        }

        // the warehouse writer: the one thread that touches disk on the
        // request path's behalf. Workers try_push solved plans onto the
        // bounded channel; this thread appends them behind the responses.
        // Closed — and joined — only after the worker pool drains, so
        // every solve that queued an append gets it written before run()
        // returns.
        let wh_writer = shared.wh_queue.as_ref().map(|_| {
            let sh = Arc::clone(&shared);
            std::thread::spawn(move || {
                let (Some(q), Some(wh)) = (&sh.wh_queue, &sh.warehouse) else { return };
                while let Some(w) = q.pop() {
                    if wh.append(&w.key, &w.line).is_ok() {
                        sh.lock_stats().warehouse_writes += 1;
                    }
                }
            })
        });

        // periodic metrics snapshots: overwrite the file every interval
        // while running, and once more after the final drain below so
        // short-lived runs still leave their last gauges behind
        let metrics_writer = self.metrics_out.as_ref().map(|path| {
            let (sh, path) = (Arc::clone(&shared), path.clone());
            let interval = self.metrics_interval;
            std::thread::spawn(move || {
                let mut last = Instant::now();
                while !sh.is_shutdown() {
                    std::thread::sleep(POLL);
                    if last.elapsed() >= interval {
                        let _ = write_metrics_file(&path, &sh.metrics());
                        last = Instant::now();
                    }
                }
            })
        });

        if let Err(e) = self.listener.set_nonblocking(true) {
            // same discipline as the fatal accept arm: never leave the
            // already-spawned workers parked on the queue (or the metrics
            // writer polling a flag, or the warehouse writer parked on
            // its channel) forever
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.queue.close();
            if let Some(q) = &shared.wh_queue {
                q.close();
            }
            return Err(e);
        }
        let mut readers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !shared.is_shutdown() {
            // reap finished readers on every iteration — a service is
            // busiest exactly when the idle (WouldBlock) branch never runs,
            // and that's when join handles would otherwise accumulate
            let mut i = 0;
            while i < readers.len() {
                if readers[i].is_finished() {
                    let _ = readers.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    shared.lock_stats().connections += 1;
                    let _ = stream.set_nodelay(true);
                    // try_clone fails under fd exhaustion (connection
                    // floods) — shed this connection, keep serving
                    let Ok(writer) = stream.try_clone() else { continue };
                    let _ = writer.set_write_timeout(Some(WRITE_TIMEOUT));
                    let sh = Arc::clone(&shared);
                    readers.push(std::thread::spawn(move || {
                        read_conn(&sh, stream, Arc::new(Conn::new(writer)));
                    }));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(POLL);
                }
                Err(e) => {
                    // fatal listener error: let the workers drain and exit
                    // rather than leaving them parked on the queue forever
                    shared.shutdown.store(true, Ordering::SeqCst);
                    shared.queue.close();
                    if let Some(q) = &shared.wh_queue {
                        q.close();
                    }
                    return Err(e);
                }
            }
        }

        // Drain: readers notice the flag within one POLL and stop feeding;
        // everything they already enqueued still gets planned and written.
        for r in readers {
            let _ = r.join();
        }
        shared.queue.close();
        for w in workers {
            let _ = w.join();
        }
        // the workers are done, so nothing can queue another append:
        // close the writer channel and wait for the backlog to land on
        // disk before reporting the final stats
        if let Some(q) = &shared.wh_queue {
            q.close();
        }
        if let Some(w) = wh_writer {
            let _ = w.join();
        }
        if let Some(w) = metrics_writer {
            let _ = w.join();
        }
        if let Some(path) = &self.metrics_out {
            // final snapshot after the drain, so the file reflects every
            // response the service ever wrote
            let _ = write_metrics_file(path, &shared.metrics());
        }
        Ok(shared.snapshot())
    }
}

/// Replace `path` with the flat [`wire::metrics_medians`] gauge
/// snapshot: write a sibling temp file, then rename, so a scraper never
/// reads a half-written document. On platforms where rename refuses to
/// replace an existing file (Windows), fall back to removing the
/// destination first — a brief gap beats a frozen first snapshot.
pub(crate) fn write_metrics_file(path: &Path, m: &wire::MetricsSnapshot) -> std::io::Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, wire::metrics_medians(m).pretty() + "\n")?;
    std::fs::rename(&tmp, path).or_else(|_| {
        std::fs::remove_file(path)?;
        std::fs::rename(&tmp, path)
    })
}

/// One connection's line assembler, shared by the single-process reader
/// ([`read_conn`]) and the cluster router ([`crate::cluster`]) so their
/// byte-level framing cannot diverge — the router's merged stream is
/// specified as byte-identical to a single service, and that identity
/// starts with both sides cutting the input into the same lines.
///
/// Lines are assembled from **raw bytes** (`read_until`, not `read_line`:
/// the latter's UTF-8 guard discards a call's appended bytes when a poll
/// timeout lands mid multi-byte character — bytes already consumed from
/// the socket would be silently lost), capped at [`MAX_LINE_BYTES`] per
/// line via `Take` so one never-newlining client can't grow memory past
/// the cap. Invalid UTF-8 flows (lossily decoded) into the normal
/// parse-error frame instead of killing the stream, and a final line
/// without a trailing newline is honored at EOF.
pub(crate) struct LineReader {
    reader: BufReader<TcpStream>,
    buf: Vec<u8>,
    eof: bool,
}

/// What [`LineReader::next`] assembled.
pub(crate) enum NextLine {
    /// A complete line, lossily decoded and trimmed. May be empty: blank
    /// lines claim a physical line number but no response, so the caller
    /// must still count them.
    Line(String),
    /// The line exceeded [`MAX_LINE_BYTES`]: a terminal protocol
    /// violation. The caller answers with an error frame (counting the
    /// line) and hangs up.
    Oversized,
    /// Clean end of input: EOF with nothing (or only whitespace) pending.
    End,
    /// Shutdown observed or the read failed: stop without another frame.
    Abort,
}

impl LineReader {
    /// Wrap `stream`, switching it to polled reads so `is_shutdown` is
    /// observed even on idle connections.
    pub fn new(stream: TcpStream) -> LineReader {
        // a read timeout turns the blocking read into a poll so shutdown
        // is observed even on idle connections
        let _ = stream.set_read_timeout(Some(POLL));
        LineReader { reader: BufReader::new(stream), buf: Vec::new(), eof: false }
    }

    /// The underlying reader, for handing to [`drain_discard`] after a
    /// terminal frame.
    pub fn reader_mut(&mut self) -> &mut BufReader<TcpStream> {
        &mut self.reader
    }

    /// Assemble the next line across poll ticks (a timeout mid-line
    /// leaves the partial bytes buffered and the next read appends to
    /// them), re-checking `is_shutdown` on every tick.
    pub fn next(&mut self, is_shutdown: impl Fn() -> bool) -> NextLine {
        if self.eof {
            return NextLine::End;
        }
        self.buf.clear();
        loop {
            if is_shutdown() {
                return NextLine::Abort;
            }
            let room = (MAX_LINE_BYTES + 1).saturating_sub(self.buf.len()) as u64;
            match self.reader.by_ref().take(room).read_until(b'\n', &mut self.buf) {
                Ok(_) => {
                    if self.buf.last() == Some(&b'\n') {
                        break; // complete line
                    }
                    if self.buf.len() > MAX_LINE_BYTES {
                        return NextLine::Oversized;
                    }
                    // no newline, under the cap: EOF — a final line
                    // without a trailing newline may still be in buf
                    self.eof = true;
                    if self.buf.iter().all(u8::is_ascii_whitespace) {
                        return NextLine::End;
                    }
                    break;
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                    ) =>
                {
                    continue; // poll tick; bytes read so far stay in buf
                }
                Err(_) => return NextLine::Abort,
            }
        }
        NextLine::Line(String::from_utf8_lossy(&self.buf).trim().to_string())
    }
}

/// Read one connection's request lines into the shared queue. Every
/// non-blank line claims the next response sequence number; on EOF, error
/// or shutdown the connection is owed exactly the responses claimed so
/// far, and [`Conn::finish_input`] arranges the close after the last one.
/// Byte-level framing (poll-tick assembly, the [`MAX_LINE_BYTES`] cap,
/// lossy UTF-8, EOF handling) lives in [`LineReader`].
fn read_conn(shared: &Shared, stream: TcpStream, conn: Arc<Conn>) {
    let mut lines = LineReader::new(stream);
    let mut seq = 0usize;
    let mut line_no = 0usize;
    loop {
        let text = match lines.next(|| shared.is_shutdown()) {
            NextLine::End | NextLine::Abort => break,
            NextLine::Oversized => {
                // answer in-order like any other response, then hang up —
                // the client is outside the protocol the bounded queue
                // can pace
                line_no += 1;
                shared.lock_stats().errors += 1;
                let e = PlanError(format!("request line exceeds {MAX_LINE_BYTES} bytes"));
                conn.deliver(seq, wire::error_frame(line_no, &e).dumps());
                seq += 1;
                conn.finish_input(seq);
                drain_discard(&|| shared.is_shutdown(), lines.reader_mut());
                return;
            }
            NextLine::Line(text) => text,
        };
        line_no += 1;
        let text = text.as_str();
        if text.is_empty() {
            continue;
        }
        // per-connection quota: `seq` counts the requests this connection
        // already submitted, so the (quota+1)-th request gets the typed
        // over-quota frame — in order, like any response — and the
        // connection is closed (the client is outside its contract; a new
        // connection gets a fresh quota)
        if shared.per_conn_quota > 0 && seq >= shared.per_conn_quota {
            shared.note_reject(wire::RejectKind::OverQuota);
            let e = PlanError(format!(
                "connection exceeded its {}-request quota",
                shared.per_conn_quota
            ));
            conn.deliver(seq, wire::reject_frame(line_no, wire::RejectKind::OverQuota, &e).dumps());
            seq += 1;
            conn.finish_input(seq);
            drain_discard(&|| shared.is_shutdown(), lines.reader_mut());
            return;
        }
        // One lazy byte scan ([`wire::scan`]) classifies the line —
        // in-band command, fast-pathable request, or ambiguous — without
        // building a JSON tree. Commands are exempt from the in-flight
        // cap below: stats/metrics must stay answerable exactly when the
        // service is saturated, which is when an operator asks. The
        // scanner's `Command` verdict holds exactly when the historical
        // substring sniff (`"cmd"` present, `"net"` absent) would — it
        // declares `Fallback` whenever the two could diverge — and on
        // `Fallback` the sniff itself still decides, so admission stays
        // byte-identical to the pre-scanner service. A sniff false
        // negative (e.g. `"net"` inside a string value) just falls back
        // to normal admission; a false positive admits one line that the
        // worker answers with a cheap error frame.
        let scanned = wire::scan::scan(text);
        let looks_like_cmd = match &scanned {
            wire::scan::Scan::Command => true,
            wire::scan::Scan::Request(_) => false,
            wire::scan::Scan::Fallback => {
                text.contains("\"cmd\"") && !text.contains("\"net\"")
            }
        };
        let admitted = shared.inflight.fetch_add(1, Ordering::SeqCst);
        if shared.max_inflight > 0 && admitted >= shared.max_inflight && !looks_like_cmd {
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            shared.note_reject(wire::RejectKind::OverInflight);
            let e = PlanError(format!(
                "service at its {}-request in-flight cap, retry later",
                shared.max_inflight
            ));
            conn.deliver(
                seq,
                wire::reject_frame(line_no, wire::RejectKind::OverInflight, &e).dumps(),
            );
            seq += 1;
            continue;
        }
        // Meter and coalesce per verdict. A scanned request skips the
        // JSON tree entirely: its tenant charge uses the scanned id and
        // its flight is keyed by the scanner's candidate key (an LRU hit
        // under that key proves it equals the canonical key; a miss
        // falls back in the worker, and the flight still settles under
        // what was joined here). An ambiguous line takes the historical
        // path — one full parse, reused by the worker — so identical
        // canonical requests can coalesce before they cost a queue slot.
        // The first request for a key leads (it proceeds to the worker
        // pool); every later one arriving while that flight is open
        // parks as a passive delivery record: it keeps the admission
        // slot just reserved (it is real in-flight work) but never
        // enqueues, so a thundering herd costs one solve even on a
        // one-worker service, and the leader's completion answers
        // everyone. Lines that fail to decode never join a flight (the
        // worker re-parses them and answers with the same error frames
        // serve_jsonl would) and are never tenant-metered — they carry
        // no trustworthy identity. Coalescing happens after admission
        // and metering, so quota/inflight behavior is byte-unchanged
        // and followers spend tenant budget like the requests they are.
        let mut parsed = None;
        let mut scan_req = None;
        match scanned {
            _ if looks_like_cmd => {}
            wire::scan::Scan::Request(s) => {
                if !tenant_admit(shared, &conn, &s.id, &mut seq, line_no) {
                    continue;
                }
                let role = shared.flights.join(&s.key, || Waiter {
                    conn: Arc::clone(&conn),
                    seq,
                    line_no,
                    id: s.id.clone(),
                });
                if role == Role::Coalesced {
                    seq += 1;
                    continue;
                }
                scan_req = Some(s);
            }
            _ => {
                if let Ok(j) = crate::util::json::parse(text) {
                    if !(j.get("cmd").is_some() && j.get("net").is_none()) {
                        if let Ok(req) = plan::MapRequest::from_json(&j) {
                            if !tenant_admit(shared, &conn, &req.id, &mut seq, line_no) {
                                continue;
                            }
                            let key = PlanCache::key(&req);
                            let role = shared.flights.join(&key, || Waiter {
                                conn: Arc::clone(&conn),
                                seq,
                                line_no,
                                id: req.id.clone(),
                            });
                            if role == Role::Coalesced {
                                seq += 1;
                                continue;
                            }
                            parsed = Some(ParsedReq { req, key });
                        }
                    }
                }
            }
        }
        let flight_key = parsed
            .as_ref()
            .map(|p| p.key.clone())
            .or_else(|| scan_req.as_ref().map(|s| s.key.clone()));
        let job = Job {
            conn: Arc::clone(&conn),
            seq,
            line_no,
            text: text.to_string(),
            parsed,
            scanned: scan_req,
        };
        seq += 1;
        // blocks while the queue is full — this is the backpressure path
        // (the socket stops being read, so the client's TCP window fills)
        if shared.queue.push(job).is_err() {
            // queue closed mid-push: shutdown raced us; the job was
            // refused, so give its sequence number (and in-flight slot)
            // back
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            seq -= 1;
            // a would-be leader refused by the closing queue still owes
            // its followers: fail them explicitly rather than stranding
            // their reserved slots and owed responses
            if let Some(key) = flight_key {
                settle_flight_error(
                    shared,
                    Some(&key),
                    None,
                    &PlanError("service shutting down".into()),
                );
            }
            break;
        }
    }
    conn.finish_input(seq);
}

/// Charge one admitted request to the tenant ledger. On refusal the
/// in-flight slot just reserved is given back, the typed `over-quota`
/// frame (with the tenant wording, so a client can tell it from the
/// per-connection quota) is delivered in order, and the connection stays
/// open — the refusal is per-request, and other tenants on the same
/// socket's service are byte-unaffected. Returns whether the request may
/// proceed.
fn tenant_admit(
    shared: &Shared,
    conn: &Arc<Conn>,
    id: &str,
    seq: &mut usize,
    line_no: usize,
) -> bool {
    if shared.tenants.try_charge(id) {
        return true;
    }
    shared.inflight.fetch_sub(1, Ordering::SeqCst);
    shared.note_tenant_reject();
    let e = PlanError(format!(
        "tenant '{id}' exceeded its {}-request quota",
        shared.tenants.quota()
    ));
    conn.deliver(*seq, wire::reject_frame(line_no, wire::RejectKind::OverQuota, &e).dumps());
    *seq += 1;
    false
}

/// How much more a client may stream after a terminal reject before the
/// service stops being polite and drops the socket: the same budget one
/// well-formed line gets. The drain exists to let the peer's TCP stack
/// deliver the owed responses before a reset, not to tail an unbounded
/// stream for free.
const DRAIN_MAX_BYTES: usize = MAX_LINE_BYTES;

/// Wall-clock cap on the post-reject drain: a client that neither
/// half-closes nor streams (just holds the socket open) parks the reader
/// only this long.
const DRAIN_MAX_WAIT: Duration = Duration::from_secs(5);

/// Read and discard a connection's remaining input until the client
/// half-closes (EOF), a read error, service shutdown, or the drain
/// bounds trip. Used after a terminal frame (over-quota, oversized
/// line): dropping the socket while unread bytes sit in the receive
/// buffer makes the kernel reset the connection, which can destroy the
/// very responses — the typed reject included — the client is still
/// owed. Discarding into a fixed scratch keeps memory flat, and the
/// [`DRAIN_MAX_BYTES`] / [`DRAIN_MAX_WAIT`] bounds keep a hostile
/// client from parking the reader thread forever: past either bound
/// the responses have had every reasonable chance to flush, and the
/// socket drops. Takes its shutdown check as a closure so the cluster
/// router (whose shared state is its own type) drains identically.
pub(crate) fn drain_discard(is_shutdown: &dyn Fn() -> bool, reader: &mut BufReader<TcpStream>) {
    let mut scratch = [0u8; 4096];
    let mut discarded = 0usize;
    let started = Instant::now();
    loop {
        if is_shutdown() || discarded >= DRAIN_MAX_BYTES || started.elapsed() >= DRAIN_MAX_WAIT {
            return;
        }
        match reader.read(&mut scratch) {
            Ok(0) => return, // EOF: nothing left to abandon
            Ok(n) => discarded += n,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) => {}
            Err(_) => return,
        }
    }
}

/// Request id that makes the worker panic mid-solve, deliberately. The
/// panic-containment path ([`Service::run`]'s `catch_unwind`) is the kind
/// of code that only ever runs when something else is already wrong, so
/// the integration suite live-fires it: a request carrying this id
/// panics inside the worker exactly like a planner bug would, and the
/// test asserts the typed `internal` reject frame comes back while the
/// service keeps serving. The id is deliberately outside anything a
/// well-behaved client would generate; a production client that does
/// send it gets its one request rejected and nothing else.
pub const PANIC_PROBE_ID: &str = "__xbarmap_panic_probe__";

/// Best-effort text of a caught panic payload (`panic!("...")` carries
/// `&str` or `String`; anything else is opaque).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Produce the response line for one job (no trailing newline), updating
/// the service counters.
fn respond(shared: &Shared, job: &Job) -> String {
    if let Some(p) = &job.parsed {
        // the reader already decoded this request (to coalesce identical
        // in-flight requests); this job leads its flight, keyed — like
        // the cache — by the canonical serialization
        return respond_planned(shared, job, &p.req, Some(&p.key), Some(&p.key));
    }
    if let Some(s) = &job.scanned {
        return respond_scanned(shared, job, s);
    }
    respond_fallback(shared, job, None)
}

/// The scanner fast path: answer an LRU hit under the scanner's
/// candidate key without ever parsing the line. Soundness: the cache is
/// keyed exclusively by canonical id-stripped serializations
/// ([`PlanCache::key`]), and the candidate key is the raw line with its
/// top-level `"id"` member spliced out byte-verbatim — so a hit proves
/// the line *is* a canonical serialization plus an id, and the cached
/// plan restamped with the scanned id is byte-identical to what the full
/// parse path would answer. A miss proves nothing (an unseen request, or
/// a known one serialized differently) and takes the full path; the
/// flight the reader opened under the scanner key settles either way.
fn respond_scanned(shared: &Shared, job: &Job, s: &wire::scan::ScanRequest) -> String {
    // the live-fire panic probe must panic even when its network's plan
    // is cached — skip the fast path so the full one reaches the guard
    // in [`respond_planned`]
    if s.id != PANIC_PROBE_ID {
        if let Some(cached) = shared.cache.get(&s.key) {
            let mut stats = shared.lock_stats();
            stats.cache_hits += 1;
            stats.served += 1;
            drop(stats);
            let mut plan = (*cached).clone();
            plan.id = s.id.clone();
            settle_flight_plan(shared, Some(&s.key), &cached, None);
            return plan.to_json().dumps();
        }
    }
    respond_fallback(shared, job, Some(&s.key))
}

/// The full-parse path: build the JSON tree, route in-band commands,
/// decode the request. Jobs the scanner fast-pathed land here only on a
/// cache miss — `flight_key` carries the scanner key their flight is
/// parked under (a scanned line always has `"net"`, so the command
/// branch cannot strand it); everything else was never in a flight and
/// passes None.
fn respond_fallback(shared: &Shared, job: &Job, flight_key: Option<&str>) -> String {
    let j = match crate::util::json::parse(&job.text) {
        Ok(j) => j,
        // same message plan::parse_request_line produces, so error frames
        // stay byte-identical to serve_jsonl's
        Err(e) => {
            let e = PlanError(format!("parse request: {e}"));
            settle_flight_error(shared, flight_key, None, &e);
            return error_response(shared, job.line_no, &e);
        }
    };
    // In-band commands are a service extension over the serve_jsonl wire.
    // The decoder ignores unknown keys, so a request carrying a stray
    // "cmd" key is still a valid MapRequest — the command path therefore
    // claims only documents without "net", which could never have decoded
    // as a request (serve_jsonl answers that class with a missing-'net'
    // error frame; this is the one deliberate divergence, documented on
    // the module).
    if j.get("cmd").is_some() && j.get("net").is_none() {
        return respond_cmd(shared, &j, job.line_no);
    }
    let req = match plan::MapRequest::from_json(&j) {
        Ok(req) => req,
        Err(e) => {
            settle_flight_error(shared, flight_key, None, &e);
            return error_response(shared, job.line_no, &e);
        }
    };
    respond_planned(shared, job, &req, flight_key, None)
}

/// Produce the response for a decoded plan request: LRU, then warehouse,
/// then solve. `flight_key` is the key the reader joined this job's
/// single-flight under — the scanner's candidate key for scanned jobs,
/// the canonical key for parsed ones, None when no flight was opened —
/// and the same outcome (plan, error or typed reject) is delivered to
/// every parked follower before this returns. `known_key` is the
/// canonical cache key when the reader already computed it, borrowed so
/// the hot path clones no key.
fn respond_planned(
    shared: &Shared,
    job: &Job,
    req: &plan::MapRequest,
    flight_key: Option<&str>,
    known_key: Option<&str>,
) -> String {
    // live-fire hook for the containment path — before the cache lookup,
    // which anonymizes ids and could otherwise answer the probe from a
    // previous solve of the same network. The panic handler in
    // [`Service::run`] settles this job's flight.
    if req.id == PANIC_PROBE_ID {
        // lint: allow(panic) deliberate live-fire probe; contained by the
        // worker's catch_unwind in [`Service::run`]
        panic!("panic probe: request id {PANIC_PROBE_ID}");
    }
    // the canonical key has three consumers (LRU, warehouse, writer);
    // borrow the reader's copy when it computed one, else serialize the
    // canonical form once here — either way, no per-request key clone
    let computed: Option<String> = match known_key {
        Some(_) => None,
        None => (shared.cache.enabled() || shared.warehouse.is_some())
            .then(|| PlanCache::key(req)),
    };
    let key: Option<&str> = known_key.or(computed.as_deref());
    if let Some(cached) = key.and_then(|k| shared.cache.get(k)) {
        let mut stats = shared.lock_stats();
        stats.cache_hits += 1;
        stats.served += 1;
        drop(stats);
        let mut plan = (*cached).clone();
        plan.id = req.id.clone();
        settle_flight_plan(shared, flight_key, &cached, None);
        return plan.to_json().dumps();
    }
    // second tier: the on-disk warehouse. A hit is answered without a
    // solve — counted separately from memory hits, and contributing no
    // latency sample (nothing was solved) — and promoted into the LRU,
    // charging bytes and starting a fresh TTL epoch, so the next
    // identical request is a memory hit.
    if let (Some(wh), Some(k)) = (shared.warehouse.as_ref(), key) {
        if let Some(stored) = wh.get(k) {
            // records re-verify their crc on read, so a decode failure
            // here means schema drift (a record written by an older
            // build), not corruption — fall through to a fresh solve,
            // whose append supersedes the stale record
            let decoded = crate::util::json::parse(&stored)
                .ok()
                .and_then(|j| plan::MapPlan::from_json(&j).ok());
            if let Some(anon) = decoded {
                let mut stats = shared.lock_stats();
                stats.warehouse_hits += 1;
                stats.served += 1;
                drop(stats);
                shared.cache.promote_serialized(k, Arc::new(anon.clone()), stored.len());
                let response = if req.id.is_empty() {
                    // the stored line IS the anonymized serialization —
                    // serve it verbatim
                    stored.clone()
                } else {
                    let mut plan = anon.clone();
                    plan.id = req.id.clone();
                    plan.to_json().dumps()
                };
                settle_flight_plan(shared, flight_key, &anon, Some(&stored));
                return response;
            }
        }
    }
    // the deadline arms when the solve starts, not when the request was
    // read: queue wait under load is backpressure, not solver runaway
    let deadline = match shared.deadline {
        Some(budget) => Deadline::after(budget),
        None => Deadline::NONE,
    };
    let t0 = Instant::now();
    match req.clone().build().and_then(|p| p.plan_with_deadline(deadline)) {
        Ok(plan) => {
            let solve_s = t0.elapsed().as_secs_f64();
            let mut stats = shared.lock_stats();
            stats.served += 1;
            if stats.latencies.len() == LATENCY_WINDOW {
                stats.latencies.pop_front();
            }
            stats.latencies.push_back(solve_s);
            drop(stats);
            if let Some(k) = key {
                // one serialization of the anonymized plan covers the
                // cache's byte accounting, the warehouse append, the
                // follower deliveries and — for the common id-less
                // request, where anonymized == response — the wire bytes
                let mut anon = plan.clone();
                anon.id.clear();
                let anon_line = anon.to_json().dumps();
                let anon_len = anon_line.len();
                let anon = Arc::new(anon);
                shared.cache.insert_serialized(k, Arc::clone(&anon), anon_len);
                // durability rides the bounded writer channel *behind*
                // the response; when the writer can't keep up the append
                // is shed, never the reply. The append is unconditional
                // on solve — re-appending a key whose stored record went
                // stale or undecodable supersedes it (self-healing).
                if let Some(q) = &shared.wh_queue {
                    let _ = q.try_push(WhWrite { key: k.to_string(), line: anon_line.clone() });
                }
                settle_flight_plan(shared, flight_key, &anon, Some(&anon_line));
                if plan.id.is_empty() {
                    return anon_line;
                }
            }
            plan.to_json().dumps()
        }
        Err(e) if e.is_deadline() => {
            shared.note_reject(wire::RejectKind::Deadline);
            settle_flight_error(shared, flight_key, Some(wire::RejectKind::Deadline), &e);
            wire::reject_frame(job.line_no, wire::RejectKind::Deadline, &e).dumps()
        }
        Err(e) => {
            settle_flight_error(shared, flight_key, None, &e);
            error_response(shared, job.line_no, &e)
        }
    }
}

/// Deliver a solved (or recovered) plan to every follower parked on this
/// job's flight — a no-op for jobs that lead no flight or have no
/// followers. Each follower gets the same plan with its own correlation
/// id restamped, byte-identical to solving its line independently (plans
/// are deterministic functions of the canonical request). Followers
/// count as `served` and `coalesced` — not as cache hits, and they add
/// no latency sample, since no solve ran for them — and each releases
/// the admission slot it has held since the reader parked it.
fn settle_flight_plan(
    shared: &Shared,
    key: Option<&str>,
    anon: &plan::MapPlan,
    anon_line: Option<&str>,
) {
    let Some(key) = key else { return };
    let followers = shared.flights.complete(key);
    if followers.is_empty() {
        return;
    }
    // the anonymized line answers id-less followers verbatim; serialize
    // it at most once, and only if such a follower exists
    let mut anon_cache: Option<String> = anon_line.map(str::to_string);
    for w in followers {
        let line = if w.id.is_empty() {
            anon_cache.get_or_insert_with(|| anon.to_json().dumps()).clone()
        } else {
            let mut plan = anon.clone();
            plan.id = w.id;
            plan.to_json().dumps()
        };
        let mut stats = shared.lock_stats();
        stats.served += 1;
        stats.coalesced += 1;
        drop(stats);
        w.conn.deliver(w.seq, line);
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Deliver a failed leader's outcome to its followers: the same error —
/// or typed reject — rebuilt with each follower's own line number, so a
/// follower's frame is byte-identical to failing its line independently.
/// Followers bump the same counters the leader's frame did (`errors`,
/// plus the reject-kind counter), except `panics`, which counts actual
/// contained panics: one per panic, not one per delivery.
fn settle_flight_error(
    shared: &Shared,
    key: Option<&str>,
    kind: Option<wire::RejectKind>,
    e: &PlanError,
) {
    let Some(key) = key else { return };
    for w in shared.flights.complete(key) {
        let line = match kind {
            Some(k) => {
                shared.note_reject(k);
                wire::reject_frame(w.line_no, k, e).dumps()
            }
            None => {
                shared.lock_stats().errors += 1;
                wire::error_frame(w.line_no, e).dumps()
            }
        };
        shared.lock_stats().coalesced += 1;
        w.conn.deliver(w.seq, line);
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

fn respond_cmd(shared: &Shared, j: &Json, line_no: usize) -> String {
    let o = match j.as_obj() {
        Some(o) => o,
        None => {
            return error_response(
                shared,
                line_no,
                &PlanError("command must be a JSON object".into()),
            )
        }
    };
    // the same version rule (and error wording) every other frame uses
    if let Err(e) = wire::check_version(o, "command") {
        return error_response(shared, line_no, &e);
    }
    match o.get("cmd").and_then(Json::as_str) {
        Some("stats") => wire::stats_frame(&shared.snapshot()).dumps(),
        Some("metrics") => wire::metrics_frame(&shared.metrics()).dumps(),
        Some("recalibrate") => {
            // the admin verb: flush every cached plan (pricing inputs
            // changed; the cached answers are stale) behind a shared
            // secret. The command must carry the exact token the service
            // was started with; a service without one treats every
            // attempt as unauthorized — flushing is opt-in. The tenant
            // ledger is deliberately untouched: recalibration invalidates
            // cached *answers*, budgets are policy.
            let authorized = match &shared.admin_token {
                Some(t) => o.get("token").and_then(Json::as_str) == Some(t.as_str()),
                None => false,
            };
            if !authorized {
                shared.note_reject(wire::RejectKind::Unauthorized);
                let e = PlanError("recalibrate requires a valid admin token".into());
                return wire::reject_frame(line_no, wire::RejectKind::Unauthorized, &e).dumps();
            }
            wire::recalibrate_frame(shared.cache.clear() as u64).dumps()
        }
        other => error_response(
            shared,
            line_no,
            &PlanError(format!(
                "unknown command '{}' (try \"stats\", \"metrics\" or \"recalibrate\")",
                other.unwrap_or("?")
            )),
        ),
    }
}

fn error_response(shared: &Shared, line_no: usize, e: &PlanError) -> String {
    shared.lock_stats().errors += 1;
    wire::error_frame(line_no, e).dumps()
}

/// The process-wide shutdown-signal flag: installed once, tripped by
/// SIGINT (ctrl-C) or SIGTERM (what init systems and `kill` send by
/// default — a supervised deployment must drain on it, not die mid-
/// response). Std-only — on unix the handlers register through libc's
/// `signal` (already linked by std; declared here rather than pulling in
/// the libc crate), and the handler body is a single async-signal-safe
/// store into the one flag both signals share.
#[cfg(unix)]
pub(crate) fn sigint_flag() -> &'static AtomicBool {
    static FLAG: AtomicBool = AtomicBool::new(false);
    static INSTALL: std::sync::Once = std::sync::Once::new();
    extern "C" fn on_shutdown_signal(_signum: i32) {
        FLAG.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    INSTALL.call_once(|| unsafe {
        signal(2 /* SIGINT */, on_shutdown_signal);
        signal(15 /* SIGTERM */, on_shutdown_signal);
    });
    &FLAG
}

/// Non-unix fallback: no signal hookup; shutdown comes from the handle.
#[cfg(not(unix))]
pub(crate) fn sigint_flag() -> &'static AtomicBool {
    static FLAG: AtomicBool = AtomicBool::new(false);
    &FLAG
}
