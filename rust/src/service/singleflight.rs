//! Single-flight coalescing: concurrent misses on one canonical key cost
//! one solve.
//!
//! A thundering herd — N connections asking the same design question at
//! once — used to pay one full solve per request that arrived before the
//! first finished. The registry here dedupes them at admission: the first
//! request for a key becomes the **leader** (it proceeds to the worker
//! pool and solves), every later request arriving while that flight is
//! open becomes a **follower** — a passive delivery record parked in the
//! registry, holding no queue slot and no thread. When the leader's
//! worker finishes it [`SingleFlight::complete`]s the flight, takes the
//! followers, and delivers each an id-restamped copy of the same outcome.
//!
//! No thread ever blocks on a flight: followers are plain values (the
//! service parks `(connection, seq, line number, id)` tuples), so the
//! design needs no condvars and cannot deadlock on shutdown — a flight
//! whose leader can't run anymore (queue closed mid-push) is completed by
//! the would-be leader itself, which fails the followers explicitly.

use std::collections::HashMap;
use std::sync::Mutex;

/// What [`SingleFlight::join`] decided for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// first in: proceed to solve, then [`SingleFlight::complete`]
    Leader,
    /// parked on an open flight: the leader's completion delivers
    Coalesced,
}

/// Registry of open flights keyed by canonical request key, each holding
/// the followers parked on it. `F` is the follower record type (the
/// service uses a connection/sequence tuple; tests use plain values).
#[derive(Debug, Default)]
pub struct SingleFlight<F> {
    inner: Mutex<HashMap<String, Vec<F>>>,
}

impl<F> SingleFlight<F> {
    /// An empty registry.
    pub fn new() -> SingleFlight<F> {
        SingleFlight { inner: Mutex::new(HashMap::new()) }
    }

    /// Join the flight for `key`: if none is open this caller opens it
    /// and leads (the closure is not called); otherwise the closure's
    /// follower record is parked on the open flight. The check and the
    /// park are one critical section, so a follower can never be parked
    /// on a flight that already completed.
    pub fn join(&self, key: &str, follower: impl FnOnce() -> F) -> Role {
        let mut inner = self.lock();
        match inner.get_mut(key) {
            Some(parked) => {
                parked.push(follower());
                Role::Coalesced
            }
            None => {
                inner.insert(key.to_string(), Vec::new());
                Role::Leader
            }
        }
    }

    /// Close the flight for `key`, returning its parked followers (empty
    /// if none parked, or if no flight was open). The leader calls this
    /// with its outcome in hand and delivers to every follower; a later
    /// request for the same key starts a fresh flight.
    pub fn complete(&self, key: &str) -> Vec<F> {
        self.lock().remove(key).unwrap_or_default()
    }

    /// Open flights right now (a gauge, used by tests).
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    /// Whether no flight is open.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, Vec<F>>> {
        // the map is valid at every step; recover from poisoning like the
        // service stats lock rather than wedging the request path
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn first_joiner_leads_and_later_joiners_park_in_order() {
        let sf: SingleFlight<u32> = SingleFlight::new();
        assert_eq!(sf.join("k", || unreachable!("leader must not build a follower")), Role::Leader);
        assert_eq!(sf.join("k", || 1), Role::Coalesced);
        assert_eq!(sf.join("k", || 2), Role::Coalesced);
        // a different key is its own flight
        assert_eq!(sf.join("other", || unreachable!()), Role::Leader);
        assert_eq!(sf.len(), 2);
        assert_eq!(sf.complete("k"), vec![1, 2]);
        // completion closes the flight: the next joiner leads a fresh one
        assert_eq!(sf.join("k", || unreachable!()), Role::Leader);
        assert_eq!(sf.complete("k"), Vec::<u32>::new());
        assert_eq!(sf.complete("never-opened"), Vec::<u32>::new());
        assert_eq!(sf.complete("other"), Vec::<u32>::new());
        assert!(sf.is_empty());
    }

    #[test]
    fn concurrent_joiners_elect_exactly_one_leader() {
        let sf: Arc<SingleFlight<usize>> = Arc::new(SingleFlight::new());
        let leaders = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..16)
            .map(|i| {
                let (sf, leaders) = (Arc::clone(&sf), Arc::clone(&leaders));
                std::thread::spawn(move || {
                    if sf.join("hot-key", || i) == Role::Leader {
                        leaders.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(leaders.load(Ordering::SeqCst), 1, "exactly one leader per flight");
        let followers = sf.complete("hot-key");
        assert_eq!(followers.len(), 15, "everyone else parked");
        assert!(sf.is_empty());
    }
}
