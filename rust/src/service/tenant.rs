//! Per-tenant request budgets that survive reconnects.
//!
//! The per-connection quota (`--per-conn-quota`) meters a *socket*: a
//! tenant that reconnects starts a fresh budget, so metering was
//! escapable by design. This ledger meters the *tenant* — the request
//! `id` field, which doubles as the tenant token on this wire — across
//! every connection for the life of the service process. Spent budget is
//! never refunded: reconnecting, erroring, or coalescing onto another
//! tenant's identical request all still count against the quota, because
//! each consumed an admission the tenant asked for.
//!
//! Anonymous requests (empty `id`) are unmetered: there is no identity
//! to bill, and billing them as one shared tenant would let one noisy
//! anonymous client starve every other. Operators who want hard
//! admission for anonymous traffic already have `--max-inflight` and the
//! per-connection quota.
//!
//! The ledger is deliberately not reset by the `recalibrate` admin verb:
//! recalibration flushes cached *answers*; budgets are policy.

use std::collections::HashMap;
use std::sync::Mutex;

/// Tenant-keyed spent-request counts against a fixed per-tenant quota.
/// `quota == 0` disables metering entirely (the default).
#[derive(Debug, Default)]
pub struct TenantLedger {
    quota: u64,
    spent: Mutex<HashMap<String, u64>>,
}

impl TenantLedger {
    /// A ledger enforcing `quota` requests per tenant id (0 = unmetered).
    pub fn new(quota: u64) -> TenantLedger {
        TenantLedger { quota, spent: Mutex::new(HashMap::new()) }
    }

    /// Whether this ledger meters anything at all.
    pub fn enabled(&self) -> bool {
        self.quota != 0
    }

    /// The configured per-tenant quota (0 = unmetered).
    pub fn quota(&self) -> u64 {
        self.quota
    }

    /// Charge one request to tenant `id`. Returns `false` when the
    /// tenant has already spent its whole quota (the request must be
    /// refused); anonymous requests (`id == ""`) and disabled ledgers
    /// always charge successfully without recording anything.
    pub fn try_charge(&self, id: &str) -> bool {
        if self.quota == 0 || id.is_empty() {
            return true;
        }
        let mut spent = self.lock();
        let n = spent.entry(id.to_string()).or_insert(0);
        if *n >= self.quota {
            return false;
        }
        *n += 1;
        true
    }

    /// How much tenant `id` has spent so far (0 for unknown tenants).
    pub fn spent(&self, id: &str) -> u64 {
        self.lock().get(id).copied().unwrap_or(0)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, u64>> {
        // counts are valid at every step; recover from poisoning like
        // the stats lock rather than wedging admission
        self.spent.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quota_survives_across_callers_and_exhausts_exactly() {
        let ledger = TenantLedger::new(3);
        assert!(ledger.enabled());
        for _ in 0..3 {
            assert!(ledger.try_charge("acme"));
        }
        // the fourth request is refused no matter who carries it — the
        // ledger has no notion of a connection to reset
        assert!(!ledger.try_charge("acme"));
        assert_eq!(ledger.spent("acme"), 3);
        // other tenants are unaffected
        assert!(ledger.try_charge("globex"));
        assert_eq!(ledger.spent("globex"), 1);
    }

    #[test]
    fn anonymous_and_disabled_are_unmetered() {
        let ledger = TenantLedger::new(2);
        for _ in 0..10 {
            assert!(ledger.try_charge(""));
        }
        assert_eq!(ledger.spent(""), 0, "anonymous spend is never recorded");
        let off = TenantLedger::new(0);
        assert!(!off.enabled());
        for _ in 0..10 {
            assert!(off.try_charge("acme"));
        }
        assert_eq!(off.spent("acme"), 0);
    }

    #[test]
    fn refused_charges_do_not_grow_spend() {
        let ledger = TenantLedger::new(1);
        assert!(ledger.try_charge("t"));
        for _ in 0..5 {
            assert!(!ledger.try_charge("t"));
        }
        assert_eq!(ledger.spent("t"), 1);
    }
}
