//! Cycle-level execution simulator for a mapped network.
//!
//! Validates the closed-form latency models (Eq. 3/4) from first
//! principles and produces the throughput/utilization numbers behind
//! Fig. 9's performance claims: the chip is simulated as a set of tiles
//! (the packing's bins), each serving the layer blocks placed on it, in
//! tile-time quanta ("cycles" of duration `t_tile`).
//!
//! * **Sequential** execution activates one layer at a time; a layer with
//!   effective reuse `r` holds its tiles for `r` cycles; the next inference
//!   starts only after the previous one drained (plus the lump `t_dig`,
//!   `t_com` terms of Eq. 3).
//! * **Pipelined** execution streams inferences: every layer works on a
//!   different inference simultaneously, so a new input is accepted every
//!   `beat = max_l r_l` cycles (Eq. 4) and the first result appears after
//!   `depth` stages.

use crate::nets::Network;
use crate::pack::{Discipline, Packing};
use crate::perf::{effective_reuse, Execution, TimingModel};

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub timing: TimingModel,
    pub exec: Execution,
    /// per-layer RAPA replication (1 = none)
    pub replication: Vec<usize>,
}

impl SimConfig {
    pub fn new(net: &Network, exec: Execution) -> SimConfig {
        SimConfig {
            timing: TimingModel::default(),
            exec,
            replication: vec![1; net.n_layers()],
        }
    }
}

/// Simulation outcome for a batch of inferences.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub n_inferences: usize,
    /// tile-time quanta until the last result
    pub makespan_cycles: u64,
    /// seconds from first input to first result
    pub first_latency_s: f64,
    /// seconds until the last result
    pub total_time_s: f64,
    /// steady-state results per second
    pub throughput_per_s: f64,
    /// per-tile busy cycles
    pub tile_busy: Vec<u64>,
    /// mean tile utilization over the makespan
    pub utilization: f64,
    /// inter-tile messages (layer boundary crossings x inferences)
    pub messages: u64,
}

/// Simulate `n_inferences` through the mapped network.
///
/// The packing must host every layer of `net` (its blocks' `layer` fields
/// index into `net.layers`).
pub fn simulate(
    net: &Network,
    packing: &Packing,
    cfg: &SimConfig,
    n_inferences: usize,
) -> SimReport {
    assert!(n_inferences > 0, "need at least one inference");
    let reuse = effective_reuse(net, &cfg.replication);
    let n_layers = net.n_layers();
    let n_tiles = packing.n_bins.max(1);

    // tiles hosting each layer — one pass over the placements (the old
    // per-layer `layer_bins` queries were O(layers x placements))
    let layer_tiles: Vec<Vec<usize>> = packing.layer_bins_map(n_layers);
    for (l, tiles) in layer_tiles.iter().enumerate() {
        assert!(
            !tiles.is_empty(),
            "layer {l} has no blocks in the packing — fragment the same network"
        );
    }

    // inter-tile messages: one per consecutive-layer tile pair per inference
    let mut messages_per_inf = 0u64;
    for w in layer_tiles.windows(2) {
        let crossing = w[0].iter().any(|t| !w[1].contains(t)) || w[0].len() > 1;
        if crossing {
            messages_per_inf += (w[0].len() * w[1].len()) as u64;
        }
    }

    let mut tile_busy = vec![0u64; n_tiles];
    let (makespan, first_latency_cycles) = match cfg.exec {
        Execution::Sequential => {
            // layers run one after another; each inference drains fully
            let per_inf: u64 = reuse.iter().map(|&r| r as u64).sum();
            for (l, tiles) in layer_tiles.iter().enumerate() {
                for &t in tiles {
                    tile_busy[t] += reuse[l] as u64 * n_inferences as u64;
                }
            }
            (per_inf * n_inferences as u64, per_inf)
        }
        Execution::Pipelined => {
            // beat = slowest stage; depth = number of stages
            let beat = reuse.iter().copied().max().unwrap_or(1) as u64;
            let depth = n_layers as u64;
            for (l, tiles) in layer_tiles.iter().enumerate() {
                for &t in tiles {
                    tile_busy[t] += reuse[l] as u64 * n_inferences as u64;
                }
            }
            (depth * beat + (n_inferences as u64 - 1) * beat, depth * beat)
        }
    };

    let lump = cfg.timing.t_dig + cfg.timing.t_com;
    let total_time_s = makespan as f64 * cfg.timing.t_tile
        + match cfg.exec {
            Execution::Sequential => lump * n_inferences as f64,
            Execution::Pipelined => lump,
        };
    let first_latency_s = first_latency_cycles as f64 * cfg.timing.t_tile + lump;
    let throughput = n_inferences as f64 / total_time_s;
    let busy_total: u64 = tile_busy.iter().sum();
    let utilization = busy_total as f64 / (makespan.max(1) * n_tiles as u64) as f64;

    SimReport {
        n_inferences,
        makespan_cycles: makespan,
        first_latency_s,
        total_time_s,
        throughput_per_s: throughput,
        tile_busy,
        utilization,
        messages: messages_per_inf * n_inferences as u64,
    }
}

/// Convenience: pack a network and simulate in one call.
pub fn map_and_simulate(
    net: &Network,
    tile: crate::geom::Tile,
    discipline: Discipline,
    cfg: &SimConfig,
    n_inferences: usize,
) -> (Packing, SimReport) {
    let blocks = crate::frag::fragment_network_replicated(net, tile, &cfg.replication);
    let packing = crate::pack::simple::pack(&blocks, tile, discipline);
    let report = simulate(net, &packing, cfg, n_inferences);
    (packing, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Tile;
    use crate::nets::zoo;
    use crate::perf::{latency, rapa};

    const T: Tile = Tile::new(512, 512);

    #[test]
    fn sequential_single_inference_matches_eq3() {
        let net = zoo::lenet();
        let cfg = SimConfig::new(&net, Execution::Sequential);
        let (_, rep) = map_and_simulate(&net, T, Discipline::Dense, &cfg, 1);
        let analytic = latency(&net, &cfg.replication, &cfg.timing, Execution::Sequential);
        assert!(
            (rep.total_time_s - analytic).abs() / analytic < 1e-9,
            "sim {} vs Eq.3 {}",
            rep.total_time_s,
            analytic
        );
    }

    #[test]
    fn pipelined_beat_matches_eq4() {
        let net = zoo::lenet();
        let cfg = SimConfig::new(&net, Execution::Pipelined);
        let (_, rep) = map_and_simulate(&net, T, Discipline::Pipeline, &cfg, 1000);
        // steady-state inter-result spacing == Eq. 4 latency
        let beat = latency(&net, &cfg.replication, &cfg.timing, Execution::Pipelined);
        let spacing = rep.total_time_s / rep.n_inferences as f64;
        assert!(
            (spacing - beat).abs() / beat < 0.05,
            "spacing {spacing} vs beat {beat}"
        );
    }

    #[test]
    fn pipeline_beats_sequential_throughput() {
        let net = zoo::lenet();
        let seq_cfg = SimConfig::new(&net, Execution::Sequential);
        let pipe_cfg = SimConfig::new(&net, Execution::Pipelined);
        let (_, seq) = map_and_simulate(&net, T, Discipline::Dense, &seq_cfg, 100);
        let (_, pipe) = map_and_simulate(&net, T, Discipline::Pipeline, &pipe_cfg, 100);
        assert!(pipe.throughput_per_s > seq.throughput_per_s);
    }

    #[test]
    fn rapa_improves_pipeline_throughput_about_100x() {
        // Fig. 9: RAPA (128/4) throughput improvement ~100x over plain
        // pipeline for ResNet18/ImageNet
        let net = zoo::resnet18();
        let base_cfg = SimConfig::new(&net, Execution::Pipelined);
        let (_, base) = map_and_simulate(&net, T, Discipline::Pipeline, &base_cfg, 200);
        let mut rapa_cfg = SimConfig::new(&net, Execution::Pipelined);
        rapa_cfg.replication = rapa::plan_balanced(&net, 128);
        let (_, fast) = map_and_simulate(&net, T, Discipline::Pipeline, &rapa_cfg, 200);
        let speedup = fast.throughput_per_s / base.throughput_per_s;
        assert!(
            (40.0..=130.0).contains(&speedup),
            "RAPA throughput speedup {speedup}"
        );
    }

    #[test]
    fn utilization_in_unit_interval_and_busy_conserved() {
        let net = zoo::alexnet();
        let cfg = SimConfig::new(&net, Execution::Sequential);
        let (packing, rep) = map_and_simulate(&net, T, Discipline::Dense, &cfg, 3);
        assert!(rep.utilization > 0.0 && rep.utilization <= 1.0);
        assert_eq!(rep.tile_busy.len(), packing.n_bins);
        // every tile hosting blocks accumulates busy time
        assert!(rep.tile_busy.iter().all(|&b| b > 0));
    }

    #[test]
    fn messages_scale_with_inferences() {
        let net = zoo::lenet();
        let cfg = SimConfig::new(&net, Execution::Pipelined);
        let (_, r1) = map_and_simulate(&net, T, Discipline::Pipeline, &cfg, 10);
        let (_, r2) = map_and_simulate(&net, T, Discipline::Pipeline, &cfg, 20);
        assert_eq!(r2.messages, 2 * r1.messages);
    }

    #[test]
    fn first_latency_less_than_total_for_batches() {
        let net = zoo::lenet();
        let cfg = SimConfig::new(&net, Execution::Pipelined);
        let (_, rep) = map_and_simulate(&net, T, Discipline::Pipeline, &cfg, 50);
        assert!(rep.first_latency_s < rep.total_time_s);
    }

    #[test]
    #[should_panic(expected = "layer")]
    fn packing_of_wrong_network_rejected() {
        let net = zoo::lenet();
        let other = zoo::alexnet();
        let blocks = crate::frag::fragment_network(&net, T);
        let packing = crate::pack::simple::pack(&blocks, T, Discipline::Dense);
        let cfg = SimConfig::new(&other, Execution::Sequential);
        simulate(&other, &packing, &cfg, 1);
    }
}
