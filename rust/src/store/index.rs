//! The warehouse index: canonical request key → newest on-disk record.
//!
//! Built by replaying segments in numeric order at boot. The warehouse is
//! append-only, so one key can appear in many records; replay order is
//! append order and **last wins** — which is also what makes compaction
//! crash-safe (compacted copies land in higher-numbered segments, so a
//! crash that leaves both old and new on disk replays to the same index).

use std::collections::HashMap;

/// Where a record's line lives on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecordLoc {
    /// segment id (`seg-{id:06}.jsonl`)
    pub segment: u64,
    /// byte offset of the record line within the segment file
    pub offset: u64,
    /// line length in bytes, excluding the newline
    pub len: u64,
    /// the record's logical append stamp
    pub stamp: u64,
}

/// In-memory map from canonical request key to the newest record holding
/// its plan. Keys are resident (they're small); plan bytes stay on disk.
#[derive(Debug, Default)]
pub struct Index {
    map: HashMap<String, RecordLoc>,
    /// records replayed over by a newer one for the same key (cumulative
    /// since load — the bytes compaction will reclaim)
    superseded: u64,
}

impl Index {
    /// An empty index.
    pub fn new() -> Index {
        Index::default()
    }

    /// Record `key` at `loc`, superseding any earlier record.
    pub fn insert(&mut self, key: String, loc: RecordLoc) {
        if self.map.insert(key, loc).is_some() {
            self.superseded += 1;
        }
    }

    /// The newest location for `key`.
    pub fn get(&self, key: &str) -> Option<RecordLoc> {
        self.map.get(key).copied()
    }

    /// Whether `key` has a live record.
    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    /// Live (newest-per-key) record count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether no key has a live record.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Records superseded by a newer same-key append since load.
    pub fn superseded(&self) -> u64 {
        self.superseded
    }

    /// Live keys in sorted order — compaction iterates this so rewritten
    /// segments are deterministic for a given live set.
    pub fn sorted_keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = self.map.keys().cloned().collect();
        keys.sort_unstable();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loc(segment: u64, offset: u64) -> RecordLoc {
        RecordLoc { segment, offset, len: 10, stamp: segment }
    }

    #[test]
    fn last_write_wins_and_supersession_is_counted() {
        let mut ix = Index::new();
        assert!(ix.is_empty());
        ix.insert("a".into(), loc(1, 0));
        ix.insert("b".into(), loc(1, 11));
        assert_eq!(ix.len(), 2);
        assert_eq!(ix.superseded(), 0);
        // replay of a newer record for "a" replaces the old location
        ix.insert("a".into(), loc(2, 0));
        assert_eq!(ix.len(), 2);
        assert_eq!(ix.superseded(), 1);
        assert_eq!(ix.get("a"), Some(loc(2, 0)));
        assert!(ix.contains("b"));
        assert!(!ix.contains("c"));
        assert_eq!(ix.sorted_keys(), vec!["a".to_string(), "b".to_string()]);
    }
}
