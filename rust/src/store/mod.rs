//! Persistent plan warehouse: an append-only on-disk store of canonical
//! request → serialized plan, the planning service's second cache tier.
//!
//! Plans for the fixed §3.1 grid are pure functions of the canonical
//! request, so they should be computed once, ever — not once per process
//! lifetime. The warehouse makes that durable: JSONL segment files
//! ([`segment`]) of `(key, plan, crc, stamp)` records, rotated at a byte
//! threshold, replayed at boot into an in-memory [`Index`] (keys resident,
//! plan bytes on disk), and compacted offline. The serving read path is
//! LRU miss → warehouse hit (promoted into the LRU) → solve; solved plans
//! are written *behind* the LRU by a dedicated writer thread so the
//! request path never blocks on disk (see [`crate::service`]).
//!
//! Durability model, in order of line of defense:
//!
//! * **torn tail**: a crash mid-append leaves the active segment's final
//!   record incomplete. [`Warehouse::open`] loads every intact record and
//!   truncates the file back to the last good record boundary, so the next
//!   append starts on a clean line — a crash can never poison the store
//!   ([`LoadReport::truncated_tails`]).
//! * **mid-file corruption** (bad sectors, external edits): caught by the
//!   per-record CRC, skipped and counted ([`LoadReport::corrupt`]); boot
//!   never aborts on content.
//! * **compaction** ([`Warehouse::compact`]): live records are rewritten
//!   into *fresh, higher-numbered* segments before the old ones are
//!   removed. Replay order is append order and the index is last-wins, so
//!   a crash at any point during compaction leaves a directory that
//!   replays to the same live set (at worst with duplicates that the next
//!   compaction drops).
//!
//! Appends go through the OS page cache without fsync — the torn-tail
//! loader is the recovery story, and a lost suffix only costs re-solves.
//!
//! **Single-writer exclusion**: two processes appending into one segment
//! would interleave half-records and corrupt each other's tails, so
//! [`Warehouse::open`] takes a [`LOCK_FILE`] (`O_EXCL` create holding the
//! owner's pid) and holds it until drop. A lock whose pid is dead —
//! `kill -9` skips destructors — is stale and taken over; a live holder
//! refuses the open with a descriptive error. Cluster shards
//! ([`crate::cluster`]) therefore each get their own subdirectory under
//! the shared `--warehouse` root rather than sharing one segment stream.
//! The read-only [`Warehouse::stat`] does not take the lock.

pub mod index;
pub mod segment;

pub use index::{Index, RecordLoc};

use segment::{scan_segment, segment_id, segment_path};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Default segment-rotation threshold. Plans run tens of bytes (fixed
/// tile, LeNet) to a few hundred KB (BERT grid), so 4 MiB keeps segment
/// count and per-file blast radius both small.
pub const DEFAULT_SEGMENT_BYTES: u64 = 4 << 20;

/// Name of the single-writer exclusion lock inside a warehouse directory.
/// Not a segment file ([`segment::segment_id`] ignores it), so replay and
/// `stat` never see it as content.
pub const LOCK_FILE: &str = "warehouse.lock";

/// Held lock on a warehouse directory; dropping it removes the file. Kept
/// as a field on [`Warehouse`] so the exclusion lives exactly as long as
/// the append handle can.
#[derive(Debug)]
struct LockGuard {
    path: PathBuf,
}

impl Drop for LockGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Take `dir`'s [`LOCK_FILE`] with an `O_EXCL` create, writing our pid.
/// On contention the holder pid is probed ([`crate::util::proc::pid_alive`]):
/// a dead or unreadable holder is stale (its destructor never ran — e.g.
/// `kill -9`) and its lock is removed and retaken once; a live holder —
/// including this very process, which is what a double `open` of one
/// directory looks like — refuses with [`std::io::ErrorKind::WouldBlock`].
fn acquire_lock(dir: &Path) -> std::io::Result<LockGuard> {
    let path = dir.join(LOCK_FILE);
    for takeover in [false, true] {
        match OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut file) => {
                file.write_all(std::process::id().to_string().as_bytes())?;
                return Ok(LockGuard { path });
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                if takeover {
                    break; // raced another stale-takeover: give up below
                }
                let holder = std::fs::read_to_string(&path).unwrap_or_default();
                if let Ok(pid) = holder.trim().parse::<u32>() {
                    if crate::util::proc::pid_alive(pid) {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::WouldBlock,
                            format!(
                                "warehouse {} is locked by live process {pid} \
                                 (remove {LOCK_FILE} only if that process is gone)",
                                dir.display()
                            ),
                        ));
                    }
                }
                // dead pid or garbage content: stale — take it over
                let _ = std::fs::remove_file(&path);
            }
            Err(e) => return Err(e),
        }
    }
    // two stale-takeover racers removed each other's create; one more
    // O_EXCL attempt already happened above, so surface the contention
    Err(std::io::Error::new(
        std::io::ErrorKind::WouldBlock,
        format!("warehouse {} lock contended during stale takeover", dir.display()),
    ))
}

/// Configuration for [`Warehouse::open`].
#[derive(Debug, Clone)]
pub struct WarehouseConfig {
    /// directory holding the segment files (created if absent)
    pub dir: PathBuf,
    /// rotate to a new segment once the active one reaches this many
    /// bytes (a single record larger than the threshold still lands
    /// whole — segments bound typical size, they don't split records)
    pub segment_bytes: u64,
}

impl WarehouseConfig {
    /// A warehouse at `dir` with the default rotation threshold.
    pub fn at(dir: impl Into<PathBuf>) -> WarehouseConfig {
        WarehouseConfig { dir: dir.into(), segment_bytes: DEFAULT_SEGMENT_BYTES }
    }
}

/// What [`Warehouse::open`] found on disk.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadReport {
    /// live records indexed (newest per key)
    pub records: usize,
    /// records replayed over by a newer same-key record
    pub superseded: u64,
    /// bad lines inside intact prefixes, skipped (dropped at compaction)
    pub corrupt: usize,
    /// segments whose torn tail was truncated back to a record boundary
    pub truncated_tails: usize,
    /// bytes cut by those truncations
    pub truncated_bytes: u64,
    /// segment files present
    pub segments: usize,
    /// total on-disk bytes across segments, after truncation
    pub bytes: u64,
}

/// Result of one [`Warehouse::compact`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CompactReport {
    /// live records carried into the fresh segments
    pub live: usize,
    /// superseded duplicate records dropped (corrupt lines are dropped
    /// too, but they're counted by [`LoadReport::corrupt`] at load time)
    pub dropped: u64,
    /// on-disk bytes before / after
    pub bytes_before: u64,
    /// on-disk bytes after the rewrite
    pub bytes_after: u64,
    /// segment files before / after
    pub segments_before: usize,
    /// segment files after the rewrite
    pub segments_after: usize,
}

struct Inner {
    index: Index,
    /// append handle for the active (highest-numbered) segment
    active: Option<File>,
    active_id: u64,
    active_len: u64,
    /// total on-disk bytes across all segments
    total_bytes: u64,
    /// segment files on disk
    segments: usize,
    /// next logical append stamp (max loaded stamp + 1)
    stamp: u64,
}

/// The open plan warehouse. All methods take `&self`; one internal lock
/// covers the index and the active-segment append state. Reads of record
/// bytes happen outside the lock (the segment files are append-only, so
/// a located record never moves — except under [`Warehouse::compact`],
/// which holds the lock for its whole rewrite and is an offline
/// operation by contract).
pub struct Warehouse {
    dir: PathBuf,
    segment_bytes: u64,
    inner: Mutex<Inner>,
    /// single-writer exclusion on `dir`; removed on drop
    _lock: LockGuard,
}

impl Warehouse {
    /// Open (creating the directory if needed) and replay every segment:
    /// index intact records last-wins, truncate torn tails back to a
    /// record boundary. Content problems never abort the open — only I/O
    /// errors do, plus one policy refusal: a [`LOCK_FILE`] held by a live
    /// process (stale locks from dead pids are taken over silently).
    pub fn open(cfg: &WarehouseConfig) -> std::io::Result<(Warehouse, LoadReport)> {
        std::fs::create_dir_all(&cfg.dir)?;
        // exclusion before replay: a second writer interleaving appends
        // into the active segment would tear both writers' records
        let lock = acquire_lock(&cfg.dir)?;
        let mut report = LoadReport::default();
        let mut inner = Inner {
            index: Index::new(),
            active: None,
            active_id: 0,
            active_len: 0,
            total_bytes: 0,
            segments: 0,
            stamp: 1,
        };
        for (id, path) in list_segments(&cfg.dir)? {
            let scan = scan_segment(&path)?;
            if scan.torn {
                // cut the torn tail so the next append starts on a clean
                // line — otherwise it would concatenate onto the partial
                // record and poison an otherwise-good line
                let file = OpenOptions::new().write(true).open(&path)?;
                let cut = file.metadata()?.len() - scan.good_bytes;
                file.set_len(scan.good_bytes)?;
                report.truncated_tails += 1;
                report.truncated_bytes += cut;
            }
            for (loc, rec) in &scan.records {
                inner.stamp = inner.stamp.max(rec.stamp + 1);
                inner.index.insert(
                    rec.key.clone(),
                    RecordLoc {
                        segment: id,
                        offset: loc.offset,
                        len: loc.len,
                        stamp: rec.stamp,
                    },
                );
            }
            report.corrupt += scan.corrupt;
            report.segments += 1;
            report.bytes += scan.good_bytes;
            inner.segments += 1;
            inner.active_id = id; // segments iterate in ascending order
            inner.active_len = scan.good_bytes;
        }
        inner.total_bytes = report.bytes;
        report.records = inner.index.len();
        report.superseded = inner.index.superseded();
        let wh = Warehouse {
            dir: cfg.dir.clone(),
            segment_bytes: cfg.segment_bytes,
            inner: Mutex::new(inner),
            _lock: lock,
        };
        Ok((wh, report))
    }

    /// Scan a warehouse directory **read-only**: the same replay as
    /// [`Warehouse::open`] without touching the files (torn tails are
    /// reported, not truncated) — `xbarmap warehouse stat`.
    pub fn stat(dir: &Path) -> std::io::Result<LoadReport> {
        let mut report = LoadReport::default();
        let mut index = Index::new();
        for (id, path) in list_segments(dir)? {
            let scan = scan_segment(&path)?;
            if scan.torn {
                report.truncated_tails += 1;
                report.truncated_bytes += std::fs::metadata(&path)?.len() - scan.good_bytes;
            }
            for (loc, rec) in &scan.records {
                index.insert(
                    rec.key.clone(),
                    RecordLoc { segment: id, offset: loc.offset, len: loc.len, stamp: rec.stamp },
                );
            }
            report.corrupt += scan.corrupt;
            report.segments += 1;
            report.bytes += scan.good_bytes;
        }
        report.records = index.len();
        report.superseded = index.superseded();
        Ok(report)
    }

    /// The serialized plan stored for `key`, read from disk and
    /// CRC-verified. `None` on a miss — or if the record fails
    /// re-verification (the caller re-solves; the fresh append
    /// supersedes the bad record).
    pub fn get(&self, key: &str) -> Option<String> {
        let loc = {
            let inner = self.lock();
            inner.index.get(key)?
        };
        let path = segment_path(&self.dir, loc.segment);
        let line = read_span(&path, loc.offset, loc.len).ok()?;
        let rec = segment::decode_record(line.trim_end()).ok()?;
        (rec.key == key).then_some(rec.plan)
    }

    /// Whether `key` has a live record.
    pub fn contains(&self, key: &str) -> bool {
        self.lock().index.contains(key)
    }

    /// Append one record, rotating to a fresh segment at the byte
    /// threshold, and index it (superseding any earlier record for the
    /// key). Returns the record's logical stamp.
    pub fn append(&self, key: &str, plan: &str) -> std::io::Result<u64> {
        let mut inner = self.lock();
        let stamp = inner.stamp;
        let line = segment::encode_record(stamp, key, plan);
        let line_len = line.len() as u64 + 1;
        let rotate = inner.active_id == 0
            || (inner.active_len > 0 && inner.active_len + line_len > self.segment_bytes);
        if rotate {
            let id = inner.active_id + 1;
            let file = OpenOptions::new()
                .create(true)
                .append(true)
                .open(segment_path(&self.dir, id))?;
            inner.active = Some(file);
            inner.active_id = id;
            inner.active_len = 0;
            inner.segments += 1;
        } else if inner.active.is_none() {
            // first append since open/compact: continue the newest segment
            // (which still has room) rather than fragmenting into a fresh
            // one per process lifetime
            let file = OpenOptions::new()
                .create(true)
                .append(true)
                .open(segment_path(&self.dir, inner.active_id))?;
            inner.active = Some(file);
        }
        let offset = inner.active_len;
        let seg = inner.active_id;
        let Some(file) = inner.active.as_mut() else {
            // both branches above populate the handle; surface a typed
            // error instead of panicking with the warehouse lock held
            return Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "warehouse append: no active segment after open",
            ));
        };
        file.write_all(line.as_bytes())?;
        file.write_all(b"\n")?;
        inner.active_len += line_len;
        inner.total_bytes += line_len;
        inner.stamp += 1;
        inner.index.insert(
            key.to_string(),
            RecordLoc { segment: seg, offset, len: line.len() as u64, stamp },
        );
        Ok(stamp)
    }

    /// Rewrite the live records into fresh segments and remove the old
    /// ones, dropping superseded duplicates and corrupt lines. **Offline
    /// by contract**: callers must not serve traffic from this warehouse
    /// concurrently (the lock is held for the whole rewrite, and old
    /// segment files are deleted).
    ///
    /// Crash-safe by construction: the fresh segments are numbered
    /// *after* every old one, so if the process dies mid-compaction the
    /// next [`Warehouse::open`] replays old-then-new and last-wins
    /// resolves to the identical live set.
    pub fn compact(&self) -> std::io::Result<CompactReport> {
        let mut inner = self.lock();
        inner.active = None; // release the append handle before old files go
        let bytes_before = inner.total_bytes;
        let segments_before = inner.segments;
        let old_ids: Vec<u64> = list_segments(&self.dir)?.into_iter().map(|(id, _)| id).collect();
        let keys = inner.index.sorted_keys();

        // copy each live record's raw line into fresh segments (crc and
        // stamp travel with the bytes — no re-encode, no re-verify drift)
        let mut new_index = Index::new();
        let mut id = inner.active_id; // fresh ids start past every old one
        let mut out: Option<File> = None;
        let (mut out_len, mut total, mut segments_after) = (0u64, 0u64, 0usize);
        for key in &keys {
            let Some(loc) = inner.index.get(key) else {
                continue; // key listed moments ago; nothing to copy if gone
            };
            let line = read_span(&segment_path(&self.dir, loc.segment), loc.offset, loc.len)?;
            let line_len = loc.len + 1;
            if out.is_none() || (out_len > 0 && out_len + line_len > self.segment_bytes) {
                id += 1;
                out = Some(
                    OpenOptions::new().create(true).append(true).open(segment_path(&self.dir, id))?,
                );
                out_len = 0;
                segments_after += 1;
            }
            let Some(file) = out.as_mut() else {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "warehouse compact: no open output segment",
                ));
            };
            file.write_all(line.as_bytes())?;
            file.write_all(b"\n")?;
            new_index.insert(
                key.clone(),
                RecordLoc { segment: id, offset: out_len, len: loc.len, stamp: loc.stamp },
            );
            out_len += line_len;
            total += line_len;
        }
        drop(out); // close before deleting old files (Windows)
        for old in old_ids {
            std::fs::remove_file(segment_path(&self.dir, old))?;
        }
        let report = CompactReport {
            live: keys.len(),
            dropped: inner.index.superseded(),
            bytes_before,
            bytes_after: total,
            segments_before,
            segments_after,
        };
        inner.index = new_index;
        inner.active = None; // reopened lazily by the next append
        inner.active_id = id.max(inner.active_id);
        inner.active_len = out_len;
        inner.total_bytes = total;
        inner.segments = segments_after;
        Ok(report)
    }

    /// Live records (newest per key).
    pub fn len(&self) -> usize {
        self.lock().index.len()
    }

    /// Whether the warehouse holds no live records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total on-disk bytes across segments (the `warehouse_bytes` gauge).
    pub fn bytes(&self) -> u64 {
        self.lock().total_bytes
    }

    /// Segment files on disk.
    pub fn segments(&self) -> usize {
        self.lock().segments
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // plain-data state is valid at every step; recover like the
        // service's stats lock rather than wedging every later call
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// Segment files under `dir` in ascending id order.
fn list_segments(dir: &Path) -> std::io::Result<Vec<(u64, PathBuf)>> {
    let mut segs = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        if let Some(id) = name.to_str().and_then(segment_id) {
            segs.push((id, entry.path()));
        }
    }
    segs.sort_unstable_by_key(|&(id, _)| id);
    Ok(segs)
}

/// Read `len` bytes at `offset` from `path`.
fn read_span(path: &Path, offset: u64, len: u64) -> std::io::Result<String> {
    let mut file = File::open(path)?;
    file.seek(SeekFrom::Start(offset))?;
    let mut buf = vec![0u8; len as usize];
    file.read_exact(&mut buf)?;
    String::from_utf8(buf)
        .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "record is not UTF-8"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("xbarmap-wh-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_cfg(dir: &Path, segment_bytes: u64) -> WarehouseConfig {
        WarehouseConfig { dir: dir.to_path_buf(), segment_bytes }
    }

    #[test]
    fn appends_persist_across_reopen_and_last_write_wins() {
        let dir = temp_dir("reopen");
        let cfg = WarehouseConfig::at(&dir);
        {
            let (wh, report) = Warehouse::open(&cfg).unwrap();
            assert_eq!(report, LoadReport::default());
            wh.append("k1", "plan-one").unwrap();
            wh.append("k2", "plan-two").unwrap();
            wh.append("k1", "plan-one-v2").unwrap(); // supersedes
            assert_eq!(wh.len(), 2);
            assert_eq!(wh.get("k1").as_deref(), Some("plan-one-v2"));
        }
        let (wh, report) = Warehouse::open(&cfg).unwrap();
        assert_eq!(report.records, 2);
        assert_eq!(report.superseded, 1);
        assert_eq!(report.truncated_tails, 0);
        assert_eq!(wh.get("k1").as_deref(), Some("plan-one-v2"));
        assert_eq!(wh.get("k2").as_deref(), Some("plan-two"));
        assert_eq!(wh.get("k3"), None);
        assert!(wh.bytes() > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segments_rotate_at_the_byte_threshold() {
        let dir = temp_dir("rotate");
        let (wh, _) = Warehouse::open(&small_cfg(&dir, 200)).unwrap();
        for i in 0..8 {
            wh.append(&format!("key-{i}"), "0123456789abcdef").unwrap();
        }
        assert!(wh.segments() > 1, "200-byte threshold must have rotated");
        assert_eq!(wh.len(), 8);
        for i in 0..8 {
            assert_eq!(wh.get(&format!("key-{i}")).as_deref(), Some("0123456789abcdef"));
        }
        // stamps are monotonic across rotations
        let s1 = wh.append("late-1", "p").unwrap();
        let s2 = wh.append("late-2", "p").unwrap();
        assert!(s2 > s1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn a_torn_tail_is_truncated_and_appends_continue_cleanly() {
        let dir = temp_dir("torn");
        let cfg = small_cfg(&dir, 1 << 20);
        {
            let (wh, _) = Warehouse::open(&cfg).unwrap();
            wh.append("k1", "plan-one").unwrap();
            wh.append("k2", "plan-two").unwrap();
        }
        // simulate a crash mid-append: half a record, no newline
        let seg = segment_path(&dir, 1);
        let intact = std::fs::metadata(&seg).unwrap().len();
        let mut f = OpenOptions::new().append(true).open(&seg).unwrap();
        f.write_all(br#"{"v":1,"stamp":9,"crc":123,"key":"k3","pl"#).unwrap();
        drop(f);

        let (wh, report) = Warehouse::open(&cfg).unwrap();
        assert_eq!(report.records, 2, "both intact records must load");
        assert_eq!(report.truncated_tails, 1);
        assert!(report.truncated_bytes > 0);
        assert_eq!(std::fs::metadata(&seg).unwrap().len(), intact, "tail must be cut");
        // the next append lands on a clean line and survives a reopen
        wh.append("k3", "plan-three").unwrap();
        drop(wh);
        let (wh, report) = Warehouse::open(&cfg).unwrap();
        assert_eq!(report.records, 3);
        assert_eq!(report.truncated_tails, 0);
        assert_eq!(wh.get("k3").as_deref(), Some("plan-three"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stat_reports_without_mutating() {
        let dir = temp_dir("stat");
        let cfg = WarehouseConfig::at(&dir);
        {
            let (wh, _) = Warehouse::open(&cfg).unwrap();
            wh.append("k1", "p1").unwrap();
        }
        let seg = segment_path(&dir, 1);
        let mut f = OpenOptions::new().append(true).open(&seg).unwrap();
        f.write_all(b"torn").unwrap();
        drop(f);
        let len_before = std::fs::metadata(&seg).unwrap().len();
        let report = Warehouse::stat(&dir).unwrap();
        assert_eq!(report.records, 1);
        assert_eq!(report.truncated_tails, 1);
        assert_eq!(report.truncated_bytes, 4);
        assert_eq!(
            std::fs::metadata(&seg).unwrap().len(),
            len_before,
            "stat must not truncate"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_drops_superseded_records_and_preserves_the_live_set() {
        let dir = temp_dir("compact");
        let (wh, _) = Warehouse::open(&small_cfg(&dir, 128)).unwrap();
        for i in 0..6 {
            wh.append(&format!("key-{i}"), "first-version-payload").unwrap();
        }
        for i in 0..6 {
            wh.append(&format!("key-{i}"), "second-version-payload").unwrap();
        }
        let bytes_before = wh.bytes();
        let report = wh.compact().unwrap();
        assert_eq!(report.live, 6);
        assert_eq!(report.bytes_before, bytes_before);
        assert!(report.bytes_after < report.bytes_before, "duplicates must be reclaimed");
        assert_eq!(wh.bytes(), report.bytes_after);
        for i in 0..6 {
            assert_eq!(wh.get(&format!("key-{i}")).as_deref(), Some("second-version-payload"));
        }
        // appends keep working after compaction and everything reopens
        wh.append("post", "after-compaction").unwrap();
        drop(wh);
        let (wh, report) = Warehouse::open(&WarehouseConfig::at(&dir)).unwrap();
        assert_eq!(report.records, 7);
        assert_eq!(report.superseded, 0, "compaction must have dropped every duplicate");
        assert_eq!(wh.get("post").as_deref(), Some("after-compaction"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn a_live_lock_refuses_a_second_open_and_drop_releases_it() {
        let dir = temp_dir("lock");
        let cfg = WarehouseConfig::at(&dir);
        let (wh, _) = Warehouse::open(&cfg).unwrap();
        // our own pid is alive, so a second open of the same directory —
        // the latent single-process double-open — is refused, not raced
        let err = Warehouse::open(&cfg).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::WouldBlock);
        assert!(err.to_string().contains("locked by live process"), "{err}");
        assert!(dir.join(LOCK_FILE).exists());
        // stat stays lock-free: read-only tooling works beside a writer
        assert_eq!(Warehouse::stat(&dir).unwrap().records, 0);
        drop(wh);
        assert!(!dir.join(LOCK_FILE).exists(), "drop must release the lock");
        let (_wh, _) = Warehouse::open(&cfg).expect("released lock must be retakable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[cfg(unix)]
    #[test]
    fn a_stale_lock_from_a_dead_pid_is_taken_over() {
        let dir = temp_dir("stale-lock");
        std::fs::create_dir_all(&dir).unwrap();
        // a real pid that is certainly dead: a reaped child's
        let mut child = std::process::Command::new("true").spawn().unwrap();
        let dead_pid = child.id();
        child.wait().unwrap();
        std::fs::write(dir.join(LOCK_FILE), dead_pid.to_string()).unwrap();
        let (wh, _) = Warehouse::open(&WarehouseConfig::at(&dir))
            .expect("a dead holder's lock is stale and must be taken over");
        wh.append("k", "p").unwrap();
        assert_eq!(
            std::fs::read_to_string(dir.join(LOCK_FILE)).unwrap().trim(),
            std::process::id().to_string(),
            "the lock must now record the new owner"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn a_garbage_lock_file_is_taken_over() {
        let dir = temp_dir("garbage-lock");
        std::fs::create_dir_all(&dir).unwrap();
        // kill -9 between create and the pid write leaves an empty file;
        // external tampering leaves arbitrary bytes — both are stale
        std::fs::write(dir.join(LOCK_FILE), "not a pid").unwrap();
        let (_wh, report) = Warehouse::open(&WarehouseConfig::at(&dir))
            .expect("an unreadable holder must be treated as stale");
        assert_eq!(report.segments, 0, "the lock file must not count as a segment");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn get_returns_none_for_a_record_corrupted_after_load() {
        let dir = temp_dir("postload");
        let (wh, _) = Warehouse::open(&WarehouseConfig::at(&dir)).unwrap();
        wh.append("k1", "plan-one").unwrap();
        // corrupt the payload in place (same length, so the span read
        // still succeeds — the crc catches it)
        let seg = segment_path(&dir, 1);
        let text = std::fs::read_to_string(&seg).unwrap().replace("plan-one", "plan-0ne");
        std::fs::write(&seg, text).unwrap();
        assert_eq!(wh.get("k1"), None, "crc re-verification must fail the read");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
