//! Append-only JSONL segment files: the warehouse's on-disk unit.
//!
//! One record per line, `{"v":1,"stamp":S,"crc":C,"key":"...","plan":"..."}`
//! with a fixed field order. `key` is the request's canonical v1
//! serialization (id cleared — [`crate::service::PlanCache::key`]) and
//! `plan` the anonymized serialized plan line; `crc` is an IEEE CRC-32
//! over the raw key bytes followed by the raw plan bytes, so a record
//! that parses but was corrupted in either payload is still caught.
//!
//! The scanner is where crash tolerance lives: a process killed mid-append
//! leaves the final record torn — an unterminated chunk, or a terminated
//! line that no longer parses or checksums. [`scan_segment`] classifies a
//! maximal all-bad *suffix* as the torn tail (reported via
//! [`SegmentScan::good_bytes`], which the warehouse truncates the file to
//! before its next append), while a bad line *followed by good ones* —
//! external corruption, not a crash — is skipped and counted so boot
//! never aborts and compaction can drop it.

use std::io::Read;
use std::path::{Path, PathBuf};

/// IEEE CRC-32 lookup table, built at compile time.
const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// IEEE CRC-32 over a sequence of byte slices (equivalent to hashing
/// their concatenation, without materializing it).
pub fn crc32(parts: &[&[u8]]) -> u32 {
    let mut c = !0u32;
    for part in parts {
        for &b in *part {
            c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
        }
    }
    !c
}

/// One decoded warehouse record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Record {
    /// logical append stamp (monotonic per warehouse, recency diagnostic)
    pub stamp: u64,
    /// canonical request serialization, correlation id cleared
    pub key: String,
    /// anonymized serialized plan line (what the service responds with
    /// for an id-less request; id-carrying requests restamp a copy)
    pub plan: String,
}

/// Encode one record as a JSONL line **without** the trailing newline.
pub fn encode_record(stamp: u64, key: &str, plan: &str) -> String {
    let mut o = crate::util::json::JsonObj::new();
    o.set("v", crate::plan::WIRE_VERSION)
        .set("stamp", stamp)
        .set("crc", crc32(&[key.as_bytes(), plan.as_bytes()]))
        .set("key", key)
        .set("plan", plan);
    crate::util::json::Json::Obj(o).dumps()
}

/// Decode and verify one record line. Errors are strings (not
/// [`std::io::Error`]) because the caller's reaction is positional —
/// torn tail versus mid-file corruption — not error-kind based.
pub fn decode_record(line: &str) -> Result<Record, String> {
    let j = crate::util::json::parse(line).map_err(|e| format!("parse record: {e}"))?;
    let o = j.as_obj().ok_or("record must be a JSON object")?;
    crate::plan::wire::check_version(o, "warehouse record").map_err(|e| e.0)?;
    let field = |name: &str| -> Result<&str, String> {
        o.get(name)
            .and_then(crate::util::json::Json::as_str)
            .ok_or_else(|| format!("record missing string '{name}'"))
    };
    let int = |name: &str| -> Result<u64, String> {
        match o.get(name).and_then(crate::util::json::Json::as_f64) {
            Some(v) if v >= 0.0 && v == v.trunc() && v < 9.0e15 => Ok(v as u64),
            _ => Err(format!("record missing integer '{name}'")),
        }
    };
    let key = field("key")?;
    let plan = field("plan")?;
    let crc = int("crc")? as u32;
    let want = crc32(&[key.as_bytes(), plan.as_bytes()]);
    if crc != want {
        return Err(format!("crc mismatch (stored {crc}, computed {want})"));
    }
    Ok(Record { stamp: int("stamp")?, key: key.to_string(), plan: plan.to_string() })
}

/// Segment file names: `seg-000001.jsonl`, numbered from 1, zero-padded
/// so lexical order is numeric order.
pub fn segment_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("seg-{id:06}.jsonl"))
}

/// Parse a segment id back out of a file name; `None` for anything that
/// is not a `seg-NNNNNN.jsonl` (the loader ignores foreign files).
pub fn segment_id(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("seg-")?.strip_suffix(".jsonl")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// One record's location within a scanned segment.
#[derive(Debug, Clone, Copy)]
pub struct ScannedRecord {
    /// byte offset of the record line within the segment file
    pub offset: u64,
    /// line length in bytes, excluding the newline
    pub len: u64,
}

/// Result of scanning one segment file.
#[derive(Debug)]
pub struct SegmentScan {
    /// decoded records in file order, with their byte spans
    pub records: Vec<(ScannedRecord, Record)>,
    /// byte length of the intact prefix: everything up to and including
    /// the last good record's newline (the truncation point when torn)
    pub good_bytes: u64,
    /// bad lines *inside* the intact prefix (skipped, not indexed)
    pub corrupt: usize,
    /// whether the file ends in a torn tail (bytes past `good_bytes`)
    pub torn: bool,
}

/// Scan a segment file: decode every line, classify the torn tail, and
/// report the intact-prefix length. Never errors on content — only on
/// I/O.
pub fn scan_segment(path: &Path) -> std::io::Result<SegmentScan> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    // split into newline-terminated lines; a trailing chunk without a
    // newline is by definition part of the torn tail
    let mut lines: Vec<(u64, u64, bool)> = Vec::new(); // (offset, len, terminated)
    let mut start = 0usize;
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' {
            lines.push((start as u64, (i - start) as u64, true));
            start = i + 1;
        }
    }
    if start < bytes.len() {
        lines.push((start as u64, (bytes.len() - start) as u64, false));
    }
    let decoded: Vec<Option<Record>> = lines
        .iter()
        .map(|&(off, len, terminated)| {
            if !terminated {
                return None;
            }
            let raw = &bytes[off as usize..(off + len) as usize];
            std::str::from_utf8(raw).ok().and_then(|s| decode_record(s.trim_end()).ok())
        })
        .collect();
    // the torn tail is the maximal all-bad suffix; bad lines before the
    // last good one are mid-file corruption, skipped but kept
    let last_good = decoded.iter().rposition(Option::is_some);
    let (prefix_end, good_bytes) = match last_good {
        Some(i) => (i + 1, lines[i].0 + lines[i].1 + 1), // +1: the newline
        None => (0, 0),
    };
    let mut records = Vec::new();
    let mut corrupt = 0usize;
    for (i, rec) in decoded.into_iter().take(prefix_end).enumerate() {
        match rec {
            Some(r) => {
                records.push((ScannedRecord { offset: lines[i].0, len: lines[i].1 }, r))
            }
            None => corrupt += 1,
        }
    }
    Ok(SegmentScan { records, good_bytes, corrupt, torn: good_bytes < bytes.len() as u64 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_ieee_reference_vector() {
        // the classic check value for IEEE CRC-32
        assert_eq!(crc32(&[b"123456789"]), 0xCBF4_3926);
        // split points don't change the digest
        assert_eq!(crc32(&[b"1234", b"56789"]), 0xCBF4_3926);
        assert_eq!(crc32(&[b""]), 0);
    }

    #[test]
    fn records_roundtrip_and_crc_guards_both_payloads() {
        let line = encode_record(7, r#"{"v":1,"net":{"zoo":"lenet"}}"#, r#"{"v":1,"best":1}"#);
        let rec = decode_record(&line).unwrap();
        assert_eq!(rec.stamp, 7);
        assert_eq!(rec.key, r#"{"v":1,"net":{"zoo":"lenet"}}"#);
        assert_eq!(rec.plan, r#"{"v":1,"best":1}"#);
        // flip one payload byte: the JSON still parses, the crc catches it
        let tampered = line.replace("lenet", "lenex");
        assert!(decode_record(&tampered).unwrap_err().contains("crc mismatch"));
        assert!(decode_record("not json").is_err());
        assert!(decode_record(r#"{"v":2,"stamp":1,"crc":0,"key":"k","plan":"p"}"#).is_err());
    }

    #[test]
    fn segment_names_roundtrip_and_reject_foreign_files() {
        let p = segment_path(Path::new("/w"), 42);
        assert_eq!(p.file_name().unwrap().to_str().unwrap(), "seg-000042.jsonl");
        assert_eq!(segment_id("seg-000042.jsonl"), Some(42));
        assert_eq!(segment_id("seg-1000000.jsonl"), Some(1_000_000)); // wider than the pad
        assert_eq!(segment_id("seg-.jsonl"), None);
        assert_eq!(segment_id("seg-12.jsonl.tmp"), None);
        assert_eq!(segment_id("metrics.json"), None);
    }

    fn temp_file(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("xbarmap-seg-{}-{name}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn scan_truncates_a_torn_tail_but_skips_mid_file_corruption() {
        let good1 = encode_record(1, "k1", "p1");
        let good2 = encode_record(2, "k2", "p2");
        let path = temp_file("torn");

        // torn tail: unterminated half-record after two good ones
        let torn = format!("{good1}\n{good2}\n{{\"v\":1,\"stamp\":3,\"crc");
        std::fs::write(&path, &torn).unwrap();
        let scan = scan_segment(&path).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.good_bytes, (good1.len() + good2.len() + 2) as u64);
        assert!(scan.torn);
        assert_eq!(scan.corrupt, 0);

        // a terminated-but-corrupt FINAL line is also a torn tail (the
        // crash landed after the newline of the previous record)
        let torn2 = format!("{good1}\ngarbage\n");
        std::fs::write(&path, &torn2).unwrap();
        let scan = scan_segment(&path).unwrap();
        assert_eq!(scan.records.len(), 1);
        assert_eq!(scan.good_bytes, (good1.len() + 1) as u64);
        assert!(scan.torn);

        // mid-file corruption followed by a good record: skipped, counted,
        // and the good suffix still loads (no truncation)
        let mid = format!("{good1}\ngarbage\n{good2}\n");
        std::fs::write(&path, &mid).unwrap();
        let scan = scan_segment(&path).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.corrupt, 1);
        assert!(!scan.torn);
        assert_eq!(scan.good_bytes, mid.len() as u64);

        // wholly-garbage file: nothing loads, everything is tail
        std::fs::write(&path, "junk with no newline").unwrap();
        let scan = scan_segment(&path).unwrap();
        assert!(scan.records.is_empty());
        assert_eq!(scan.good_bytes, 0);
        assert!(scan.torn);

        let _ = std::fs::remove_file(&path);
    }
}
