//! Micro-benchmark harness (criterion is not vendored offline).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench::run`]
//! directly. The harness warms up, auto-scales iteration counts to a target
//! measurement time, and reports min/p50/p95/mean per benchmark in both
//! human-readable and machine-readable (JSON lines) form.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark's collected statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Stats {
    /// benchmark name (the key in `BENCH_*.json`)
    pub name: String,
    /// total iterations measured
    pub iters: u64,
    /// fastest sample, ns/iter
    pub min_ns: f64,
    /// median sample, ns/iter (the gated number)
    pub p50_ns: f64,
    /// 95th-percentile sample, ns/iter
    pub p95_ns: f64,
    /// mean across samples, ns/iter
    pub mean_ns: f64,
}

impl Stats {
    /// Iterations per second at the median sample.
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.p50_ns
    }
}

/// Benchmark runner with fixed warmup/measure budgets.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    /// every benchmark measured so far, in run order
    pub results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(Duration::from_millis(200), Duration::from_millis(800))
    }
}

impl Bench {
    /// A runner with explicit warmup and measurement budgets.
    pub fn new(warmup: Duration, measure: Duration) -> Self {
        Bench { warmup, measure, results: Vec::new() }
    }

    /// Fast profile for CI / smoke runs (XBARMAP_BENCH_FAST=1).
    pub fn from_env() -> Self {
        if std::env::var("XBARMAP_BENCH_FAST").is_ok() {
            Bench::new(Duration::from_millis(20), Duration::from_millis(100))
        } else {
            Bench::default()
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    /// The return value is passed through `black_box` to keep the work alive.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Stats {
        // Warmup + calibration: how many iters fit in the warmup budget?
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup || warm_iters < 3 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        // Batch size targeting ~1ms per sample so Instant overhead is noise.
        let batch = ((1e-3 / per_iter.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples: Vec<f64> = Vec::new();
        let meas_start = Instant::now();
        let mut total_iters = 0u64;
        while meas_start.elapsed() < self.measure || samples.len() < 5 {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() * 1e9 / batch as f64;
            samples.push(dt);
            total_iters += batch;
            if samples.len() > 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| samples[(p * (samples.len() - 1) as f64).round() as usize];
        let stats = Stats {
            name: name.to_string(),
            iters: total_iters,
            min_ns: samples[0],
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        };
        println!(
            "bench {:<44} p50 {:>12}  p95 {:>12}  min {:>12}  ({} iters)",
            stats.name,
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p95_ns),
            fmt_ns(stats.min_ns),
            stats.iters
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Emit one JSON line per result (consumed by EXPERIMENTS.md tooling).
    pub fn emit_jsonl(&self) {
        use crate::util::json::{Json, JsonObj};
        for s in &self.results {
            let mut o = JsonObj::new();
            o.set("name", s.name.as_str())
                .set("p50_ns", s.p50_ns)
                .set("p95_ns", s.p95_ns)
                .set("min_ns", s.min_ns)
                .set("mean_ns", s.mean_ns)
                .set("iters", s.iters);
            println!("BENCH_JSON {}", Json::Obj(o).dumps());
        }
    }

    /// Write a `BENCH_<tag>.json` medians file (bench name -> p50 ns) at the
    /// repo root, printing per-bench deltas against the previous file when
    /// one exists — the perf trajectory record EXPERIMENTS.md tracks.
    /// Returns the path written.
    pub fn write_json_report(&self, tag: &str) -> std::io::Result<std::path::PathBuf> {
        self.write_json_report_to(&bench_report_dir(), tag)
    }

    /// [`Self::write_json_report`] into an explicit directory (no
    /// environment lookups — also what the unit tests use, since mutating
    /// env vars races with concurrently running tests).
    pub fn write_json_report_to(
        &self,
        dir: &std::path::Path,
        tag: &str,
    ) -> std::io::Result<std::path::PathBuf> {
        use crate::util::json::{self, Json, JsonObj};
        let path = dir.join(format!("BENCH_{tag}.json"));
        let previous = std::fs::read_to_string(&path)
            .ok()
            .and_then(|s| json::parse(&s).ok());
        if let Some(prev) = previous.as_ref().and_then(Json::as_obj) {
            let mut any = false;
            for s in &self.results {
                if let Some(old) = prev.get(&s.name).and_then(Json::as_f64) {
                    if old > 0.0 {
                        let delta = (s.p50_ns - old) / old * 100.0;
                        println!(
                            "delta {:<44} {:>12} -> {:>12}  ({:+.1}%)",
                            s.name,
                            fmt_ns(old),
                            fmt_ns(s.p50_ns),
                            delta
                        );
                        any = true;
                    }
                }
            }
            if any {
                println!("(vs previous {})", path.display());
            }
        }
        let mut o = JsonObj::new();
        for s in &self.results {
            o.set(s.name.as_str(), s.p50_ns);
        }
        std::fs::write(&path, Json::Obj(o).pretty() + "\n")?;
        Ok(path)
    }
}

/// Result of a [`gate_medians`] comparison.
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    /// one human-readable line per benchmark present in both files
    pub compared: Vec<String>,
    /// descriptions of benchmarks that regressed past the tolerance
    pub regressions: Vec<String>,
}

/// Compare two `BENCH_*.json` medians documents (benchmark name -> p50 ns)
/// and flag every shared benchmark whose current median is more than
/// `tol_pct` percent slower than the baseline. Benchmarks present in only
/// one file are ignored (new/retired benches don't gate), so the committed
/// baseline only needs refreshing when names or hardware change.
pub fn gate_medians(baseline: &crate::util::json::Json, current: &crate::util::json::Json, tol_pct: f64) -> GateReport {
    use crate::util::json::Json;
    let mut report = GateReport::default();
    let (Some(base), Some(cur)) = (baseline.as_obj(), current.as_obj()) else {
        return report;
    };
    for (name, old) in base.iter() {
        let (Some(old_ns), Some(new_ns)) =
            (old.as_f64(), cur.get(name).and_then(Json::as_f64))
        else {
            continue;
        };
        if old_ns <= 0.0 {
            continue;
        }
        let delta = (new_ns - old_ns) / old_ns * 100.0;
        report.compared.push(format!(
            "gate  {:<44} {:>12} -> {:>12}  ({:+.1}%)",
            name,
            fmt_ns(old_ns),
            fmt_ns(new_ns),
            delta
        ));
        if delta > tol_pct {
            report.regressions.push(format!(
                "{name}: {} -> {} ({delta:+.1}% > {tol_pct}%)",
                fmt_ns(old_ns),
                fmt_ns(new_ns)
            ));
        }
    }
    report
}

/// Directory for `BENCH_*.json` reports: `XBARMAP_BENCH_DIR` when set, else
/// the nearest ancestor of the working directory containing `ROADMAP.md`
/// (the repo root — benches run from `rust/`), else the working directory.
fn bench_report_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("XBARMAP_BENCH_DIR") {
        return std::path::PathBuf::from(d);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    loop {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return std::path::PathBuf::from(".");
        }
    }
}

/// Human-friendly nanosecond formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new(Duration::from_millis(5), Duration::from_millis(20));
        let s = b.run("noop-ish", || 1 + 1).clone();
        assert!(s.min_ns >= 0.0 && s.p50_ns >= s.min_ns && s.p95_ns >= s.p50_ns);
        assert!(s.iters > 0);
    }

    #[test]
    fn slower_work_measures_slower() {
        let mut b = Bench::new(Duration::from_millis(5), Duration::from_millis(30));
        let fast = b.run("fast", || 0u64).p50_ns;
        let slow = b
            .run("slow", || {
                (0..2000u64).fold(0u64, |a, x| a.wrapping_add(black_box(x) * x))
            })
            .p50_ns;
        assert!(slow > fast, "slow {slow} !> fast {fast}");
    }

    #[test]
    fn json_report_written_and_compared() {
        let dir = std::env::temp_dir().join("xbarmap_benchkit_report");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut b = Bench::new(Duration::from_millis(2), Duration::from_millis(5));
        b.run("unit/report", || 1u64);
        let p = b.write_json_report_to(&dir, "test").unwrap();
        assert!(p.ends_with("BENCH_test.json"), "{}", p.display());
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("unit/report"), "{text}");
        // second write compares against the first and overwrites cleanly
        b.write_json_report_to(&dir, "test").unwrap();
    }

    #[test]
    fn gate_flags_only_regressions_past_tolerance() {
        let parse = |s: &str| crate::util::json::parse(s).unwrap();
        let base = parse(r#"{"a": 100.0, "b": 100.0, "gone": 50.0}"#);
        let cur = parse(r#"{"a": 110.0, "b": 130.0, "new": 1.0}"#);
        let r = gate_medians(&base, &cur, 15.0);
        // "gone"/"new" are unshared and ignored; "a" (+10%) passes, "b"
        // (+30%) regresses
        assert_eq!(r.compared.len(), 2);
        assert_eq!(r.regressions.len(), 1);
        assert!(r.regressions[0].starts_with("b:"), "{:?}", r.regressions);
        // speedups never gate
        let faster = parse(r#"{"a": 50.0, "b": 60.0}"#);
        assert!(gate_medians(&base, &faster, 15.0).regressions.is_empty());
        // non-object documents compare nothing
        assert!(gate_medians(&parse("[]"), &cur, 15.0).compared.is_empty());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
