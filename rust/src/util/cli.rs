//! Minimal command-line parser (clap is not in the offline vendor set).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value` and
//! positional arguments, with typed accessors and generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec used for usage text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// option name as typed after `--`
    pub name: &'static str,
    /// one-line help shown in usage text
    pub help: &'static str,
    /// None => boolean flag; Some(meta) => takes a value shown as <meta>.
    pub value: Option<&'static str>,
    /// value applied when the option is omitted (None = no default)
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// arguments that were not `--options`, in order
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv items against a spec. Unknown `--options` error out.
    pub fn parse(raw: &[String], specs: &[OptSpec]) -> Result<Args, String> {
        let mut a = Args::default();
        for s in specs {
            if let (Some(_), Some(d)) = (s.value, s.default) {
                a.opts.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                match (spec.value, inline) {
                    (None, None) => a.flags.push(name),
                    (None, Some(_)) => return Err(format!("--{name} takes no value")),
                    (Some(_), Some(v)) => {
                        a.opts.insert(name, v);
                    }
                    (Some(_), None) => {
                        let v = it
                            .next()
                            .ok_or_else(|| format!("--{name} requires a value"))?;
                        a.opts.insert(name, v.clone());
                    }
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    /// Whether a boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// A value option's string (the default when one was declared).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// [`Args::get`] parsed as usize; `Err` on a malformed value.
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.opts
            .get(name)
            .map(|v| v.parse::<usize>().map_err(|_| format!("--{name}: expected integer, got '{v}'")))
            .transpose()
    }

    /// [`Args::get`] parsed as f64; `Err` on a malformed value.
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.opts
            .get(name)
            .map(|v| v.parse::<f64>().map_err(|_| format!("--{name}: expected number, got '{v}'")))
            .transpose()
    }

    /// Required string accessor (use after defaults were supplied).
    pub fn req(&self, name: &str) -> Result<&str, String> {
        self.get(name).ok_or_else(|| format!("--{name} is required"))
    }

    /// Required usize accessor (use after defaults were supplied).
    pub fn req_usize(&self, name: &str) -> Result<usize, String> {
        self.get_usize(name)?
            .ok_or_else(|| format!("--{name} is required"))
    }

    /// Required f64 accessor (use after defaults were supplied).
    pub fn req_f64(&self, name: &str) -> Result<f64, String> {
        self.get_f64(name)?
            .ok_or_else(|| format!("--{name} is required"))
    }
}

/// Render usage text for a command.
pub fn usage(prog: &str, about: &str, subcommands: &[(&str, &str)], specs: &[OptSpec]) -> String {
    let mut s = format!("{prog} — {about}\n\nUSAGE:\n  {prog}");
    if !subcommands.is_empty() {
        s.push_str(" <COMMAND>");
    }
    s.push_str(" [OPTIONS]\n");
    if !subcommands.is_empty() {
        s.push_str("\nCOMMANDS:\n");
        for (name, help) in subcommands {
            s.push_str(&format!("  {name:<14} {help}\n"));
        }
    }
    if !specs.is_empty() {
        s.push_str("\nOPTIONS:\n");
        for spec in specs {
            let left = match spec.value {
                Some(meta) => format!("--{} <{meta}>", spec.name),
                None => format!("--{}", spec.name),
            };
            let dflt = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {left:<26} {}{dflt}\n", spec.help));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "out", help: "output dir", value: Some("DIR"), default: Some("results") },
            OptSpec { name: "seed", help: "prng seed", value: Some("N"), default: Some("7") },
            OptSpec { name: "verbose", help: "log more", value: None, default: None },
        ]
    }

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_applied() {
        let a = Args::parse(&[], &specs()).unwrap();
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.get_usize("seed").unwrap(), Some(7));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parse_forms() {
        let a = Args::parse(&sv(&["--out", "/tmp/x", "--seed=9", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert_eq!(a.get_usize("seed").unwrap(), Some(9));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(Args::parse(&sv(&["--nope"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--out"]), &specs()).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(Args::parse(&sv(&["--verbose=yes"]), &specs()).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&sv(&["--seed", "abc"]), &specs()).unwrap();
        assert!(a.get_usize("seed").is_err());
    }

    #[test]
    fn usage_mentions_everything() {
        let u = usage("xbarmap", "test", &[("repro", "regen figures")], &specs());
        for needle in ["xbarmap", "repro", "--out", "--verbose", "default: results"] {
            assert!(u.contains(needle), "usage missing {needle}: {u}");
        }
    }
}
