//! Wall-clock deadlines for cooperative cancellation.
//!
//! The solver stack is budgeted in *nodes* ([`crate::ilp::Budget`]), which
//! bounds work deterministically but not time: a pathological request can
//! spend its whole node budget inside one sweep and pin a service worker
//! for seconds. A [`Deadline`] is the wall-clock counterpart: a single
//! `Option<Instant>` threaded by value through `opt::sweep`,
//! `pack::counted` and `ilp::exact`, checked at the same cooperative
//! checkpoints as the node budget. Expiry never corrupts state — solvers
//! bail out exactly as they do on node exhaustion, and the caller (the
//! planning front door) maps the expiry to a typed error.
//!
//! An unset deadline is free: [`Deadline::expired`] on [`Deadline::NONE`]
//! never reads the clock, so batch/CLI paths that don't pass `--deadline-ms`
//! are bit-identical to the pre-deadline code (the determinism suites pin
//! this indirectly via the node-accounting equalities).

use std::time::{Duration, Instant};

/// A wall-clock deadline: either unset (never expires) or an [`Instant`]
/// after which cooperative checkpoints report expiry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Deadline(Option<Instant>);

impl Deadline {
    /// The unset deadline: never expires, never reads the clock.
    pub const NONE: Deadline = Deadline(None);

    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline(Some(Instant::now() + budget))
    }

    /// A deadline at an explicit instant (lets one request's stages share
    /// a single deadline instead of each stage re-adding the budget).
    pub fn at(instant: Instant) -> Deadline {
        Deadline(Some(instant))
    }

    /// Whether a deadline is set at all — checkpoints gate on this so the
    /// unset case stays branch-cheap and clock-free.
    pub fn is_set(&self) -> bool {
        self.0.is_some()
    }

    /// Whether the deadline has passed. Unset deadlines never expire and
    /// never read the clock.
    pub fn expired(&self) -> bool {
        match self.0 {
            Some(t) => Instant::now() >= t,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_never_expires() {
        assert!(!Deadline::NONE.expired());
        assert!(!Deadline::NONE.is_set());
        assert_eq!(Deadline::default(), Deadline::NONE);
    }

    #[test]
    fn generous_budget_not_expired() {
        let d = Deadline::after(Duration::from_secs(3600));
        assert!(d.is_set());
        assert!(!d.expired());
    }

    #[test]
    fn past_instant_expired() {
        let d = Deadline::at(Instant::now());
        // an instant at-or-before now counts as expired
        assert!(d.expired());
    }

    #[test]
    fn zero_budget_expires_immediately() {
        let d = Deadline::after(Duration::ZERO);
        assert!(d.expired());
    }
}
