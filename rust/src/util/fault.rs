//! Deterministic fault injection for stream I/O.
//!
//! [`FaultyStream`] wraps any `Read + Write` transport (in the chaos
//! suite: the client side of a TCP connection to the planning service)
//! and perturbs the byte flow the way real networks and sick clients do —
//! **short writes** (a line crosses many segments), **short reads**,
//! **write stalls** (a slow sender that trickles mid-line), and a
//! **mid-stream cut** (the peer vanishes with a partial line on the
//! wire). Every perturbation is drawn from the seeded
//! [`crate::util::prng::Rng`], so a failing seed replays bit-for-bit:
//! the chaos harness (`tests/chaos_service.rs`) is a seed matrix, not a
//! flake generator.
//!
//! The wrapper only *shapes* traffic; it never invents or reorders
//! bytes. Everything forwarded reaches the inner stream unmodified and
//! in order, so an un-cut faulty connection still carries a
//! byte-identical request stream — which is exactly what lets the chaos
//! suite assert oracle equality through arbitrary fragmentation.

use crate::util::prng::Rng;
use std::io::{ErrorKind, Read, Write};
use std::time::Duration;

/// What to inject, and how hard. The default plan injects nothing —
/// enable each fault class explicitly so tests state what they exercise.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// cap on bytes forwarded per `write` call (0 = no cap): every write
    /// of a longer buffer becomes a short write of 1..=cap bytes, length
    /// drawn from the seed
    pub max_write: usize,
    /// cap on bytes requested per `read` call (0 = no cap): forces short
    /// reads of 1..=cap bytes
    pub max_read: usize,
    /// probability (per `write` call) of sleeping [`FaultPlan::stall`]
    /// before forwarding — a trickling sender that parks mid-line
    pub stall_chance: f64,
    /// how long a stalled write sleeps
    pub stall: Duration,
    /// total bytes after which the write side is cut: the forwarded
    /// prefix stops at the boundary (possibly mid-line) and every later
    /// write fails with [`ErrorKind::BrokenPipe`] so the caller drops the
    /// transport (None = never cut)
    pub cut_after: Option<usize>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            max_write: 0,
            max_read: 0,
            stall_chance: 0.0,
            stall: Duration::from_millis(1),
            cut_after: None,
        }
    }
}

/// A `Read + Write` transport with seed-deterministic fault injection
/// (see the module docs).
#[derive(Debug)]
pub struct FaultyStream<S> {
    inner: S,
    rng: Rng,
    plan: FaultPlan,
    written: usize,
    cut: bool,
}

impl<S> FaultyStream<S> {
    /// Wrap `inner`, drawing every fault decision from `seed`.
    pub fn new(inner: S, seed: u64, plan: FaultPlan) -> FaultyStream<S> {
        FaultyStream { inner, rng: Rng::new(seed), plan, written: 0, cut: false }
    }

    /// Total bytes actually forwarded to the inner stream's write side.
    pub fn written(&self) -> usize {
        self.written
    }

    /// Whether the cut threshold has been crossed.
    pub fn is_cut(&self) -> bool {
        self.cut
    }

    /// The wrapped transport back (e.g. to half-close a socket cleanly
    /// after the faulted write phase).
    pub fn into_inner(self) -> S {
        self.inner
    }

    /// Borrow the wrapped transport (e.g. to `shutdown` a socket without
    /// giving up the wrapper).
    pub fn get_ref(&self) -> &S {
        &self.inner
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.cut {
            return Err(std::io::Error::new(ErrorKind::BrokenPipe, "fault: connection cut"));
        }
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        if self.plan.stall_chance > 0.0 && self.rng.chance(self.plan.stall_chance) {
            std::thread::sleep(self.plan.stall);
        }
        let mut n = buf.len();
        if self.plan.max_write > 0 && n > 1 {
            n = self.rng.range(1, n.min(self.plan.max_write));
        }
        if let Some(cut) = self.plan.cut_after {
            let room = cut.saturating_sub(self.written);
            if room == 0 {
                self.cut = true;
                return Err(std::io::Error::new(ErrorKind::BrokenPipe, "fault: connection cut"));
            }
            n = n.min(room);
        }
        let n = self.inner.write(&buf[..n])?;
        self.written += n;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return self.inner.read(buf);
        }
        let mut n = buf.len();
        if self.plan.max_read > 0 && n > 1 {
            n = self.rng.range(1, n.min(self.plan.max_read));
        }
        self.inner.read(&mut buf[..n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In-memory transport: reads drain `input`, writes append to `sunk`.
    #[derive(Debug, Default)]
    struct Pipe {
        input: Vec<u8>,
        pos: usize,
        sunk: Vec<u8>,
    }

    impl Read for Pipe {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.input.len() - self.pos);
            buf[..n].copy_from_slice(&self.input[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    impl Write for Pipe {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.sunk.extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn write_all_chunks(s: &mut FaultyStream<Pipe>, payload: &[u8]) -> std::io::Result<()> {
        let mut off = 0;
        while off < payload.len() {
            off += s.write(&payload[off..])?;
        }
        Ok(())
    }

    #[test]
    fn short_writes_preserve_bytes_and_order() {
        let payload: Vec<u8> = (0u8..=255).cycle().take(4096).collect();
        let plan = FaultPlan { max_write: 7, ..FaultPlan::default() };
        let mut s = FaultyStream::new(Pipe::default(), 42, plan);
        write_all_chunks(&mut s, &payload).unwrap();
        assert_eq!(s.written(), payload.len());
        assert_eq!(s.into_inner().sunk, payload, "shaping must not corrupt the stream");
    }

    #[test]
    fn short_reads_preserve_bytes_and_order() {
        let payload: Vec<u8> = (0u8..=255).cycle().take(1024).collect();
        let pipe = Pipe { input: payload.clone(), ..Pipe::default() };
        let plan = FaultPlan { max_read: 5, ..FaultPlan::default() };
        let mut s = FaultyStream::new(pipe, 7, plan);
        let mut got = Vec::new();
        s.read_to_end(&mut got).unwrap();
        assert_eq!(got, payload);
    }

    #[test]
    fn cut_stops_exactly_at_the_boundary() {
        let payload = vec![9u8; 1000];
        let plan = FaultPlan { max_write: 64, cut_after: Some(300), ..FaultPlan::default() };
        let mut s = FaultyStream::new(Pipe::default(), 3, plan);
        let err = write_all_chunks(&mut s, &payload).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::BrokenPipe);
        assert!(s.is_cut());
        assert_eq!(s.written(), 300, "forwarded prefix must stop at the cut");
        assert_eq!(s.get_ref().sunk.len(), 300);
        // and the cut is terminal
        assert!(matches!(s.write(b"x"), Err(e) if e.kind() == ErrorKind::BrokenPipe));
    }

    #[test]
    fn same_seed_same_fragmentation() {
        let payload = vec![1u8; 512];
        let plan = FaultPlan { max_write: 9, ..FaultPlan::default() };
        let frag = |seed: u64| -> Vec<usize> {
            let mut s = FaultyStream::new(Pipe::default(), seed, plan.clone());
            let mut sizes = Vec::new();
            let mut off = 0;
            while off < payload.len() {
                let n = s.write(&payload[off..]).unwrap();
                sizes.push(n);
                off += n;
            }
            sizes
        };
        assert_eq!(frag(11), frag(11), "fault schedule must replay from the seed");
        assert_ne!(frag(11), frag(12), "different seeds should fragment differently");
    }

    #[test]
    fn default_plan_is_transparent() {
        let payload = vec![5u8; 256];
        let mut s = FaultyStream::new(Pipe::default(), 1, FaultPlan::default());
        assert_eq!(s.write(&payload).unwrap(), payload.len(), "no cap: one write, whole buffer");
        let pipe = Pipe { input: payload.clone(), ..Pipe::default() };
        let mut s = FaultyStream::new(pipe, 1, FaultPlan::default());
        let mut buf = vec![0u8; 256];
        assert_eq!(s.read(&mut buf).unwrap(), 256);
    }
}
