//! Minimal JSON value model, parser and pretty-printer.
//!
//! serde/serde_json are not in the offline vendor set, so the library
//! serializes results (figures, tables, metrics) and reads artifact
//! metadata through this module. Supports the full JSON grammar with
//! f64 numbers; object key order is preserved (insertion order) so emitted
//! reports are stable across runs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects preserve insertion order via a parallel key list.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`
    Null,
    /// JSON `true`/`false`
    Bool(bool),
    /// any JSON number (integers ride exactly up to 2^53)
    Num(f64),
    /// a JSON string
    Str(String),
    /// a JSON array
    Arr(Vec<Json>),
    /// a JSON object (insertion-ordered)
    Obj(JsonObj),
}

/// Insertion-ordered string-keyed map.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    /// An empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (or replace) a key.
    pub fn set(&mut self, k: &str, v: impl Into<Json>) -> &mut Self {
        if !self.map.contains_key(k) {
            self.keys.push(k.to_string());
        }
        self.map.insert(k.to_string(), v.into());
        self
    }

    /// Look up one key (no path traversal; see [`Json::get`] for paths).
    pub fn get(&self, k: &str) -> Option<&Json> {
        self.map.get(k)
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// Whether the object has no keys.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Iterate `(key, value)` pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.keys.iter().map(move |k| (k.as_str(), &self.map[k]))
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}
impl From<JsonObj> for Json {
    fn from(v: JsonObj) -> Self {
        Json::Obj(v)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(v: &[T]) -> Self {
        Json::Arr(v.iter().cloned().map(Into::into).collect())
    }
}

impl Json {
    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to usize, if this is a `Num` (wire decoders
    /// that must reject fractions use their own exact-integer checks).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The object, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `get("train.acc_fp32")`.
    pub fn get(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = cur.as_obj()?.get(seg)?;
        }
        Some(cur)
    }

    /// Compact serialization.
    pub fn dumps(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // Surrogate pairs: accept and combine.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b.len() < self.i + 11
                                    || &self.b[self.i + 5..self.i + 7] != b"\\u"
                                {
                                    return Err("lone high surrogate".into());
                                }
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i + 7..self.i + 11])
                                        .map_err(|_| "bad surrogate".to_string())?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| "bad surrogate".to_string())?;
                                let combined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(
                                    char::from_u32(combined)
                                        .ok_or_else(|| "bad surrogate pair".to_string())?,
                                );
                                self.i += 6;
                            } else {
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| "bad codepoint".to_string())?,
                                );
                            }
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut obj = JsonObj::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            obj.set(&k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\"", "1e3"] {
            let v = parse(src).unwrap();
            let v2 = parse(&v.dumps()).unwrap();
            assert_eq!(v, v2, "src {src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn object_preserves_insertion_order() {
        let mut o = JsonObj::new();
        o.set("z", 1u64).set("a", 2u64).set("m", 3u64);
        let keys: Vec<&str> = o.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
        assert_eq!(Json::Obj(o).dumps(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line\nquote\"tab\tback\\slash";
        let v = Json::Str(s.to_string());
        assert_eq!(parse(&v.dumps()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::Num(16.0).dumps(), "16");
        assert_eq!(Json::Num(16.5).dumps(), "16.5");
    }

    #[test]
    fn path_get() {
        let v = parse(r#"{"train": {"acc": 0.99}}"#).unwrap();
        assert_eq!(v.get("train.acc").unwrap().as_f64(), Some(0.99));
        assert!(v.get("train.missing").is_none());
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }
}
