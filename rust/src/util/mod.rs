//! Self-built substrates: the offline crate set vendors only the xla stack,
//! so JSON, CLI parsing, PRNG, property testing and micro-benchmarking are
//! implemented here (see DESIGN.md §3 substitutions).
pub mod benchkit;
pub mod cli;
pub mod deadline;
pub mod fault;
pub mod json;
pub mod mpmc;
pub mod par;
pub mod prng;
pub mod proc;
pub mod prop;
pub mod stats;
pub mod table;
