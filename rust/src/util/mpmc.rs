//! Bounded multi-producer multi-consumer queue (std has only the
//! unbounded one-consumer `mpsc`).
//!
//! The planning service's request path is many connection readers feeding
//! a shared worker pool; the queue between them must be *bounded* so a
//! flood of requests backpressures the sockets instead of buffering
//! without limit. [`Queue::push`] blocks while the queue is full,
//! [`Queue::pop`] blocks while it is empty, and [`Queue::close`] wakes
//! everyone: pushes start failing immediately, pops drain what is already
//! queued and then return `None` — exactly the "finish in-flight work,
//! accept no more" shutdown the service needs.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why a non-blocking push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum TryPushError<T> {
    /// queue at capacity — the caller should block or shed load
    Full(T),
    /// queue closed — no more items will ever be accepted
    Closed(T),
}

struct State<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// A bounded MPMC queue. Shared by reference (`Arc<Queue<T>>` or scoped
/// borrows); every method takes `&self`.
pub struct Queue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> Queue<T> {
    /// A queue holding at most `capacity` items (minimum 1).
    pub fn bounded(capacity: usize) -> Queue<T> {
        Queue {
            state: Mutex::new(State { buf: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Enqueue, blocking while the queue is full. `Err(item)` once closed
    /// (including while blocked — close wakes waiting producers).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        while s.buf.len() >= self.capacity && !s.closed {
            s = self.not_full.wait(s).unwrap();
        }
        if s.closed {
            return Err(item);
        }
        s.buf.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Enqueue without blocking.
    pub fn try_push(&self, item: T) -> Result<(), TryPushError<T>> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(TryPushError::Closed(item));
        }
        if s.buf.len() >= self.capacity {
            return Err(TryPushError::Full(item));
        }
        s.buf.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking while the queue is empty. `None` only after
    /// [`Queue::close`] *and* the buffer has drained, so consumers see
    /// every item that was accepted.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.buf.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Close the queue: subsequent (and blocked) pushes fail, pops drain
    /// the remaining items then return `None`. Idempotent.
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        drop(s);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued (a snapshot — racy by nature).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().buf.len()
    }

    /// Whether nothing is currently queued (a snapshot, like
    /// [`Queue::len`]).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_within_a_single_producer() {
        let q = Queue::bounded(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn try_push_reports_full_then_accepts_after_pop() {
        let q = Queue::bounded(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(TryPushError::Full(3)));
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn push_blocks_at_capacity_until_a_consumer_drains() {
        // the backpressure path: a producer at capacity parks until pop
        let q = Arc::new(Queue::bounded(1));
        q.push(0usize).unwrap();
        let unblocked = Arc::new(AtomicBool::new(false));
        let producer = {
            let (q, unblocked) = (Arc::clone(&q), Arc::clone(&unblocked));
            std::thread::spawn(move || {
                q.push(1).unwrap();
                unblocked.store(true, Ordering::SeqCst);
            })
        };
        // give the producer ample time to park on the full queue
        std::thread::sleep(Duration::from_millis(50));
        assert!(!unblocked.load(Ordering::SeqCst), "push returned while full");
        assert_eq!(q.pop(), Some(0));
        producer.join().unwrap();
        assert!(unblocked.load(Ordering::SeqCst));
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_drains_queued_items_then_ends_consumers() {
        let q = Queue::bounded(4);
        q.push('a').unwrap();
        q.push('b').unwrap();
        q.close();
        assert_eq!(q.push('c'), Err('c'));
        assert_eq!(q.try_push('d'), Err(TryPushError::Closed('d')));
        // already-accepted items still come out, in order
        assert_eq!(q.pop(), Some('a'));
        assert_eq!(q.pop(), Some('b'));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_a_blocked_producer() {
        let q = Arc::new(Queue::bounded(1));
        q.push(0usize).unwrap();
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.push(1))
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(producer.join().unwrap(), Err(1));
    }

    #[test]
    fn many_producers_many_consumers_lose_nothing() {
        let q = Arc::new(Queue::bounded(3));
        let n_producers = 4;
        let per_producer = 50;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.push(p * per_producer + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = Arc::clone(&q);
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut all: Vec<usize> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        let expect: Vec<usize> = (0..n_producers * per_producer).collect();
        assert_eq!(all, expect);
    }
}
